"""Shared pytest setup: the u64 datapaths require x64 mode."""
import jax

jax.config.update("jax_enable_x64", True)
