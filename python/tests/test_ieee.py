"""IEEE-754 semantics tests: the oracle itself, plus the named corner
cases every FMA implementation gets wrong first."""

import math
import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def f32_bits(x: float) -> int:
    return struct.unpack("<I", struct.pack("<f", x))[0]


def bits_f32(b: int) -> float:
    return struct.unpack("<f", struct.pack("<I", b))[0]


def f64_bits(x: float) -> int:
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def bits_f64(b: int) -> float:
    return struct.unpack("<d", struct.pack("<Q", b))[0]


class TestOracleAgainstHostFma:
    """math.fma is the platform's correctly-rounded binary64 FMA — an
    independent check of the Python-integer oracle for DP."""

    @settings(max_examples=400, deadline=None)
    @given(st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1))
    def test_dp_oracle_matches_math_fma(self, a, b, c):
        fa, fb, fc = bits_f64(a), bits_f64(b), bits_f64(c)
        try:
            want = math.fma(fa, fb, fc)
        except (OverflowError, ValueError):
            # CPython raises instead of returning Inf/NaN for some cases;
            # the oracle's behaviour there is covered by the jnp tests.
            return
        got = ref.dp_fmac_exact(a, b, c)
        if math.isnan(want):
            assert ((got >> 52) & 0x7FF) == 0x7FF and (got & ((1 << 52) - 1)) != 0
        else:
            assert got == f64_bits(want), f"{fa!r},{fb!r},{fc!r}"

    def test_dp_known_values(self):
        cases = [
            (1.5, 2.0, 0.25, 3.25),
            (0.1, 10.0, -1.0, math.fma(0.1, 10.0, -1.0)),
            (2.0**-537, 2.0**-537, 0.0, 2.0**-1074),
        ]
        for a, b, c, want in cases:
            got = bits_f64(ref.dp_fmac_exact(f64_bits(a), f64_bits(b), f64_bits(c)))
            assert got == want


class TestSingleRounding:
    def test_fused_vs_cascade_discriminator(self):
        # (1+2^-12)² − (1+2^-11): fused = 2^-24, cascade = 0.
        a = f32_bits(1.0 + 2.0**-12)
        c = f32_bits(-(1.0 + 2.0**-11))
        got = bits_f32(ref.sp_fmac_exact(a, a, c))
        assert got == 2.0**-24
        # The cascade result really is different (computed via two
        # roundings on the host).
        av = bits_f32(a)
        cascade = np.float32(np.float32(av * av) + np.float32(bits_f32(c)))
        assert cascade == 0.0

    def test_sp_double_rounding_trap(self):
        # Product exactly halfway between two representables, with c
        # nudging the tie: a two-step rounding loses the nudge.
        a = f32_bits(1.0 + 2.0**-23)  # 1+ε
        b = f32_bits(1.0 + 2.0**-23)
        c = f32_bits(2.0**-48)
        got = bits_f32(ref.sp_fmac_exact(a, b, c))
        # Exact: 1 + 2^-22 + 2^-46 + 2^-48 → rounds to 1 + 2^-22? The tie
        # at 2^-46+2^-48 is above half-ulp(2^-23 scale)… assert against
        # the integer-exact expectation instead of hand-derivation.
        exact = (1 + 2**-23) * (1 + 2**-23) + 2**-48  # fits f64 exactly? close enough to compare
        assert abs(got - exact) <= 2.0**-23


class TestSpecialValues:
    def test_nan_propagation(self):
        nan = f32_bits(float("nan"))
        one = f32_bits(1.0)
        for triple in [(nan, one, one), (one, nan, one), (one, one, nan)]:
            out = ref.sp_fmac_exact(*triple)
            assert ((out >> 23) & 0xFF) == 0xFF and (out & 0x7FFFFF) != 0

    def test_inf_times_zero_invalid(self):
        inf = f32_bits(float("inf"))
        out = ref.sp_fmac_exact(inf, 0, f32_bits(1.0))
        assert ((out >> 23) & 0xFF) == 0xFF and (out & 0x7FFFFF) != 0

    def test_inf_minus_inf_invalid(self):
        inf = f32_bits(float("inf"))
        ninf = f32_bits(float("-inf"))
        out = ref.sp_fmac_exact(inf, f32_bits(1.0), ninf)
        assert (out & 0x7FFFFF) != 0  # NaN

    def test_inf_propagation_signs(self):
        inf = f32_bits(float("inf"))
        one = f32_bits(1.0)
        none = f32_bits(-1.0)
        assert bits_f32(ref.sp_fmac_exact(inf, none, one)) == float("-inf")
        assert bits_f32(ref.sp_fmac_exact(one, one, inf)) == float("inf")

    def test_signed_zero_rules(self):
        nzero = f32_bits(-0.0)
        zero = 0
        one = f32_bits(1.0)
        # (+0)·1 + (−0) = +0 ; (−0)·1 + (−0) = −0.
        assert ref.sp_fmac_exact(zero, one, nzero) == 0
        assert ref.sp_fmac_exact(nzero, one, nzero) == nzero
        # 1·1 − 1 = +0 (RNE cancellation).
        assert ref.sp_fmac_exact(one, one, f32_bits(-1.0)) == 0

    def test_jnp_core_matches_oracle_on_specials(self):
        vals = np.array(
            [0, 0x80000000, f32_bits(float("inf")), f32_bits(float("-inf")),
             f32_bits(float("nan")), f32_bits(1.0), 1, 0x7F7FFFFF],
            dtype=np.uint32,
        )
        a, b, c = np.meshgrid(vals, vals, vals, indexing="ij")
        a, b, c = a.ravel(), b.ravel(), c.ravel()
        got = np.asarray(ref.sp_fmac_ref(a, b, c))
        want = ref.sp_fmac_exact_batch(a, b, c)
        assert (got == want).all()


class TestSubnormals:
    def test_subnormal_products(self):
        # min_normal × 0.5 = largest subnormal + 1 step region.
        a = f32_bits(2.0**-126)
        b = f32_bits(0.5)
        got = bits_f32(ref.sp_fmac_exact(a, b, 0))
        assert got == 2.0**-127

    def test_underflow_to_zero_rne(self):
        s = 0x00000200  # 2^-140
        assert ref.sp_fmac_exact(s, s, 0) == 0

    def test_subnormal_plus_subnormal(self):
        got = ref.sp_fmac_exact(f32_bits(1.0), 1, 1)  # 1·minsub + minsub
        assert got == 2

    def test_gradual_underflow_boundary(self):
        # Largest subnormal + smallest normal arithmetic stays exact.
        big_sub = 0x007FFFFF
        min_norm = 0x00800000
        got = ref.sp_fmac_exact(f32_bits(1.0), big_sub, min_norm)
        want = ref.sp_fmac_exact_batch(
            np.array([f32_bits(1.0)], np.uint32),
            np.array([big_sub], np.uint32),
            np.array([min_norm], np.uint32),
        )[0]
        assert got == want

    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 0x007FFFFF), st.integers(0, 0x007FFFFF), st.integers(0, 2**32 - 1))
    def test_hypothesis_subnormal_heavy(self, a, b, c):
        aa = np.array([a], dtype=np.uint32)
        bb = np.array([b], dtype=np.uint32)
        cc = np.array([c], dtype=np.uint32)
        got = int(np.asarray(ref.sp_fmac_ref(aa, bb, cc))[0])
        want = ref.sp_fmac_exact(a, b, c)
        assert got == want


class TestRoundingBoundaries:
    @pytest.mark.parametrize("frac_c", [0, 1, 2, 3])
    def test_ties_around_half_ulp(self, frac_c):
        # a·b exactly at a tie, c a few ulps of perturbation.
        a = f32_bits(1.0 + 2.0**-12)
        b = f32_bits(1.0 - 2.0**-12)
        c = frac_c  # tiny subnormal perturbations
        got = int(np.asarray(ref.sp_fmac_ref(
            np.array([a], np.uint32), np.array([b], np.uint32), np.array([c], np.uint32)
        ))[0])
        want = ref.sp_fmac_exact(a, b, c)
        assert got == want

    def test_carry_out_of_significand(self):
        # Result all-ones significand + round-up ⇒ exponent bump.
        a = f32_bits(np.float32(2.0) - np.float32(2.0**-23))  # 0x3FFFFFFF…
        got = ref.sp_fmac_exact(a, a, 0)
        want_f = bits_f32(a) * bits_f32(a)
        assert bits_f32(got) == np.float32(want_f)

    def test_overflow_to_inf(self):
        m = f32_bits(3.4e38)
        out = ref.sp_fmac_exact(m, f32_bits(2.0), 0)
        assert bits_f32(out) == float("inf")
        out = ref.sp_fmac_exact(m, f32_bits(-2.0), 0)
        assert bits_f32(out) == float("-inf")
