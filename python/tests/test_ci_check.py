"""Gate-logic tests for ``python/ci_check_bench.py``: synthetic pass /
fail / unmeasured artifacts for the engine, serve, routed-fleet,
routing-parity, chaos, trace-replay dominance, and repeat-buffer kernel
checks (no bench run needed — the artifacts are hand-built dicts dumped
to temp files)."""

import importlib.util
import json
import os
import sys

_SPEC = importlib.util.spec_from_file_location(
    "ci_check_bench",
    os.path.join(os.path.dirname(__file__), "..", "ci_check_bench.py"),
)
ci_check = importlib.util.module_from_spec(_SPEC)
# Registered before exec: the module uses @dataclass, which resolves
# type annotations through sys.modules[cls.__module__].
sys.modules["ci_check_bench"] = ci_check
_SPEC.loader.exec_module(ci_check)


def serve_doc():
    return {
        "bench": "serve",
        "measured": True,
        "thresholds": {
            "min_serve_vs_plain_windowed_ratio": 0.8,
            "max_p99_over_p50": 10.0,
            "max_crosscheck_mismatches": 0,
            "require_bb_identity": True,
            "min_routed_vs_best_shard_ratio": 0.8,
            "max_fleet_p99_over_p50": 10.0,
            "max_misrouted": 0,
            "require_shard_bb_identity": True,
        },
        "units": {
            "SP FMA": {
                "serve_vs_plain_windowed_ratio": 0.95,
                "p99_over_p50": 2.5,
                "crosscheck_mismatches": 0,
                "bb_schedule_match": True,
                "bb_energy_match": True,
            },
        },
        "routed": {
            "fleet_vs_best_shard_ratio": 2.1,
            "fleet_p99_over_p50": 4.0,
            "misrouted": 0,
            "crosscheck_mismatches": 0,
            "all_shards_bb_identity": True,
        },
    }


def run_doc(tmp_path, doc):
    path = tmp_path / "artifact.json"
    path.write_text(json.dumps(doc))
    checks, errors = ci_check.check_file(str(path))
    return checks, errors


def test_serve_with_routed_all_pass(tmp_path):
    checks, errors = run_doc(tmp_path, serve_doc())
    assert not errors
    # 5 per-unit checks + 5 fleet checks.
    assert len(checks) == 10
    assert all(c.ok for c in checks)
    fleet = [c for c in checks if c.unit == "fleet"]
    assert {c.name for c in fleet} == {
        "routed_vs_best_shard",
        "fleet_p99_over_p50",
        "misrouted",
        "crosscheck_mismatches",
        "all_shards_bb_identity",
    }


def test_routed_budget_violations_fail(tmp_path):
    doc = serve_doc()
    doc["routed"]["fleet_vs_best_shard_ratio"] = 0.5
    doc["routed"]["misrouted"] = 2
    doc["routed"]["all_shards_bb_identity"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {
        "routed_vs_best_shard",
        "misrouted",
        "all_shards_bb_identity",
    }


def test_serve_without_routed_section_still_checks_units(tmp_path):
    # Backwards compatibility: a pre-PR-5 artifact (no "routed" object)
    # gates only the per-unit rows.
    doc = serve_doc()
    del doc["routed"]
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert len(checks) == 5
    assert all(c.unit != "fleet" for c in checks)
    assert all(c.ok for c in checks)


def test_unmeasured_artifact_is_an_error(tmp_path):
    doc = serve_doc()
    doc["measured"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "measured" in errors[0]


def engine_doc(simd_feature):
    unit_row = {
        "speedup_simd_word_vs_scalar_word": 3.0,
        "speedup_simd_vector_vs_scalar_lane": 2.5 if simd_feature else 0.0,
        "trace_overhead_windowed_vs_untracked": 1.1,
        "crosscheck_mismatches": 0,
        "simd_crosscheck_mismatches": 0,
    }
    return {
        "bench": "engine",
        "measured": True,
        "simd_feature": simd_feature,
        "thresholds": {
            "min_speedup_simd_word_vs_scalar_word": 2.0,
            "min_speedup_simd_vector_vs_scalar_lane": 2.0,
            "max_trace_overhead_windowed_vs_untracked": 2.0,
            "max_crosscheck_mismatches": 0,
        },
        "units": {
            "SP FMA": dict(unit_row),
            "SP CMA": dict(unit_row),
        },
    }


def test_engine_simd_vector_gate_applies_to_fma_rows_on_simd_builds(tmp_path):
    checks, errors = run_doc(tmp_path, engine_doc(simd_feature=True))
    assert not errors
    vector = [c for c in checks if c.name == "simd_vector_vs_scalar_lane"]
    # Gated on the FMA row only: the CMA cascade keeps a scalar tail.
    assert [c.unit for c in vector] == ["SP FMA"]
    assert all(c.ok for c in checks)


def test_engine_simd_vector_gate_fails_below_threshold(tmp_path):
    doc = engine_doc(simd_feature=True)
    doc["units"]["SP FMA"]["speedup_simd_vector_vs_scalar_lane"] = 1.3
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = [(c.unit, c.name) for c in checks if not c.ok]
    assert failed == [("SP FMA", "simd_vector_vs_scalar_lane")]


def test_engine_simd_vector_gate_skipped_on_scalar_builds(tmp_path):
    # A scalar-build artifact carries 0 in the simd_vector rows; the
    # gate must not fire (the dispatching path IS the scalar path).
    checks, errors = run_doc(tmp_path, engine_doc(simd_feature=False))
    assert not errors
    assert all(c.name != "simd_vector_vs_scalar_lane" for c in checks)
    assert all(c.ok for c in checks)


def test_engine_legacy_artifact_without_simd_feature_key(tmp_path):
    # Pre-PR-6 artifacts have neither the key nor the threshold: both
    # absences independently disable the new gate.
    doc = engine_doc(simd_feature=False)
    del doc["simd_feature"]
    del doc["thresholds"]["min_speedup_simd_vector_vs_scalar_lane"]
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert all(c.name != "simd_vector_vs_scalar_lane" for c in checks)


def formats_doc():
    # Mirrors the `fpmax fuzz --json` artifact for one small format: two
    # streams per op kind, a clean differential matrix, and the raw
    # packed-probe rates (no precomputed speedup — the checker derives
    # it).
    runs = []
    for kind in ("fma", "cma", "mul", "add"):
        for stream in ("UniformBits", "Structured"):
            runs.append({
                "format": "fp16",
                "kind": kind,
                "stream": stream,
                "executed": 100000,
                "counterexamples": 0,
                "engines": 6,
                "packed_engine": True,
            })
    return {
        "bench": "formats",
        "measured": True,
        "ops_per_format_kind": 200000,
        "seed": 7,
        "simd_feature": False,
        "thresholds": {
            "max_counterexamples": 0,
            "min_packed_speedup_fp16_fma_vs_sp_scalar_word": 1.5,
        },
        "runs": runs,
        "packed_probe": [
            {
                "format": "fp16",
                "kind": "fma",
                "elems_per_word": 2,
                "packed_elems_per_s": 2.0e8,
                "sp_scalar_word_ops_per_s": 1.0e8,
            },
        ],
    }


def test_formats_clean_matrix_passes(tmp_path):
    checks, errors = run_doc(tmp_path, formats_doc())
    assert not errors
    # 2 checks per run row (8 rows) + 1 packed-speedup check.
    assert len(checks) == 17
    assert all(c.ok for c in checks)
    speedup = [c for c in checks if c.name == "packed_vs_sp_scalar_word"]
    assert len(speedup) == 1
    # Re-derived from the raw rates: 2e8 / 1e8 = 2.0x.
    assert abs(speedup[0].value - 2.0) < 1e-9


def test_formats_counterexample_fails_its_row_only(tmp_path):
    doc = formats_doc()
    doc["runs"][3]["counterexamples"] = 2
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = [(c.unit, c.name) for c in checks if not c.ok]
    row = doc["runs"][3]
    unit = f"{row['format']}_{row['kind']}_{row['stream'].lower()}"
    assert failed == [(unit, "counterexamples")]


def test_formats_packed_speedup_rederived_not_trusted(tmp_path):
    # Below-threshold raw rates must fail even though the artifact
    # carries no ratio field at all to falsify.
    doc = formats_doc()
    doc["packed_probe"][0]["packed_elems_per_s"] = 1.2e8  # 1.2x < 1.5x
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"packed_vs_sp_scalar_word"}


def test_formats_non_fp16_probe_rows_gate_existence_only(tmp_path):
    doc = formats_doc()
    doc["packed_probe"].append({
        "format": "fp8e4m3",
        "kind": "fma",
        "elems_per_word": 4,
        "packed_elems_per_s": 5.0e7,  # 0.5x SP — allowed, not the gated row
        "sp_scalar_word_ops_per_s": 1.0e8,
    })
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert all(c.ok for c in checks)
    fp8 = [c for c in checks if c.unit == "fp8e4m3_fma_packed"]
    assert [c.name for c in fp8] == ["packed_elems_per_s"]


def test_formats_empty_run_is_a_failure(tmp_path):
    doc = formats_doc()
    doc["runs"][0]["executed"] = 0
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"executed"}


def test_formats_needs_thresholds(tmp_path):
    doc = formats_doc()
    del doc["thresholds"]
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "thresholds" in errors[0]


def test_engine_packed_section_gates_fp16_fma_only(tmp_path):
    # PR-9 engine schema: the packed object rides along; the fp16_fma
    # row is gated against the SP FMA scalar-word baseline, siblings
    # only need a nonzero rate. Older artifacts without the section (or
    # the threshold) skip cleanly — covered by the legacy test above.
    doc = engine_doc(simd_feature=False)
    doc["units"]["SP FMA"]["scalar_word_ops_per_s"] = 1.0e8
    doc["thresholds"]["min_packed_speedup_fp16_fma_vs_sp_scalar_word"] = 1.5
    doc["packed"] = {
        "fp16_fma": {
            "elems_per_word": 2,
            "packed_elems_per_s": 1.6e8,
            "lane_soa_elems_per_s": 1.0e8,
            "speedup_packed_vs_sp_scalar_word": 99.0,  # never read back
        },
        "fp8e5m2_cma": {
            "elems_per_word": 4,
            "packed_elems_per_s": 4.0e7,
            "lane_soa_elems_per_s": 2.0e7,
            "speedup_packed_vs_sp_scalar_word": 0.4,
        },
    }
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert all(c.ok for c in checks)
    gated = {c.unit: c for c in checks if c.unit in doc["packed"]}
    assert gated["fp16_fma"].name == "packed_vs_sp_scalar_word"
    assert abs(gated["fp16_fma"].value - 1.6) < 1e-9  # re-derived, not 99.0
    assert gated["fp8e5m2_cma"].name == "packed_elems_per_s"
    # Below threshold on the raw rates → the gated row fails.
    doc["packed"]["fp16_fma"]["packed_elems_per_s"] = 1.0e8
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = [(c.unit, c.name) for c in checks if not c.ok]
    assert failed == [("fp16_fma", "packed_vs_sp_scalar_word")]


def chaos_doc():
    # Mirrors ChaosReport::render_json: a 4-shard kill-all drill where
    # every gate holds.
    return {
        "bench": "chaos",
        "measured": True,
        "seed": 42,
        "tier": "word-simd",
        "shards": 4,
        "wall_secs": 1.5,
        "faults": {
            "planned": 4,
            "fired": 4,
            "kills": 4,
            "worker_panics": 0,
            "ring_floods": 0,
            "latency_injections": 0,
            "nan_storms": 0,
        },
        "producer": {
            "submitted_subs": 100,
            "completed_subs": 98,
            "errored_subs": 2,
            "hung_subs": 0,
            "submitted_ops": 100000,
            "completed_ops": 98000,
            "errored_ops": 2000,
            "hung_ops": 0,
            "retries": 7,
            "checksums": ["cbf29ce484222325"],
        },
        "fleet": {
            "ops": 98000,
            "respawns": 4,
            "rerouted_on_failure": 3,
            "crosscheck_sampled": 512,
            "crosscheck_mismatches": 0,
            "pj_per_op": 11.2,
        },
        "gates": {
            "zero_hung": True,
            "zero_lost": True,
            "crosscheck_clean": True,
            "coverage_ok": True,
            "conservation_ok": True,
            "all": True,
        },
    }


def test_chaos_all_gates_pass(tmp_path):
    # Chaos artifacts carry no thresholds object — the gates are
    # absolute, and its absence must not be an error.
    checks, errors = run_doc(tmp_path, chaos_doc())
    assert not errors
    assert len(checks) == 9
    assert all(c.ok for c in checks)


def test_chaos_ledger_violations_fail(tmp_path):
    # The checker recomputes the gates from the raw ledger, so a doc
    # whose own "gates" booleans still claim success cannot pass.
    doc = chaos_doc()
    doc["producer"]["hung_subs"] = 1
    doc["producer"]["hung_ops"] = 1000
    doc["producer"]["completed_ops"] = 90000  # loses 7000 ops
    doc["fleet"]["respawns"] = 3  # one shard stayed dead
    doc["faults"]["fired"] = 3  # one fault never fired
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {(c.unit, c.name) for c in checks if not c.ok}
    assert failed == {
        ("producer", "hung_subs"),
        ("producer", "hung_ops"),
        ("producer", "sub_ledger_balance"),
        ("producer", "op_ledger_balance"),
        ("faults", "coverage"),
        ("fleet", "respawns_vs_kills"),
    }


def test_chaos_conservation_break_fails_even_with_clean_ledger(tmp_path):
    doc = chaos_doc()
    doc["gates"]["conservation_ok"] = False
    doc["gates"]["all"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"conservation_ok", "all"}


def test_chaos_unmeasured_is_an_error(tmp_path):
    doc = chaos_doc()
    doc["measured"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "measured" in errors[0]


def serve_routing_parity(ratio):
    def arm(ops_per_s):
        return {
            "sustained_ops_per_s": ops_per_s,
            "fleet_pj_per_op": 12.0,
            "policy_routed": 0,
            "digest": "cbf29ce484222325",
            "gates_ok": True,
        }

    return {
        "trace": "uniform",
        "trace_ops": 25000,
        "trace_fingerprint": "cbf29ce484222325",
        "static": arm(1e8),
        "energy_aware": arm(ratio * 1e8),
        # A deliberately wrong ratio field: the checker must re-derive
        # from the raw arm numbers, never read this.
        "dynamic_vs_static_uniform_ratio": 99.0,
    }


def test_serve_routing_parity_rederives_ratio_from_raw_arms(tmp_path):
    doc = serve_doc()
    doc["thresholds"]["min_dynamic_vs_static_uniform_ratio"] = 0.99
    doc["routing"] = serve_routing_parity(1.002)
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    parity = [c for c in checks if c.name == "dynamic_vs_static_uniform"]
    assert len(parity) == 1
    assert abs(parity[0].value - 1.002) < 1e-9
    assert all(c.ok for c in checks)


def test_serve_routing_parity_fails_below_budget(tmp_path):
    doc = serve_doc()
    doc["thresholds"]["min_dynamic_vs_static_uniform_ratio"] = 0.99
    doc["routing"] = serve_routing_parity(0.9)
    doc["routing"]["energy_aware"]["gates_ok"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"dynamic_vs_static_uniform", "energy_aware_gates_ok"}


def test_serve_without_routing_section_is_backwards_compatible(tmp_path):
    # A pre-PR-8 artifact (no "routing" object) gates units + fleet only.
    checks, errors = run_doc(tmp_path, serve_doc())
    assert not errors
    assert all(c.unit != "routing" for c in checks)


def routing_doc():
    # Mirrors the `fpmax replay --policy both --verify-determinism`
    # artifact: energy-aware dominates static on the diurnal-skew trace.
    def arm(policy, ops_per_s, pj_per_op):
        return {
            "policy": policy,
            "sustained_ops_per_s": ops_per_s,
            "fleet_pj_per_op": pj_per_op,
            "submitted_ops": 60000,
            "completed_ops": 60000,
            "errored_ops": 0,
            "hung_subs": 0,
            "retries": 3,
            "policy_routed": 120 if policy == "energy-aware" else 0,
            "misrouted": 0,
            "rerouted_on_failure": 0,
            "admission_denied": 0,
            "respawns": 0,
            "faults_fired": 0,
            "crosscheck_sampled": 512,
            "crosscheck_mismatches": 0,
            "conservation_ok": True,
            "digest": "cbf29ce484222325",
            "results_in_digest": policy == "static",
            "digest_stable": True,
            "gates_ok": True,
            "wall_secs": 0.8,
        }

    return {
        "bench": "routing",
        "measured": True,
        "seed": 42,
        "trace": "diurnal-skew",
        "tier": "word-simd",
        "total_ops": 60000,
        "tenants": 4,
        "events": 700,
        "last_slot": 1400,
        "trace_fingerprint": "cbf29ce484222325",
        "faults_planned": 0,
        "verify_determinism": True,
        "arms": [
            arm("static", 1.0e8, 13.0),
            arm("energy-aware", 1.2e8, 12.4),
        ],
        "dominance": {
            "throughput_ratio": 1.2,
            "pj_ratio": 0.9538,
            "dynamic_dominates": True,
        },
        "thresholds": {
            "min_throughput_ratio": 1.0,
            "max_pj_ratio": 1.0,
        },
    }


def test_routing_dominance_passes_and_is_rederived(tmp_path):
    checks, errors = run_doc(tmp_path, routing_doc())
    assert not errors
    # 7 per-arm checks x 2 arms + 3 dominance checks.
    assert len(checks) == 17
    assert all(c.ok for c in checks)
    dom = {c.name: c for c in checks if c.unit == "dominance"}
    assert set(dom) == {"throughput_ratio", "pj_ratio", "verdict_agrees"}
    assert abs(dom["throughput_ratio"].value - 1.2) < 1e-9


def test_routing_equal_throughput_does_not_dominate(tmp_path):
    # Dominance is strict on throughput: a tie must fail the gate, and
    # an artifact still claiming dominance must also fail verdict_agrees.
    doc = routing_doc()
    doc["arms"][1]["sustained_ops_per_s"] = doc["arms"][0]["sustained_ops_per_s"]
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"throughput_ratio", "verdict_agrees"}


def test_routing_ledger_and_determinism_violations_fail(tmp_path):
    doc = routing_doc()
    doc["arms"][0]["completed_ops"] = 59000  # loses 1000 ops
    doc["arms"][1]["digest_stable"] = False
    doc["arms"][1]["faults_fired"] = 1  # fired more than planned
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {(c.unit, c.name) for c in checks if not c.ok}
    assert failed == {
        ("static", "op_ledger_balance"),
        ("energy-aware", "digest_stable"),
        ("energy-aware", "fault_coverage"),
    }


def test_routing_single_arm_skips_dominance(tmp_path):
    # A --policy static run has no dominance verdict to re-derive; the
    # per-arm gates still apply.
    doc = routing_doc()
    doc["arms"] = doc["arms"][:1]
    doc["dominance"] = None
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert len(checks) == 7
    assert all(c.unit == "static" for c in checks)
    assert all(c.ok for c in checks)


def test_routing_without_determinism_flag_skips_digest_gate(tmp_path):
    doc = routing_doc()
    doc["verify_determinism"] = False
    doc["arms"][0]["digest_stable"] = False  # ignored without the flag
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    assert all(c.name != "digest_stable" for c in checks)
    assert all(c.ok for c in checks)


def test_routing_needs_thresholds(tmp_path):
    doc = routing_doc()
    del doc["thresholds"]
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "thresholds" in errors[0]


def test_routing_unmeasured_is_an_error(tmp_path):
    doc = routing_doc()
    doc["measured"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "measured" in errors[0]


def kernels_doc():
    # Mirrors the `fpmax kernels --json` artifact: one GEMM tile row.
    # window_ops/window_cycles = 2048/2053 ≈ 0.9976 occupancy; the
    # unrolled encoding pays 1 + latency cycles per op → 4.99x speedup.
    return {
        "bench": "kernels",
        "measured": True,
        "seed": 42,
        "window_slots": 256,
        "thresholds": {
            "min_frep_occupancy": 0.9,
            "min_frep_issue_speedup_vs_unrolled": 1.5,
            "max_result_mismatches": 0,
        },
        "rows": [
            {
                "kernel": "gemm16x16x8",
                "unit": "sp-fma",
                "ops": 2048,
                "repeat": {
                    "cycles": 2077,
                    "window_ops": 2048,
                    "window_cycles": 2053,
                },
                "unrolled": {"cycles": 10365},
                "result_mismatches": 0,
                "occupancy_in_burst": 2048 / 2053,
                "issue_speedup": 10365 / 2077,
                "pj_per_op_repeat": 11.8,
                "pj_per_op_unrolled": 13.4,
            },
        ],
    }


def test_kernels_clean_row_passes_and_is_rederived(tmp_path):
    checks, errors = run_doc(tmp_path, kernels_doc())
    assert not errors
    assert len(checks) == 6
    assert all(c.ok for c in checks)
    by_name = {c.name: c for c in checks}
    assert set(by_name) == {
        "ops",
        "frep_occupancy",
        "frep_issue_speedup",
        "result_mismatches",
        "occupancy_claim_agrees",
        "speedup_claim_agrees",
    }
    # Derived from the raw counts, not read back from the claim fields.
    assert abs(by_name["frep_occupancy"].value - 2048 / 2053) < 1e-9
    assert abs(by_name["frep_issue_speedup"].value - 10365 / 2077) < 1e-9


def test_kernels_gates_rederive_from_raw_counts(tmp_path):
    # Degrade the raw counts but leave the (now stale) claim fields at
    # their passing values: the re-derived gates AND the claim
    # cross-checks must both fail — the claims are never trusted.
    doc = kernels_doc()
    row = doc["rows"][0]
    row["repeat"]["window_cycles"] = 4096  # occ = 0.5 < 0.9
    row["repeat"]["cycles"] = 9000  # speedup = 1.15x < 1.5x
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {
        "frep_occupancy",
        "frep_issue_speedup",
        "occupancy_claim_agrees",
        "speedup_claim_agrees",
    }


def test_kernels_result_mismatch_fails_bit_identity(tmp_path):
    doc = kernels_doc()
    doc["rows"][0]["result_mismatches"] = 3
    checks, errors = run_doc(tmp_path, doc)
    assert not errors
    failed = {c.name for c in checks if not c.ok}
    assert failed == {"result_mismatches"}


def test_kernels_needs_thresholds(tmp_path):
    doc = kernels_doc()
    del doc["thresholds"]
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "thresholds" in errors[0]


def test_kernels_unmeasured_is_an_error(tmp_path):
    doc = kernels_doc()
    doc["measured"] = False
    checks, errors = run_doc(tmp_path, doc)
    assert not checks
    assert errors and "measured" in errors[0]
