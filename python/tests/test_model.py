"""L2 model and AOT-export tests: batch graphs, toggle statistics, and
the HLO-text artifacts the Rust runtime loads."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rand_sp(rng, n):
    return (
        (rng.integers(0, 2, n, dtype=np.uint32) << 31)
        | (rng.integers(0, 256, n, dtype=np.uint32) << 23)
        | rng.integers(0, 1 << 23, n, dtype=np.uint32)
    )


class TestBatchGraphs:
    def test_sp_batch_outputs(self):
        rng = np.random.default_rng(1)
        n = model.BATCH
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        out, toggles = model.sp_fmac_batch(a, b, c)
        assert out.shape == (n,) and out.dtype == jnp.uint32
        assert toggles.dtype == jnp.uint64
        want = np.asarray(ref.sp_fmac_ref(a, b, c))
        assert (np.asarray(out) == want).all()

    def test_dp_batch_outputs(self):
        rng = np.random.default_rng(2)
        n = model.BATCH
        a = rng.integers(0, 2**63, n, dtype=np.uint64)
        b = rng.integers(0, 2**63, n, dtype=np.uint64)
        c = rng.integers(0, 2**63, n, dtype=np.uint64)
        out, toggles = model.dp_fmac_batch(a, b, c)
        assert out.shape == (n,) and out.dtype == jnp.uint64
        assert int(toggles) > 0

    def test_toggle_count_semantics(self):
        # Identical consecutive results → zero toggles; alternating
        # all-ones/zeros → 32 per transition for u32 inputs.
        same = jnp.full((16,), 0xDEADBEEF, dtype=jnp.uint32)
        assert int(model.toggle_count(same)) == 0
        alt = jnp.tile(jnp.array([0x0, 0xFFFFFFFF], dtype=jnp.uint32), 8)
        assert int(model.toggle_count(alt)) == 32 * 15

    def test_toggle_count_tracks_activity(self):
        # A quiet stream (all results equal) toggles less than a random
        # stream — the energy model relies on this ordering.
        rng = np.random.default_rng(3)
        n = model.BATCH
        one = np.full(n, 0x3F800000, dtype=np.uint32)
        zero = np.zeros(n, dtype=np.uint32)
        _, quiet = model.sp_fmac_batch(one, one, zero)  # 1·1+0 = 1 always
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        _, busy = model.sp_fmac_batch(a, b, c)
        assert int(quiet) == 0
        assert int(busy) > 10 * n  # ≫ 10 toggles/op on random data


class TestAotExport:
    @pytest.fixture(scope="class")
    def exported(self):
        with tempfile.TemporaryDirectory() as d:
            manifest = aot.export_all(d, batch=256)
            texts = {}
            for name, m in manifest.items():
                with open(m["path"]) as f:
                    texts[name] = f.read()
            yield manifest, texts

    def test_both_entry_points_exported(self, exported):
        manifest, texts = exported
        assert set(manifest) == {"sp_fmac", "dp_fmac"}
        for name in manifest:
            assert len(texts[name]) > 1000

    def test_hlo_text_structure(self, exported):
        _, texts = exported
        for name, text in texts.items():
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text
            # The batch size is baked into the shapes.
            assert "[256]" in text, f"{name} missing batch-256 shapes"
            # Outputs are a tuple (results, toggles).
            assert "tuple" in text.lower()

    def test_no_custom_calls_in_artifact(self, exported):
        # interpret=True must have lowered pallas to plain HLO the CPU
        # PJRT client can run — a Mosaic custom-call would be fatal.
        _, texts = exported
        for name, text in texts.items():
            assert "custom-call" not in text, f"{name} contains a custom call"

    def test_manifest_written(self, exported):
        manifest, _ = exported
        for m in manifest.values():
            assert m["batch"] == 256
            assert len(m["sha256_16"]) == 16

    def test_checked_in_artifacts_match_entry_points(self):
        # `make artifacts` output, if present, must cover every entry
        # point with consistent batch sizes.
        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        if not os.path.isdir(art):
            pytest.skip("artifacts/ not built")
        for name in model.ENTRY_POINTS:
            path = os.path.join(art, f"{name}.hlo.txt")
            assert os.path.exists(path), f"missing {path}; run `make artifacts`"
            with open(path) as f:
                head = f.read(4096)
            assert head.startswith("HloModule")


class TestLoweringDeterminism:
    def test_same_input_same_hlo(self):
        lowered1 = jax.jit(model.sp_fmac_batch).lower(*model.sp_example_args(128))
        lowered2 = jax.jit(model.sp_fmac_batch).lower(*model.sp_example_args(128))
        assert aot.to_hlo_text(lowered1) == aot.to_hlo_text(lowered2)
