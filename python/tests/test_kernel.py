"""Kernel-vs-reference tests — the core correctness signal for L1.

Layered agreement, strongest check last:

1. Pallas kernel ≡ pure-jnp core (plumbing: BlockSpec, grid, dtypes);
2. jnp cores ≡ the independent Python-integer oracle (algorithm);
3. hypothesis sweeps over batch shapes and adversarial bit patterns.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fmac import sp_fmac_pallas, BLOCK


def rand_sp(rng, n):
    return (
        (rng.integers(0, 2, n, dtype=np.uint32) << 31)
        | (rng.integers(0, 256, n, dtype=np.uint32) << 23)
        | rng.integers(0, 1 << 23, n, dtype=np.uint32)
    )


def rand_dp(rng, n):
    return (
        (rng.integers(0, 2, n, dtype=np.uint64) << 63)
        | (rng.integers(0, 2048, n, dtype=np.uint64) << 52)
        | rng.integers(0, 1 << 52, n, dtype=np.uint64)
    )


# Adversarial single-operand values: zeros, subnormal extremes, powers of
# two, all-ones significands, near-overflow, specials.
SP_EDGE = np.array(
    [0x00000000, 0x80000000, 0x00000001, 0x80000001, 0x007FFFFF, 0x00800000,
     0x3F800000, 0xBF800000, 0x3F7FFFFF, 0x3F800001, 0x7F7FFFFF, 0xFF7FFFFF,
     0x7F800000, 0xFF800000, 0x7FC00000, 0x7F800001, 0x00400000, 0x34000000,
     0x01000000, 0xFE7FFFFF],
    dtype=np.uint32,
)

DP_EDGE = np.array(
    [0x0000000000000000, 0x8000000000000000, 0x0000000000000001,
     0x000FFFFFFFFFFFFF, 0x0010000000000000, 0x3FF0000000000000,
     0xBFF0000000000000, 0x7FEFFFFFFFFFFFFF, 0xFFEFFFFFFFFFFFFF,
     0x7FF0000000000000, 0xFFF0000000000000, 0x7FF8000000000000,
     0x7FF0000000000001, 0x3CA0000000000000, 0x0008000000000000],
    dtype=np.uint64,
)


def assert_sp_matches_oracle(a, b, c, got):
    want = ref.sp_fmac_exact_batch(a, b, c)
    bad = np.where(got != want)[0]
    assert len(bad) == 0, [
        (hex(a[i]), hex(b[i]), hex(c[i]), hex(got[i]), hex(want[i])) for i in bad[:5]
    ]


class TestPallasPlumbing:
    def test_kernel_equals_jnp_core_random(self):
        rng = np.random.default_rng(11)
        n = 4 * BLOCK
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        got = np.asarray(sp_fmac_pallas(a, b, c))
        want = np.asarray(ref.sp_fmac_ref(a, b, c))
        assert (got == want).all()

    @pytest.mark.parametrize("blocks", [1, 2, 3, 8])
    def test_grid_sizes(self, blocks):
        rng = np.random.default_rng(blocks)
        n = blocks * BLOCK
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        got = np.asarray(sp_fmac_pallas(a, b, c))
        want = np.asarray(ref.sp_fmac_ref(a, b, c))
        assert (got == want).all()

    @pytest.mark.parametrize("block", [128, 256, 512])
    def test_alternate_block_shapes(self, block):
        rng = np.random.default_rng(block)
        n = 2 * block
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        got = np.asarray(sp_fmac_pallas(a, b, c, block=block))
        want = np.asarray(ref.sp_fmac_ref(a, b, c))
        assert (got == want).all()

    def test_non_multiple_batch_rejected(self):
        rng = np.random.default_rng(0)
        a = rand_sp(rng, BLOCK + 1)
        with pytest.raises(AssertionError):
            sp_fmac_pallas(a, a, a)


class TestSpAgainstOracle:
    def test_random_full_range(self):
        rng = np.random.default_rng(21)
        n = 4000
        a, b, c = rand_sp(rng, n), rand_sp(rng, n), rand_sp(rng, n)
        got = np.asarray(ref.sp_fmac_ref(a, b, c))
        assert_sp_matches_oracle(a, b, c, got)

    def test_edge_triples_exhaustive(self):
        a, b, c = np.meshgrid(SP_EDGE, SP_EDGE, SP_EDGE, indexing="ij")
        a, b, c = a.ravel(), b.ravel(), c.ravel()
        got = np.asarray(ref.sp_fmac_ref(a, b, c))
        want = ref.sp_fmac_exact_batch(a, b, c)
        bad = np.where(got != want)[0]
        assert len(bad) == 0, [
            (hex(a[i]), hex(b[i]), hex(c[i]), hex(got[i]), hex(want[i])) for i in bad[:8]
        ]

    def test_cancellation_stress(self):
        # a·b ≈ −c with |a·b + c| spanning every cancellation depth.
        rng = np.random.default_rng(5)
        n = 3000
        a = rand_sp(rng, n) & np.uint32(0x7FFFFFFF) | np.uint32(0x3F800000)
        b = a.copy()
        # c = −(a·b rounded), perturbed by a few ulps.
        prod = np.float32(a.view(np.float32)) * b.view(np.float32)
        cb = prod.view(np.uint32) ^ np.uint32(0x80000000)
        cb = cb + rng.integers(0, 4, n, dtype=np.uint32)
        got = np.asarray(ref.sp_fmac_ref(a, b, cb))
        assert_sp_matches_oracle(a, b, cb, got)

    @settings(max_examples=300, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
    def test_hypothesis_any_bits(self, a, b, c):
        a = np.array([a], dtype=np.uint32)
        b = np.array([b], dtype=np.uint32)
        c = np.array([c], dtype=np.uint32)
        got = int(np.asarray(ref.sp_fmac_ref(a, b, c))[0])
        want = int(ref.sp_fmac_exact(a[0], b[0], c[0]))
        assert got == want, f"{a[0]:#x},{b[0]:#x},{c[0]:#x}: {got:#x} vs {want:#x}"


class TestDpAgainstOracle:
    def test_random_full_range(self):
        rng = np.random.default_rng(31)
        n = 2000
        a, b, c = rand_dp(rng, n), rand_dp(rng, n), rand_dp(rng, n)
        got = np.asarray(ref.dp_fmac_ref(a, b, c))
        want = ref.dp_fmac_exact_batch(a, b, c)
        bad = np.where(got != want)[0]
        assert len(bad) == 0, [
            (hex(a[i]), hex(b[i]), hex(c[i]), hex(got[i]), hex(want[i])) for i in bad[:5]
        ]

    def test_edge_triples_sampled(self):
        # Full DP edge cube is 15³ = 3375 — affordable.
        a, b, c = np.meshgrid(DP_EDGE, DP_EDGE, DP_EDGE, indexing="ij")
        a, b, c = a.ravel(), b.ravel(), c.ravel()
        got = np.asarray(ref.dp_fmac_ref(a, b, c))
        want = ref.dp_fmac_exact_batch(a, b, c)
        bad = np.where(got != want)[0]
        assert len(bad) == 0, [
            (hex(a[i]), hex(b[i]), hex(c[i]), hex(got[i]), hex(want[i])) for i in bad[:8]
        ]

    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1))
    def test_hypothesis_any_bits(self, a, b, c):
        aa = np.array([a], dtype=np.uint64)
        bb = np.array([b], dtype=np.uint64)
        cc = np.array([c], dtype=np.uint64)
        got = int(np.asarray(ref.dp_fmac_ref(aa, bb, cc))[0])
        want = int(ref.dp_fmac_exact(a, b, c))
        assert got == want, f"{a:#x},{b:#x},{c:#x}: {got:#x} vs {want:#x}"
