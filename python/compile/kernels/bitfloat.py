"""Vectorized bit-exact IEEE-754 FMAC cores in integer jnp ops.

This is the compute hot-spot of the FPMax reproduction, written so the
same functions serve three masters:

* the **Pallas kernel** (`fmac.py`) calls :func:`sp_fmac_core` on VMEM
  blocks — every step below is a vectorized integer op, so the kernel
  lowers to plain element-wise HLO under ``interpret=True``;
* the **L2 model** (`model.py`) calls :func:`dp_fmac_core` (two-limb
  arithmetic — the 106-bit DP product does not fit a machine word) and
  wraps both into the AOT-exported batch graphs;
* the **pytest suite** cross-checks both against the independent
  integer oracle in ``ref.py``.

The algorithm mirrors the Rust golden model (``rust/src/arch/softfloat.rs``):
exact product → normalize the larger addend to the top of the working
word → align the smaller with sticky capture → add/sub with the
sticky-decrement trick → round-to-nearest-even with subnormal and
overflow handling. Round-to-nearest-even only: the AOT artifact is the
chip's RNE cross-check reference (the other modes are exercised on the
Rust side).

Everything runs in uint64 (``jax_enable_x64`` required; ``aot.py`` and
``conftest.py`` set it).
"""

import jax.numpy as jnp

# ---------------------------------------------------------------- helpers

_U64 = jnp.uint64
_I64 = jnp.int64


def u64(x):
    return jnp.asarray(x, dtype=_U64)


def i64(x):
    return jnp.asarray(x, dtype=_I64)


def clz64(x):
    """Count leading zeros of a uint64 (64 for zero), by binary search:
    at each step, if the top `shift` bits are clear, skip past them."""
    x = u64(x)
    zero = x == 0
    n = jnp.zeros_like(x, dtype=_I64)
    for shift in (32, 16, 8, 4, 2, 1):
        take = (x >> u64(64 - shift)) == 0
        n = jnp.where(take, n + shift, n)
        x = jnp.where(take, x << u64(shift), x)
    return jnp.where(zero, i64(64), n)


def bitlen64(x):
    """Number of significant bits (0 for 0)."""
    return i64(64) - clz64(x)


def shl64(x, n):
    """x << n with out-of-range shifts (n < 0 or n ≥ 64) yielding 0.

    XLA leaves such shifts implementation-defined, and `jnp.where`
    evaluates both branches, so every shift amount must be clamped even
    in lanes the caller will discard.
    """
    n = i64(n)
    bad = (n >= 64) | (n < 0)
    safe = jnp.clip(n, 0, 63)
    return jnp.where(bad, u64(0), u64(x) << safe.astype(_U64))


def shr64(x, n):
    """x >> n with out-of-range shifts yielding 0."""
    n = i64(n)
    bad = (n >= 64) | (n < 0)
    safe = jnp.clip(n, 0, 63)
    return jnp.where(bad, u64(0), u64(x) >> safe.astype(_U64))


def shr64_rs(x, n):
    """Right shift with round/sticky capture.

    Returns (kept, round_bit, sticky) for a shift of ``n ≥ 0``: the round
    bit is the highest bit shifted out, sticky ORs the rest.
    """
    x = u64(x)
    n = i64(n)
    kept = shr64(x, n)
    rnd = shr64(x, n - 1) & u64(1)
    rnd = jnp.where(n <= 0, u64(0), jnp.where(n > 64, u64(0), rnd))
    below = shl64(u64(1), n - 1) - u64(1)  # mask of bits strictly below round
    below = jnp.where(n <= 0, u64(0), below)
    sticky = jnp.where(n > 64, (x != 0).astype(_U64), ((x & below) != 0).astype(_U64))
    # n == 64: kept = 0, round bit = bit 63, sticky = rest.
    kept = jnp.where(n >= 64, u64(0), kept)
    rnd = jnp.where(n == 64, (x >> u64(63)) & u64(1), rnd)
    sticky64 = ((x & ((u64(1) << u64(63)) - u64(1))) != 0).astype(_U64)
    sticky = jnp.where(n == 64, sticky64, sticky)
    return kept, rnd, sticky

# ---------------------------------------------------------------- SP core


_SP_FRAC_MASK = 0x7FFFFF
_SP_HIDDEN = 0x800000
_SP_QNAN = 0x7FC00000
_SP_EXP_MASK = 0xFF


def _sp_decode(bits):
    bits = u64(bits) & u64(0xFFFFFFFF)
    sign = (bits >> u64(31)) & u64(1)
    e = (bits >> u64(23)) & u64(_SP_EXP_MASK)
    frac = bits & u64(_SP_FRAC_MASK)
    is_zero = (e == 0) & (frac == 0)
    is_sub = (e == 0) & (frac != 0)
    is_inf = (e == _SP_EXP_MASK) & (frac == 0)
    is_nan = (e == _SP_EXP_MASK) & (frac != 0)
    sig = jnp.where(is_sub | is_zero, frac, frac | u64(_SP_HIDDEN))
    # LSB exponent: value = sig · 2^exp.
    exp = jnp.where(e == 0, i64(-149), e.astype(_I64) - 150)
    return sign, exp, sig, is_zero, is_inf, is_nan


def _sp_round_rne(sign, exp, sig, sticky_in):
    """Round exact (sign, sig·2^exp + sticky residue) to SP RNE bits."""
    npos = exp + bitlen64(sig)
    target_q = jnp.maximum(npos - 24, i64(-149))
    shift = target_q - exp  # ≥ 0 whenever sig is wide; may exceed 64
    kept, rnd, st = shr64_rs(sig, shift)
    st = st | sticky_in
    lsb = kept & u64(1)
    inc = (rnd == 1) & ((st == 1) | (lsb == 1))
    kept = kept + inc.astype(_U64)
    carry = kept == u64(1 << 24)
    kept = jnp.where(carry, kept >> u64(1), kept)
    q = jnp.where(carry, target_q + 1, target_q)
    # Overflow to ±Inf.
    msb = q + bitlen64(kept) - 1
    overflow = (kept != 0) & (msb > 127)
    # Encode: normal iff hidden bit present.
    is_norm = (kept & u64(_SP_HIDDEN)) != 0
    biased = jnp.where(is_norm, (q + 150).astype(_U64), u64(0))
    body = (biased << u64(23)) | (kept & u64(_SP_FRAC_MASK))
    body = jnp.where(kept == 0, u64(0), body)
    body = jnp.where(overflow, u64(0x7F800000), body)
    return (sign << u64(31)) | body


def sp_fmac_core(a_bits, b_bits, c_bits):
    """Bit-exact SP fused multiply-add: round(a·b + c), RNE.

    Inputs and output are uint32 bit patterns carried in uint64 lanes.
    """
    sa, ea, siga, za, infa, nana = _sp_decode(a_bits)
    sb, eb, sigb, zb, infb, nanb = _sp_decode(b_bits)
    sc, ec, sigc, zc, infc, nanc = _sp_decode(c_bits)

    # ---- finite path ------------------------------------------------
    psign = sa ^ sb
    pexp = ea + eb
    psig = siga * sigb  # ≤ 2^48
    pzero = psig == 0

    # Magnitude order between product P and addend C.
    npos_p = pexp + bitlen64(psig)
    npos_c = ec + bitlen64(sigc)
    # Aligned compare at e = min(pexp, ec): both fit in u64 when npos tie.
    emin = jnp.minimum(pexp, ec)
    p_al = shl64(psig, pexp - emin)
    c_al = shl64(sigc, ec - emin)
    p_bigger = jnp.where(
        npos_p != npos_c, npos_p > npos_c, p_al > c_al
    )
    equal_mag = (npos_p == npos_c) & (p_al == c_al)

    big_sig = jnp.where(p_bigger, psig, sigc)
    big_exp = jnp.where(p_bigger, pexp, ec)
    big_sign = jnp.where(p_bigger, psign, sc)
    small_sig = jnp.where(p_bigger, sigc, psig)
    small_exp = jnp.where(p_bigger, ec, pexp)
    small_sign = jnp.where(p_bigger, sc, psign)

    # Degenerate operand handling: if one side is zero, the sum is the
    # other side (exact).
    one_zero = pzero | (sigc == 0)
    lone_sig = jnp.where(pzero, sigc, psig)
    lone_exp = jnp.where(pzero, ec, pexp)
    lone_sign = jnp.where(pzero, sc, psign)

    # Normalize big to bit 62.
    lsh = i64(62) - (bitlen64(big_sig) - 1)
    nbig = shl64(big_sig, lsh)
    nexp = big_exp - lsh
    d = nexp - small_exp
    # d < 0: small shifts left (fits: aligned length ≤ 63); d ≥ 0: right
    # with sticky.
    small_left = shl64(small_sig, -d)
    small_right, s_rnd, s_st = shr64_rs(small_sig, d)
    # Fold the round bit into sticky: big has one headroom bit, so a
    # 1-bit-finer alignment is unnecessary — instead keep (d−1)-shift and
    # one guard. Simpler: shift by d but keep round|sticky as sticky.
    ssig = jnp.where(d < 0, small_left, small_right)
    sticky = jnp.where(d < 0, u64(0), (s_rnd | s_st))

    same_sign = big_sign == small_sign
    sum_same = nbig + ssig
    sub = nbig - ssig - sticky  # sticky-decrement trick
    sum_sig = jnp.where(same_sign, sum_same, sub)
    sum_sign = big_sign
    sum_exp = nexp

    # One-side-zero and exact-cancellation overrides.
    sum_sig = jnp.where(one_zero, lone_sig, sum_sig)
    sum_exp = jnp.where(one_zero, lone_exp, sum_exp)
    sum_sign = jnp.where(one_zero, lone_sign, sum_sign)
    sticky = jnp.where(one_zero, u64(0), sticky)
    cancel = (~one_zero) & (~same_sign) & equal_mag
    sum_sig = jnp.where(cancel, u64(0), sum_sig)
    sum_sign = jnp.where(cancel, u64(0), sum_sign)  # +0 under RNE

    # Both zero: IEEE sign rule (+0 unless both −0).
    both_zero = pzero & (sigc == 0)
    zero_sign = jnp.where(psign == sc, psign, u64(0))

    rounded = _sp_round_rne(sum_sign, sum_exp, sum_sig, sticky)
    rounded = jnp.where(both_zero, zero_sign << u64(31), rounded)

    # ---- specials ----------------------------------------------------
    inf_p = infa | infb
    invalid = (infa & zb) | (infb & za) | (inf_p & infc & (psign != sc))
    any_nan = nana | nanb | nanc
    inf_result = jnp.where(inf_p, psign, sc) << u64(31) | u64(0x7F800000)
    out = rounded
    out = jnp.where(inf_p | infc, inf_result, out)
    out = jnp.where(any_nan | invalid, u64(_SP_QNAN), out)
    return out & u64(0xFFFFFFFF)

# ---------------------------------------------------------------- DP core
#
# DP significand products reach 106 bits, so values travel as (hi, lo)
# uint64 limb pairs. Only the handful of 128-bit primitives the FMA
# needs are implemented.


def _add128(hi_a, lo_a, hi_b, lo_b):
    lo = lo_a + lo_b
    carry = (lo < lo_a).astype(_U64)
    return hi_a + hi_b + carry, lo


def _sub128(hi_a, lo_a, hi_b, lo_b):
    lo = lo_a - lo_b
    borrow = (lo_a < lo_b).astype(_U64)
    return hi_a - hi_b - borrow, lo


def _shl128(hi, lo, n):
    """(hi,lo) << n for 0 ≤ n < 128."""
    n = i64(n)
    ge64 = n >= 64
    n1 = jnp.where(ge64, n - 64, n)
    # n < 64 case:
    hi_lt = shl64(hi, n) | jnp.where(n == 0, u64(0), shr64(lo, 64 - n))
    lo_lt = shl64(lo, n)
    # n ≥ 64 case:
    hi_ge = shl64(lo, n1)
    return jnp.where(ge64, hi_ge, hi_lt), jnp.where(ge64, u64(0), lo_lt)


def _shr128_sticky(hi, lo, n):
    """(hi,lo) >> n with sticky of everything shifted out (n ≥ 0)."""
    n = i64(n)
    ge128 = n >= 128
    ge64 = (n >= 64) & ~ge128
    n1 = jnp.where(ge64, n - 64, n)
    # n < 64:
    lo_lt = shr64(lo, n) | jnp.where(n == 0, u64(0), shl64(hi, 64 - n))
    hi_lt = shr64(hi, n)
    st_lt = ((lo & (shl64(u64(1), n) - u64(1))) != 0).astype(_U64)
    # 64 ≤ n < 128:
    lo_ge = shr64(hi, n1)
    st_ge_low = (lo != 0).astype(_U64)
    st_ge_hi = ((hi & (shl64(u64(1), n1) - u64(1))) != 0).astype(_U64)
    st_ge = st_ge_low | st_ge_hi
    lo_out = jnp.where(ge64, lo_ge, lo_lt)
    hi_out = jnp.where(ge64, u64(0), hi_lt)
    st = jnp.where(ge64, st_ge, st_lt)
    # n ≥ 128:
    any_bits = ((hi != 0) | (lo != 0)).astype(_U64)
    lo_out = jnp.where(ge128, u64(0), lo_out)
    hi_out = jnp.where(ge128, u64(0), hi_out)
    st = jnp.where(ge128, any_bits, st)
    return hi_out, lo_out, st


def _bitlen128(hi, lo):
    return jnp.where(hi != 0, i64(64) + bitlen64(hi), bitlen64(lo))


def _mul_53x53(x, y):
    """Exact 53×53-bit product as a (hi, lo) u64 pair."""
    x = u64(x)
    y = u64(y)
    m26 = u64((1 << 26) - 1)
    x_hi = x >> u64(26)  # ≤ 2^27
    x_lo = x & m26
    y_hi = y >> u64(26)
    y_lo = y & m26
    t0 = x_lo * y_lo          # ≤ 2^52, weight 0
    t1 = x_hi * y_lo + x_lo * y_hi  # ≤ 2^54, weight 26
    t2 = x_hi * y_hi          # ≤ 2^54, weight 52
    lo1 = t0 + shl64(t1, 26)
    c1 = (lo1 < t0).astype(_U64)
    lo = lo1 + shl64(t2, 52)
    c2 = (lo < lo1).astype(_U64)
    hi = shr64(t1, 38) + shr64(t2, 12) + c1 + c2
    return hi, lo


_DP_FRAC_MASK = (1 << 52) - 1
_DP_HIDDEN = 1 << 52
_DP_QNAN = 0x7FF8000000000000
_DP_EXP_MASK = 0x7FF


def _dp_decode(bits):
    bits = u64(bits)
    sign = (bits >> u64(63)) & u64(1)
    e = (bits >> u64(52)) & u64(_DP_EXP_MASK)
    frac = bits & u64(_DP_FRAC_MASK)
    is_zero = (e == 0) & (frac == 0)
    is_sub = (e == 0) & (frac != 0)
    is_inf = (e == _DP_EXP_MASK) & (frac == 0)
    is_nan = (e == _DP_EXP_MASK) & (frac != 0)
    sig = jnp.where(is_sub | is_zero, frac, frac | u64(_DP_HIDDEN))
    exp = jnp.where(e == 0, i64(-1074), e.astype(_I64) - 1075)
    return sign, exp, sig, is_zero, is_inf, is_nan


def _dp_round_rne(sign, exp, hi, lo, sticky_in):
    npos = exp + _bitlen128(hi, lo)
    target_q = jnp.maximum(npos - 53, i64(-1074))
    shift = target_q - exp
    kept_hi, kept_lo, st_low = _shr128_sticky(hi, lo, jnp.maximum(shift - 1, 0))
    # kept with one guard bit at the bottom (shift−1), then split off the
    # round bit. shift may be 0 when the value is narrower than 53 bits.
    no_shift = shift <= 0
    rnd = jnp.where(no_shift, u64(0), kept_lo & u64(1))
    kept = jnp.where(no_shift, shl64(lo, -shift), shr64(kept_lo, 1) | shl64(kept_hi, 63))
    st = jnp.where(no_shift, u64(0), st_low) | sticky_in
    lsb = kept & u64(1)
    inc = (rnd == 1) & ((st == 1) | (lsb == 1))
    kept = kept + inc.astype(_U64)
    carry = kept == u64(1 << 53)
    kept = jnp.where(carry, kept >> u64(1), kept)
    q = jnp.where(carry, target_q + 1, target_q)
    msb = q + bitlen64(kept) - 1
    overflow = (kept != 0) & (msb > 1023)
    is_norm = (kept & u64(_DP_HIDDEN)) != 0
    biased = jnp.where(is_norm, (q + 1075).astype(_U64), u64(0))
    body = (biased << u64(52)) | (kept & u64(_DP_FRAC_MASK))
    body = jnp.where(kept == 0, u64(0), body)
    body = jnp.where(overflow, u64(0x7FF0000000000000), body)
    return (sign << u64(63)) | body


def dp_fmac_core(a_bits, b_bits, c_bits):
    """Bit-exact DP fused multiply-add: round(a·b + c), RNE, via 128-bit
    limb arithmetic."""
    sa, ea, siga, za, infa, nana = _dp_decode(a_bits)
    sb, eb, sigb, zb, infb, nanb = _dp_decode(b_bits)
    sc, ec, sigc, zc, infc, nanc = _dp_decode(c_bits)

    psign = sa ^ sb
    pexp = ea + eb
    phi, plo = _mul_53x53(siga, sigb)
    pzero = (phi == 0) & (plo == 0)

    chi = u64(jnp.zeros_like(sigc))
    clo = sigc

    npos_p = pexp + _bitlen128(phi, plo)
    npos_c = ec + bitlen64(sigc)
    # Aligned compare at min exponent; both fit 128 bits when npos tie.
    emin = jnp.minimum(pexp, ec)
    pa_hi, pa_lo = _shl128(phi, plo, pexp - emin)
    ca_hi, ca_lo = _shl128(chi, clo, ec - emin)
    p_gt = (pa_hi > ca_hi) | ((pa_hi == ca_hi) & (pa_lo > ca_lo))
    p_bigger = jnp.where(npos_p != npos_c, npos_p > npos_c, p_gt)
    equal_mag = (npos_p == npos_c) & (pa_hi == ca_hi) & (pa_lo == ca_lo)

    big_hi = jnp.where(p_bigger, phi, chi)
    big_lo = jnp.where(p_bigger, plo, clo)
    big_exp = jnp.where(p_bigger, pexp, ec)
    big_sign = jnp.where(p_bigger, psign, sc)
    small_hi = jnp.where(p_bigger, chi, phi)
    small_lo = jnp.where(p_bigger, clo, plo)
    small_exp = jnp.where(p_bigger, ec, pexp)
    small_sign = jnp.where(p_bigger, sc, psign)

    one_zero = pzero | (sigc == 0)
    lone_hi = jnp.where(pzero, chi, phi)
    lone_lo = jnp.where(pzero, clo, plo)
    lone_exp = jnp.where(pzero, ec, pexp)
    lone_sign = jnp.where(pzero, sc, psign)

    # Normalize big to bit 126.
    lsh = i64(126) - (_bitlen128(big_hi, big_lo) - 1)
    nb_hi, nb_lo = _shl128(big_hi, big_lo, lsh)
    nexp = big_exp - lsh
    d = nexp - small_exp
    sl_hi, sl_lo = _shl128(small_hi, small_lo, jnp.maximum(-d, 0))
    sr_hi, sr_lo, s_st = _shr128_sticky(small_hi, small_lo, jnp.maximum(d, 0))
    ssig_hi = jnp.where(d < 0, sl_hi, sr_hi)
    ssig_lo = jnp.where(d < 0, sl_lo, sr_lo)
    sticky = jnp.where(d < 0, u64(0), s_st)

    same_sign = big_sign == small_sign
    add_hi, add_lo = _add128(nb_hi, nb_lo, ssig_hi, ssig_lo)
    sub_hi, sub_lo = _sub128(nb_hi, nb_lo, ssig_hi, ssig_lo)
    sub_hi, sub_lo = _sub128(sub_hi, sub_lo, u64(jnp.zeros_like(sticky)), sticky)
    sum_hi = jnp.where(same_sign, add_hi, sub_hi)
    sum_lo = jnp.where(same_sign, add_lo, sub_lo)
    sum_sign = big_sign
    sum_exp = nexp

    sum_hi = jnp.where(one_zero, lone_hi, sum_hi)
    sum_lo = jnp.where(one_zero, lone_lo, sum_lo)
    sum_exp = jnp.where(one_zero, lone_exp, sum_exp)
    sum_sign = jnp.where(one_zero, lone_sign, sum_sign)
    sticky = jnp.where(one_zero, u64(0), sticky)
    cancel = (~one_zero) & (~same_sign) & equal_mag
    sum_hi = jnp.where(cancel, u64(0), sum_hi)
    sum_lo = jnp.where(cancel, u64(0), sum_lo)
    sum_sign = jnp.where(cancel, u64(0), sum_sign)

    both_zero = pzero & (sigc == 0)
    zero_sign = jnp.where(psign == sc, psign, u64(0))

    rounded = _dp_round_rne(sum_sign, sum_exp, sum_hi, sum_lo, sticky)
    rounded = jnp.where(both_zero, zero_sign << u64(63), rounded)

    inf_p = infa | infb
    invalid = (infa & zb) | (infb & za) | (inf_p & infc & (psign != sc))
    any_nan = nana | nanb | nanc
    inf_result = (jnp.where(inf_p, psign, sc) << u64(63)) | u64(0x7FF0000000000000)
    out = rounded
    out = jnp.where(inf_p | infc, inf_result, out)
    out = jnp.where(any_nan | invalid, u64(_DP_QNAN), out)
    return out
