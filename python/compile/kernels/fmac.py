"""L1 — the Pallas FMAC kernel.

The compute hot-spot of the reproduction: a batched, bit-exact SP FMAC
datapath over uint32 operand arrays. One grid step processes one
``BLOCK``-sized tile; the BlockSpec expresses the HBM↔VMEM streaming of
operand blocks the way the FPMax chip streams operands from its on-chip
stimulus RAMs (Fig. 5(a)).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's unit
is an ASIC datapath, so on a TPU-shaped target we tile the *operation
batch*, not the bit-level structure — every datapath step is a
vectorized integer op (VPU work), and a block's working set is

    3 inputs + 1 output + ~6 u64 temps ≈ BLOCK · 72 B ≈ 72 KiB @ 1024

comfortably inside VMEM. ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitfloat

# Operations per VMEM tile.
BLOCK = 1024


def _fmac_kernel(a_ref, b_ref, c_ref, o_ref):
    """One tile: load u32 operands, run the bit-exact datapath in u64
    lanes, store u32 results."""
    a = a_ref[...].astype(jnp.uint64)
    b = b_ref[...].astype(jnp.uint64)
    c = c_ref[...].astype(jnp.uint64)
    o_ref[...] = bitfloat.sp_fmac_core(a, b, c).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block",))
def sp_fmac_pallas(a_bits, b_bits, c_bits, *, block=BLOCK):
    """Batched SP FMAC through the Pallas kernel.

    Arguments are uint32 arrays whose length must be a multiple of
    ``block`` (the AOT entry point fixes the batch; the runtime pads).
    """
    n = a_bits.shape[0]
    block = min(block, n)  # small batches become a single tile
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _fmac_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(a_bits, b_bits, c_bits)
