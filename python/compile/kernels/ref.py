"""Correctness oracles for the FMAC kernels.

Two independent references:

* :func:`fmac_exact` — a scalar softfloat FMA over Python's unbounded
  integers, written from the IEEE-754 definition with none of the
  vectorization tricks of ``bitfloat.py``. This is the ground truth the
  kernel and the jnp cores are tested against (and it in turn is tested
  against ``math.fma`` for DP, where the host FMA is exact).
* :func:`sp_fmac_ref` / :func:`dp_fmac_ref` — thin pure-jnp wrappers
  over the shared cores, used to check that the *Pallas plumbing*
  (BlockSpec streaming, grid partitioning) does not perturb values.
"""

import numpy as np
import jax.numpy as jnp

from . import bitfloat


# ------------------------------------------------------------- formats

class Fmt:
    def __init__(self, exp_bits, sig_bits):
        self.exp_bits = exp_bits
        self.sig_bits = sig_bits  # incl. hidden bit
        self.bias = (1 << (exp_bits - 1)) - 1
        self.emax = self.bias
        self.qmin = 1 - self.bias - (sig_bits - 1)
        self.width = 1 + exp_bits + sig_bits - 1
        self.frac_mask = (1 << (sig_bits - 1)) - 1
        self.hidden = 1 << (sig_bits - 1)
        self.exp_mask = (1 << exp_bits) - 1
        self.qnan = (self.exp_mask << (sig_bits - 1)) | (1 << (sig_bits - 2))

    def inf(self, sign):
        v = self.exp_mask << (self.sig_bits - 1)
        return v | (sign << (self.width - 1))


SP = Fmt(8, 24)
DP = Fmt(11, 53)


def _decode(fmt, bits):
    sign = (bits >> (fmt.width - 1)) & 1
    e = (bits >> (fmt.sig_bits - 1)) & fmt.exp_mask
    frac = bits & fmt.frac_mask
    if e == fmt.exp_mask:
        kind = "inf" if frac == 0 else "nan"
        return sign, 0, 0, kind
    if e == 0:
        if frac == 0:
            return sign, 0, 0, "zero"
        return sign, fmt.qmin, frac, "finite"
    return sign, e - fmt.bias - (fmt.sig_bits - 1), frac | fmt.hidden, "finite"


def fmac_exact(fmt, a_bits, b_bits, c_bits):
    """round(a·b + c) to nearest-even, computed with exact integers."""
    sa, ea, ma, ka = _decode(fmt, a_bits)
    sb, eb, mb, kb = _decode(fmt, b_bits)
    sc, ec, mc, kc = _decode(fmt, c_bits)

    psign = sa ^ sb
    if ka == "nan" or kb == "nan" or kc == "nan":
        return fmt.qnan
    p_inf = ka == "inf" or kb == "inf"
    if (ka == "inf" and kb == "zero") or (kb == "inf" and ka == "zero"):
        return fmt.qnan
    if p_inf and kc == "inf" and psign != sc:
        return fmt.qnan
    if p_inf:
        return fmt.inf(psign)
    if kc == "inf":
        return fmt.inf(sc)

    # Exact values as scaled integers: v = (-1)^s · m · 2^e.
    pm, pe = ma * mb, ea + eb
    if pm == 0 and mc == 0:
        sign = psign if psign == sc else 0
        return sign << (fmt.width - 1)
    # Bring both to a common exponent exactly (unbounded ints).
    if pm and mc:
        e = min(pe, ec)
    elif pm:
        e = pe
    else:
        e = ec
    p = (pm << (pe - e)) if pm else 0
    c = (mc << (ec - e)) if mc else 0
    v = (p if psign == 0 else -p) + (c if sc == 0 else -c)
    if v == 0:
        return 0  # +0 under RNE cancellation
    sign = 0 if v > 0 else 1
    mag = abs(v)
    # Round mag·2^e to the format.
    npos = mag.bit_length() + e
    q = max(npos - fmt.sig_bits, fmt.qmin)
    shift = q - e
    if shift <= 0:
        kept, rnd, sticky = mag << (-shift), 0, 0
    else:
        kept = mag >> shift
        rnd = (mag >> (shift - 1)) & 1
        sticky = 1 if (mag & ((1 << (shift - 1)) - 1)) else 0
    if rnd and (sticky or (kept & 1)):
        kept += 1
        if kept == (1 << fmt.sig_bits):
            kept >>= 1
            q += 1
    if kept == 0:
        return sign << (fmt.width - 1)
    if q + kept.bit_length() - 1 > fmt.emax:
        return fmt.inf(sign)
    if kept & fmt.hidden:
        biased = q + fmt.bias + fmt.sig_bits - 1
        body = (biased << (fmt.sig_bits - 1)) | (kept & fmt.frac_mask)
    else:
        body = kept  # subnormal (q == qmin by construction)
    return (sign << (fmt.width - 1)) | body


def sp_fmac_exact(a_bits, b_bits, c_bits):
    return fmac_exact(SP, int(a_bits), int(b_bits), int(c_bits))


def dp_fmac_exact(a_bits, b_bits, c_bits):
    return fmac_exact(DP, int(a_bits), int(b_bits), int(c_bits))


def sp_fmac_exact_batch(a, b, c):
    """Vectorized (slow, exact) SP oracle over numpy uint32 arrays."""
    return np.array(
        [sp_fmac_exact(x, y, z) for x, y, z in zip(np.asarray(a), np.asarray(b), np.asarray(c))],
        dtype=np.uint32,
    )


def dp_fmac_exact_batch(a, b, c):
    return np.array(
        [dp_fmac_exact(x, y, z) for x, y, z in zip(np.asarray(a), np.asarray(b), np.asarray(c))],
        dtype=np.uint64,
    )


# ------------------------------------------------------- jnp wrappers

def sp_fmac_ref(a_bits, b_bits, c_bits):
    """Pure-jnp SP FMAC (no pallas): uint32 in/out."""
    out = bitfloat.sp_fmac_core(
        jnp.asarray(a_bits).astype(jnp.uint64),
        jnp.asarray(b_bits).astype(jnp.uint64),
        jnp.asarray(c_bits).astype(jnp.uint64),
    )
    return out.astype(jnp.uint32)


def dp_fmac_ref(a_bits, b_bits, c_bits):
    """Pure-jnp DP FMAC: uint64 in/out."""
    return bitfloat.dp_fmac_core(
        jnp.asarray(a_bits, jnp.uint64),
        jnp.asarray(b_bits, jnp.uint64),
        jnp.asarray(c_bits, jnp.uint64),
    )
