"""AOT export: lower the L2 graphs to HLO **text** for the Rust runtime.

HLO text — not a serialized ``HloModuleProto`` — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. Lowering goes
through stablehlo → XlaComputation with ``return_tuple=True``; the Rust
side unwraps with ``to_tuple()``. (See /opt/xla-example/gen_hlo.py.)

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONLY here, at build time; the produced ``*.hlo.txt`` files
are self-contained.
"""

import argparse
import hashlib
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)  # u64 datapaths require x64

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str, batch: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, example_args) in model.ENTRY_POINTS.items():
        lowered = jax.jit(fn).lower(*example_args(batch))
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest[name] = {"path": path, "batch": batch, "sha256_16": digest, "chars": len(text)}
        print(f"wrote {path}: {len(text)} chars, batch={batch}, sha256[:16]={digest}")
    # A tiny manifest so the runtime can sanity-check batch sizes.
    with open(os.path.join(out_dir, "MANIFEST.txt"), "w") as f:
        for name, m in manifest.items():
            f.write(f"{name} batch={m['batch']} sha256_16={m['sha256_16']}\n")
    return manifest


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--batch", type=int, default=model.BATCH)
    args = p.parse_args()
    export_all(args.out_dir, args.batch)
    return 0


if __name__ == "__main__":
    sys.exit(main())
