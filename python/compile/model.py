"""L2 — the JAX verification graphs that get AOT-compiled for the Rust
runtime.

The FPMax "model" is not a neural network: the chip computes FMACs, so
the compute graph the coordinator needs is a **batched bit-exact FMAC
verifier** plus the activity statistics the energy model consumes:

* :func:`sp_fmac_batch` — SP: calls the L1 Pallas kernel
  (`kernels.fmac`), returns result bits and a toggle count (Hamming
  weight of result-stream transitions — the dynamic-power proxy).
* :func:`dp_fmac_batch` — DP: the two-limb jnp core (a 106-bit product
  does not fit a machine word; Pallas brings nothing at build time for
  pure element-wise u64-pair code).

Both lower to a single fused HLO module with no Python on the run
path; ``aot.py`` exports them as HLO text for `rust/src/runtime/`.
"""

import jax
import jax.numpy as jnp

from .kernels import bitfloat
from .kernels.fmac import sp_fmac_pallas

# The AOT batch size baked into the artifacts (the Rust runtime pads the
# tail block).
BATCH = 4096


def toggle_count(bits):
    """Total Hamming distance between consecutive results — the
    switching-activity proxy the coordinator feeds to the energy model
    (result-bus toggles track datapath activity to first order)."""
    x = bits.astype(jnp.uint64)
    trans = x[1:] ^ x[:-1]

    def popcount(v):
        m1 = jnp.uint64(0x5555555555555555)
        m2 = jnp.uint64(0x3333333333333333)
        m4 = jnp.uint64(0x0F0F0F0F0F0F0F0F)
        h01 = jnp.uint64(0x0101010101010101)
        v = v - ((v >> jnp.uint64(1)) & m1)
        v = (v & m2) + ((v >> jnp.uint64(2)) & m2)
        v = (v + (v >> jnp.uint64(4))) & m4
        return (v * h01) >> jnp.uint64(56)

    return popcount(trans).sum().astype(jnp.uint64)


def sp_fmac_batch(a_bits, b_bits, c_bits):
    """SP FMAC over a fixed batch: (results u32[N], toggles u64[])."""
    out = sp_fmac_pallas(a_bits, b_bits, c_bits)
    return out, toggle_count(out)


def dp_fmac_batch(a_bits, b_bits, c_bits):
    """DP FMAC over a fixed batch: (results u64[N], toggles u64[])."""
    out = bitfloat.dp_fmac_core(a_bits, b_bits, c_bits)
    return out, toggle_count(out)


def sp_example_args(batch=BATCH):
    spec = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    return (spec, spec, spec)


def dp_example_args(batch=BATCH):
    spec = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    return (spec, spec, spec)


#: The AOT export manifest: artifact name → (function, example-args fn).
ENTRY_POINTS = {
    "sp_fmac": (sp_fmac_batch, sp_example_args),
    "dp_fmac": (dp_fmac_batch, dp_example_args),
}
