#!/usr/bin/env python3
"""Bench-regression gate for CI.

Compares measured bench artifacts (``BENCH_engine.ci.json``,
``BENCH_serve.ci.json``) against the acceptance thresholds **embedded in
the JSON itself** (the ``thresholds`` object each bench writes), and
fails the job with a readable delta table when any budget is blown:

* engine: ``word-simd >= 2x scalar word`` per unit, windowed trace
  overhead ``< 2x`` untracked, zero sampled gate cross-check mismatches;
  on ``--features simd`` artifacts (``"simd_feature": true``)
  additionally ``simd_vector >= 2x scalar_lane`` on the FMA rows — the
  raw std::simd lane-kernel speedup (skipped on scalar builds, where the
  dispatching path *is* the scalar path and the rows are 0);
* serve: sustained (4 producers) ``>= 0.8x`` the plain windowed-tracked
  batch throughput, ``p99 <= 10x p50`` submission latency, zero
  cross-check mismatches, streamed BB bit-identical to post-hoc;
* routed fleet (``routed`` object in the serve artifact): fleet
  sustained ``>= 0.8x`` the best single shard, fleet ``p99 <= 10x p50``,
  zero misrouted submissions under the static policy, zero cross-check
  mismatches, and every shard's streamed BB bit-identical to its own
  post-hoc pass;
* routing parity (``routing`` object in the serve artifact): the
  energy-aware replay must sustain ``>= 0.99x`` static throughput on the
  uniform trace, re-derived from the raw per-arm numbers, with both
  arms' replay gates clean;
* chaos (``BENCH_chaos.ci.json``, from ``fpmax chaos``): the fault
  drill's hard gates, re-validated from the raw ledger rather than
  trusting the artifact's own ``gates`` verdicts — zero hung tickets,
  zero lost ops (completed + errored + hung == submitted at both the
  submission and the op ledger), zero cross-check mismatches on
  surviving work, every planned fault fired, fleet accounting conserved
  across shard incarnations, and at least one respawn per dispatcher
  kill. Chaos artifacts carry no ``thresholds`` object: the gates are
  absolute;
* formats (``BENCH_formats*.ci.json``, from ``fpmax fuzz --json``): the
  transprecision format-matrix gate — every (format × op kind × stream)
  differential run must report zero counterexamples on a non-empty op
  count, and the packed-SWAR FP16 FMA probe must beat the SP
  scalar-word baseline by the embedded ``min_packed_speedup`` threshold,
  with the speedup re-derived from the raw rates (the artifact carries
  no precomputed ratio to trust);
* routing (``BENCH_routing.ci.json``, from ``fpmax replay``): per-arm
  replay gates re-derived from the raw ledger (zero hung, ledger
  balanced, crosscheck clean, every fault fired, conservation exact,
  replay digest stable across the double run), and — when both policy
  arms are present — the dominance verdict re-derived from the raw
  throughput and pJ/op numbers against the artifact's embedded
  thresholds, cross-checked against the artifact's own
  ``dynamic_dominates`` claim;
* kernels (``BENCH_kernels*.ci.json``, from ``fpmax kernels --json``):
  the repeat-buffer sequencer gates, re-derived from the raw cycle/op
  counts — in-burst occupancy ``window_ops / window_cycles >=
  min_frep_occupancy``, issue-rate speedup ``unrolled cycles / repeat
  cycles >= min_frep_issue_speedup_vs_unrolled``, zero result-bank
  mismatches between the repeat and unrolled encodings — with the
  artifact's own occupancy/speedup claims cross-checked against the
  derivation rather than trusted.

Usage::

    python3 python/ci_check_bench.py BENCH_engine.ci.json BENCH_serve.ci.json BENCH_chaos.ci.json BENCH_routing.ci.json BENCH_kernels.ci.json

Exit status 0 iff every check passes. Artifacts with ``"measured":
false`` fail immediately — the gate only makes sense on freshly measured
numbers, which is exactly what the CI bench-smoke steps produce.
"""

from __future__ import annotations

import json
import sys
from dataclasses import dataclass


@dataclass
class Check:
    """One gated quantity: ``value`` must satisfy ``op`` vs ``bound``."""

    unit: str
    name: str
    value: float
    op: str  # ">=", "<=", "==", "is-true"
    bound: float

    @property
    def ok(self) -> bool:
        if self.op == ">=":
            return self.value >= self.bound
        if self.op == ">":
            return self.value > self.bound
        if self.op == "<=":
            return self.value <= self.bound
        if self.op == "==":
            return self.value == self.bound
        if self.op == "is-true":
            return bool(self.value)
        raise ValueError(f"unknown op {self.op!r}")

    @property
    def margin(self) -> str:
        if self.op == "is-true":
            return "-"
        if self.bound == 0:
            return f"{self.value - self.bound:+g}"
        return f"{(self.value / self.bound - 1.0) * 100.0:+.1f}%"


def engine_checks(doc: dict) -> list[Check]:
    t = doc["thresholds"]
    # The raw lane-kernel vectorization gate only exists on simd builds
    # (scalar builds dispatch to the scalar_ref path, so the comparison
    # degenerates to 1x and the bench writes 0 rows); it gates the FMA
    # hot path, the fully vectorized kernel.
    gate_vector = (doc.get("simd_feature", False)
                   and "min_speedup_simd_vector_vs_scalar_lane" in t)
    out = []
    for unit, row in doc["units"].items():
        out.append(
            Check(unit, "simd_word_vs_scalar_word",
                  row["speedup_simd_word_vs_scalar_word"], ">=",
                  t["min_speedup_simd_word_vs_scalar_word"]))
        if gate_vector and "FMA" in unit.upper():
            out.append(
                Check(unit, "simd_vector_vs_scalar_lane",
                      row["speedup_simd_vector_vs_scalar_lane"], ">=",
                      t["min_speedup_simd_vector_vs_scalar_lane"]))
        out.append(
            Check(unit, "trace_overhead_windowed",
                  row["trace_overhead_windowed_vs_untracked"], "<=",
                  t["max_trace_overhead_windowed_vs_untracked"]))
        out.append(
            Check(unit, "crosscheck_mismatches",
                  row["crosscheck_mismatches"] + row["simd_crosscheck_mismatches"],
                  "==", t["max_crosscheck_mismatches"]))
    # Packed-SWAR transprecision rows (PR 9 schema; absent on older
    # artifacts). The speedup is re-derived from the raw element rates
    # against the SP FMA scalar-word baseline, never read back.
    packed = doc.get("packed")
    min_packed = t.get("min_packed_speedup_fp16_fma_vs_sp_scalar_word")
    if packed and min_packed is not None:
        sp_fma = doc["units"].get("SP FMA", {})
        baseline = max(sp_fma.get("scalar_word_ops_per_s", 0.0), 1e-12)
        for unit, row in packed.items():
            speedup = row["packed_elems_per_s"] / baseline
            if unit == "fp16_fma":
                out.append(
                    Check(unit, "packed_vs_sp_scalar_word", speedup, ">=",
                          min_packed))
            else:
                out.append(
                    Check(unit, "packed_elems_per_s",
                          row["packed_elems_per_s"], ">", 0))
    return out


def formats_checks(doc: dict) -> list[Check]:
    """The ``fpmax fuzz --json`` artifact: transprecision conformance
    (zero counterexamples per run row, on a non-empty op count) plus the
    packed-SWAR speedup gate, re-derived from the raw element rates."""
    t = doc["thresholds"]
    out = []
    for row in doc["runs"]:
        unit = f"{row['format']}_{row['kind']}_{row['stream'].lower()}"
        out.append(Check(unit, "executed", row["executed"], ">", 0))
        out.append(
            Check(unit, "counterexamples", row["counterexamples"], "==",
                  t["max_counterexamples"]))
    min_speedup = t.get("min_packed_speedup_fp16_fma_vs_sp_scalar_word")
    for probe in doc.get("packed_probe", []):
        unit = f"{probe['format']}_{probe['kind']}_packed"
        baseline = max(probe["sp_scalar_word_ops_per_s"], 1e-12)
        speedup = probe["packed_elems_per_s"] / baseline
        if probe["format"] == "fp16" and probe["kind"] == "fma" \
                and min_speedup is not None:
            out.append(
                Check(unit, "packed_vs_sp_scalar_word", speedup, ">=",
                      min_speedup))
        else:
            # Informational floor: packed throughput must at least exist.
            out.append(
                Check(unit, "packed_elems_per_s",
                      probe["packed_elems_per_s"], ">", 0))
    return out


def serve_checks(doc: dict) -> list[Check]:
    t = doc["thresholds"]
    out = []
    for unit, row in doc["units"].items():
        out.append(
            Check(unit, "serve_vs_plain_windowed",
                  row["serve_vs_plain_windowed_ratio"], ">=",
                  t["min_serve_vs_plain_windowed_ratio"]))
        out.append(
            Check(unit, "p99_over_p50", row["p99_over_p50"], "<=",
                  t["max_p99_over_p50"]))
        out.append(
            Check(unit, "crosscheck_mismatches", row["crosscheck_mismatches"],
                  "==", t["max_crosscheck_mismatches"]))
        if t.get("require_bb_identity", False):
            out.append(
                Check(unit, "bb_schedule_match",
                      1.0 if row["bb_schedule_match"] else 0.0, "is-true", 1.0))
            out.append(
                Check(unit, "bb_energy_match",
                      1.0 if row["bb_energy_match"] else 0.0, "is-true", 1.0))
    routed = doc.get("routed")
    if routed is not None:
        out.append(
            Check("fleet", "routed_vs_best_shard",
                  routed["fleet_vs_best_shard_ratio"], ">=",
                  t.get("min_routed_vs_best_shard_ratio", 0.8)))
        out.append(
            Check("fleet", "fleet_p99_over_p50", routed["fleet_p99_over_p50"],
                  "<=", t.get("max_fleet_p99_over_p50", 10.0)))
        out.append(
            Check("fleet", "misrouted", routed["misrouted"], "==",
                  t.get("max_misrouted", 0)))
        out.append(
            Check("fleet", "crosscheck_mismatches",
                  routed["crosscheck_mismatches"], "==",
                  t["max_crosscheck_mismatches"]))
        if t.get("require_shard_bb_identity", False):
            out.append(
                Check("fleet", "all_shards_bb_identity",
                      1.0 if routed["all_shards_bb_identity"] else 0.0,
                      "is-true", 1.0))
    routing = doc.get("routing")
    if routing is not None:
        # Parity is re-derived from the raw per-arm numbers, never read
        # from the artifact's own ratio field.
        ratio = (routing["energy_aware"]["sustained_ops_per_s"]
                 / max(routing["static"]["sustained_ops_per_s"], 1e-12))
        out.append(
            Check("routing", "dynamic_vs_static_uniform", ratio, ">=",
                  t.get("min_dynamic_vs_static_uniform_ratio", 0.99)))
        for arm in ("static", "energy_aware"):
            out.append(
                Check("routing", f"{arm}_gates_ok",
                      1.0 if routing[arm]["gates_ok"] else 0.0,
                      "is-true", 1.0))
    return out


def chaos_checks(doc: dict) -> list[Check]:
    p = doc["producer"]
    faults = doc["faults"]
    fleet = doc["fleet"]
    gates = doc["gates"]
    out = [
        # Re-derive every gate from the raw ledger; the artifact's own
        # booleans are checked last so a disagreement shows up as two
        # failures, not a silently-trusted verdict.
        Check("producer", "hung_subs", p["hung_subs"], "==", 0),
        Check("producer", "hung_ops", p["hung_ops"], "==", 0),
        Check("producer", "sub_ledger_balance",
              p["completed_subs"] + p["errored_subs"] + p["hung_subs"]
              - p["submitted_subs"], "==", 0),
        Check("producer", "op_ledger_balance",
              p["completed_ops"] + p["errored_ops"] + p["hung_ops"]
              - p["submitted_ops"], "==", 0),
        Check("fleet", "crosscheck_mismatches",
              fleet["crosscheck_mismatches"], "==", 0),
        Check("faults", "coverage",
              faults["fired"] - faults["planned"], "==", 0),
        Check("fleet", "respawns_vs_kills",
              fleet["respawns"], ">=", faults["kills"]),
        Check("gates", "conservation_ok",
              1.0 if gates["conservation_ok"] else 0.0, "is-true", 1.0),
        Check("gates", "all",
              1.0 if gates["all"] else 0.0, "is-true", 1.0),
    ]
    return out


def routing_checks(doc: dict) -> list[Check]:
    """The ``fpmax replay`` artifact: per-arm replay gates re-derived
    from the raw ledger, plus the static-vs-dynamic dominance verdict
    recomputed from the raw throughput/energy numbers (the artifact's
    own ``dynamic_dominates`` claim is cross-checked, never trusted)."""
    t = doc["thresholds"]
    out = []
    arms = {arm["policy"]: arm for arm in doc["arms"]}
    for name, arm in arms.items():
        out.append(Check(name, "hung_subs", arm["hung_subs"], "==", 0))
        out.append(
            Check(name, "op_ledger_balance",
                  arm["completed_ops"] + arm["errored_ops"]
                  - arm["submitted_ops"], "==", 0))
        out.append(
            Check(name, "crosscheck_mismatches",
                  arm["crosscheck_mismatches"], "==", 0))
        out.append(
            Check(name, "fault_coverage",
                  arm["faults_fired"] - doc["faults_planned"], "==", 0))
        out.append(
            Check(name, "conservation_ok",
                  1.0 if arm["conservation_ok"] else 0.0, "is-true", 1.0))
        if doc.get("verify_determinism", False):
            out.append(
                Check(name, "digest_stable",
                      1.0 if arm["digest_stable"] else 0.0, "is-true", 1.0))
        out.append(
            Check(name, "gates_ok",
                  1.0 if arm["gates_ok"] else 0.0, "is-true", 1.0))
    static = arms.get("static")
    dynamic = arms.get("energy-aware")
    if static is not None and dynamic is not None:
        throughput_ratio = (dynamic["sustained_ops_per_s"]
                            / max(static["sustained_ops_per_s"], 1e-12))
        pj_ratio = (dynamic["fleet_pj_per_op"]
                    / max(static["fleet_pj_per_op"], 1e-12))
        out.append(
            Check("dominance", "throughput_ratio", throughput_ratio, ">",
                  t["min_throughput_ratio"]))
        out.append(
            Check("dominance", "pj_ratio", pj_ratio, "<=",
                  t["max_pj_ratio"]))
        derived = (throughput_ratio > t["min_throughput_ratio"]
                   and pj_ratio <= t["max_pj_ratio"])
        claimed = bool((doc.get("dominance") or {}).get("dynamic_dominates",
                                                        False))
        out.append(
            Check("dominance", "verdict_agrees",
                  1.0 if claimed == derived else 0.0, "is-true", 1.0))
    return out


def kernels_checks(doc: dict) -> list[Check]:
    """The ``fpmax kernels --json`` artifact: repeat-buffer kernel gates
    re-derived from the raw cycle/op counts. Occupancy is recomputed as
    ``window_ops / window_cycles`` and the speedup as ``unrolled.cycles
    / repeat.cycles``; the artifact's own ``occupancy_in_burst`` and
    ``issue_speedup`` claims are cross-checked against the derivation so
    a drifted emitter shows up as its own failure."""
    t = doc["thresholds"]
    out = []
    for row in doc["rows"]:
        unit = f"{row['kernel']}@{row['unit']}"
        rep = row["repeat"]
        occ = rep["window_ops"] / max(rep["window_cycles"], 1)
        speedup = row["unrolled"]["cycles"] / max(rep["cycles"], 1)
        out.append(Check(unit, "ops", row["ops"], ">", 0))
        out.append(
            Check(unit, "frep_occupancy", occ, ">=",
                  t["min_frep_occupancy"]))
        out.append(
            Check(unit, "frep_issue_speedup", speedup, ">=",
                  t["min_frep_issue_speedup_vs_unrolled"]))
        out.append(
            Check(unit, "result_mismatches", row["result_mismatches"],
                  "==", t.get("max_result_mismatches", 0)))
        out.append(
            Check(unit, "occupancy_claim_agrees",
                  1.0 if abs(occ - row["occupancy_in_burst"]) < 1e-4
                  else 0.0, "is-true", 1.0))
        out.append(
            Check(unit, "speedup_claim_agrees",
                  1.0 if abs(speedup - row["issue_speedup"]) < 1e-4
                  else 0.0, "is-true", 1.0))
    return out


CHECKERS = {
    "engine": engine_checks,
    "formats": formats_checks,
    "serve": serve_checks,
    "chaos": chaos_checks,
    "routing": routing_checks,
    "kernels": kernels_checks,
}

# Chaos gates are absolute (zero hung, zero lost, ...) — the artifact
# embeds no tunable thresholds object.
NEEDS_THRESHOLDS = {"engine", "formats", "serve", "routing", "kernels"}


def check_file(path: str) -> tuple[list[Check], list[str]]:
    """Returns (checks, errors) for one artifact."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    errors = []
    if not doc.get("measured", False):
        errors.append(
            f"{path}: \"measured\" is false — the gate needs a freshly "
            "measured artifact (run the bench first)")
        return [], errors
    bench = doc.get("bench")
    checker = CHECKERS.get(bench)
    if checker is None:
        errors.append(f"{path}: unknown bench kind {bench!r}")
        return [], errors
    if bench in NEEDS_THRESHOLDS and "thresholds" not in doc:
        errors.append(f"{path}: no embedded thresholds object")
        return [], errors
    return checker(doc), errors


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    failures = 0
    for path in argv:
        checks, errors = check_file(path)
        for e in errors:
            print(f"ERROR  {e}")
            failures += 1
        if not checks:
            continue
        print(f"\n== {path} ==")
        width = max(len(c.name) for c in checks)
        uwidth = max(len(c.unit) for c in checks)
        for c in checks:
            status = "PASS" if c.ok else "FAIL"
            if not c.ok:
                failures += 1
            print(f"  {status}  {c.unit:<{uwidth}}  {c.name:<{width}}  "
                  f"value {c.value:>10.4g}  budget {c.op} {c.bound:<8.4g}  "
                  f"margin {c.margin}")
    print()
    if failures:
        print(f"ci_check_bench: {failures} check(s) FAILED")
        return 1
    print("ci_check_bench: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
