//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate, carrying exactly the subset `fpmax` uses: a string-backed
//! [`Error`], the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the
//! defaulted [`Result`] alias.
//!
//! The build environment has no crates.io access, so this crate is
//! vendored in-tree and wired up with a path dependency. Like the real
//! `anyhow::Error`, this `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` conversion
//! below coherent.

use std::fmt;

/// A string-backed error value with optional context frames.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with a context line, outermost first (like `anyhow::Context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("value {x} and {}", x + 1);
        assert_eq!(e.to_string(), "value 3 and 4");
        assert_eq!(format!("{e:?}"), "value 3 and 4");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn from_std_error_and_context() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = io.into();
        assert_eq!(e.to_string(), "boom");
        assert_eq!(e.context("reading file").to_string(), "reading file: boom");
    }

    fn bails() -> Result<()> {
        bail!("bailed with {n}", n = 2);
    }

    #[test]
    fn bail_formats() {
        assert_eq!(bails().unwrap_err().to_string(), "bailed with 2");
    }
}
