//! Bench E6 — the unified execution engine: scalar vs batch vs fidelity
//! tiers on a 1M-triple stream, for all four Table I presets.
//!
//! This is the perf baseline behind the engine acceptance criterion
//! (`BatchExecutor` + `Fidelity::WordLevel` ≥ 5× the seed scalar
//! gate-level loop, with sampled gate-level cross-checks clean). Results
//! are written to `BENCH_engine.json` at the repository root (override
//! with `FPMAX_BENCH_OUT=path`), so future PRs have a perf trajectory.
//!
//! Run: `cargo bench --bench engine` (FPMAX_BENCH_FAST=1 for a smoke run).

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::util::bench::{black_box, header, BenchRunner};
use fpmax::workloads::throughput::{OperandMix, OperandStream};

struct UnitRow {
    name: String,
    scalar_gate: f64,
    batch_gate: f64,
    scalar_word: f64,
    batch_word: f64,
    crosscheck_sampled: usize,
    crosscheck_mismatches: usize,
}

impl UnitRow {
    fn speedup(&self) -> f64 {
        self.batch_word / self.scalar_gate
    }
}

fn main() {
    let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 50_000 } else { 1_000_000 };
    // Gate-level passes over 1M ops are expensive; a few samples give a
    // stable median without an hour-long run.
    let runner = BenchRunner { samples: if fast { 2 } else { 3 }, warmup_iters: 1, iters_per_sample: 1 };
    let exec = BatchExecutor::auto();

    header(&format!(
        "execution engine — {n} ops/unit, {} workers",
        exec.workers()
    ));

    let mut rows = Vec::new();
    for cfg in FpuConfig::fpmax_units() {
        let unit = FpuUnit::generate(&cfg);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        let triples = OperandStream::new(cfg.precision, OperandMix::Finite, 42).batch(n);

        // The seed baseline: one scalar gate-level op at a time.
        let scalar_gate = runner
            .run(&format!("engine/{}/scalar_gate", cfg.name()), Some(n as f64), || {
                let mut acc = 0u64;
                for t in &triples {
                    acc ^= unit.fmac(t.a, t.b, t.c).bits;
                }
                black_box(acc);
            })
            .throughput()
            .unwrap();

        let batch_gate = runner
            .run(&format!("engine/{}/batch_gate", cfg.name()), Some(n as f64), || {
                black_box(exec.run(&unit, &triples));
            })
            .throughput()
            .unwrap();

        let scalar_word = runner
            .run(&format!("engine/{}/scalar_word", cfg.name()), Some(n as f64), || {
                let mut acc = 0u64;
                for t in &triples {
                    acc ^= word.fmac_one(t.a, t.b, t.c);
                }
                black_box(acc);
            })
            .throughput()
            .unwrap();

        let batch_word = runner
            .run(&format!("engine/{}/batch_word", cfg.name()), Some(n as f64), || {
                black_box(exec.run(&word, &triples));
            })
            .throughput()
            .unwrap();

        // One checked pass (not timed separately: the sampling cost is the
        // point being recorded).
        let (_, check) = exec.run_checked(&unit, &triples, 997);
        assert!(
            check.clean(),
            "{}: word-level diverged from gate-level at {:?}",
            cfg.name(),
            check.mismatches
        );

        rows.push(UnitRow {
            name: cfg.name(),
            scalar_gate,
            batch_gate,
            scalar_word,
            batch_word,
            crosscheck_sampled: check.sampled,
            crosscheck_mismatches: check.mismatches.len(),
        });
    }

    println!();
    for r in &rows {
        println!(
            "{:<7}  scalar-gate {:>8.2} Mops/s  batch-gate {:>8.2}  scalar-word {:>8.2}  batch-word {:>8.2}  → {:.1}× (crosscheck {}/{} clean)",
            r.name,
            r.scalar_gate / 1e6,
            r.batch_gate / 1e6,
            r.scalar_word / 1e6,
            r.batch_word / 1e6,
            r.speedup(),
            r.crosscheck_sampled - r.crosscheck_mismatches,
            r.crosscheck_sampled,
        );
    }

    let out_path = std::env::var("FPMAX_BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_engine.json", env!("CARGO_MANIFEST_DIR")));
    let json = render_json(n, exec.workers(), &rows);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => println!("\ncould not write {out_path}: {e}"),
    }
}

/// Hand-rolled JSON (no serde offline): stable key order, one unit per
/// entry.
fn render_json(ops: usize, workers: usize, rows: &[UnitRow]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"engine\",\n");
    s.push_str("  \"measured\": true,\n");
    s.push_str(&format!("  \"ops_per_unit\": {ops},\n"));
    s.push_str(&format!("  \"workers\": {workers},\n"));
    s.push_str("  \"units\": {\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("    \"{}\": {{\n", r.name));
        s.push_str(&format!("      \"scalar_gate_ops_per_s\": {:.0},\n", r.scalar_gate));
        s.push_str(&format!("      \"batch_gate_ops_per_s\": {:.0},\n", r.batch_gate));
        s.push_str(&format!("      \"scalar_word_ops_per_s\": {:.0},\n", r.scalar_word));
        s.push_str(&format!("      \"batch_word_ops_per_s\": {:.0},\n", r.batch_word));
        s.push_str(&format!(
            "      \"speedup_batch_word_vs_scalar_gate\": {:.2},\n",
            r.speedup()
        ));
        s.push_str(&format!("      \"crosscheck_sampled\": {},\n", r.crosscheck_sampled));
        s.push_str(&format!(
            "      \"crosscheck_mismatches\": {}\n",
            r.crosscheck_mismatches
        ));
        s.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    s.push_str("  }\n}\n");
    s
}
