//! Bench E6 — the unified execution engine: scalar vs batch vs fidelity
//! tiers on a 1M-triple stream, for all four Table I presets.
//!
//! This is the perf baseline behind the engine acceptance criteria:
//!
//! * `BatchExecutor` + `Fidelity::WordLevel` ≥ 5× the seed scalar
//!   gate-level loop (PR 1), and
//! * `Fidelity::WordSimd` (the lane-batched SoA kernels) ≥ 2× the scalar
//!   word-level loop on the FMAC burst workload (PR 2) — measured
//!   single-threaded so the lane-kernel speedup is isolated from thread
//!   parallelism — with **zero** sampled gate-level cross-check
//!   mismatches on both word tiers.
//!
//! Results are written to `BENCH_engine.json` at the repository root
//! (override with `FPMAX_BENCH_OUT=path`), so future PRs have a perf
//! trajectory. All runs reuse one preallocated output buffer through the
//! `run_into` path — what steady-state serving does.
//!
//! Run: `cargo bench --bench engine` (FPMAX_BENCH_FAST=1 for a smoke run).

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::fp::{Format, Precision};
use fpmax::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use fpmax::arch::softfloat::lanes;
use fpmax::chip::UnitSel;
use fpmax::report::kernels::{run_suite, KernelRow};
use fpmax::util::bench::{black_box, header, BenchRunner};
use fpmax::workloads::throughput::{OperandMix, OperandStream, OperandTriple};

struct UnitRow {
    name: String,
    scalar_gate: f64,
    batch_gate: f64,
    scalar_word: f64,
    batch_word: f64,
    simd_word_serial: f64,
    scalar_lane_serial: f64,
    simd_vector_serial: f64,
    batch_word_simd: f64,
    windowed_word_simd: f64,
    crosscheck_sampled: usize,
    crosscheck_mismatches: usize,
    simd_crosscheck_sampled: usize,
    simd_crosscheck_mismatches: usize,
}

impl UnitRow {
    /// Whole-engine speedup: parallel word tier vs the seed scalar
    /// gate-level loop.
    fn speedup(&self) -> f64 {
        self.batch_word / self.scalar_gate
    }

    /// Lane-kernel speedup in isolation: single-thread SIMD word tier vs
    /// the single-thread scalar word loop (the PR 2 acceptance number).
    fn simd_speedup(&self) -> f64 {
        self.simd_word_serial / self.scalar_word
    }

    /// Raw lane-kernel vectorization speedup: the dispatching blocks
    /// (`std::simd` stages under `--features simd`) vs the always-scalar
    /// `scalar_ref` SoA blocks, both single-threaded over full blocks —
    /// the std::simd acceptance number. 0.0 when the feature is off
    /// (the dispatching path IS the scalar path then, so there is
    /// nothing to compare).
    fn simd_vector_speedup(&self) -> f64 {
        if self.simd_vector_serial > 0.0 && self.scalar_lane_serial > 0.0 {
            self.simd_vector_serial / self.scalar_lane_serial
        } else {
            0.0
        }
    }

    /// Cost of time-resolved tracing: windowed-tracked word-simd run vs
    /// the untracked batch (×; 1.0 = free, target < 1.05 on toolchain
    /// hosts — the CI smoke gate enforces < 2× via `verify --bb`).
    fn trace_overhead(&self) -> f64 {
        self.batch_word_simd / self.windowed_word_simd
    }
}

/// Trace window width the windowed rows use (ops per window).
const TRACE_WINDOW_OPS: usize = 4096;

/// Trace window (slots) and seed for the repeat-buffer kernel rows.
const KERNEL_WINDOW_SLOTS: u64 = 256;
const KERNEL_SEED: u64 = 42;

/// One packed-SWAR row: a small format's FMA/CMA element throughput
/// through the `lanes::packed` word entry point next to the dispatching
/// SoA lane blocks on the same operand population.
struct PackedRow {
    /// Canonical format name (`fp16`, `bf16`, `fp8e4m3`, `fp8e5m2`).
    format: &'static str,
    /// `fma` or `cma`.
    kind: &'static str,
    elems_per_word: usize,
    packed_elems_per_s: f64,
    lane_soa_elems_per_s: f64,
}

fn main() {
    let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 50_000 } else { 1_000_000 };
    // Gate-level passes over 1M ops are expensive; a few samples give a
    // stable median without an hour-long run.
    let runner = BenchRunner { samples: if fast { 2 } else { 3 }, warmup_iters: 1, iters_per_sample: 1 };
    let exec = BatchExecutor::auto();
    let serial = BatchExecutor::serial();

    header(&format!(
        "execution engine — {n} ops/unit, {} workers",
        exec.workers()
    ));

    let mut rows = Vec::new();
    for cfg in FpuConfig::fpmax_units() {
        let unit = FpuUnit::generate(&cfg);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
        let triples = OperandStream::new(cfg.precision, OperandMix::Finite, 42).batch(n);
        let mut out = vec![0u64; n];

        // The seed baseline: one scalar gate-level op at a time.
        let scalar_gate = runner
            .run(&format!("engine/{}/scalar_gate", cfg.name()), Some(n as f64), || {
                let mut acc = 0u64;
                for t in &triples {
                    acc ^= unit.fmac(t.a, t.b, t.c).bits;
                }
                black_box(acc);
            })
            .throughput()
            .unwrap();

        // Per-op cost differs ~10× between tiers: drop the persisted
        // chunk calibration before each tier so every measurement runs
        // with a chunk size tuned to its own datapath.
        exec.recalibrate();
        let batch_gate = runner
            .run(&format!("engine/{}/batch_gate", cfg.name()), Some(n as f64), || {
                exec.run_into(&unit, &triples, &mut out).unwrap();
                black_box(out[0]);
            })
            .throughput()
            .unwrap();

        let scalar_word = runner
            .run(&format!("engine/{}/scalar_word", cfg.name()), Some(n as f64), || {
                let mut acc = 0u64;
                for t in &triples {
                    acc ^= word.fmac_one(t.a, t.b, t.c);
                }
                black_box(acc);
            })
            .throughput()
            .unwrap();

        exec.recalibrate();
        let batch_word = runner
            .run(&format!("engine/{}/batch_word", cfg.name()), Some(n as f64), || {
                exec.run_into(&word, &triples, &mut out).unwrap();
                black_box(out[0]);
            })
            .throughput()
            .unwrap();

        // Scalar-word vs SIMD-word, side by side on the same thread: the
        // committed lane-kernel speedup.
        let simd_word_serial = runner
            .run(&format!("engine/{}/simd_word_serial", cfg.name()), Some(n as f64), || {
                serial.run_into(&simd, &triples, &mut out).unwrap();
                black_box(out[0]);
            })
            .throughput()
            .unwrap();

        // Raw lane-kernel blocks, no executor: the always-scalar
        // `scalar_ref` SoA baseline vs the dispatching blocks (vector
        // stages under --features simd). Same block loop on both sides
        // so the delta is the kernel body alone.
        let fmt = unit.format;
        let scalar_lane_serial = runner
            .run(&format!("engine/{}/scalar_lane_serial", cfg.name()), Some(n as f64), || {
                lane_block_pass(cfg.kind, fmt, &triples, &mut out, false);
                black_box(out[0]);
            })
            .throughput()
            .unwrap();
        let simd_vector_serial = if cfg!(feature = "simd") {
            runner
                .run(&format!("engine/{}/simd_vector_serial", cfg.name()), Some(n as f64), || {
                    lane_block_pass(cfg.kind, fmt, &triples, &mut out, true);
                    black_box(out[0]);
                })
                .throughput()
                .unwrap()
        } else {
            0.0
        };

        exec.recalibrate();
        let batch_word_simd = runner
            .run(&format!("engine/{}/batch_word_simd", cfg.name()), Some(n as f64), || {
                exec.run_into(&simd, &triples, &mut out).unwrap();
                black_box(out[0]);
            })
            .throughput()
            .unwrap();

        // Time-resolved tracing cost: the windowed-tracked run against
        // the untracked batch above (same tier, same chunk calibration).
        let windowed_word_simd = runner
            .run(&format!("engine/{}/windowed_word_simd", cfg.name()), Some(n as f64), || {
                let trace = exec
                    .run_windowed_into(&simd, &triples, &mut out, TRACE_WINDOW_OPS)
                    .unwrap();
                black_box(trace.len());
                black_box(out[0]);
            })
            .throughput()
            .unwrap();
        exec.recalibrate();

        // One checked pass per word tier (not timed separately: the
        // sampling cost is the point being recorded). A single mismatch
        // is a hard failure — this is what the CI bench-smoke step
        // enforces.
        let (_, check) = exec.run_checked(&unit, &triples, 997);
        assert!(
            check.clean(),
            "{}: word-level diverged from gate-level at {:?}",
            cfg.name(),
            check.mismatches
        );
        let (_, simd_check) = exec.run_checked_tier(&unit, Fidelity::WordSimd, &triples, 997);
        assert!(
            simd_check.clean(),
            "{}: word-simd diverged from gate-level at {:?}",
            cfg.name(),
            simd_check.mismatches
        );

        rows.push(UnitRow {
            name: cfg.name(),
            scalar_gate,
            batch_gate,
            scalar_word,
            batch_word,
            simd_word_serial,
            scalar_lane_serial,
            simd_vector_serial,
            batch_word_simd,
            windowed_word_simd,
            crosscheck_sampled: check.sampled,
            crosscheck_mismatches: check.mismatches.len(),
            simd_crosscheck_sampled: simd_check.sampled,
            simd_crosscheck_mismatches: simd_check.mismatches.len(),
        });
    }

    // Packed-SWAR tier: the small transprecision formats through the
    // `lanes::packed` 32-bit word entry point (2×FP16/BF16 or 4×FP8 per
    // word) vs the dispatching SoA lane blocks on the same operands.
    // Element counts are what is compared — a packed pass covers
    // `elems_per_word`× more values per word than the scalar tiers.
    let mut packed_rows = Vec::new();
    for precision in
        [Precision::Half, Precision::Bfloat16, Precision::Fp8E4M3, Precision::Fp8E5M2]
    {
        let fmt = precision.format();
        let epw = lanes::packed::elems_per_word(fmt);
        let words = n / epw;
        let elems = words * epw;
        let triples = OperandStream::new(precision, OperandMix::Finite, 42).batch(elems);
        let mut buf = vec![0u64; epw];
        let (mut aw, mut bw, mut cw) =
            (Vec::with_capacity(words), Vec::with_capacity(words), Vec::with_capacity(words));
        for ch in triples.chunks(epw) {
            for (sel, dst) in [(0usize, &mut aw), (1, &mut bw), (2, &mut cw)] {
                for (i, t) in ch.iter().enumerate() {
                    buf[i] = match sel {
                        0 => t.a,
                        1 => t.b,
                        _ => t.c,
                    };
                }
                dst.push(lanes::packed::pack_word(fmt, &buf));
            }
        }
        let mut ow = vec![0u32; words];
        let mut soa_out = vec![0u64; elems];
        for kind in [FpuKind::Fma, FpuKind::Cma] {
            let kind_name = if kind == FpuKind::Fma { "fma" } else { "cma" };
            let packed_rate = runner
                .run(
                    &format!("engine/packed/{}_{kind_name}", precision.name()),
                    Some(elems as f64),
                    || {
                        match kind {
                            FpuKind::Fma => lanes::packed::fma_words(fmt, &aw, &bw, &cw, &mut ow),
                            FpuKind::Cma => lanes::packed::cma_words(fmt, &aw, &bw, &cw, &mut ow),
                        }
                        black_box(ow[0]);
                    },
                )
                .throughput()
                .unwrap();
            let lane_rate = runner
                .run(
                    &format!("engine/lane_soa/{}_{kind_name}", precision.name()),
                    Some(elems as f64),
                    || {
                        lane_block_pass(kind, fmt, &triples, &mut soa_out, true);
                        black_box(soa_out[0]);
                    },
                )
                .throughput()
                .unwrap();
            packed_rows.push(PackedRow {
                format: precision.name(),
                kind: kind_name,
                elems_per_word: epw,
                packed_elems_per_s: packed_rate,
                lane_soa_elems_per_s: lane_rate,
            });
        }
    }

    println!();
    for r in &rows {
        println!(
            "{:<7}  scalar-gate {:>8.2} Mops/s  batch-gate {:>8.2}  scalar-word {:>8.2}  simd-word {:>8.2} ({:.2}× lane)  lane-scalar {:>8.2}  lane-vector {:>8.2} ({:.2}× vec)  batch-word {:>8.2}  batch-simd {:>8.2}  windowed-simd {:>8.2} ({:.2}× trace cost)  → {:.1}× (crosschecks {}/{} and {}/{} clean)",
            r.name,
            r.scalar_gate / 1e6,
            r.batch_gate / 1e6,
            r.scalar_word / 1e6,
            r.simd_word_serial / 1e6,
            r.simd_speedup(),
            r.scalar_lane_serial / 1e6,
            r.simd_vector_serial / 1e6,
            r.simd_vector_speedup(),
            r.batch_word / 1e6,
            r.batch_word_simd / 1e6,
            r.windowed_word_simd / 1e6,
            r.trace_overhead(),
            r.speedup(),
            r.crosscheck_sampled - r.crosscheck_mismatches,
            r.crosscheck_sampled,
            r.simd_crosscheck_sampled - r.simd_crosscheck_mismatches,
            r.simd_crosscheck_sampled,
        );
    }

    let sp_scalar_word = rows
        .iter()
        .find(|r| r.name == "SP FMA")
        .map(|r| r.scalar_word)
        .unwrap_or(0.0);
    println!();
    for p in &packed_rows {
        println!(
            "packed {}_{}  {} elems/word  packed {:>8.2} Melems/s  lane-soa {:>8.2} Melems/s  ({:.2}× SP scalar-word)",
            p.format,
            p.kind,
            p.elems_per_word,
            p.packed_elems_per_s / 1e6,
            p.lane_soa_elems_per_s / 1e6,
            if sp_scalar_word > 0.0 { p.packed_elems_per_s / sp_scalar_word } else { 0.0 },
        );
    }

    // Repeat-buffer kernel rows: the default suite (GEMM tile, stencil,
    // dot chains) through the chip sequencer on every unit preset, both
    // encodings bit-diffed. Cycle-accounted, not wall-clocked — no
    // fast/full split needed.
    let kernel_rows =
        run_suite(&UnitSel::ALL, KERNEL_SEED, KERNEL_WINDOW_SLOTS).expect("kernel suite");
    println!();
    for k in &kernel_rows {
        assert_eq!(
            k.result_mismatches, 0,
            "{} on {}: repeat-buffer encoding diverged from unrolled issue",
            k.kernel,
            k.unit.name()
        );
        println!(
            "kernel {:<12} {:<6}  {:>6} ops  repeat {:>6} cyc  unrolled {:>6} cyc  occ(burst) {:.3}  {:.2}× issue",
            k.kernel,
            k.unit.name(),
            k.ops,
            k.repeat_cycles,
            k.unrolled_cycles,
            k.occupancy_in_burst,
            k.issue_speedup,
        );
    }

    let out_path = std::env::var("FPMAX_BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_engine.json", env!("CARGO_MANIFEST_DIR")));
    let json = render_json(n, exec.workers(), &rows, &packed_rows, &kernel_rows);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => println!("\ncould not write {out_path}: {e}"),
    }
}

/// One full pass over `triples` through the lane-kernel blocks
/// (FMA-kind units take the fused block, CMA-kind the cascade block),
/// `vector: true` → the dispatching blocks (std::simd stages when the
/// feature is on), `false` → the always-compiled `scalar_ref` SoA
/// blocks. The scalar remainder (< LANES triples) goes through the
/// scalar_ref block padded with zeros, matching what `WordSimdUnit`
/// does internally.
fn lane_block_pass(
    kind: FpuKind,
    fmt: Format,
    triples: &[OperandTriple],
    out: &mut [u64],
    vector: bool,
) {
    let mut av = [0u64; lanes::LANES];
    let mut bv = [0u64; lanes::LANES];
    let mut cv = [0u64; lanes::LANES];
    let mut rv = [0u64; lanes::LANES];
    for (block, dst) in triples.chunks(lanes::LANES).zip(out.chunks_mut(lanes::LANES)) {
        for (i, t) in block.iter().enumerate() {
            av[i] = t.a;
            bv[i] = t.b;
            cv[i] = t.c;
        }
        for i in block.len()..lanes::LANES {
            av[i] = 0;
            bv[i] = 0;
            cv[i] = 0;
        }
        match (kind, vector) {
            (FpuKind::Fma, true) => lanes::fma_block_rne(fmt, &av, &bv, &cv, &mut rv),
            (FpuKind::Fma, false) => {
                lanes::scalar_ref::fma_block_rne(fmt, &av, &bv, &cv, &mut rv)
            }
            (FpuKind::Cma, true) => lanes::cma_block_rne(fmt, &av, &bv, &cv, &mut rv),
            (FpuKind::Cma, false) => {
                lanes::scalar_ref::cma_block_rne(fmt, &av, &bv, &cv, &mut rv)
            }
        }
        dst.copy_from_slice(&rv[..block.len()]);
    }
}

/// Hand-rolled JSON (no serde offline): stable key order, one unit per
/// entry.
fn render_json(
    ops: usize,
    workers: usize,
    rows: &[UnitRow],
    packed_rows: &[PackedRow],
    kernel_rows: &[KernelRow],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"engine\",\n");
    s.push_str("  \"measured\": true,\n");
    s.push_str(&format!("  \"ops_per_unit\": {ops},\n"));
    s.push_str(&format!("  \"workers\": {workers},\n"));
    s.push_str(&format!("  \"trace_window_ops\": {TRACE_WINDOW_OPS},\n"));
    s.push_str(&format!("  \"simd_feature\": {},\n", cfg!(feature = "simd")));
    // Budgets the CI regression gate (python/ci_check_bench.py) enforces
    // against every unit row of this artifact. The simd_vector threshold
    // only applies to FMA rows of simd_feature builds (the FMA hot path
    // is the fully vectorized one; the checker skips it otherwise).
    s.push_str("  \"thresholds\": {\n");
    s.push_str("    \"min_speedup_simd_word_vs_scalar_word\": 2.0,\n");
    s.push_str("    \"min_speedup_simd_vector_vs_scalar_lane\": 2.0,\n");
    s.push_str("    \"max_trace_overhead_windowed_vs_untracked\": 2.0,\n");
    s.push_str("    \"max_crosscheck_mismatches\": 0,\n");
    s.push_str("    \"min_packed_speedup_fp16_fma_vs_sp_scalar_word\": 1.5,\n");
    s.push_str("    \"min_frep_occupancy\": 0.9,\n");
    s.push_str("    \"min_frep_issue_speedup_vs_unrolled\": 1.5\n");
    s.push_str("  },\n");
    s.push_str("  \"units\": {\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("    \"{}\": {{\n", r.name));
        s.push_str(&format!("      \"scalar_gate_ops_per_s\": {:.0},\n", r.scalar_gate));
        s.push_str(&format!("      \"batch_gate_ops_per_s\": {:.0},\n", r.batch_gate));
        s.push_str(&format!("      \"scalar_word_ops_per_s\": {:.0},\n", r.scalar_word));
        s.push_str(&format!("      \"batch_word_ops_per_s\": {:.0},\n", r.batch_word));
        s.push_str(&format!(
            "      \"simd_word_serial_ops_per_s\": {:.0},\n",
            r.simd_word_serial
        ));
        s.push_str(&format!(
            "      \"scalar_lane_serial_ops_per_s\": {:.0},\n",
            r.scalar_lane_serial
        ));
        s.push_str(&format!(
            "      \"simd_vector_serial_ops_per_s\": {:.0},\n",
            r.simd_vector_serial
        ));
        s.push_str(&format!(
            "      \"batch_word_simd_ops_per_s\": {:.0},\n",
            r.batch_word_simd
        ));
        s.push_str(&format!(
            "      \"windowed_word_simd_ops_per_s\": {:.0},\n",
            r.windowed_word_simd
        ));
        s.push_str(&format!(
            "      \"trace_overhead_windowed_vs_untracked\": {:.2},\n",
            r.trace_overhead()
        ));
        s.push_str(&format!(
            "      \"speedup_batch_word_vs_scalar_gate\": {:.2},\n",
            r.speedup()
        ));
        s.push_str(&format!(
            "      \"speedup_simd_word_vs_scalar_word\": {:.2},\n",
            r.simd_speedup()
        ));
        s.push_str(&format!(
            "      \"speedup_simd_vector_vs_scalar_lane\": {:.2},\n",
            r.simd_vector_speedup()
        ));
        s.push_str(&format!("      \"crosscheck_sampled\": {},\n", r.crosscheck_sampled));
        s.push_str(&format!(
            "      \"crosscheck_mismatches\": {},\n",
            r.crosscheck_mismatches
        ));
        s.push_str(&format!(
            "      \"simd_crosscheck_sampled\": {},\n",
            r.simd_crosscheck_sampled
        ));
        s.push_str(&format!(
            "      \"simd_crosscheck_mismatches\": {}\n",
            r.simd_crosscheck_mismatches
        ));
        s.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    s.push_str("  },\n");
    let sp_scalar_word = rows
        .iter()
        .find(|r| r.name == "SP FMA")
        .map(|r| r.scalar_word)
        .unwrap_or(0.0);
    s.push_str("  \"packed\": {\n");
    for (i, p) in packed_rows.iter().enumerate() {
        s.push_str(&format!("    \"{}_{}\": {{\n", p.format, p.kind));
        s.push_str(&format!("      \"elems_per_word\": {},\n", p.elems_per_word));
        s.push_str(&format!(
            "      \"packed_elems_per_s\": {:.0},\n",
            p.packed_elems_per_s
        ));
        s.push_str(&format!(
            "      \"lane_soa_elems_per_s\": {:.0},\n",
            p.lane_soa_elems_per_s
        ));
        s.push_str(&format!(
            "      \"speedup_packed_vs_sp_scalar_word\": {:.2}\n",
            if sp_scalar_word > 0.0 { p.packed_elems_per_s / sp_scalar_word } else { 0.0 }
        ));
        s.push_str(if i + 1 == packed_rows.len() { "    }\n" } else { "    },\n" });
    }
    s.push_str("  },\n");
    // Repeat-buffer kernel rows, same shape as the `fpmax kernels --json`
    // artifact so python/ci_check_bench.py's kernels checker (and a
    // human) can re-derive occupancy/speedup from the raw counts.
    s.push_str("  \"kernels\": {\n");
    s.push_str(&format!("    \"window_slots\": {KERNEL_WINDOW_SLOTS},\n"));
    s.push_str("    \"rows\": [\n");
    for (i, k) in kernel_rows.iter().enumerate() {
        s.push_str("      {\n");
        s.push_str(&format!("        \"kernel\": \"{}\",\n", k.kernel));
        s.push_str(&format!("        \"unit\": \"{}\",\n", k.unit.name()));
        s.push_str(&format!("        \"ops\": {},\n", k.ops));
        s.push_str(&format!(
            "        \"repeat\": {{ \"cycles\": {}, \"window_ops\": {}, \"window_cycles\": {} }},\n",
            k.repeat_cycles, k.window_ops, k.window_cycles
        ));
        s.push_str(&format!(
            "        \"unrolled\": {{ \"cycles\": {} }},\n",
            k.unrolled_cycles
        ));
        s.push_str(&format!(
            "        \"result_mismatches\": {},\n",
            k.result_mismatches
        ));
        s.push_str(&format!(
            "        \"occupancy_in_burst\": {:.6},\n",
            k.occupancy_in_burst
        ));
        s.push_str(&format!("        \"issue_speedup\": {:.6},\n", k.issue_speedup));
        s.push_str(&format!(
            "        \"pj_per_op_repeat\": {:.4},\n",
            k.pj_per_op_repeat
        ));
        s.push_str(&format!(
            "        \"pj_per_op_unrolled\": {:.4}\n",
            k.pj_per_op_unrolled
        ));
        s.push_str(if i + 1 == kernel_rows.len() { "      }\n" } else { "      },\n" });
    }
    s.push_str("    ]\n");
    s.push_str("  }\n}\n");
    s
}
