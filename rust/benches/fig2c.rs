//! Bench E1 — regenerates **Fig. 2(c)**: average latency penalty of the
//! DP CMA (with internal before-rounding bypasses) vs a 5-cycle FMA with
//! and without unrounded-result forwarding, over the SPEC-FP-like suite.
//!
//! Paper: CMA is 37% / 57% better. Run: `cargo bench --bench fig2c`.

use fpmax::report::fig2c;
use fpmax::util::bench::{header, BenchRunner};

fn main() {
    header("Fig 2(c) — average latency penalty");
    let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
    let ops = if fast { 5_000 } else { 100_000 };
    let f = fig2c::compute(ops, 42);
    fig2c::print(&f);

    // Seed robustness: the reductions must hold across trace seeds.
    println!("\nseed sweep (reduction vs FMA w/ fwd, w/o fwd):");
    for seed in [1u64, 7, 13, 99] {
        let g = fig2c::compute(ops / 2, seed);
        println!(
            "  seed {seed:>3}: {:.1}% / {:.1}%",
            g.reduction_vs_fwd * 100.0,
            g.reduction_vs_nofwd * 100.0
        );
    }

    let runner = BenchRunner::from_env();
    runner.run("fig2c/suite_simulation", Some((ops * 8 * 3) as f64), || {
        let f = fig2c::compute(ops, 42);
        assert!(f.reduction_vs_fwd > 0.0);
    });
}
