//! Ablation benches — the design choices DESIGN.md calls out, isolated:
//!
//! * Booth radix: 2 vs 3 (PP count, tree cells, energy, delay);
//! * reduction tree: Wallace vs ZM vs array at fixed radix;
//! * pipeline depth: stages vs frequency vs register energy;
//! * internal forwarding: on vs off for each unit (latency penalty);
//! * design-style κ: what each unit would do under the other sizing;
//! * execution engine: scalar vs batch execution at both fidelity tiers.
//!
//! Run: `cargo bench --bench ablation`.

use std::time::Instant;

use fpmax::arch::booth::BoothRadix;
use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::arch::tree::TreeKind;
use fpmax::energy::components::unit_cost;
use fpmax::energy::power::evaluate;
use fpmax::energy::tech::Technology;
use fpmax::pipesim::{simulate, LatencyModel};
use fpmax::report::TextTable;
use fpmax::timing::{nominal_op, timing};
use fpmax::workloads::specfp::Profile;

fn eval_row(cfg: &FpuConfig) -> (f64, f64, f64, f64) {
    let tech = Technology::fdsoi28();
    let unit = FpuUnit::generate(cfg);
    let cost = unit_cost(&unit);
    let op = nominal_op(cfg);
    let t = timing(cfg, &tech, op).unwrap();
    let eff = evaluate(&unit, &tech, op, 1.0).unwrap();
    (cost.area_mm2, t.freq_ghz, eff.pj_per_flop, eff.gflops_per_mm2)
}

fn main() {
    println!("\n=== ablation: Booth radix (SP FMA baseline) ===\n");
    let mut t = TextTable::new(vec!["booth", "PPs", "area mm²", "f GHz", "pJ/FLOP", "GFLOPS/mm²"]);
    for booth in [BoothRadix::Booth2, BoothRadix::Booth3] {
        let mut cfg = FpuConfig::sp_fma();
        cfg.booth = booth;
        let (a, f, e, g) = eval_row(&cfg);
        t.row(vec![
            booth.name().to_string(),
            cfg.multiplier().pp_count().to_string(),
            format!("{a:.4}"),
            format!("{f:.2}"),
            format!("{e:.2}"),
            format!("{g:.0}"),
        ]);
    }
    t.print();

    println!("\n=== ablation: reduction tree (DP FMA baseline) ===\n");
    let mut t = TextTable::new(vec!["tree", "levels", "area mm²", "f GHz", "pJ/FLOP", "GFLOPS/mm²"]);
    for tree in [TreeKind::Wallace, TreeKind::Zm, TreeKind::Array] {
        let mut cfg = FpuConfig::dp_fma();
        cfg.tree = tree;
        let (a, f, e, g) = eval_row(&cfg);
        t.row(vec![
            tree.name().to_string(),
            cfg.multiplier().tree_depth().to_string(),
            format!("{a:.4}"),
            format!("{f:.2}"),
            format!("{e:.2}"),
            format!("{g:.0}"),
        ]);
    }
    t.print();

    println!("\n=== ablation: pipeline depth (SP FMA) ===\n");
    let mut t = TextTable::new(vec!["stages", "f GHz", "pJ/FLOP", "GFLOPS/mm²", "reg bits"]);
    for stages in 3..=8 {
        let mut cfg = FpuConfig::sp_fma();
        cfg.stages = stages;
        cfg.mul_pipe = (stages / 2).max(1);
        if cfg.validate().is_err() {
            continue;
        }
        let unit = FpuUnit::generate(&cfg);
        let (_, f, e, g) = eval_row(&cfg);
        t.row(vec![
            stages.to_string(),
            format!("{f:.2}"),
            format!("{e:.2}"),
            format!("{g:.0}"),
            unit.structure().register_bits.to_string(),
        ]);
    }
    t.print();

    println!("\n=== ablation: internal forwarding (latency penalty, SPEC suite) ===\n");
    let mut t = TextTable::new(vec!["unit", "fwd on", "fwd off", "saving"]);
    for mk in [FpuConfig::dp_cma, FpuConfig::dp_fma, FpuConfig::sp_cma, FpuConfig::sp_fma] {
        let on_cfg = mk();
        let mut off_cfg = on_cfg;
        off_cfg.forwarding = false;
        let suite = Profile::suite();
        let pen = |cfg: &FpuConfig| -> f64 {
            let lat = LatencyModel::of(&FpuUnit::generate(cfg));
            suite.iter().map(|p| simulate(&lat, &p.generate(20_000, 42)).avg_penalty).sum::<f64>()
                / suite.len() as f64
        };
        let on = pen(&on_cfg);
        let off = pen(&off_cfg);
        t.row(vec![
            on_cfg.name(),
            format!("{on:.3}"),
            format!("{off:.3}"),
            format!("{:.0}%", (1.0 - on / off) * 100.0),
        ]);
    }
    t.print();

    println!("\n=== ablation: execution engine (scalar vs batch vs fidelity) ===\n");
    {
        use fpmax::workloads::throughput::{OperandMix, OperandStream};
        let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
        let n = if fast { 5_000 } else { 50_000 };
        let exec = BatchExecutor::auto();
        let mut t = TextTable::new(vec![
            "unit",
            "scalar gate Mops/s",
            "batch gate",
            "batch word",
            "batch simd",
            "speedup",
        ]);
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
            let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
            let triples =
                OperandStream::new(cfg.precision, OperandMix::Finite, 42).batch(n);
            let mut out = vec![0u64; n];
            let time = |f: &mut dyn FnMut()| -> f64 {
                let t0 = Instant::now();
                f();
                n as f64 / t0.elapsed().as_secs_f64()
            };
            let scalar_gate = time(&mut || {
                let mut acc = 0u64;
                for tr in &triples {
                    acc ^= unit.fmac_one(tr.a, tr.b, tr.c);
                }
                std::hint::black_box(acc);
            });
            // One untimed warmup per tier absorbs the executor's one-shot
            // serial calibration pass, keeping it out of the measurement;
            // recalibrate between tiers (per-op cost differs ~10×).
            exec.run_into(&unit, &triples, &mut out).unwrap();
            let batch_gate = time(&mut || {
                exec.run_into(&unit, &triples, &mut out).unwrap();
                std::hint::black_box(out[0]);
            });
            exec.recalibrate();
            exec.run_into(&word, &triples, &mut out).unwrap();
            let batch_word = time(&mut || {
                exec.run_into(&word, &triples, &mut out).unwrap();
                std::hint::black_box(out[0]);
            });
            exec.recalibrate();
            exec.run_into(&simd, &triples, &mut out).unwrap();
            let batch_simd = time(&mut || {
                exec.run_into(&simd, &triples, &mut out).unwrap();
                std::hint::black_box(out[0]);
            });
            exec.recalibrate(); // next unit recalibrates from scratch
            t.row(vec![
                cfg.name(),
                format!("{:.2}", scalar_gate / 1e6),
                format!("{:.2}", batch_gate / 1e6),
                format!("{:.2}", batch_word / 1e6),
                format!("{:.2}", batch_simd / 1e6),
                format!("{:.1}×", batch_simd / scalar_gate),
            ]);
        }
        t.print();
    }

    println!("\n=== ablation: CMA-vs-FMA accumulation chain scaling ===\n");
    let mut t = TextTable::new(vec!["chain fraction", "DP CMA pen.", "DP FMA(5) pen.", "CMA advantage"]);
    for frac in [0.0f64, 0.2, 0.4, 0.6, 0.8, 1.0] {
        use fpmax::pipesim::trace::{Trace, TraceOp};
        let n = 50_000;
        let ops: Vec<TraceOp> = (0..n)
            .map(|i| {
                if i > 0 && ((i % 100) as f64) < frac * 100.0 {
                    TraceOp::accumulate(1)
                } else {
                    TraceOp::INDEPENDENT
                }
            })
            .collect();
        let trace = Trace::new(ops);
        let cma = simulate(&LatencyModel::of(&FpuUnit::generate(&FpuConfig::dp_cma())), &trace);
        let mut fma5 = FpuConfig::dp_fma();
        fma5.stages = 5;
        let fma = simulate(&LatencyModel::of(&FpuUnit::generate(&fma5)), &trace);
        t.row(vec![
            format!("{:.0}%", frac * 100.0),
            format!("{:.3}", cma.avg_penalty),
            format!("{:.3}", fma.avg_penalty),
            if fma.avg_penalty > 0.0 {
                format!("{:.1}×", fma.avg_penalty / cma.avg_penalty.max(1e-9))
            } else {
                "-".to_string()
            },
        ]);
    }
    t.print();
}
