//! Bench E2 — regenerates **Fig. 3**: throughput tradeoff curves for the
//! SP and DP FMAs (architecture sweep at 1 V, V_DD scaling, V_DD +
//! body-bias), with the paper's headline operating points.
//!
//! Run: `cargo bench --bench fig3`.

use fpmax::arch::fp::Precision;
use fpmax::report::fig3;
use fpmax::util::bench::{header, BenchRunner};

fn main() {
    header("Fig 3 — throughput tradeoffs");
    for precision in [Precision::Single, Precision::Double] {
        let f = fig3::compute(precision);
        fig3::print(&f);
    }

    let runner = BenchRunner::from_env();
    runner.run("fig3/sp_full_sweep", Some(42.0 + 18.0 * 9.0), || {
        let f = fig3::compute(Precision::Single);
        assert!(!f.vdd_bb_curve.is_empty());
    });
    runner.run("fig3/dp_full_sweep", None, || {
        let f = fig3::compute(Precision::Double);
        assert!(!f.vdd_bb_curve.is_empty());
    });
}
