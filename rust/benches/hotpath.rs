//! Hot-path microbenchmarks — the §Perf targets of DESIGN.md:
//!
//! * bit-accurate FMAC datapath ops/s (per unit, single core),
//! * golden softfloat ops/s (the spec the datapath is checked against),
//! * pipeline-simulator cycles/s,
//! * coordinator end-to-end verification throughput (multi-core),
//! * PJRT artifact throughput (when artifacts are built).
//!
//! Run: `cargo bench --bench hotpath`.

use fpmax::arch::engine::{BatchExecutor, Datapath, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::arch::rounding::RoundMode;
use fpmax::arch::softfloat;
use fpmax::coordinator;
use fpmax::pipesim::{simulate, LatencyModel};
use fpmax::runtime::Runtime;
use fpmax::util::bench::{black_box, header, BenchRunner};
use fpmax::workloads::specfp::Profile;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn main() {
    let runner = BenchRunner::from_env();
    let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 20_000 } else { 200_000 };

    header("hot path — bit-accurate datapaths");
    for cfg in FpuConfig::fpmax_units() {
        let unit = FpuUnit::generate(&cfg);
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 42);
        let triples = stream.batch(n);
        runner.run(&format!("datapath/{}", cfg.name()), Some(n as f64), || {
            let mut acc = 0u64;
            for t in &triples {
                acc ^= unit.fmac(t.a, t.b, t.c).bits;
            }
            black_box(acc);
        });
    }

    header("hot path — execution engine (scalar vs batch vs fidelity)");
    {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 4);
        let triples = stream.batch(n);
        let exec = BatchExecutor::auto();
        let mut out = vec![0u64; n];
        runner.run("engine/sp_fma/scalar_gate", Some(n as f64), || {
            let mut acc = 0u64;
            for t in &triples {
                acc ^= unit.fmac_one(t.a, t.b, t.c);
            }
            black_box(acc);
        });
        runner.run("engine/sp_fma/batch_gate", Some(n as f64), || {
            exec.run_into(&unit, &triples, &mut out).unwrap();
            black_box(out[0]);
        });
        // Recalibrate between tiers: the chunk hint tuned for one
        // datapath's per-op cost is ~10× off for the next.
        exec.recalibrate();
        runner.run("engine/sp_fma/batch_word", Some(n as f64), || {
            exec.run_into(&word, &triples, &mut out).unwrap();
            black_box(out[0]);
        });
        exec.recalibrate();
        runner.run("engine/sp_fma/batch_word_simd", Some(n as f64), || {
            exec.run_into(&simd, &triples, &mut out).unwrap();
            black_box(out[0]);
        });
        exec.recalibrate();
        runner.run("engine/sp_fma/batch_word_checked", Some(n as f64), || {
            let check =
                exec.run_checked_into(&unit, Fidelity::WordLevel, &triples, 997, &mut out).unwrap();
            assert!(check.clean());
            black_box(out[0]);
        });
        exec.recalibrate();
        runner.run("engine/sp_fma/batch_simd_checked", Some(n as f64), || {
            let check =
                exec.run_checked_into(&unit, Fidelity::WordSimd, &triples, 997, &mut out).unwrap();
            assert!(check.clean());
            black_box(out[0]);
        });
    }

    header("hot path — golden softfloat");
    {
        let mut stream = OperandStream::new(
            fpmax::arch::fp::Precision::Double,
            OperandMix::Finite,
            7,
        );
        let triples = stream.batch(n);
        let fmt = fpmax::arch::fp::Format::DP;
        runner.run("softfloat/dp_fma", Some(n as f64), || {
            let mut acc = 0u64;
            for t in &triples {
                acc ^= softfloat::fma(fmt, RoundMode::NearestEven, t.a, t.b, t.c).bits;
            }
            black_box(acc);
        });
    }

    header("hot path — pipeline simulator");
    {
        let unit = FpuUnit::generate(&FpuConfig::dp_cma());
        let lat = LatencyModel::of(&unit);
        let trace = Profile::suite()[0].generate(n, 42);
        runner.run("pipesim/spec_trace", Some(n as f64), || {
            let sim = simulate(&lat, &trace);
            black_box(sim.cycles);
        });
    }

    header("hot path — coordinator (multi-core verification)");
    {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 9);
        let triples = stream.batch(n);
        let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        runner.run(
            &format!("coordinator/verify_x{workers}"),
            Some(n as f64),
            || {
                let r = coordinator::verify_datapath_only(&unit, &triples, workers);
                assert!(r.clean());
            },
        );
    }

    header("hot path — PJRT artifact (needs `make artifacts`)");
    match Runtime::cpu("artifacts") {
        Ok(rt) => {
            for (name, precision) in [
                ("sp_fmac", fpmax::arch::fp::Precision::Single),
                ("dp_fmac", fpmax::arch::fp::Precision::Double),
            ] {
                match rt.load_fmac(name, precision) {
                    Ok(artifact) => {
                        let mut stream = OperandStream::new(precision, OperandMix::Finite, 3);
                        let triples = stream.batch(artifact.batch * 4);
                        let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
                        let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
                        let c: Vec<u64> = triples.iter().map(|t| t.c).collect();
                        runner.run(
                            &format!("pjrt/{name}_batch{}", artifact.batch),
                            Some(a.len() as f64),
                            || {
                                let out = artifact.fmac(&a, &b, &c).expect("execute");
                                black_box(out.toggles);
                            },
                        );
                    }
                    Err(e) => println!("skipping {name}: {e}"),
                }
            }
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
}
