//! Bench S1 — the streaming serve layer against the plain batch engine:
//! does the async queue + work-stealing dispatch + live BB controller
//! sustain the hardware's batch throughput?
//!
//! Acceptance targets (embedded in the JSON under `thresholds`, enforced
//! by `python/ci_check_bench.py` on the CI artifact):
//!
//! * serve sustained (4 producers, word-simd) ≥ **0.8×** the plain
//!   windowed-tracked batch throughput of the same executor — the
//!   apples-to-apples baseline: same fidelity, same activity tracking,
//!   none of the queueing;
//! * p99 submission latency ≤ 10× p50;
//! * zero sampled gate-level cross-check mismatches;
//! * streamed bias schedule and energies bit-identical to post-hoc;
//! * **routed fleet** (4 Table-1 shards, mixed SP/DP latency/bulk
//!   producers): fleet sustained ≥ **0.8×** the best single shard,
//!   fleet p99 ≤ 10× p50, zero misrouted under the static policy, and
//!   every shard's streamed BB bit-identical to its own post-hoc pass;
//! * **routing parity** (uniform trace replay, static vs energy-aware):
//!   the dynamic policy must sustain ≥ **0.99×** static throughput on
//!   the flat, affinity-friendly shape where the cost score has nothing
//!   to win — feedback overhead must stay in the noise. (The shape the
//!   policy exists for — skewed, bursty traces — is the `fpmax replay`
//!   dominance experiment, gated by the CI `routing` checker.)
//!
//! Results are written to `BENCH_serve.json` at the repository root
//! (override with `FPMAX_BENCH_OUT=path`).
//!
//! Run: `cargo bench --bench serve` (FPMAX_BENCH_FAST=1 for a smoke run).

use std::sync::Arc;
use std::time::Duration;

use fpmax::arch::engine::{BatchExecutor, Fidelity, UnitDatapath};
use fpmax::arch::generator::{FpuConfig, FpuUnit};
use fpmax::coordinator::{self, ReplayReport, RoutedLoad};
use fpmax::runtime::chaos::FaultPlan;
use fpmax::runtime::router::{
    EnergyAware, FleetReport, RetryPolicy, RoutePolicy, RouterConfig, ServeRouter, StaticAffinity,
};
use fpmax::runtime::serve::{ServeConfig, ServeLoad};
use fpmax::runtime::trace::{Trace, TraceConfig};
use fpmax::util::bench::header;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

const WINDOW_OPS: usize = 4_096;
const SUB_OPS: usize = 8_192;

struct ServeRow {
    name: String,
    plain_windowed: f64,
    plain_untracked: f64,
    serve_1p: f64,
    serve_4p: f64,
    p50_us: f64,
    p99_us: f64,
    crosscheck_sampled: u64,
    crosscheck_mismatches: u64,
    schedule_match: bool,
    energy_match: bool,
    ring_coalesced: u64,
}

impl ServeRow {
    fn ratio(&self) -> f64 {
        self.serve_4p / self.plain_windowed.max(1e-12)
    }

    fn p99_over_p50(&self) -> f64 {
        if self.p50_us > 0.0 {
            self.p99_us / self.p50_us
        } else {
            1.0
        }
    }
}

fn main() {
    let fast = std::env::var("FPMAX_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 200_000 } else { 2_000_000 };
    let samples = if fast { 2 } else { 3 };
    let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);

    header(&format!("serve layer — {n} ops/unit, {workers} workers, word-simd tier"));

    let mut rows = Vec::new();
    for cfg in [FpuConfig::sp_fma(), FpuConfig::dp_fma()] {
        let unit = FpuUnit::generate(&cfg);
        let dp = UnitDatapath::new(&unit, Fidelity::WordSimd);
        let triples = OperandStream::new(cfg.precision, OperandMix::Finite, 42).batch(n);
        let mut out = vec![0u64; n];
        let exec = BatchExecutor::new(workers);

        // Plain baselines (best of `samples`, pool + calibration warm).
        exec.run_windowed_into(&dp, &triples, &mut out, WINDOW_OPS).unwrap();
        let mut windowed_secs = f64::INFINITY;
        let mut untracked_secs = f64::INFINITY;
        for _ in 0..samples {
            let t0 = std::time::Instant::now();
            exec.run_windowed_into(&dp, &triples, &mut out, WINDOW_OPS).unwrap();
            windowed_secs = windowed_secs.min(t0.elapsed().as_secs_f64());
            let t1 = std::time::Instant::now();
            exec.run_into(&dp, &triples, &mut out).unwrap();
            untracked_secs = untracked_secs.min(t1.elapsed().as_secs_f64());
        }
        let plain_windowed = n as f64 / windowed_secs;
        let plain_untracked = n as f64 / untracked_secs;

        // Serve runs: best sustained over `samples` runs per producer
        // count; report latency/correctness from the best 4-producer run.
        let serve_once = |producers: usize, seed: u64| {
            let mut scfg = ServeConfig::nominal(&cfg, true).expect("nominal config");
            scfg.workers = workers;
            scfg.window_ops = WINDOW_OPS;
            let load =
                ServeLoad { total_ops: n, producers, sub_ops: SUB_OPS, duty: 1.0, seed };
            coordinator::serve_datapath(&unit, Fidelity::WordSimd, load, scfg)
                .expect("serve run")
        };
        let mut serve_1p = 0.0f64;
        for s in 0..samples {
            serve_1p = serve_1p.max(serve_once(1, 42 + s as u64).sustained_ops_per_s);
        }
        let mut serve_4p = 0.0f64;
        let mut best = None;
        for s in 0..samples {
            let r = serve_once(4, 142 + s as u64);
            if r.sustained_ops_per_s > serve_4p {
                serve_4p = r.sustained_ops_per_s;
                best = Some(r);
            }
        }
        let best = best.expect("at least one serve sample");
        assert_eq!(
            best.crosscheck_mismatches, 0,
            "{}: serve gate cross-check mismatches at {:?}",
            cfg.name(),
            best.mismatch_indices
        );
        assert!(
            best.bb_gate_ok(),
            "{}: streamed BB diverged from post-hoc (ring coalesced {})",
            cfg.name(),
            best.ring_coalesced
        );

        rows.push(ServeRow {
            name: cfg.name(),
            plain_windowed,
            plain_untracked,
            serve_1p,
            serve_4p,
            p50_us: best.p50_latency_s * 1e6,
            p99_us: best.p99_latency_s * 1e6,
            crosscheck_sampled: best.crosscheck_sampled,
            crosscheck_mismatches: best.crosscheck_mismatches,
            schedule_match: best.schedule_matches,
            energy_match: best.energy_matches,
            ring_coalesced: best.ring_coalesced,
        });
    }

    // Routed fleet: all four Table-1 units behind the shard router,
    // mixed SP/DP latency/bulk producers, fair-share worker budget.
    let routed_once = |seed: u64| -> FleetReport {
        let specs = ServeRouter::fleet_nominal(Fidelity::WordSimd, true, workers, WINDOW_OPS, 1_024)
            .expect("fleet specs");
        let load = RoutedLoad {
            total_ops: n,
            producers_per_class: 1,
            sub_ops: SUB_OPS,
            duty: 1.0,
            seed,
        };
        coordinator::serve_routed(&specs, RouterConfig::no_spill(workers), Fidelity::WordSimd, load)
            .expect("routed serve run")
    };
    let mut routed = routed_once(42);
    for s in 1..samples {
        let r = routed_once(42 + s as u64);
        if r.fleet_sustained_ops_per_s > routed.fleet_sustained_ops_per_s {
            routed = r;
        }
    }
    assert_eq!(
        routed.crosscheck_mismatches(),
        0,
        "routed fleet gate cross-check mismatches"
    );
    assert!(routed.bb_gate_ok(), "a routed shard's streamed BB diverged from post-hoc");
    assert_eq!(routed.misrouted, 0, "static policy with no spill pressure misrouted work");

    // Routing parity: the same uniform trace replayed under both
    // policies. Flat duty, even class mix — the affinity placement is
    // already optimal, so all the dynamic policy can do here is cost
    // time; it must stay within 1% of static throughput.
    let trace = Trace::generate(TraceConfig::preset("uniform", 42, n as u64 / 8).unwrap())
        .expect("uniform trace");
    let replay_once = |policy: Arc<dyn RoutePolicy>| -> ReplayReport {
        let specs = ServeRouter::fleet_nominal(Fidelity::WordSimd, true, workers, WINDOW_OPS, 1_024)
            .expect("fleet specs");
        let outcome = coordinator::serve_trace(
            &specs,
            RouterConfig::no_spill(workers),
            Fidelity::WordSimd,
            &trace,
            policy,
            &FaultPlan::none(42),
            Duration::from_secs(120),
            RetryPolicy::bounded(200, Duration::from_micros(200), Duration::from_millis(10)),
        )
        .expect("trace replay");
        outcome.report
    };
    let best_replay = |policy: fn() -> Arc<dyn RoutePolicy>| -> ReplayReport {
        let mut best = replay_once(policy());
        for _ in 1..samples {
            let r = replay_once(policy());
            if r.sustained_ops_per_s > best.sustained_ops_per_s {
                best = r;
            }
        }
        best
    };
    let replay_static = best_replay(|| Arc::new(StaticAffinity));
    let replay_dynamic = best_replay(|| Arc::new(EnergyAware::nominal()));
    for r in [&replay_static, &replay_dynamic] {
        assert!(
            r.gates_ok(),
            "[{}] replay gates failed (ledger/crosscheck/conservation)",
            r.policy_name
        );
    }
    let parity_ratio =
        replay_dynamic.sustained_ops_per_s / replay_static.sustained_ops_per_s.max(1e-12);

    println!();
    for r in &rows {
        println!(
            "{:<7}  plain-windowed {:>8.2} Mops/s (untracked {:>8.2})  serve-1p {:>8.2}  serve-4p {:>8.2} ({:.2}× plain)  p50 {:>7.1} µs  p99 {:>7.1} µs ({:.1}×)  crosscheck {}/{} clean  bb {}",
            r.name,
            r.plain_windowed / 1e6,
            r.plain_untracked / 1e6,
            r.serve_1p / 1e6,
            r.serve_4p / 1e6,
            r.ratio(),
            r.p50_us,
            r.p99_us,
            r.p99_over_p50(),
            r.crosscheck_sampled - r.crosscheck_mismatches,
            r.crosscheck_sampled,
            if r.schedule_match && r.energy_match { "bit-identical" } else { "DIVERGED" },
        );
    }

    let routed_best = routed.best_shard_ops_per_s();
    let routed_ratio = routed.fleet_vs_best_shard_ratio();
    let routed_p99_over_p50 = routed.fleet_p99_over_p50();
    println!(
        "routed   fleet {:>8.2} Mops/s ({routed_ratio:.2}× best shard {:>8.2})  p50 {:>7.1} µs  p99 {:>7.1} µs ({routed_p99_over_p50:.1}×)  misrouted {}  bb {}",
        routed.fleet_sustained_ops_per_s / 1e6,
        routed_best / 1e6,
        routed.fleet_p50_latency_s * 1e6,
        routed.fleet_p99_latency_s * 1e6,
        routed.misrouted,
        if routed.bb_gate_ok() { "bit-identical/shard" } else { "DIVERGED" },
    );
    println!(
        "routing  uniform-trace parity: static {:>8.2} Mops/s ({:.3} pJ/op)  energy-aware {:>8.2} Mops/s ({:.3} pJ/op)  ratio {parity_ratio:.3} (gate ≥ 0.99)",
        replay_static.sustained_ops_per_s / 1e6,
        replay_static.fleet_pj_per_op,
        replay_dynamic.sustained_ops_per_s / 1e6,
        replay_dynamic.fleet_pj_per_op,
    );

    let out_path = std::env::var("FPMAX_BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR")));
    let json = render_json(n, workers, &rows, &routed, &trace, &replay_static, &replay_dynamic);
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => println!("\ncould not write {out_path}: {e}"),
    }
}

/// Hand-rolled JSON (no serde offline): stable key order, thresholds
/// embedded so the CI regression gate reads its budgets from the
/// artifact itself.
fn render_json(
    ops: usize,
    workers: usize,
    rows: &[ServeRow],
    routed: &FleetReport,
    trace: &Trace,
    replay_static: &ReplayReport,
    replay_dynamic: &ReplayReport,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve\",\n");
    s.push_str("  \"measured\": true,\n");
    s.push_str(&format!("  \"ops_per_unit\": {ops},\n"));
    s.push_str(&format!("  \"workers\": {workers},\n"));
    s.push_str(&format!("  \"window_ops\": {WINDOW_OPS},\n"));
    s.push_str(&format!("  \"sub_ops_mean\": {SUB_OPS},\n"));
    s.push_str("  \"thresholds\": {\n");
    s.push_str("    \"min_serve_vs_plain_windowed_ratio\": 0.8,\n");
    s.push_str("    \"max_p99_over_p50\": 10.0,\n");
    s.push_str("    \"max_crosscheck_mismatches\": 0,\n");
    s.push_str("    \"require_bb_identity\": true,\n");
    s.push_str("    \"min_routed_vs_best_shard_ratio\": 0.8,\n");
    s.push_str("    \"max_fleet_p99_over_p50\": 10.0,\n");
    s.push_str("    \"max_misrouted\": 0,\n");
    s.push_str("    \"require_shard_bb_identity\": true,\n");
    s.push_str("    \"min_dynamic_vs_static_uniform_ratio\": 0.99\n");
    s.push_str("  },\n");
    s.push_str("  \"units\": {\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("    \"{}\": {{\n", r.name));
        s.push_str(&format!(
            "      \"plain_windowed_ops_per_s\": {:.0},\n",
            r.plain_windowed
        ));
        s.push_str(&format!(
            "      \"plain_untracked_ops_per_s\": {:.0},\n",
            r.plain_untracked
        ));
        s.push_str(&format!("      \"serve_1p_ops_per_s\": {:.0},\n", r.serve_1p));
        s.push_str(&format!("      \"serve_4p_ops_per_s\": {:.0},\n", r.serve_4p));
        s.push_str(&format!(
            "      \"serve_vs_plain_windowed_ratio\": {:.4},\n",
            r.ratio()
        ));
        s.push_str(&format!("      \"p50_submit_us\": {:.3},\n", r.p50_us));
        s.push_str(&format!("      \"p99_submit_us\": {:.3},\n", r.p99_us));
        s.push_str(&format!("      \"p99_over_p50\": {:.3},\n", r.p99_over_p50()));
        s.push_str(&format!(
            "      \"crosscheck_sampled\": {},\n",
            r.crosscheck_sampled
        ));
        s.push_str(&format!(
            "      \"crosscheck_mismatches\": {},\n",
            r.crosscheck_mismatches
        ));
        s.push_str(&format!("      \"bb_schedule_match\": {},\n", r.schedule_match));
        s.push_str(&format!("      \"bb_energy_match\": {},\n", r.energy_match));
        s.push_str(&format!("      \"ring_coalesced\": {}\n", r.ring_coalesced));
        s.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    s.push_str("  },\n");
    let best = routed.best_shard_ops_per_s();
    let ratio = routed.fleet_vs_best_shard_ratio();
    let p99_over_p50 = routed.fleet_p99_over_p50();
    s.push_str("  \"routed\": {\n");
    s.push_str(&format!("    \"shard_count\": {},\n", routed.shards.len()));
    s.push_str(&format!(
        "    \"fleet_sustained_ops_per_s\": {:.0},\n",
        routed.fleet_sustained_ops_per_s
    ));
    s.push_str(&format!("    \"best_shard_ops_per_s\": {best:.0},\n"));
    s.push_str(&format!("    \"fleet_vs_best_shard_ratio\": {ratio:.4},\n"));
    s.push_str(&format!(
        "    \"fleet_p50_us\": {:.3},\n",
        routed.fleet_p50_latency_s * 1e6
    ));
    s.push_str(&format!(
        "    \"fleet_p99_us\": {:.3},\n",
        routed.fleet_p99_latency_s * 1e6
    ));
    s.push_str(&format!("    \"fleet_p99_over_p50\": {p99_over_p50:.3},\n"));
    s.push_str(&format!("    \"misrouted\": {},\n", routed.misrouted));
    s.push_str(&format!("    \"spilled\": {},\n", routed.spilled));
    s.push_str(&format!(
        "    \"crosscheck_sampled\": {},\n",
        routed.crosscheck_sampled()
    ));
    s.push_str(&format!(
        "    \"crosscheck_mismatches\": {},\n",
        routed.crosscheck_mismatches()
    ));
    s.push_str(&format!(
        "    \"all_shards_bb_identity\": {},\n",
        routed.bb_gate_ok()
    ));
    s.push_str("    \"shards\": {\n");
    for (i, sh) in routed.shards.iter().enumerate() {
        s.push_str(&format!(
            "      \"{}\": {{ \"ops\": {}, \"sustained_ops_per_s\": {:.0}, \"bb_gate_ok\": {} }}{}\n",
            sh.unit,
            sh.report.ops,
            sh.report.sustained_ops_per_s,
            sh.report.bb_gate_ok(),
            if i + 1 == routed.shards.len() { "" } else { "," }
        ));
    }
    s.push_str("    }\n");
    s.push_str("  },\n");
    let parity_ratio =
        replay_dynamic.sustained_ops_per_s / replay_static.sustained_ops_per_s.max(1e-12);
    s.push_str("  \"routing\": {\n");
    s.push_str("    \"trace\": \"uniform\",\n");
    s.push_str(&format!("    \"trace_ops\": {},\n", trace.total_ops()));
    s.push_str(&format!(
        "    \"trace_fingerprint\": \"{:016x}\",\n",
        trace.fingerprint
    ));
    for (key, r) in [("static", replay_static), ("energy_aware", replay_dynamic)] {
        s.push_str(&format!("    \"{key}\": {{\n"));
        s.push_str(&format!(
            "      \"sustained_ops_per_s\": {:.0},\n",
            r.sustained_ops_per_s
        ));
        s.push_str(&format!("      \"fleet_pj_per_op\": {:.6},\n", r.fleet_pj_per_op));
        s.push_str(&format!("      \"policy_routed\": {},\n", r.policy_routed));
        s.push_str(&format!("      \"digest\": \"{:016x}\",\n", r.digest));
        s.push_str(&format!("      \"gates_ok\": {}\n", r.gates_ok()));
        s.push_str("    },\n");
    }
    s.push_str(&format!(
        "    \"dynamic_vs_static_uniform_ratio\": {parity_ratio:.4}\n"
    ));
    s.push_str("  }\n}\n");
    s
}
