//! Bench E5 — regenerates **Table II** (SP FMA vs published designs,
//! scaled to 28nm by the feature-size + FO4 rule).
//!
//! Run: `cargo bench --bench table2`.

use fpmax::report::table2;
use fpmax::util::bench::{header, BenchRunner};

fn main() {
    header("Table II — scaled comparison");
    let rows = table2::compute();
    table2::print(&rows);

    // The qualitative shape asserted by the paper's conclusion.
    let fpmax = &rows[0];
    let winners_energy = rows[1..].iter().filter(|r| r.gflops_w >= fpmax.gflops_w).count();
    println!(
        "\nFPMax SP FMA wins GFLOPS/W against {}/4 competitors (paper: 4/4)",
        4 - winners_energy
    );

    let runner = BenchRunner::from_env();
    runner.run("table2/full_regeneration", Some(5.0), || {
        let r = table2::compute();
        assert_eq!(r.len(), 5);
    });
}
