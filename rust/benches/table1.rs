//! Bench E4 — regenerates **Table I** (per-unit performance summary)
//! and times the full pipeline (generator → cost models → operating-
//! window scan → benchmarked-delay simulation).
//!
//! Run: `cargo bench --bench table1` (FPMAX_BENCH_FAST=1 for a smoke run).

use fpmax::report::table1;
use fpmax::util::bench::{header, BenchRunner};
use fpmax::util::stats::rel_diff;

fn main() {
    header("Table I — performance summary");
    let entries = table1::compute();
    table1::print(&entries);

    println!("\nper-cell relative error vs silicon:");
    for (e, p) in entries.iter().zip(table1::PAPER) {
        println!(
            "  {:<7} area {:>5.1}%  freq {:>5.1}%  power {:>5.1}%  normAeff {:>5.1}%  normEeff {:>5.1}%  delay {:>5.1}%",
            e.name,
            100.0 * rel_diff(e.area_mm2, p.1),
            100.0 * rel_diff(e.freq_ghz, p.2),
            100.0 * rel_diff(e.total_mw, p.4),
            100.0 * rel_diff(e.norm_area_eff, p.5),
            100.0 * rel_diff(e.norm_energy_eff, p.7),
            100.0 * rel_diff(e.norm_delay_ns, p.9),
        );
    }

    let runner = BenchRunner::from_env();
    runner.run("table1/full_regeneration", Some(4.0), || {
        let e = table1::compute();
        assert_eq!(e.len(), 4);
    });
}
