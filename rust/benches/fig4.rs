//! Bench E3 — regenerates **Fig. 4**: latency-unit tradeoffs (energy/op
//! vs average benchmarked delay) at 100% utilization with/without body
//! bias and at 10% utilization with static vs adaptive body bias.
//!
//! Paper: BB ≈ 13% power at full load; static BB at 10% utilization
//! blows energy/op up ~3×; adaptive BB recovers to ~1.5×.
//!
//! Run: `cargo bench --bench fig4`.

use fpmax::arch::fp::Precision;
use fpmax::report::fig4;
use fpmax::util::bench::{header, BenchRunner};

fn main() {
    header("Fig 4 — latency tradeoffs, body-bias policies");
    for precision in [Precision::Single, Precision::Double] {
        let f = fig4::compute(precision);
        fig4::print(&f);
    }

    // Utilization sweep: where does adaptive BB stop paying?
    println!("\nutilization sweep (SP CMA, V_DD 0.6, blow-up vs 100%):");
    {
        use fpmax::arch::generator::{FpuConfig, FpuUnit};
        use fpmax::bb::controller::{blowup_vs_full, BbPolicy};
        use fpmax::energy::tech::Technology;
        use fpmax::workloads::utilization::UtilizationProfile;
        let unit = FpuUnit::generate(&FpuConfig::sp_cma());
        let tech = Technology::fdsoi28();
        for util in [0.05, 0.1, 0.25, 0.5, 0.9] {
            let prof = UtilizationProfile::duty(util, 10_000, 1_000_000);
            let s = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::static_nominal(), &prof).unwrap();
            let a = blowup_vs_full(&unit, &tech, 0.6, BbPolicy::adaptive_nominal(1.0), &prof).unwrap();
            println!("  util {:>4.0}%: static {s:>5.2}×  adaptive {a:>5.2}×", util * 100.0);
        }
    }

    let runner = BenchRunner::from_env();
    runner.run("fig4/sp_four_curves", None, || {
        let f = fig4::compute(Precision::Single);
        assert!(f.adaptive_blowup < f.static_blowup);
    });
}
