//! `fpmax` — the L3 coordinator CLI.
//!
//! One subcommand per reproduced experiment plus the chip self-test:
//!
//! ```text
//! fpmax table1                      # Table I summary (model vs silicon)
//! fpmax table2                      # Table II scaled comparison
//! fpmax fig2c  [--ops 20000]        # latency-penalty comparison
//! fpmax fig3   [--precision sp|dp]  # throughput tradeoff curves
//! fpmax fig4   [--precision sp|dp]  # latency tradeoff curves
//! fpmax calib                       # calibration residuals vs Table I
//! fpmax sweep  [--precision sp|dp] [--kind fma|cma]
//! fpmax verify [--unit sp_fma] [--ops 100000] [--fidelity gate|word|word-simd]
//! fpmax selftest [--ops 65536] [--artifacts DIR] # chip + PJRT cross-check
//! ```
//!
//! `verify --fidelity word` runs the batched word-level tier with a
//! sampled gate-level cross-check — the fast path the DSE sweeps use;
//! `--fidelity word-simd` runs the lane-batched SoA kernels under the
//! same cross-check machinery.

use fpmax::arch::fp::Precision;
use fpmax::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use fpmax::chip::{
    FpMaxChip, Instruction, UnitSel, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A, BANK_STIM_B,
    BANK_STIM_C,
};
use fpmax::coordinator;
use fpmax::dse;
use fpmax::energy::tech::{OperatingPoint, Technology};
use fpmax::report;
use fpmax::runtime::Runtime;
use fpmax::util::cli::Args;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn precision_arg(args: &Args) -> fpmax::Result<Precision> {
    match args.get("precision").unwrap_or("sp") {
        "sp" => Ok(Precision::Single),
        "dp" => Ok(Precision::Double),
        other => anyhow::bail!("--precision must be sp or dp, got {other}"),
    }
}

fn unit_arg(args: &Args) -> fpmax::Result<FpuConfig> {
    Ok(match args.get("unit").unwrap_or("sp_fma") {
        "sp_fma" => FpuConfig::sp_fma(),
        "sp_cma" => FpuConfig::sp_cma(),
        "dp_fma" => FpuConfig::dp_fma(),
        "dp_cma" => FpuConfig::dp_cma(),
        other => anyhow::bail!("--unit must be one of sp_fma|sp_cma|dp_fma|dp_cma, got {other}"),
    })
}

fn main() -> fpmax::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("table1") => {
            report::table1::print(&report::table1::compute());
        }
        Some("table2") => {
            report::table2::print(&report::table2::compute());
        }
        Some("fig2c") => {
            let ops = args.get_parse("ops", 20_000usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            report::fig2c::print(&report::fig2c::compute(ops, seed));
        }
        Some("fig3") => {
            report::fig3::print(&report::fig3::compute(precision_arg(&args)?));
        }
        Some("fig4") => {
            report::fig4::print(&report::fig4::compute(precision_arg(&args)?));
        }
        Some("calib") => {
            let r = fpmax::energy::calibrate::calibration_report();
            println!("implied κ_latency    = {:.3}", r.kappa_latency);
            println!("implied κ_throughput = {:.3}", r.kappa_throughput);
            println!("implied leak density = {:.2} mW/mm²", r.leak_density);
            println!("\nper-unit model/silicon ratios:");
            println!("{:<8} {:>6} {:>6} {:>6} {:>6}", "unit", "freq", "dynE", "area", "leak");
            for (name, f, e, a, l) in &r.residuals {
                println!("{name:<8} {f:>6.3} {e:>6.3} {a:>6.3} {l:>6.3}");
            }
        }
        Some("sweep") => {
            let precision = precision_arg(&args)?;
            let kind = match args.get("kind").unwrap_or("fma") {
                "fma" => FpuKind::Fma,
                "cma" => FpuKind::Cma,
                other => anyhow::bail!("--kind must be fma or cma, got {other}"),
            };
            let tech = Technology::fdsoi28();
            let pts = dse::arch_sweep(precision, kind, &tech, OperatingPoint::new(1.0, 0.0));
            let front = dse::frontier(&pts);
            println!("{} designs evaluated, {} on the Pareto frontier:", pts.len(), front.len());
            for &i in &front {
                let p = &pts[i];
                println!(
                    "  stages={} booth={} tree={:<7} {:>7.1} GFLOPS/mm²  {:>6.2} pJ/FLOP",
                    p.config.stages,
                    p.config.booth.name(),
                    p.config.tree.name(),
                    p.eff.gflops_per_mm2,
                    p.eff.pj_per_flop,
                );
            }
        }
        Some("verify") => {
            let cfg = unit_arg(&args)?;
            let ops = args.get_parse("ops", 100_000usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            let workers = args.get_parse("workers", num_threads())?;
            let fidelity = match args.get("fidelity").unwrap_or("gate") {
                "gate" => fpmax::arch::engine::Fidelity::GateLevel,
                "word" => fpmax::arch::engine::Fidelity::WordLevel,
                "word-simd" | "simd" => fpmax::arch::engine::Fidelity::WordSimd,
                other => anyhow::bail!("--fidelity must be gate, word or word-simd, got {other}"),
            };
            let unit = FpuUnit::generate(&cfg);
            let mut stream = OperandStream::new(cfg.precision, OperandMix::Anything, seed);
            let triples = stream.batch(ops);
            match fidelity {
                fpmax::arch::engine::Fidelity::GateLevel => {
                    let r = coordinator::verify_datapath_only(&unit, &triples, workers);
                    println!(
                        "{}: {} ops gate-level, {} mismatches, {:.2} Mops/s ({} workers)",
                        cfg.name(),
                        r.ops,
                        r.datapath_mismatches.len(),
                        r.ops as f64 / r.rust_secs / 1e6,
                        workers
                    );
                    anyhow::ensure!(r.clean(), "datapath mismatches: {:?}", r.datapath_mismatches);
                }
                tier => {
                    // Fast word tier (scalar or lane-batched SIMD) with a
                    // sampled gate-level cross-check.
                    let exec = fpmax::arch::engine::BatchExecutor::new(workers);
                    let t0 = std::time::Instant::now();
                    let (_, check) = exec.run_checked_tier(&unit, tier, &triples, 64);
                    let secs = t0.elapsed().as_secs_f64();
                    println!(
                        "{}: {} ops {}-level, {} gate-checked, {} mismatches, {:.2} Mops/s ({} workers)",
                        cfg.name(),
                        triples.len(),
                        tier.name(),
                        check.sampled,
                        check.mismatches.len(),
                        triples.len() as f64 / secs / 1e6,
                        workers
                    );
                    anyhow::ensure!(
                        check.clean(),
                        "{} tier diverged from gate level at indices {:?}",
                        tier.name(),
                        check.mismatches
                    );
                }
            }
        }
        Some("selftest") => {
            selftest(&args)?;
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!(
                "usage: fpmax <table1|table2|fig2c|fig3|fig4|calib|sweep|verify|selftest> [options]"
            );
            std::process::exit(2);
        }
    }
    args.reject_unknown()?;
    Ok(())
}

/// End-to-end chip self-test: JTAG-load stimulus, run all four FPUs at
/// speed, read back, check against golden softfloat, and cross-check the
/// SP/DP FMA streams against the AOT artifacts through PJRT.
fn selftest(args: &Args) -> fpmax::Result<()> {
    let ops = args.get_parse("ops", 65_536usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
    let ram_depth = 1024usize;

    println!("=== FPMax chip self-test: {ops} ops/unit ===");
    let mut chip = FpMaxChip::new(ram_depth);
    let mut total_ops = 0u64;
    let mut total_cycles = 0u64;
    let mut mismatches = 0usize;

    for (sel, cfg) in [
        (UnitSel::DpCma, FpuConfig::dp_cma()),
        (UnitSel::DpFma, FpuConfig::dp_fma()),
        (UnitSel::SpCma, FpuConfig::sp_cma()),
        (UnitSel::SpFma, FpuConfig::sp_fma()),
    ] {
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, seed);
        let mut done = 0usize;
        while done < ops {
            let n = ram_depth.min(ops - done);
            let triples = stream.batch(n);
            let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
            let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
            let c: Vec<u64> = triples.iter().map(|t| t.c).collect();
            {
                let mut port = chip.jtag();
                port.load_bank(BANK_STIM_A, &a)?;
                port.load_bank(BANK_STIM_B, &b)?;
                port.load_bank(BANK_STIM_C, &c)?;
                // One burst instruction per RAM fill (max repeat 1024).
                let prog = [Instruction::fmac_burst(sel, 0, n as u16).encode() as u64, 0];
                port.load_bank(BANK_PROGRAM, &prog)?;
            }
            let stats = chip.run()?;
            total_ops += stats.ops;
            total_cycles += stats.cycles;
            let results = chip.jtag().read_bank(BANK_RESULT, n)?;
            let unit = chip.unit(sel);
            for i in 0..n {
                let want = fpmax::chip::expected_result(
                    unit,
                    fpmax::arch::rounding::RoundMode::NearestEven,
                    a[i],
                    b[i],
                    c[i],
                    fpmax::chip::Op::Fmac,
                );
                if results[i] != want {
                    mismatches += 1;
                }
            }
            done += n;
        }
        println!("{:<8} {ops} ops at speed: OK", format!("{sel:?}"));
    }
    println!("chip total: {total_ops} ops in {total_cycles} at-speed cycles, {mismatches} mismatches");
    anyhow::ensure!(mismatches == 0, "{mismatches} chip-vs-golden mismatches");

    // PJRT cross-check of the fused FMA streams against the artifacts.
    match Runtime::cpu(&artifacts) {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            for (name, cfg) in [("sp_fmac", FpuConfig::sp_fma()), ("dp_fmac", FpuConfig::dp_fma())]
            {
                let artifact = rt.load_fmac(name, cfg.precision)?;
                let unit = FpuUnit::generate(&cfg);
                let mut stream =
                    OperandStream::new(cfg.precision, OperandMix::Finite, seed ^ 0x5a5a);
                let triples = stream.batch(ops.min(4 * artifact.batch));
                let r = coordinator::verify_batch(&unit, &artifact, &triples, num_threads())?;
                println!(
                    "{name}: {} ops  artifact-vs-golden {}  datapath-vs-golden {}  toggles {}  (pjrt {:.1} ms, rust {:.1} ms)",
                    r.ops,
                    r.artifact_mismatches.len(),
                    r.datapath_mismatches.len(),
                    r.artifact_toggles,
                    r.pjrt_secs * 1e3,
                    r.rust_secs * 1e3,
                );
                anyhow::ensure!(
                    r.clean(),
                    "cross-check failed: {:?}",
                    r.artifact_mismatches.first()
                );
            }
            println!("\nSELFTEST PASS: chip, golden model, and AOT artifacts agree bit-for-bit");
        }
        Err(e) => {
            println!("\nPJRT unavailable ({e}); chip-vs-golden portion passed");
        }
    }
    Ok(())
}

fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
