//! `fpmax` — the L3 coordinator CLI.
//!
//! One subcommand per reproduced experiment plus the chip self-test:
//!
//! ```text
//! fpmax table1                      # Table I summary (model vs silicon)
//! fpmax table2                      # Table II scaled comparison
//! fpmax fig2c  [--ops 20000]        # latency-penalty comparison
//! fpmax fig3   [--precision sp|dp]  # throughput tradeoff curves
//! fpmax fig4   [--precision sp|dp] [--measured] [--window 1000] [--total 1000000]
//! fpmax calib                       # calibration residuals vs Table I
//! fpmax sweep  [--precision sp|dp] [--kind fma|cma] [--bb adaptive] [--window 1000]
//! fpmax verify [--unit sp_fma] [--ops 100000] [--fidelity gate|word|word-simd]
//!              [--bb static|adaptive] [--window 4096] [--bb-json PATH]
//!              [--max-trace-overhead X]
//! fpmax fuzz   [--ops 200000] [--seed 7] [--precision sp|dp|both]
//!              [--stream uniform|structured|both]
//!              [--max-counterexamples 8] [--out PATH]
//! fpmax selftest [--ops 65536] [--artifacts DIR] # chip + PJRT cross-check
//! fpmax serve  [--unit sp_fma] [--ops 1000000] [--producers 4]
//!              [--fidelity gate|word|word-simd] [--bb static|adaptive]
//!              [--window 4096] [--duty 1.0] [--sub-ops 8192] [--ring 1024]
//!              [--workers N] [--json PATH] [--max-p99-ratio X]
//!              [--min-sustained-ratio R]
//! fpmax serve --routed [--ops 200000] [--producers 1(per class)]
//!              [--fidelity ...] [--bb ...] [--window] [--duty] [--sub-ops]
//!              [--ring] [--workers BUDGET] [--spill-pressure OPS]
//!              [--json PATH] [--max-p99-ratio X] [--min-sustained-ratio R]
//! fpmax chaos  [--ops 100000] [--producers 1(per class)] [--seed 42]
//!              [--plan kill-all|full|none] [--fidelity ...] [--bb ...]
//!              [--window] [--sub-ops] [--ring] [--workers BUDGET]
//!              [--deadline-ms 60000] [--retries 8] [--backoff-us 500]
//!              [--backoff-cap-ms 50] [--json PATH]
//! fpmax replay [--trace uniform|diurnal-skew|burst-shift|transprecision] [--ops 60000]
//!              [--seed 42] [--policy static|energy-aware|both]
//!              [--plan none|kill-all-slots] [--fidelity ...] [--bb ...]
//!              [--window] [--ring] [--workers BUDGET] [--deadline-ms 60000]
//!              [--retries 200] [--backoff-us 200] [--backoff-cap-ms 10]
//!              [--verify-determinism] [--expect-dominance] [--json PATH]
//! fpmax kernels [--unit dp_cma|dp_fma|sp_cma|sp_fma] [--seed 42]
//!              [--window 256] [--min-occupancy 0.9] [--min-speedup 1.5]
//!              [--gemm MxNxK] [--json PATH]
//! ```
//!
//! `fuzz` is the differential conformance harness (`arch::fuzz`): every
//! seeded operand triple runs four ways — gate tier vs scalar word vs
//! the dispatching word-simd lane kernels vs the host CPU's own
//! IEEE-754 hardware (five ways with the scalar lane reference under
//! `--features simd`) — and any disagreement is bit-flip minimized and
//! written to `--out` in `edge_vectors.rs` corpus format. Exits
//! non-zero on any mismatch (the CI fuzz smoke gates on this).
//!
//! `verify --fidelity word` runs the batched word-level tier with a
//! sampled gate-level cross-check — the fast path the DSE sweeps use;
//! `--fidelity word-simd` runs the lane-batched SoA kernels under the
//! same cross-check machinery.
//!
//! `verify --bb adaptive --window N` additionally runs the batch
//! **windowed-tracked** (N ops per window), reports the trace-tracking
//! overhead against the untracked run, and scores the measured trace —
//! woven into the Fig. 4 10%-duty schedule — under the static and
//! adaptive body-bias policies (`--max-trace-overhead X` turns the
//! overhead report into a hard failure; `--bb-json PATH` writes the
//! windowed-BB summary as JSON). `fig4 --measured` regenerates the
//! figure's four curves from measured traces; `sweep --bb adaptive` adds
//! the measured phase-aware adaptive-BB energy column to every design
//! point.
//!
//! `serve` drives the streaming serve layer: P producer threads submit
//! variable-sized op slices into the async queue, the dispatcher
//! coalesces them into fidelity-tiered batches over the persistent
//! pool's work-stealing scheduler, and the streaming body-bias
//! controller re-biases mid-run off the window ring. Reports sustained
//! ops/s, p50/p99 submission latency and the streamed-BB energy as
//! JSON (`--json PATH`), and hard-fails on any sampled gate cross-check
//! mismatch, any streamed-vs-post-hoc bias-schedule divergence, a p99
//! latency above `--max-p99-ratio`×p50, or a sustained throughput below
//! `--min-sustained-ratio`× the plain windowed-tracked batch baseline.
//!
//! `serve --routed` drives the **whole Table-1 fleet** behind the shard
//! router: one serve shard per fabricated unit, mixed SP/DP
//! latency/bulk producers submitting classified work, static unit
//! affinity (latency → CMA, bulk → FMA) with optional load-aware spill
//! (`--spill-pressure OPS`; off by default). Emits the per-shard +
//! fleet JSON report and hard-fails on any shard's cross-check or BB
//! divergence, a fleet p99 above `--max-p99-ratio`×p50, a fleet
//! sustained throughput below `--min-sustained-ratio`× the best single
//! shard, or any misrouted submission while spill is off.
//!
//! `chaos` drives the same routed fleet under a seeded fault plan
//! (`--plan kill-all` kills every shard once mid-load; `full` adds a
//! worker panic, a ring flood, a latency stall and a NaN storm; `none`
//! is the bit-identity control run). Producers submit through the
//! resilient deadline + bounded-retry path while the supervisor
//! quarantines, salvages and respawns killed shards. Emits the chaos
//! JSON report (`--json`) and hard-fails unless every gate holds: zero
//! hung tickets, zero lost ops (completed + errored == submitted),
//! crosscheck clean on surviving work, every scheduled fault fired,
//! every killed shard respawned, and fleet accounting conserved across
//! shard incarnations.
//!
//! `replay` is the routing-policy experiment: a seeded multi-tenant
//! trace (diurnal duty cycles, heavy-tailed bursts, mid-run mix shifts,
//! transprecision tenants spanning the 12-class matrix —
//! `runtime::trace`; the fleet automatically grows a CMA + FMA shard
//! per small format the trace arms) is replayed against the fleet
//! under one or both routing policies. `--policy both` (default) runs the static Table-1
//! baseline and the energy-aware feedback policy on the **same** trace
//! and reports the dominance verdict (dynamic throughput and fleet
//! pJ/op vs static); `--expect-dominance` turns the verdict into a hard
//! gate. `--verify-determinism` replays each arm twice and fails unless
//! the replay digests (trace fingerprint + per-class ops + producer
//! ledger, result checksums when kind-preserving) are bit-identical.
//! `--plan kill-all-slots` arms a trace-slot-anchored kill of every
//! shard, composing the chaos drill with the trace's duty cycle. Emits
//! the `bench: "routing"` JSON artifact the CI `routing` checker
//! re-derives the verdict from.
//!
//! `kernels` runs the repeat-buffer kernel suite (GEMM tile, 3-tap
//! stencil, dot-product chains — `workloads::kernels`) on the chip
//! sequencer: each kernel executes both as a stream-fed repeat-buffer
//! program and as its bit-identical unrolled reference, and the command
//! hard-fails on any result-bank mismatch, an in-burst occupancy below
//! `--min-occupancy`, or an issue-rate speedup below `--min-speedup`.
//! `--gemm MxNxK` swaps the default 16×16×8 tile (the CI smoke runs a
//! small tile on two presets). Emits the `bench: "kernels"` JSON
//! artifact (`--json PATH`) whose raw cycle/op counts the CI `kernels`
//! checker re-derives both verdicts from.

use fpmax::arch::fp::Precision;
use fpmax::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use fpmax::chip::{
    FpMaxChip, Instruction, UnitSel, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A, BANK_STIM_B,
    BANK_STIM_C,
};
use fpmax::coordinator;
use fpmax::dse;
use fpmax::energy::tech::{OperatingPoint, Technology};
use fpmax::report;
use fpmax::runtime::Runtime;
use fpmax::util::cli::Args;
use fpmax::workloads::throughput::{OperandMix, OperandStream};

fn precision_arg(args: &Args) -> fpmax::Result<Precision> {
    let s = args.get("precision").unwrap_or("sp");
    Precision::parse(s).ok_or_else(|| {
        anyhow::anyhow!("--precision must be one of sp|dp|fp16|bf16|fp8e4m3|fp8e5m2, got {s}")
    })
}

fn unit_arg(args: &Args) -> fpmax::Result<FpuConfig> {
    // `<precision>_<kind>`: the four Table-1 names plus the
    // transprecision presets (fp16_fma, bf16_cma, fp8e4m3_fma, …).
    let s = args.get("unit").unwrap_or("sp_fma");
    s.rsplit_once('_')
        .and_then(|(p, k)| {
            let p = Precision::parse(p)?;
            match k {
                "fma" => Some(FpuConfig::fma_of(p)),
                "cma" => Some(FpuConfig::cma_of(p)),
                _ => None,
            }
        })
        .ok_or_else(|| {
            anyhow::anyhow!(
                "--unit must be <precision>_<fma|cma> with precision one of \
                 sp|dp|fp16|bf16|fp8e4m3|fp8e5m2, got {s}"
            )
        })
}

fn fidelity_arg(args: &Args, default: &str) -> fpmax::Result<fpmax::arch::engine::Fidelity> {
    use fpmax::arch::engine::Fidelity;
    Ok(match args.get("fidelity").unwrap_or(default) {
        "gate" => Fidelity::GateLevel,
        "word" => Fidelity::WordLevel,
        "word-simd" | "simd" => Fidelity::WordSimd,
        other => anyhow::bail!("--fidelity must be gate, word or word-simd, got {other}"),
    })
}

/// `--bb static|adaptive` → `true` for adaptive (the serve default).
fn bb_adaptive_arg(args: &Args) -> fpmax::Result<bool> {
    match args.get("bb").unwrap_or("adaptive") {
        "adaptive" => Ok(true),
        "static" => Ok(false),
        other => anyhow::bail!("--bb must be static or adaptive, got {other}"),
    }
}

fn main() -> fpmax::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("table1") => {
            report::table1::print(&report::table1::compute());
        }
        Some("table2") => {
            report::table2::print(&report::table2::compute());
        }
        Some("fig2c") => {
            let ops = args.get_parse("ops", 20_000usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            report::fig2c::print(&report::fig2c::compute(ops, seed));
        }
        Some("fig3") => {
            report::fig3::print(&report::fig3::compute(precision_arg(&args)?));
        }
        Some("fig4") => {
            let precision = precision_arg(&args)?;
            if args.flag("measured") {
                let window = args.get_parse("window", 1_000u64)?;
                let total = args.get_parse("total", 1_000_000u64)?;
                anyhow::ensure!(window >= 1, "--window must be at least 1 slot");
                anyhow::ensure!(
                    total >= 100_000,
                    "--total must cover at least one 10%-duty period (100000 cycles), got {total}"
                );
                report::fig4::print_measured(&report::fig4::compute_measured(
                    precision, window, total,
                ));
            } else {
                report::fig4::print(&report::fig4::compute(precision));
            }
        }
        Some("formats") => {
            let pts = report::formats::compute();
            report::formats::print(&pts);
            if let Some(path) = args.get("json") {
                let mut s = String::from("{\n  \"bench\": \"formats-curve\",\n");
                s.push_str(&report::formats::render_json(&pts));
                s.push_str("\n}\n");
                std::fs::write(path, s)?;
                println!("wrote {path}");
            }
        }
        Some("calib") => {
            let r = fpmax::energy::calibrate::calibration_report();
            println!("implied κ_latency    = {:.3}", r.kappa_latency);
            println!("implied κ_throughput = {:.3}", r.kappa_throughput);
            println!("implied leak density = {:.2} mW/mm²", r.leak_density);
            println!("\nper-unit model/silicon ratios:");
            println!("{:<8} {:>6} {:>6} {:>6} {:>6}", "unit", "freq", "dynE", "area", "leak");
            for (name, f, e, a, l) in &r.residuals {
                println!("{name:<8} {f:>6.3} {e:>6.3} {a:>6.3} {l:>6.3}");
            }
        }
        Some("sweep") => {
            let precision = precision_arg(&args)?;
            let kind = match args.get("kind").unwrap_or("fma") {
                "fma" => FpuKind::Fma,
                "cma" => FpuKind::Cma,
                other => anyhow::bail!("--kind must be fma or cma, got {other}"),
            };
            let tech = Technology::fdsoi28();
            let op = OperatingPoint::new(1.0, 0.0);
            let pts = match args.get("bb") {
                Some("adaptive") => {
                    // Phase-aware sweep: every candidate executes a
                    // measured low-utilization trace and gains the
                    // adaptive-BB energy column.
                    let window = args.get_parse("window", 1_000u64)?;
                    let ops = args.get_parse("sample-ops", 10_000usize)?;
                    dse::arch_sweep_measured_bb(
                        precision,
                        kind,
                        &tech,
                        op,
                        ops,
                        fpmax::arch::engine::Fidelity::WordLevel,
                        42,
                        window,
                        0.1,
                    )
                }
                Some(other) => anyhow::bail!("--bb must be adaptive for sweep, got {other}"),
                None => dse::arch_sweep(precision, kind, &tech, op),
            };
            let front = dse::frontier(&pts);
            println!("{} designs evaluated, {} on the Pareto frontier:", pts.len(), front.len());
            for &i in &front {
                let p = &pts[i];
                let bb_col = match p.bb_adaptive_pj_per_op {
                    Some(v) => format!("  {v:>6.2} pJ/op @10% adaptive-BB"),
                    None => String::new(),
                };
                println!(
                    "  stages={} booth={} tree={:<7} {:>7.1} GFLOPS/mm²  {:>6.2} pJ/FLOP{bb_col}",
                    p.config.stages,
                    p.config.booth.name(),
                    p.config.tree.name(),
                    p.eff.gflops_per_mm2,
                    p.eff.pj_per_flop,
                );
            }
        }
        Some("verify") => {
            let cfg = unit_arg(&args)?;
            let ops = args.get_parse("ops", 100_000usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            let workers = args.get_parse("workers", num_threads())?;
            let fidelity = fidelity_arg(&args, "gate")?;
            let unit = FpuUnit::generate(&cfg);
            let mut stream = OperandStream::new(cfg.precision, OperandMix::Anything, seed);
            let triples = stream.batch(ops);
            match fidelity {
                fpmax::arch::engine::Fidelity::GateLevel => {
                    let r = coordinator::verify_datapath_only(&unit, &triples, workers);
                    println!(
                        "{}: {} ops gate-level, {} mismatches, {:.2} Mops/s ({} workers)",
                        cfg.name(),
                        r.ops,
                        r.datapath_mismatches.len(),
                        r.ops as f64 / r.rust_secs / 1e6,
                        workers
                    );
                    anyhow::ensure!(r.clean(), "datapath mismatches: {:?}", r.datapath_mismatches);
                }
                tier => {
                    // Fast word tier (scalar or lane-batched SIMD) with a
                    // sampled gate-level cross-check.
                    let exec = fpmax::arch::engine::BatchExecutor::new(workers);
                    let t0 = std::time::Instant::now();
                    let (_, check) = exec.run_checked_tier(&unit, tier, &triples, 64);
                    let secs = t0.elapsed().as_secs_f64();
                    println!(
                        "{}: {} ops {}-level, {} gate-checked, {} mismatches, {:.2} Mops/s ({} workers)",
                        cfg.name(),
                        triples.len(),
                        tier.name(),
                        check.sampled,
                        check.mismatches.len(),
                        triples.len() as f64 / secs / 1e6,
                        workers
                    );
                    anyhow::ensure!(
                        check.clean(),
                        "{} tier diverged from gate level at indices {:?}",
                        tier.name(),
                        check.mismatches
                    );
                }
            }
            if args.get("bb").is_some() {
                windowed_bb_report(&cfg, &unit, fidelity, &triples, workers, &args)?;
            }
        }
        Some("fuzz") => {
            fuzz_cmd(&args)?;
        }
        Some("selftest") => {
            selftest(&args)?;
        }
        Some("serve") => {
            serve_cmd(&args)?;
        }
        Some("chaos") => {
            chaos_cmd(&args)?;
        }
        Some("replay") => {
            replay_cmd(&args)?;
        }
        Some("kernels") => {
            kernels_cmd(&args)?;
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!(
                "usage: fpmax <table1|table2|fig2c|fig3|fig4|calib|sweep|verify|fuzz|selftest|serve|chaos|replay|kernels> [options]"
            );
            std::process::exit(2);
        }
    }
    args.reject_unknown()?;
    Ok(())
}

/// The `fpmax fuzz` subcommand: differential conformance fuzzing of the
/// full tier stack (gate / scalar word / word-simd / host hardware) on
/// seeded uniform-bits and structured operand streams, all four op
/// kinds. Minimized counterexamples are always written to `--out`
/// (header-only when clean, so the CI artifact upload is
/// unconditional); any mismatch exits non-zero.
fn fuzz_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::arch::fuzz::{run_differential, standard_engines, FuzzConfig, OpKind, StreamKind};

    let ops = args.get_parse("ops", 200_000usize)?;
    let seed = args.get_parse("seed", 7u64)?;
    let max_ce = args.get_parse("max-counterexamples", 8usize)?;
    let out_path = args.get("out").map(|s| s.to_string());
    anyhow::ensure!(ops >= 1, "--ops must be at least 1");
    // `--format` selects any canonical format (or `all` = the full
    // transprecision matrix); `--precision sp|dp|both` is the original
    // spelling and keeps working unchanged.
    let precisions: Vec<Precision> = match (args.get("format"), args.get("precision")) {
        (Some(f), _) => match f {
            "all" => Precision::ALL.to_vec(),
            "both" => vec![Precision::Single, Precision::Double],
            one => vec![Precision::parse(one).ok_or_else(|| {
                anyhow::anyhow!(
                    "--format must be one of sp|dp|fp16|bf16|fp8e4m3|fp8e5m2|both|all, got {one}"
                )
            })?],
        },
        (None, p) => match p.unwrap_or("both") {
            "sp" => vec![Precision::Single],
            "dp" => vec![Precision::Double],
            "both" => vec![Precision::Single, Precision::Double],
            other => anyhow::bail!("--precision must be sp, dp or both, got {other}"),
        },
    };
    let streams: &[StreamKind] = match args.get("stream").unwrap_or("both") {
        "uniform" => &[StreamKind::UniformBits],
        "structured" => &[StreamKind::Structured],
        "both" => &[StreamKind::UniformBits, StreamKind::Structured],
        other => anyhow::bail!("--stream must be uniform, structured or both, got {other}"),
    };

    let json_path = args.get("json").map(|s| s.to_string());

    let mut artifact = format!(
        "# fpmax fuzz: differential counterexamples (edge_vectors.rs format)\n\
         # ops={ops} seed={seed} simd_feature={}\n",
        cfg!(feature = "simd")
    );
    let mut total_executed = 0usize;
    let mut total_ce = 0usize;
    let mut json_rows: Vec<String> = Vec::new();
    for &precision in &precisions {
        let (fma_cfg, cma_cfg) = (FpuConfig::fma_of(precision), FpuConfig::cma_of(precision));
        let fma_unit = FpuUnit::generate(&fma_cfg);
        let cma_unit = FpuUnit::generate(&cma_cfg);
        let engines = standard_engines(&fma_unit, &cma_unit);
        let fmt = fma_unit.format;
        for kind in OpKind::ALL {
            for &stream in streams {
                // Split the op budget across the streams so `--ops` is
                // the total per precision × kind (the CI smoke contract).
                let share = (ops / streams.len()).max(1);
                let mut fcfg = FuzzConfig::new(
                    share,
                    seed ^ ((fmt.sig_bits as u64) << 8),
                    stream,
                );
                fcfg.max_counterexamples = max_ce;
                let report = run_differential(fmt, kind, &engines, &fcfg);
                total_executed += report.executed;
                total_ce += report.counterexamples.len();
                println!(
                    "{} {:<4} {:<11} {:>8} ops  {} engines  {} counterexample(s)",
                    precision.name(),
                    kind.name(),
                    format!("{stream:?}"),
                    report.executed,
                    engines.len(),
                    report.counterexamples.len(),
                );
                json_rows.push(format!(
                    "    {{\"format\": \"{}\", \"kind\": \"{}\", \"stream\": \"{:?}\", \
                     \"executed\": {}, \"counterexamples\": {}, \"engines\": {}, \
                     \"packed_engine\": {}}}",
                    precision.name(),
                    kind.name(),
                    stream,
                    report.executed,
                    report.counterexamples.len(),
                    engines.len(),
                    fpmax::arch::softfloat::lanes::packed::supports(fmt),
                ));
                if !report.clean() {
                    artifact.push_str(&report.render());
                }
            }
        }
    }
    if total_ce == 0 {
        artifact.push_str("# none\n");
    }
    if let Some(path) = out_path {
        std::fs::write(&path, &artifact)?;
        println!("wrote {path}");
    }
    if let Some(path) = json_path {
        // The machine-readable `bench: "formats"` artifact the CI
        // format-matrix checker re-derives its verdicts from: raw
        // per-(format × kind × stream) differential counts plus a raw
        // packed-vs-SP-scalar-word throughput probe (the checker
        // recomputes the speedup, never trusts a precomputed ratio).
        let probes = packed_probe(&precisions);
        let mut s = String::from("{\n  \"bench\": \"formats\",\n  \"measured\": true,\n");
        s.push_str(&format!("  \"ops_per_format_kind\": {ops},\n  \"seed\": {seed},\n"));
        s.push_str(&format!("  \"simd_feature\": {},\n", cfg!(feature = "simd")));
        s.push_str("  \"thresholds\": {\n    \"max_counterexamples\": 0,\n");
        s.push_str("    \"min_packed_speedup_fp16_fma_vs_sp_scalar_word\": 1.5\n  },\n");
        s.push_str("  \"runs\": [\n");
        s.push_str(&json_rows.join(",\n"));
        s.push_str("\n  ],\n  \"packed_probe\": [\n");
        for (i, p) in probes.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"format\": \"{}\", \"kind\": \"fma\", \"elems_per_word\": {}, \
                 \"packed_elems_per_s\": {:.0}, \"sp_scalar_word_ops_per_s\": {:.0}}}{}\n",
                p.0,
                p.1,
                p.2,
                p.3,
                if i + 1 == probes.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, &s)?;
        println!("wrote {path}");
    }
    println!(
        "fuzz total: {total_executed} ops executed, {total_ce} counterexample(s), simd_feature={}",
        cfg!(feature = "simd")
    );
    anyhow::ensure!(
        total_ce == 0,
        "differential fuzzing found {total_ce} counterexample(s):\n{artifact}"
    );
    Ok(())
}

/// Raw packed-SWAR throughput probe for the `bench: "formats"` artifact:
/// FMA elements/s through `lanes::packed` per requested small format,
/// next to the SP scalar-word baseline the CI threshold is expressed
/// against. Returns `(format, elems_per_word, packed_elems_per_s,
/// sp_scalar_word_ops_per_s)` rows — raw rates only; the checker derives
/// the speedup itself.
fn packed_probe(precisions: &[Precision]) -> Vec<(&'static str, usize, f64, f64)> {
    use fpmax::arch::engine::{Datapath, Fidelity, UnitDatapath};
    use fpmax::arch::softfloat::lanes::packed;
    use std::time::Instant;

    const N: usize = 200_000;
    fn rate(mut pass: impl FnMut() -> u64, elems: usize) -> f64 {
        let mut iters = 0usize;
        let mut acc = 0u64;
        let t0 = Instant::now();
        loop {
            acc ^= pass();
            iters += 1;
            if t0.elapsed().as_secs_f64() >= 0.05 && iters >= 2 {
                break;
            }
        }
        std::hint::black_box(acc);
        (elems * iters) as f64 / t0.elapsed().as_secs_f64()
    }

    let small: Vec<Precision> =
        precisions.iter().copied().filter(|p| packed::supports(p.format())).collect();
    if small.is_empty() {
        return Vec::new();
    }

    let sp = UnitDatapath::generate(&FpuConfig::sp_fma(), Fidelity::WordLevel);
    let sp_triples = OperandStream::new(Precision::Single, OperandMix::Finite, 11).batch(N);
    let sp_rate = rate(
        || {
            let mut acc = 0u64;
            for t in &sp_triples {
                acc ^= sp.fmac_one(t.a, t.b, t.c);
            }
            acc
        },
        N,
    );

    let mut out = Vec::new();
    for p in small {
        let fmt = p.format();
        let epw = packed::elems_per_word(fmt);
        let words = N / epw;
        let triples = OperandStream::new(p, OperandMix::Finite, 11).batch(words * epw);
        let mut buf = vec![0u64; epw];
        let (mut aw, mut bw, mut cw) =
            (Vec::with_capacity(words), Vec::with_capacity(words), Vec::with_capacity(words));
        for ch in triples.chunks(epw) {
            for (sel, dst) in [(0usize, &mut aw), (1, &mut bw), (2, &mut cw)] {
                for (i, t) in ch.iter().enumerate() {
                    buf[i] = match sel {
                        0 => t.a,
                        1 => t.b,
                        _ => t.c,
                    };
                }
                dst.push(packed::pack_word(fmt, &buf));
            }
        }
        let mut ow = vec![0u32; words];
        let packed_rate = rate(
            || {
                packed::fma_words(fmt, &aw, &bw, &cw, &mut ow);
                ow[0] as u64
            },
            words * epw,
        );
        out.push((p.name(), epw, packed_rate, sp_rate));
    }
    out
}

/// End-to-end chip self-test: JTAG-load stimulus, run all four FPUs at
/// speed, read back, check against golden softfloat, and cross-check the
/// SP/DP FMA streams against the AOT artifacts through PJRT.
fn selftest(args: &Args) -> fpmax::Result<()> {
    let ops = args.get_parse("ops", 65_536usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
    let ram_depth = 1024usize;

    println!("=== FPMax chip self-test: {ops} ops/unit ===");
    let mut chip = FpMaxChip::new(ram_depth);
    let mut total_ops = 0u64;
    let mut total_cycles = 0u64;
    let mut mismatches = 0usize;

    for (sel, cfg) in [
        (UnitSel::DpCma, FpuConfig::dp_cma()),
        (UnitSel::DpFma, FpuConfig::dp_fma()),
        (UnitSel::SpCma, FpuConfig::sp_cma()),
        (UnitSel::SpFma, FpuConfig::sp_fma()),
    ] {
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, seed);
        let mut done = 0usize;
        while done < ops {
            let n = ram_depth.min(ops - done);
            let triples = stream.batch(n);
            let a: Vec<u64> = triples.iter().map(|t| t.a).collect();
            let b: Vec<u64> = triples.iter().map(|t| t.b).collect();
            let c: Vec<u64> = triples.iter().map(|t| t.c).collect();
            {
                let mut port = chip.jtag();
                port.load_bank(BANK_STIM_A, &a)?;
                port.load_bank(BANK_STIM_B, &b)?;
                port.load_bank(BANK_STIM_C, &c)?;
                // One burst instruction per RAM fill (max repeat 1024).
                let prog = [Instruction::fmac_burst(sel, 0, n as u16).encode() as u64, 0];
                port.load_bank(BANK_PROGRAM, &prog)?;
            }
            let stats = chip.run()?;
            total_ops += stats.ops;
            total_cycles += stats.cycles;
            let results = chip.jtag().read_bank(BANK_RESULT, n)?;
            let unit = chip.unit(sel);
            for i in 0..n {
                let want = fpmax::chip::expected_result(
                    unit,
                    fpmax::arch::rounding::RoundMode::NearestEven,
                    a[i],
                    b[i],
                    c[i],
                    fpmax::chip::Op::Fmac,
                );
                if results[i] != want {
                    mismatches += 1;
                }
            }
            done += n;
        }
        println!("{:<8} {ops} ops at speed: OK", format!("{sel:?}"));
    }
    println!("chip total: {total_ops} ops in {total_cycles} at-speed cycles, {mismatches} mismatches");
    anyhow::ensure!(mismatches == 0, "{mismatches} chip-vs-golden mismatches");

    // PJRT cross-check of the fused FMA streams against the artifacts.
    match Runtime::cpu(&artifacts) {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            for (name, cfg) in [("sp_fmac", FpuConfig::sp_fma()), ("dp_fmac", FpuConfig::dp_fma())]
            {
                let artifact = rt.load_fmac(name, cfg.precision)?;
                let unit = FpuUnit::generate(&cfg);
                let mut stream =
                    OperandStream::new(cfg.precision, OperandMix::Finite, seed ^ 0x5a5a);
                let triples = stream.batch(ops.min(4 * artifact.batch));
                let r = coordinator::verify_batch(&unit, &artifact, &triples, num_threads())?;
                println!(
                    "{name}: {} ops  artifact-vs-golden {}  datapath-vs-golden {}  toggles {}  (pjrt {:.1} ms, rust {:.1} ms)",
                    r.ops,
                    r.artifact_mismatches.len(),
                    r.datapath_mismatches.len(),
                    r.artifact_toggles,
                    r.pjrt_secs * 1e3,
                    r.rust_secs * 1e3,
                );
                anyhow::ensure!(
                    r.clean(),
                    "cross-check failed: {:?}",
                    r.artifact_mismatches.first()
                );
            }
            println!("\nSELFTEST PASS: chip, golden model, and AOT artifacts agree bit-for-bit");
        }
        Err(e) => {
            println!("\nPJRT unavailable ({e}); chip-vs-golden portion passed");
        }
    }
    Ok(())
}

/// The `fpmax serve` subcommand: measure a plain windowed-tracked batch
/// baseline, then drive the same ops through the streaming serve layer
/// (async queue → coalesced batches → stealing scheduler → window ring →
/// live BB controller) and gate on measured behavior: clean sampled gate
/// cross-checks, a streamed bias schedule bit-identical to the post-hoc
/// one, bounded tail latency, and a sustained-throughput floor.
fn serve_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::arch::engine::{BatchExecutor, UnitDatapath};
    use fpmax::runtime::serve::{ServeConfig, ServeLoad};

    if args.flag("routed") {
        return serve_routed_cmd(args);
    }
    let cfg = unit_arg(args)?;
    let ops = args.get_parse("ops", 1_000_000usize)?;
    let producers = args.get_parse("producers", 4usize)?;
    let workers = args.get_parse("workers", num_threads())?;
    let fidelity = fidelity_arg(args, "word-simd")?;
    let adaptive = bb_adaptive_arg(args)?;
    let window = args.get_parse("window", 4_096usize)?;
    let duty = args.get_parse("duty", 1.0f64)?;
    let sub_ops = args.get_parse("sub-ops", 8_192usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let ring = args.get_parse("ring", 1_024usize)?;
    let max_p99_ratio = args.get_parse("max-p99-ratio", f64::INFINITY)?;
    let min_sustained_ratio = args.get_parse("min-sustained-ratio", 0.0f64)?;
    let json_path = args.get("json").map(|s| s.to_string());
    anyhow::ensure!(ops >= 1, "--ops must be at least 1");
    anyhow::ensure!(window >= 1, "--window must be at least 1 op");
    anyhow::ensure!(duty > 0.0 && duty <= 1.0, "--duty must be in (0, 1], got {duty}");

    let unit = FpuUnit::generate(&cfg);
    let mut scfg = ServeConfig::nominal(&cfg, adaptive)?;
    scfg.workers = workers;
    scfg.window_ops = window;
    scfg.ring_windows = ring;

    // Plain-batch baseline: the same ops as ONE windowed-tracked batch
    // through the executor — the serving-equivalent fidelity and
    // tracking with none of the queueing. (The untracked run is also
    // timed, for reference in the JSON.)
    let dp = UnitDatapath::new(&unit, fidelity);
    let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, seed);
    let triples = stream.batch(ops);
    let mut out = vec![0u64; ops];
    let exec = BatchExecutor::new(workers);
    // Warmup spawns the pool and calibrates the chunk size.
    exec.run_windowed_into(&dp, &triples, &mut out, window)?;
    let t0 = std::time::Instant::now();
    exec.run_windowed_into(&dp, &triples, &mut out, window)?;
    let plain_windowed = ops as f64 / t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    exec.run_into(&dp, &triples, &mut out)?;
    let plain_untracked = ops as f64 / t1.elapsed().as_secs_f64();
    drop(triples);
    drop(out);

    let load = ServeLoad { total_ops: ops, producers, sub_ops, duty, seed };
    let report = coordinator::serve_datapath(&unit, fidelity, load, scfg)?;

    let ratio = report.sustained_ops_per_s / plain_windowed.max(1e-12);
    let p99_over_p50 = if report.p50_latency_s > 0.0 {
        report.p99_latency_s / report.p50_latency_s
    } else {
        1.0
    };
    println!(
        "{}: served {} ops ({} submissions → {} batches, {} producers, {} workers, {}-level)",
        cfg.name(),
        report.ops,
        report.submissions,
        report.batches,
        producers,
        workers,
        fidelity.name()
    );
    println!(
        "throughput: serve {:.2} Mops/s vs plain windowed batch {:.2} Mops/s ({ratio:.2}×; untracked {:.2})",
        report.sustained_ops_per_s / 1e6,
        plain_windowed / 1e6,
        plain_untracked / 1e6
    );
    println!(
        "submission latency: p50 {:.1} µs, p99 {:.1} µs ({p99_over_p50:.1}× p50)",
        report.p50_latency_s * 1e6,
        report.p99_latency_s * 1e6
    );
    println!(
        "streamed BB [{}]: {} windows (occupancy {:.2}), {:.3} pJ/op, schedule {} post-hoc, energy {} (ring coalesced {})",
        if adaptive { "adaptive" } else { "static" },
        report.streamed.windows,
        report.occupancy,
        report.streamed.energy.pj_per_op,
        if report.schedule_matches { "==" } else { "!=" },
        if report.energy_matches { "bit-identical" } else { "DIVERGED" },
        report.ring_coalesced
    );
    println!(
        "gate cross-check: {} sampled, {} mismatches",
        report.crosscheck_sampled, report.crosscheck_mismatches
    );

    if let Some(path) = json_path {
        let json = format!(
            "{{\n  \"unit\": \"{}\",\n  \"fidelity\": \"{}\",\n  \"ops\": {},\n  \"producers\": {producers},\n  \"workers\": {workers},\n  \"window_ops\": {window},\n  \"sub_ops_mean\": {sub_ops},\n  \"duty\": {duty},\n  \"bb_policy\": \"{}\",\n  \"submissions\": {},\n  \"batches\": {},\n  \"sustained_ops_per_s\": {:.0},\n  \"plain_windowed_ops_per_s\": {plain_windowed:.0},\n  \"plain_untracked_ops_per_s\": {plain_untracked:.0},\n  \"serve_vs_plain_ratio\": {ratio:.4},\n  \"p50_submit_us\": {:.3},\n  \"p99_submit_us\": {:.3},\n  \"p99_over_p50\": {p99_over_p50:.3},\n  \"streamed_pj_per_op\": {:.6},\n  \"posthoc_pj_per_op\": {:.6},\n  \"bb_schedule_match\": {},\n  \"bb_energy_match\": {},\n  \"ring_coalesced\": {},\n  \"crosscheck_sampled\": {},\n  \"crosscheck_mismatches\": {}\n}}\n",
            cfg.name(),
            fidelity.name(),
            report.ops,
            if adaptive { "adaptive" } else { "static" },
            report.submissions,
            report.batches,
            report.sustained_ops_per_s,
            report.p50_latency_s * 1e6,
            report.p99_latency_s * 1e6,
            report.streamed.energy.pj_per_op,
            report.posthoc_energy.pj_per_op,
            report.schedule_matches,
            report.energy_matches,
            report.ring_coalesced,
            report.crosscheck_sampled,
            report.crosscheck_mismatches,
        );
        std::fs::write(&path, json)?;
        println!("wrote {path}");
    }

    // Hard gates (the CI serve smoke step relies on these exit codes).
    anyhow::ensure!(
        report.crosscheck_mismatches == 0,
        "sampled gate cross-check found {} mismatches at global indices {:?}",
        report.crosscheck_mismatches,
        report.mismatch_indices
    );
    anyhow::ensure!(
        report.bb_gate_ok(),
        "streamed BB diverged from post-hoc (schedule match {}, energy match {}, received-stream match {}, activity preserved {}, ring coalesced {})",
        report.schedule_matches,
        report.energy_matches,
        report.received_schedule_matches,
        report.activity_preserved,
        report.ring_coalesced
    );
    anyhow::ensure!(
        p99_over_p50 <= max_p99_ratio,
        "p99 submission latency is {p99_over_p50:.1}× p50, above the --max-p99-ratio {max_p99_ratio}× budget"
    );
    anyhow::ensure!(
        ratio >= min_sustained_ratio,
        "serve sustained only {ratio:.2}× the plain windowed batch throughput, below the --min-sustained-ratio {min_sustained_ratio} floor"
    );
    Ok(())
}

/// The `fpmax serve --routed` subcommand: the sharded multi-unit serve
/// router over the full Table-1 fleet. Four shards — one per fabricated
/// unit at the chosen fidelity tier, each with its own persistent pool
/// (sized from one fleet-wide worker budget), window ring, and live BB
/// controller — take classified submissions from mixed SP/DP
/// latency/bulk producers, dispatched by static unit affinity with
/// optional load-aware spill. Gates on measured behavior: clean
/// cross-checks and streamed-vs-post-hoc BB identity on **every**
/// shard, zero misrouted submissions while spill is off, bounded fleet
/// tail latency, and a fleet sustained-throughput floor against the
/// best single shard.
fn serve_routed_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::coordinator::RoutedLoad;
    use fpmax::runtime::router::{RouterConfig, ServeRouter, WorkloadClass};

    let ops = args.get_parse("ops", 200_000usize)?;
    let producers_per_class = args.get_parse("producers", 1usize)?;
    let workers_budget = args.get_parse("workers", num_threads())?;
    let fidelity = fidelity_arg(args, "word-simd")?;
    let adaptive = bb_adaptive_arg(args)?;
    let window = args.get_parse("window", 4_096usize)?;
    let duty = args.get_parse("duty", 1.0f64)?;
    let sub_ops = args.get_parse("sub-ops", 8_192usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let ring = args.get_parse("ring", 1_024usize)?;
    let spill = args.get_parse("spill-pressure", usize::MAX)?;
    let max_p99_ratio = args.get_parse("max-p99-ratio", f64::INFINITY)?;
    let min_sustained_ratio = args.get_parse("min-sustained-ratio", 0.0f64)?;
    let json_path = args.get("json").map(|s| s.to_string());
    anyhow::ensure!(ops >= 1, "--ops must be at least 1");
    anyhow::ensure!(window >= 1, "--window must be at least 1 op");
    anyhow::ensure!(duty > 0.0 && duty <= 1.0, "--duty must be in (0, 1], got {duty}");
    let spill_off = spill == usize::MAX;

    let specs = ServeRouter::fleet_nominal(fidelity, adaptive, workers_budget, window, ring)?;
    let rcfg = if spill_off {
        RouterConfig::no_spill(workers_budget)
    } else {
        RouterConfig::with_spill(workers_budget, spill)
    };
    let load = RoutedLoad { total_ops: ops, producers_per_class, sub_ops, duty, seed };
    let report = fpmax::coordinator::serve_routed(&specs, rcfg, fidelity, load)?;

    let best = report.best_shard_ops_per_s();
    let fleet_ratio = report.fleet_vs_best_shard_ratio();
    let p99_over_p50 = report.fleet_p99_over_p50();
    println!(
        "routed fleet: {} shards, {} ops ({} submissions, {} producers, {} workers budget, {}-level)",
        report.shards.len(),
        report.ops,
        report.submissions,
        4 * producers_per_class,
        workers_budget,
        fidelity.name()
    );
    for s in &report.shards {
        println!(
            "  {:<7} [{}] workers {}  ops {:>9}  sustained {:>8.2} Mops/s  p50 {:>7.1} µs  p99 {:>7.1} µs  occ {:.2}  bb {}  ring-coalesced {}  spilled-in {}",
            s.unit,
            s.config.kind.name(),
            s.workers,
            s.report.ops,
            s.report.sustained_ops_per_s / 1e6,
            s.report.p50_latency_s * 1e6,
            s.report.p99_latency_s * 1e6,
            s.report.occupancy,
            if s.report.bb_gate_ok() { "ok" } else { "DIVERGED" },
            s.report.ring_coalesced,
            s.spilled_in,
        );
    }
    let hist = report.class_histogram();
    for class in WorkloadClass::ALL {
        let row: Vec<String> = report
            .shards
            .iter()
            .zip(&hist[class.index()])
            .map(|(s, &n)| format!("{}:{n}", s.unit))
            .collect();
        println!("  class {:<10} → {}", class.name(), row.join("  "));
    }
    println!(
        "fleet: sustained {:.2} Mops/s ({fleet_ratio:.2}× best shard {:.2}), p50 {:.1} µs, p99 {:.1} µs ({p99_over_p50:.1}×), {:.3} pJ/op merged, misrouted {}/{} ({}), spilled {}",
        report.fleet_sustained_ops_per_s / 1e6,
        best / 1e6,
        report.fleet_p50_latency_s * 1e6,
        report.fleet_p99_latency_s * 1e6,
        report.fleet_energy.pj_per_op,
        report.misrouted,
        report.submissions,
        if spill_off { "spill off" } else { "spill on" },
        report.spilled,
    );
    println!(
        "gate cross-check: {} sampled, {} mismatches across the fleet",
        report.crosscheck_sampled(),
        report.crosscheck_mismatches()
    );

    if let Some(path) = json_path {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"routed\": true,\n");
        s.push_str(&format!("  \"fidelity\": \"{}\",\n", fidelity.name()));
        s.push_str(&format!("  \"ops\": {},\n", report.ops));
        s.push_str(&format!("  \"producers_per_class\": {producers_per_class},\n"));
        s.push_str(&format!("  \"workers_budget\": {workers_budget},\n"));
        s.push_str(&format!("  \"window_ops\": {window},\n"));
        s.push_str(&format!("  \"sub_ops_mean\": {sub_ops},\n"));
        s.push_str(&format!("  \"duty\": {duty},\n"));
        s.push_str(&format!(
            "  \"bb_policy\": \"{}\",\n",
            if adaptive { "adaptive" } else { "static" }
        ));
        s.push_str(&format!(
            "  \"spill_pressure_ops\": {},\n",
            if spill_off { "null".to_string() } else { spill.to_string() }
        ));
        s.push_str(&format!("  \"submissions\": {},\n", report.submissions));
        s.push_str(&format!("  \"misrouted\": {},\n", report.misrouted));
        s.push_str(&format!("  \"spilled\": {},\n", report.spilled));
        s.push_str(&format!(
            "  \"misrouted_fraction\": {:.6},\n",
            report.misrouted_fraction()
        ));
        s.push_str(&format!(
            "  \"fleet_sustained_ops_per_s\": {:.0},\n",
            report.fleet_sustained_ops_per_s
        ));
        s.push_str(&format!("  \"best_shard_ops_per_s\": {best:.0},\n"));
        s.push_str(&format!("  \"fleet_vs_best_shard_ratio\": {fleet_ratio:.4},\n"));
        s.push_str(&format!(
            "  \"fleet_p50_us\": {:.3},\n",
            report.fleet_p50_latency_s * 1e6
        ));
        s.push_str(&format!(
            "  \"fleet_p99_us\": {:.3},\n",
            report.fleet_p99_latency_s * 1e6
        ));
        s.push_str(&format!("  \"fleet_p99_over_p50\": {p99_over_p50:.3},\n"));
        s.push_str(&format!(
            "  \"fleet_pj_per_op\": {:.6},\n",
            report.fleet_energy.pj_per_op
        ));
        s.push_str(&format!(
            "  \"all_shards_bb_identity\": {},\n",
            report.bb_gate_ok()
        ));
        s.push_str(&format!(
            "  \"crosscheck_sampled\": {},\n",
            report.crosscheck_sampled()
        ));
        s.push_str(&format!(
            "  \"crosscheck_mismatches\": {},\n",
            report.crosscheck_mismatches()
        ));
        s.push_str("  \"class_histogram\": {\n");
        for (ci, class) in WorkloadClass::ALL.into_iter().enumerate() {
            let row: Vec<String> =
                hist[class.index()].iter().map(|n| n.to_string()).collect();
            s.push_str(&format!(
                "    \"{}\": [{}]{}\n",
                class.name(),
                row.join(", "),
                if ci + 1 == WorkloadClass::ALL.len() { "" } else { "," }
            ));
        }
        s.push_str("  },\n");
        s.push_str("  \"shards\": [\n");
        for (si, sh) in report.shards.iter().enumerate() {
            s.push_str("    {\n");
            s.push_str(&format!("      \"unit\": \"{}\",\n", sh.unit));
            s.push_str(&format!("      \"kind\": \"{}\",\n", sh.config.kind.name()));
            s.push_str(&format!("      \"tier\": \"{}\",\n", sh.tier.name()));
            s.push_str(&format!("      \"workers\": {},\n", sh.workers));
            s.push_str(&format!("      \"ops\": {},\n", sh.report.ops));
            s.push_str(&format!(
                "      \"sustained_ops_per_s\": {:.0},\n",
                sh.report.sustained_ops_per_s
            ));
            s.push_str(&format!(
                "      \"p50_submit_us\": {:.3},\n",
                sh.report.p50_latency_s * 1e6
            ));
            s.push_str(&format!(
                "      \"p99_submit_us\": {:.3},\n",
                sh.report.p99_latency_s * 1e6
            ));
            s.push_str(&format!("      \"occupancy\": {:.4},\n", sh.report.occupancy));
            s.push_str(&format!(
                "      \"streamed_pj_per_op\": {:.6},\n",
                sh.report.streamed.energy.pj_per_op
            ));
            s.push_str(&format!("      \"bb_gate_ok\": {},\n", sh.report.bb_gate_ok()));
            s.push_str(&format!(
                "      \"ring_coalesced\": {},\n",
                sh.report.ring_coalesced
            ));
            s.push_str(&format!(
                "      \"crosscheck_mismatches\": {},\n",
                sh.report.crosscheck_mismatches
            ));
            s.push_str(&format!("      \"spilled_in\": {}\n", sh.spilled_in));
            s.push_str(if si + 1 == report.shards.len() { "    }\n" } else { "    },\n" });
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, s)?;
        println!("wrote {path}");
    }

    // Hard gates (the routed-serve CI smoke step relies on these exit
    // codes).
    anyhow::ensure!(
        report.crosscheck_mismatches() == 0,
        "sampled gate cross-check found {} mismatches across the fleet",
        report.crosscheck_mismatches()
    );
    for s in &report.shards {
        anyhow::ensure!(
            s.report.bb_gate_ok(),
            "{}: streamed BB diverged from post-hoc (schedule match {}, energy match {}, received-stream match {}, activity preserved {}, ring coalesced {})",
            s.unit,
            s.report.schedule_matches,
            s.report.energy_matches,
            s.report.received_schedule_matches,
            s.report.activity_preserved,
            s.report.ring_coalesced
        );
    }
    if spill_off {
        anyhow::ensure!(
            report.misrouted == 0,
            "{} submissions misrouted under the static policy with spill off",
            report.misrouted
        );
    }
    anyhow::ensure!(
        p99_over_p50 <= max_p99_ratio,
        "fleet p99 latency is {p99_over_p50:.1}× p50, above the --max-p99-ratio {max_p99_ratio}× budget"
    );
    anyhow::ensure!(
        fleet_ratio >= min_sustained_ratio,
        "fleet sustained only {fleet_ratio:.2}× the best single shard, below the --min-sustained-ratio {min_sustained_ratio} floor"
    );
    Ok(())
}

/// The `fpmax chaos` subcommand: the routed fleet under a seeded fault
/// plan, producers on the resilient deadline + retry path, supervisor
/// respawning killed shards mid-run. Exit code IS the gate: non-zero
/// unless zero tickets hung, zero ops were lost, the cross-check stayed
/// clean on surviving work, every scheduled fault fired, every killed
/// shard respawned, and the fleet report conserved ops/energy/latency
/// accounting across shard incarnations.
fn chaos_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::coordinator::RoutedLoad;
    use fpmax::runtime::chaos::FaultPlan;
    use fpmax::runtime::router::{RetryPolicy, RouterConfig, ServeRouter, WorkloadClass};
    use std::time::Duration;

    let ops = args.get_parse("ops", 100_000usize)?;
    let producers_per_class = args.get_parse("producers", 1usize)?;
    let workers_budget = args.get_parse("workers", num_threads())?;
    let fidelity = fidelity_arg(args, "word-simd")?;
    let adaptive = bb_adaptive_arg(args)?;
    let window = args.get_parse("window", 4_096usize)?;
    let sub_ops = args.get_parse("sub-ops", 4_096usize)?;
    let seed = args.get_parse("seed", 42u64)?;
    let ring = args.get_parse("ring", 1_024usize)?;
    let deadline_ms = args.get_parse("deadline-ms", 60_000u64)?;
    let retries = args.get_parse("retries", 8u32)?;
    let backoff_us = args.get_parse("backoff-us", 500u64)?;
    let backoff_cap_ms = args.get_parse("backoff-cap-ms", 50u64)?;
    let json_path = args.get("json").map(|s| s.to_string());
    anyhow::ensure!(ops >= 1, "--ops must be at least 1");
    anyhow::ensure!(window >= 1, "--window must be at least 1 op");
    anyhow::ensure!(deadline_ms >= 1, "--deadline-ms must be at least 1");

    let specs = ServeRouter::fleet_nominal(fidelity, adaptive, workers_budget, window, ring)?;
    let plan = match args.get("plan").unwrap_or("kill-all") {
        "kill-all" => FaultPlan::kill_each_shard_once(seed, specs.len(), ops as u64),
        "full" => {
            FaultPlan::full_drill(seed, specs.len(), WorkloadClass::ALL.len(), ops as u64)
        }
        "none" => FaultPlan::none(seed),
        other => anyhow::bail!("--plan must be kill-all, full or none, got {other}"),
    };
    let rcfg = RouterConfig::no_spill(workers_budget);
    let load = RoutedLoad { total_ops: ops, producers_per_class, sub_ops, duty: 1.0, seed };
    let retry = RetryPolicy::bounded(
        retries,
        Duration::from_micros(backoff_us),
        Duration::from_millis(backoff_cap_ms),
    );
    let outcome = fpmax::coordinator::serve_chaos(
        &specs,
        rcfg,
        fidelity,
        load,
        &plan,
        Duration::from_millis(deadline_ms),
        retry,
    )?;
    let report = &outcome.report;
    let p = &report.producer;

    println!(
        "chaos: {} shards, seed {}, plan {} fault(s) ({} fired) — kills {}, worker panics {}, ring floods {}, latency {}, NaN storms {}",
        report.shards,
        report.seed,
        report.faults_planned,
        report.faults_fired,
        report.kills,
        report.worker_panics,
        report.ring_floods,
        report.latency_injections,
        report.nan_storms,
    );
    println!(
        "producer ledger: {} submissions ({} ops) → {} completed, {} errored, {} hung; {} retries",
        p.submitted_subs, p.submitted_ops, p.completed_subs, p.errored_subs, p.hung_subs, p.retries,
    );
    println!(
        "fleet: {} ops across incarnations, {} respawns, {} rerouted-on-failure, crosscheck {}/{} mismatches, {:.3} pJ/op merged, conservation {}",
        report.fleet_ops,
        report.respawns,
        report.rerouted_on_failure,
        report.crosscheck_mismatches,
        report.crosscheck_sampled,
        report.fleet_pj_per_op,
        if report.conservation_ok { "exact" } else { "BROKEN" },
    );
    for sh in &outcome.fleet.shards {
        println!(
            "  {:<7} respawns {}  incarnation ops {:>8} (+{} prior)  rerouted {}  health {:?}",
            sh.unit,
            sh.respawns,
            sh.report.ops,
            sh.prior.iter().map(|r| r.ops).sum::<u64>(),
            sh.rerouted_on_failure,
            sh.health,
        );
    }

    if let Some(path) = json_path {
        std::fs::write(&path, report.render_json())?;
        println!("wrote {path}");
    }

    // Hard gates (the CI chaos smoke step relies on these exit codes).
    anyhow::ensure!(
        report.zero_hung(),
        "{} submission(s) ({} ops) hung past the {deadline_ms} ms deadline",
        p.hung_subs,
        p.hung_ops
    );
    anyhow::ensure!(
        report.zero_lost(),
        "op ledger does not balance: {} completed + {} errored != {} submitted",
        p.completed_ops,
        p.errored_ops,
        p.submitted_ops
    );
    anyhow::ensure!(
        report.crosscheck_clean(),
        "sampled gate cross-check found {} mismatches on surviving work",
        report.crosscheck_mismatches
    );
    anyhow::ensure!(
        report.coverage_ok(),
        "only {} of {} scheduled faults fired",
        report.faults_fired,
        report.faults_planned
    );
    anyhow::ensure!(
        report.respawns >= report.kills,
        "{} dispatcher kill(s) but only {} respawn(s) — a shard stayed dead",
        report.kills,
        report.respawns
    );
    anyhow::ensure!(
        report.conservation_ok,
        "fleet report accounting is not conserved across shard incarnations"
    );
    Ok(())
}

/// The `fpmax replay` subcommand: seeded multi-tenant trace replay
/// judging the routing policies. See the module docs for the experiment
/// description; the hard gates are per-arm (ledger balanced, nothing
/// hung, cross-check clean, every fault fired, conservation exact),
/// plus digest bit-identity under `--verify-determinism` and the
/// static-vs-dynamic dominance verdict under `--expect-dominance`.
fn replay_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::coordinator::{ReplayOutcome, ReplayReport};
    use fpmax::runtime::chaos::FaultPlan;
    use fpmax::runtime::router::{
        EnergyAware, RetryPolicy, RoutePolicy, RouterConfig, ServeRouter, ShardSpec,
        StaticAffinity,
    };
    use fpmax::runtime::serve::ServeConfig;
    use fpmax::runtime::trace::{Trace, TraceConfig, SMALL_TIERS};
    use std::sync::Arc;
    use std::time::Duration;

    let trace_name = args.get("trace").unwrap_or("diurnal-skew").to_string();
    let ops = args.get_parse("ops", 60_000u64)?;
    let seed = args.get_parse("seed", 42u64)?;
    let workers_budget = args.get_parse("workers", num_threads())?;
    let fidelity = fidelity_arg(args, "word-simd")?;
    let adaptive = bb_adaptive_arg(args)?;
    let window = args.get_parse("window", 2_048usize)?;
    let ring = args.get_parse("ring", 1_024usize)?;
    let deadline_ms = args.get_parse("deadline-ms", 60_000u64)?;
    // Effectively-unbounded retries by default: with retryable faults
    // outwaited, completed == submitted, which is what pins the replay
    // digest (wall-clock effects stay out of the ledger).
    let retries = args.get_parse("retries", 200u32)?;
    let backoff_us = args.get_parse("backoff-us", 200u64)?;
    let backoff_cap_ms = args.get_parse("backoff-cap-ms", 10u64)?;
    let verify_det = args.flag("verify-determinism");
    let expect_dom = args.flag("expect-dominance");
    let policy_sel = args.get("policy").unwrap_or("both").to_string();
    let json_path = args.get("json").map(|s| s.to_string());
    anyhow::ensure!(ops >= 1, "--ops must be at least 1");
    anyhow::ensure!(window >= 1, "--window must be at least 1 op");
    anyhow::ensure!(deadline_ms >= 1, "--deadline-ms must be at least 1");

    let tcfg = TraceConfig::preset(&trace_name, seed, ops).ok_or_else(|| {
        anyhow::anyhow!(
            "--trace must be one of {:?}, got {trace_name}",
            TraceConfig::PRESETS
        )
    })?;
    let trace = Trace::generate(tcfg)?;
    println!(
        "trace {trace_name}: {} events from {} tenants, {} ops, last slot {}, fingerprint {:016x}",
        trace.events.len(),
        tcfg.tenants,
        trace.total_ops(),
        trace.last_slot(),
        trace.fingerprint,
    );

    // The Table-1 four, plus a CMA + FMA shard per transprecision tier
    // the trace actually arms — the static policy hard-errors on any
    // class no shard serves, so the fleet must cover the trace's mix.
    let build_fleet = || -> fpmax::Result<Vec<ShardSpec>> {
        let mut specs =
            ServeRouter::fleet_nominal(fidelity, adaptive, workers_budget, window, ring)?;
        for (prec, &frac) in SMALL_TIERS.iter().zip(&tcfg.small_fracs) {
            if frac > 0.0 {
                for config in [FpuConfig::cma_of(*prec), FpuConfig::fma_of(*prec)] {
                    let mut serve = ServeConfig::nominal(&config, adaptive)?;
                    serve.workers = 1;
                    serve.window_ops = window;
                    serve.ring_windows = ring;
                    specs.push(ShardSpec { config, tier: fidelity, serve });
                }
            }
        }
        Ok(specs)
    };
    let specs = build_fleet()?;
    let plan = match args.get("plan").unwrap_or("none") {
        "none" => FaultPlan::none(seed),
        "kill-all-slots" => {
            FaultPlan::kill_each_shard_once_at_slots(seed, specs.len(), trace.last_slot().max(1))
        }
        other => anyhow::bail!("--plan must be none or kill-all-slots, got {other}"),
    };
    let retry = RetryPolicy::bounded(
        retries,
        Duration::from_micros(backoff_us),
        Duration::from_millis(backoff_cap_ms),
    );
    let deadline = Duration::from_millis(deadline_ms);

    let run_arm = |policy: Arc<dyn RoutePolicy>| -> fpmax::Result<ReplayOutcome> {
        let specs = build_fleet()?;
        let rcfg = RouterConfig::no_spill(workers_budget);
        fpmax::coordinator::serve_trace(
            &specs, rcfg, fidelity, &trace, policy, &plan, deadline, retry,
        )
    };
    let policies: Vec<(&str, Arc<dyn RoutePolicy>)> = match policy_sel.as_str() {
        "static" => vec![("static", Arc::new(StaticAffinity))],
        "energy-aware" => vec![("energy-aware", Arc::new(EnergyAware::nominal()))],
        "both" => vec![
            ("static", Arc::new(StaticAffinity)),
            ("energy-aware", Arc::new(EnergyAware::nominal())),
        ],
        other => anyhow::bail!("--policy must be static, energy-aware or both, got {other}"),
    };

    let mut arms: Vec<(ReplayReport, bool)> = Vec::new(); // (report, digest_stable)
    for (name, policy) in &policies {
        let outcome = run_arm(Arc::clone(policy))?;
        let r = outcome.report;
        let digest_stable = if verify_det {
            let again = run_arm(Arc::clone(policy))?;
            let stable = again.report.digest == r.digest;
            println!(
                "  [{name}] determinism: digest {:016x} vs rerun {:016x} — {}",
                r.digest,
                again.report.digest,
                if stable { "bit-identical" } else { "DIVERGED" },
            );
            stable
        } else {
            true
        };
        let p = &r.producer;
        println!(
            "  [{name}] sustained {:.2} Mops/s, fleet {:.3} pJ/op; {} subs ({} ops) → {} completed, {} errored, {} hung; {} retries",
            r.sustained_ops_per_s / 1e6,
            r.fleet_pj_per_op,
            p.submitted_subs,
            p.submitted_ops,
            p.completed_subs,
            p.errored_subs,
            p.hung_subs,
            p.retries,
        );
        println!(
            "  [{name}] placement: policy-routed {}, misrouted {}, rerouted-on-failure {}, admission-denied {}, respawns {}; faults {}/{}; crosscheck {}/{}; conservation {}",
            r.policy_routed,
            r.misrouted,
            r.rerouted_on_failure,
            r.admission_denied,
            r.respawns,
            r.faults_fired,
            r.faults_planned,
            r.crosscheck_mismatches,
            r.crosscheck_sampled,
            if r.conservation_ok { "exact" } else { "BROKEN" },
        );
        arms.push((r, digest_stable));
    }

    // Dominance verdict — computed whenever both policies ran on the
    // same trace, gated only under --expect-dominance. Thresholds are
    // embedded in the artifact so the CI checker re-derives the verdict
    // from the same raw numbers and can never silently drift.
    const MIN_THROUGHPUT_RATIO: f64 = 1.0; // strict: dynamic must exceed
    const MAX_PJ_RATIO: f64 = 1.0; // equal-or-better energy
    let dominance = {
        let stat = arms.iter().find(|(r, _)| r.policy_name == "static");
        let dynm = arms.iter().find(|(r, _)| r.policy_name == "energy-aware");
        match (stat, dynm) {
            (Some((s, _)), Some((d, _))) => {
                let throughput_ratio =
                    d.sustained_ops_per_s / s.sustained_ops_per_s.max(1e-12);
                let pj_ratio = d.fleet_pj_per_op / s.fleet_pj_per_op.max(1e-12);
                let dominates =
                    throughput_ratio > MIN_THROUGHPUT_RATIO && pj_ratio <= MAX_PJ_RATIO;
                println!(
                    "dominance: energy-aware vs static — throughput {throughput_ratio:.3}×, pJ/op {pj_ratio:.3}× → {}",
                    if dominates { "DOMINATES" } else { "does not dominate" },
                );
                Some((throughput_ratio, pj_ratio, dominates))
            }
            _ => None,
        }
    };

    if let Some(path) = json_path {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"bench\": \"routing\",\n");
        s.push_str("  \"measured\": true,\n");
        s.push_str(&format!("  \"seed\": {seed},\n"));
        s.push_str(&format!("  \"trace\": \"{trace_name}\",\n"));
        s.push_str(&format!("  \"tier\": \"{}\",\n", fidelity.name()));
        s.push_str(&format!("  \"total_ops\": {},\n", trace.total_ops()));
        s.push_str(&format!("  \"tenants\": {},\n", tcfg.tenants));
        s.push_str(&format!("  \"events\": {},\n", trace.events.len()));
        s.push_str(&format!("  \"last_slot\": {},\n", trace.last_slot()));
        s.push_str(&format!(
            "  \"trace_fingerprint\": \"{:016x}\",\n",
            trace.fingerprint
        ));
        s.push_str(&format!("  \"faults_planned\": {},\n", plan.faults.len()));
        s.push_str(&format!("  \"verify_determinism\": {verify_det},\n"));
        s.push_str("  \"arms\": [\n");
        for (ai, (r, stable)) in arms.iter().enumerate() {
            let p = &r.producer;
            s.push_str("    {\n");
            s.push_str(&format!("      \"policy\": \"{}\",\n", r.policy_name));
            s.push_str(&format!(
                "      \"sustained_ops_per_s\": {:.0},\n",
                r.sustained_ops_per_s
            ));
            s.push_str(&format!(
                "      \"fleet_pj_per_op\": {:.6},\n",
                r.fleet_pj_per_op
            ));
            s.push_str(&format!("      \"submitted_ops\": {},\n", p.submitted_ops));
            s.push_str(&format!("      \"completed_ops\": {},\n", p.completed_ops));
            s.push_str(&format!("      \"errored_ops\": {},\n", p.errored_ops));
            s.push_str(&format!("      \"hung_subs\": {},\n", p.hung_subs));
            s.push_str(&format!("      \"retries\": {},\n", p.retries));
            s.push_str(&format!("      \"policy_routed\": {},\n", r.policy_routed));
            s.push_str(&format!("      \"misrouted\": {},\n", r.misrouted));
            s.push_str(&format!(
                "      \"rerouted_on_failure\": {},\n",
                r.rerouted_on_failure
            ));
            s.push_str(&format!(
                "      \"admission_denied\": {},\n",
                r.admission_denied
            ));
            s.push_str(&format!("      \"respawns\": {},\n", r.respawns));
            s.push_str(&format!("      \"faults_fired\": {},\n", r.faults_fired));
            s.push_str(&format!(
                "      \"crosscheck_sampled\": {},\n",
                r.crosscheck_sampled
            ));
            s.push_str(&format!(
                "      \"crosscheck_mismatches\": {},\n",
                r.crosscheck_mismatches
            ));
            s.push_str(&format!(
                "      \"conservation_ok\": {},\n",
                r.conservation_ok
            ));
            s.push_str(&format!("      \"digest\": \"{:016x}\",\n", r.digest));
            s.push_str(&format!(
                "      \"results_in_digest\": {},\n",
                r.results_in_digest
            ));
            s.push_str(&format!("      \"digest_stable\": {stable},\n"));
            s.push_str(&format!("      \"gates_ok\": {},\n", r.gates_ok()));
            s.push_str(&format!("      \"wall_secs\": {:.3}\n", r.wall_secs));
            s.push_str(if ai + 1 == arms.len() { "    }\n" } else { "    },\n" });
        }
        s.push_str("  ],\n");
        match dominance {
            Some((tr, pj, dom)) => {
                s.push_str("  \"dominance\": {\n");
                s.push_str(&format!("    \"throughput_ratio\": {tr:.4},\n"));
                s.push_str(&format!("    \"pj_ratio\": {pj:.4},\n"));
                s.push_str(&format!("    \"dynamic_dominates\": {dom}\n"));
                s.push_str("  },\n");
            }
            None => s.push_str("  \"dominance\": null,\n"),
        }
        s.push_str("  \"thresholds\": {\n");
        s.push_str(&format!(
            "    \"min_throughput_ratio\": {MIN_THROUGHPUT_RATIO:.4},\n"
        ));
        s.push_str(&format!("    \"max_pj_ratio\": {MAX_PJ_RATIO:.4}\n"));
        s.push_str("  }\n");
        s.push_str("}\n");
        std::fs::write(&path, s)?;
        println!("wrote {path}");
    }

    // Hard gates (the CI replay smoke step relies on these exit codes).
    for (r, digest_stable) in &arms {
        let name = r.policy_name;
        anyhow::ensure!(
            r.zero_hung(),
            "[{name}] {} submission(s) hung past the {deadline_ms} ms deadline",
            r.producer.hung_subs
        );
        anyhow::ensure!(
            r.zero_lost(),
            "[{name}] op ledger does not balance: {} completed + {} errored != {} submitted",
            r.producer.completed_ops,
            r.producer.errored_ops,
            r.producer.submitted_ops
        );
        anyhow::ensure!(
            r.crosscheck_clean(),
            "[{name}] sampled gate cross-check found {} mismatches",
            r.crosscheck_mismatches
        );
        anyhow::ensure!(
            r.coverage_ok(),
            "[{name}] only {} of {} scheduled faults fired",
            r.faults_fired,
            r.faults_planned
        );
        anyhow::ensure!(
            r.conservation_ok,
            "[{name}] fleet accounting is not conserved across shard incarnations"
        );
        anyhow::ensure!(
            *digest_stable,
            "[{name}] replay digest diverged across identical runs — determinism broken"
        );
    }
    if expect_dom {
        let (tr, pj, dom) = dominance.ok_or_else(|| {
            anyhow::anyhow!("--expect-dominance needs --policy both (both arms must run)")
        })?;
        anyhow::ensure!(
            dom,
            "energy-aware does not dominate static on {trace_name}: throughput {tr:.3}× (need > {MIN_THROUGHPUT_RATIO}), pJ/op {pj:.3}× (need <= {MAX_PJ_RATIO})"
        );
    }
    Ok(())
}

/// The `verify --bb` extension: run the batch windowed-tracked at the
/// chosen tier, report the trace-tracking overhead against the untracked
/// run, then weave fresh operands into the Fig. 4 10%-duty schedule and
/// compare the static forward-bias policy with the adaptive controller
/// on that measured trace. `--max-trace-overhead X` makes an overhead
/// above X× a hard failure (the CI bench-smoke gate); `--bb-json PATH`
/// writes the summary as JSON.
fn windowed_bb_report(
    cfg: &FpuConfig,
    unit: &FpuUnit,
    fidelity: fpmax::arch::engine::Fidelity,
    triples: &[fpmax::workloads::throughput::OperandTriple],
    workers: usize,
    args: &Args,
) -> fpmax::Result<()> {
    use fpmax::arch::engine::{ActivityTrace, BatchExecutor, UnitDatapath};
    use fpmax::bb::{run_energy_trace, BbPolicy};
    use fpmax::workloads::utilization::UtilizationProfile;

    // The report always scores BOTH policies (the recovery ratio needs
    // the pair); the flag's value is just validated so typos fail loudly.
    let policy_name = args.get("bb").unwrap_or("adaptive").to_string();
    anyhow::ensure!(
        matches!(policy_name.as_str(), "static" | "adaptive"),
        "--bb must be static or adaptive, got {policy_name}"
    );
    let window = args.get_parse("window", 4_096usize)?;
    anyhow::ensure!(window >= 1, "--window must be at least 1 op");
    let max_overhead = args.get_parse("max-trace-overhead", f64::INFINITY)?;

    let exec = BatchExecutor::new(workers);
    let dp = UnitDatapath::new(unit, fidelity);
    let mut out = vec![0u64; triples.len()];

    // Untracked baseline, warmed: the first run spawns the pool and
    // calibrates the chunk size; the timed runs below compare steady
    // state. Best-of-3 on both sides keeps the CI overhead gate robust
    // to scheduler noise on shared runners (one preempted
    // millisecond-scale run must not fail the <2× budget).
    exec.run_into(&dp, triples, &mut out)?;
    let mut untracked_secs = f64::INFINITY;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        exec.run_into(&dp, triples, &mut out)?;
        untracked_secs = untracked_secs.min(t0.elapsed().as_secs_f64());
    }
    let mut traced_secs = f64::INFINITY;
    let mut trace = None;
    for _ in 0..3 {
        let t1 = std::time::Instant::now();
        let t = exec.run_windowed_into(&dp, triples, &mut out, window)?;
        traced_secs = traced_secs.min(t1.elapsed().as_secs_f64());
        trace = Some(t);
    }
    let trace = trace.expect("three timed runs completed");
    let overhead = traced_secs / untracked_secs.max(1e-12);
    println!(
        "trace: {} windows × {} ops, occupancy {:.2}, tracking overhead {overhead:.2}× untracked",
        trace.len(),
        window,
        trace.occupancy()
    );

    // Phase-aware comparison: the same tier executing the Fig. 4
    // 10%-duty schedule, scored at the unit's nominal operating point.
    let op = fpmax::timing::nominal_op(cfg);
    let freq = fpmax::timing::timing(cfg, &Technology::fdsoi28(), op)
        .ok_or_else(|| anyhow::anyhow!("nominal operating point not operable"))?
        .freq_ghz;
    let total = (triples.len() as u64 * 10).max(100_000);
    let burst = 10_000u64.min(total / 10).max(1);
    let profile = UtilizationProfile::duty(0.1, burst, total);
    let mut stream =
        OperandStream::new(cfg.precision, OperandMix::Finite, args.get_parse("seed", 42u64)?);
    let weave = ActivityTrace::record_profile(&dp, &profile, window as u64, &mut stream);
    let tech = Technology::fdsoi28();
    let static_e = run_energy_trace(unit, &tech, op.vdd, BbPolicy::static_nominal(), &weave)
        .ok_or_else(|| anyhow::anyhow!("static policy not evaluable at nominal point"))?;
    let adaptive_e =
        run_energy_trace(unit, &tech, op.vdd, BbPolicy::adaptive_nominal(freq), &weave)
            .ok_or_else(|| anyhow::anyhow!("adaptive policy not evaluable at nominal point"))?;
    let recovery = static_e.pj_per_op / adaptive_e.pj_per_op;
    println!(
        "phase-aware BB on measured 10%-duty trace ({} ops): static {:.2} pJ/op, adaptive {:.2} pJ/op ({recovery:.2}× recovery)",
        static_e.ops, static_e.pj_per_op, adaptive_e.pj_per_op
    );

    if let Some(path) = args.get("bb-json") {
        // Both policies' energies are recorded — the summary IS the
        // static-vs-adaptive comparison, so there is no single "policy"
        // field to filter on.
        let json = format!(
            "{{\n  \"unit\": \"{}\",\n  \"fidelity\": \"{}\",\n  \"window_ops\": {window},\n  \"batch_ops\": {},\n  \"batch_windows\": {},\n  \"trace_overhead_vs_untracked\": {overhead:.4},\n  \"weave_occupancy\": {:.4},\n  \"weave_ops\": {},\n  \"static_pj_per_op\": {:.4},\n  \"adaptive_pj_per_op\": {:.4},\n  \"adaptive_recovery\": {recovery:.4}\n}}\n",
            cfg.name(),
            fidelity.name(),
            triples.len(),
            trace.len(),
            weave.occupancy(),
            static_e.ops,
            static_e.pj_per_op,
            adaptive_e.pj_per_op,
        );
        std::fs::write(path, json)?;
        println!("wrote {path}");
    }
    anyhow::ensure!(
        overhead <= max_overhead,
        "trace-tracking overhead {overhead:.2}× exceeds the --max-trace-overhead {max_overhead}× budget"
    );
    Ok(())
}

/// The `fpmax kernels` subcommand: run the repeat-buffer kernel suite
/// against its unrolled references on the chip sequencer, print the
/// per-kernel table, optionally emit the `bench: "kernels"` JSON
/// artifact, and hard-gate on bit-identity, in-burst occupancy and
/// issue-rate speedup.
fn kernels_cmd(args: &Args) -> fpmax::Result<()> {
    use fpmax::report::kernels::{render, run_kernel, run_suite, KernelRow};
    use fpmax::workloads::kernels::gemm_tile;

    let seed = args.get_parse("seed", 42u64)?;
    let window = args.get_parse("window", 256u64)?;
    let min_occ = args.get_parse("min-occupancy", 0.9f64)?;
    let min_speedup = args.get_parse("min-speedup", 1.5f64)?;
    let json_path = args.get("json").map(|s| s.to_string());
    anyhow::ensure!(window >= 1, "--window must be at least 1 slot");
    let units: Vec<UnitSel> = match args.get("unit") {
        None => UnitSel::ALL.to_vec(),
        Some(name) => vec![match name {
            "dp_cma" | "dp-cma" => UnitSel::DpCma,
            "dp_fma" | "dp-fma" => UnitSel::DpFma,
            "sp_cma" | "sp-cma" => UnitSel::SpCma,
            "sp_fma" | "sp-fma" => UnitSel::SpFma,
            other => {
                anyhow::bail!("--unit must be one of dp_cma|dp_fma|sp_cma|sp_fma, got {other}")
            }
        }],
    };
    let rows: Vec<KernelRow> = match args.get("gemm") {
        // A single explicit GEMM tile (the CI smoke shape) instead of
        // the full three-kernel suite.
        Some(shape) => {
            let dims: Vec<usize> =
                shape.split('x').map(str::parse).collect::<Result<_, _>>().map_err(|_| {
                    anyhow::anyhow!("--gemm must be MxNxK (e.g. 8x8x4), got {shape}")
                })?;
            anyhow::ensure!(dims.len() == 3, "--gemm must be MxNxK (e.g. 8x8x4), got {shape}");
            let mut rows = Vec::new();
            for &unit in &units {
                rows.push(run_kernel(&gemm_tile(unit, dims[0], dims[1], dims[2], seed), window)?);
            }
            rows
        }
        None => run_suite(&units, seed, window)?,
    };
    print!("{}", render(&rows));

    if let Some(path) = &json_path {
        let mut s = String::from("{\n  \"bench\": \"kernels\",\n  \"measured\": true,\n");
        s.push_str(&format!("  \"seed\": {seed},\n  \"window_slots\": {window},\n"));
        s.push_str(&format!(
            "  \"thresholds\": {{\n    \"min_frep_occupancy\": {min_occ},\n    \
             \"min_frep_issue_speedup_vs_unrolled\": {min_speedup},\n    \
             \"max_result_mismatches\": 0\n  }},\n  \"rows\": [\n"
        ));
        for (i, r) in rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"unit\": \"{}\", \"ops\": {}, \
                 \"repeat\": {{\"cycles\": {}, \"window_ops\": {}, \"window_cycles\": {}}}, \
                 \"unrolled\": {{\"cycles\": {}}}, \"result_mismatches\": {}, \
                 \"occupancy_in_burst\": {:.6}, \"issue_speedup\": {:.6}, \
                 \"pj_per_op_repeat\": {:.6}, \"pj_per_op_unrolled\": {:.6}}}{}\n",
                r.kernel,
                r.unit.name(),
                r.ops,
                r.repeat_cycles,
                r.window_ops,
                r.window_cycles,
                r.unrolled_cycles,
                r.result_mismatches,
                r.occupancy_in_burst,
                r.issue_speedup,
                r.pj_per_op_repeat,
                r.pj_per_op_unrolled,
                if i + 1 == rows.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s)?;
        println!("wrote {path}");
    }

    // Hard gates: every kernel on every preset, no averaging.
    for r in &rows {
        anyhow::ensure!(
            r.result_mismatches == 0,
            "{} on {}: {} result words differ between repeat and unrolled programs",
            r.kernel,
            r.unit.name(),
            r.result_mismatches
        );
        anyhow::ensure!(
            r.occupancy_in_burst >= min_occ,
            "{} on {}: in-burst occupancy {:.4} below the {min_occ} gate",
            r.kernel,
            r.unit.name(),
            r.occupancy_in_burst
        );
        anyhow::ensure!(
            r.issue_speedup >= min_speedup,
            "{} on {}: issue speedup {:.3}x below the {min_speedup}x gate",
            r.kernel,
            r.unit.name(),
            r.issue_speedup
        );
    }
    println!(
        "kernels: {} rows, all bit-identical; occupancy >= {min_occ}, speedup >= {min_speedup}x",
        rows.len()
    );
    Ok(())
}

fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
