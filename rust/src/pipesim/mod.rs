//! Cycle-accurate FPU pipeline simulation: dependence traces ([`trace`])
//! and the bypass-aware issue simulator ([`sim`]) that measures the
//! paper's average-latency-penalty metric.

pub mod sim;
pub mod trace;

pub use sim::{benchmarked_delay_ns, simulate, LatencyModel, SimResult};
pub use trace::{DepKind, Trace, TraceOp};
