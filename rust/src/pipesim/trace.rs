//! FP instruction traces: the dependence structure of a workload.
//!
//! The latency experiments (Fig. 2(c), Fig. 4) depend only on *where*
//! each FMAC's result flows — into the next op's accumulator input, its
//! multiplier input, or nowhere — and at what program-order distance.
//! A [`Trace`] captures exactly that; operand *values* live in the chip
//! workloads ([`crate::workloads`]), not here.

/// Which consumer input a producer's result feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DepKind {
    /// Result feeds the addend/accumulator input (`c` of `a·b+c`) — the
    /// short path through a CMA's bypass network.
    Accumulate,
    /// Result feeds a multiplier input (`a` or `b`).
    Multiplier,
}

/// One FMAC in program order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOp {
    /// Distance to the producer in program order (1 = immediately
    /// preceding op); `None` for independent ops.
    pub dep: Option<(u32, DepKind)>,
}

impl TraceOp {
    pub const INDEPENDENT: TraceOp = TraceOp { dep: None };

    pub fn accumulate(distance: u32) -> TraceOp {
        TraceOp { dep: Some((distance, DepKind::Accumulate)) }
    }

    pub fn multiplier(distance: u32) -> TraceOp {
        TraceOp { dep: Some((distance, DepKind::Multiplier)) }
    }
}

/// A dependence trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
}

impl Trace {
    pub fn new(ops: Vec<TraceOp>) -> Trace {
        Trace { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Fraction of ops with a dependence of the given kind.
    pub fn dep_fraction(&self, kind: DepKind) -> f64 {
        if self.ops.is_empty() {
            return 0.0;
        }
        let n = self.ops.iter().filter(|o| matches!(o.dep, Some((_, k)) if k == kind)).count();
        n as f64 / self.ops.len() as f64
    }

    /// Validate that no op depends on something before the trace start.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, op) in self.ops.iter().enumerate() {
            if let Some((d, _)) = op.dep {
                if d == 0 {
                    anyhow::bail!("op {i}: zero dependence distance");
                }
                if d as usize > i {
                    anyhow::bail!("op {i}: dependence distance {d} reaches before trace start");
                }
            }
        }
        Ok(())
    }

    /// A pure accumulation chain of `n` ops (dot-product inner loop).
    pub fn accumulation_chain(n: usize) -> Trace {
        let ops = (0..n)
            .map(|i| if i == 0 { TraceOp::INDEPENDENT } else { TraceOp::accumulate(1) })
            .collect();
        Trace { ops }
    }

    /// A pure multiply-dependence chain (polynomial evaluation, Horner).
    pub fn multiply_chain(n: usize) -> Trace {
        let ops = (0..n)
            .map(|i| if i == 0 { TraceOp::INDEPENDENT } else { TraceOp::multiplier(1) })
            .collect();
        Trace { ops }
    }

    /// `n` fully independent ops (the GPU-style throughput workload).
    pub fn independent(n: usize) -> Trace {
        Trace { ops: vec![TraceOp::INDEPENDENT; n] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_shapes() {
        let t = Trace::accumulation_chain(10);
        assert_eq!(t.len(), 10);
        assert!((t.dep_fraction(DepKind::Accumulate) - 0.9).abs() < 1e-12);
        assert_eq!(t.dep_fraction(DepKind::Multiplier), 0.0);
        let t = Trace::multiply_chain(4);
        assert!((t.dep_fraction(DepKind::Multiplier) - 0.75).abs() < 1e-12);
        let t = Trace::independent(5);
        assert_eq!(t.dep_fraction(DepKind::Accumulate), 0.0);
    }

    #[test]
    fn validation_catches_bad_distances() {
        assert!(Trace::accumulation_chain(100).validate().is_ok());
        let bad = Trace::new(vec![TraceOp::accumulate(1)]);
        assert!(bad.validate().is_err()); // first op cannot depend
        let bad = Trace::new(vec![TraceOp::INDEPENDENT, TraceOp { dep: Some((0, DepKind::Accumulate)) }]);
        assert!(bad.validate().is_err());
        let ok = Trace::new(vec![TraceOp::INDEPENDENT, TraceOp::multiplier(1)]);
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn empty_trace() {
        let t = Trace::default();
        assert!(t.is_empty());
        assert_eq!(t.dep_fraction(DepKind::Accumulate), 0.0);
        assert!(t.validate().is_ok());
    }
}
