//! Cycle-accurate in-order pipeline simulation with the internal bypass
//! network — the machinery behind Fig. 2(c) and the x-axis of Fig. 4.
//!
//! The simulated machine issues one FMAC per cycle in program order
//! (the FPU's local view; the surrounding core's reordering is already
//! reflected in the trace's dependence distances). An op stalls at issue
//! until its producer's result reaches the input port it needs:
//!
//! * full (rounded, written-back) result: `latency_full` cycles after
//!   the producer issued;
//! * bypassed unrounded result into the adder: `latency_to_add_input`;
//! * bypassed into the multiplier: `latency_to_mul_input`.
//!
//! The paper's **average latency penalty** is the mean number of cycles
//! a dependent op waits beyond the 1-per-cycle issue rate; its
//! **average cycles per FLOP** is `1 + penalty` (§FPU Architectures).

use crate::arch::generator::FpuUnit;

use super::trace::{DepKind, Trace};

/// The three bypass-tap latencies of a unit (in cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyModel {
    pub full: u32,
    pub to_add: u32,
    pub to_mul: u32,
}

impl LatencyModel {
    /// Extract from a generated unit.
    pub fn of(unit: &FpuUnit) -> LatencyModel {
        LatencyModel {
            full: unit.latency_full(),
            to_add: unit.latency_to_add_input(),
            to_mul: unit.latency_to_mul_input(),
        }
    }

    /// Issue-to-issue distance required for a dependence kind.
    #[inline]
    pub fn tap(&self, kind: DepKind) -> u32 {
        match kind {
            DepKind::Accumulate => self.to_add,
            DepKind::Multiplier => self.to_mul,
        }
    }
}

/// Result of simulating one trace on one latency model.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Ops simulated.
    pub ops: usize,
    /// Total cycles from first issue to last writeback.
    pub cycles: u64,
    /// Σ issue stalls / ops — the paper's average latency penalty.
    pub avg_penalty: f64,
    /// 1 + avg_penalty — average cycles per FLOP.
    pub avg_cycles_per_op: f64,
    /// Histogram of per-op stall lengths (index = stall cycles, capped).
    pub stall_histogram: Vec<u64>,
}

/// Maximum stall bucket tracked in the histogram.
const MAX_STALL_BUCKET: usize = 16;

/// Simulate a trace. Dependences must be valid (`trace.validate()`).
pub fn simulate(lat: &LatencyModel, trace: &Trace) -> SimResult {
    let n = trace.ops.len();
    let mut issue = vec![0u64; n];
    let mut stalls_total = 0u64;
    let mut hist = vec![0u64; MAX_STALL_BUCKET + 1];
    let mut last_issue: Option<u64> = None;
    for (i, op) in trace.ops.iter().enumerate() {
        // Earliest slot from the issue port (1 per cycle).
        let port_ready = last_issue.map(|t| t + 1).unwrap_or(0);
        // Earliest slot from the producer, if any.
        let data_ready = match op.dep {
            None => 0,
            Some((d, kind)) => {
                let producer = issue[i - d as usize];
                producer + lat.tap(kind) as u64
            }
        };
        let t = port_ready.max(data_ready);
        let stall = t - port_ready;
        stalls_total += stall;
        hist[(stall as usize).min(MAX_STALL_BUCKET)] += 1;
        issue[i] = t;
        last_issue = Some(t);
    }
    let cycles = match last_issue {
        Some(t) => t + lat.full as u64,
        None => 0,
    };
    let avg_penalty = if n > 0 { stalls_total as f64 / n as f64 } else { 0.0 };
    SimResult {
        ops: n,
        cycles,
        avg_penalty,
        avg_cycles_per_op: 1.0 + avg_penalty,
        stall_histogram: hist,
    }
}

/// Average *benchmarked delay* in ns (Fig. 4's x-axis): cycle time ×
/// average cycles per FLOP.
pub fn benchmarked_delay_ns(cycle_ps: f64, sim: &SimResult) -> f64 {
    cycle_ps * sim.avg_cycles_per_op / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;
    use crate::pipesim::trace::TraceOp;

    fn dp_cma_lat() -> LatencyModel {
        LatencyModel::of(&FpuUnit::generate(&FpuConfig::dp_cma()))
    }

    fn fma5(forwarding: bool) -> LatencyModel {
        let mut cfg = FpuConfig::dp_fma();
        cfg.stages = 5;
        cfg.forwarding = forwarding;
        LatencyModel::of(&FpuUnit::generate(&cfg))
    }

    #[test]
    fn independent_stream_no_penalty() {
        let sim = simulate(&dp_cma_lat(), &Trace::independent(1000));
        assert_eq!(sim.avg_penalty, 0.0);
        assert_eq!(sim.avg_cycles_per_op, 1.0);
        // 1000 issues + pipeline drain.
        assert_eq!(sim.cycles, 999 + 5);
    }

    #[test]
    fn accumulation_chain_penalty_matches_tap() {
        // Back-to-back accumulation: each dependent op stalls tap−1.
        let lat = dp_cma_lat();
        assert_eq!(lat.to_add, 2);
        let n = 1000;
        let sim = simulate(&lat, &Trace::accumulation_chain(n));
        // 999 of 1000 ops stall (to_add − 1) = 1 cycle.
        let want = 999.0 / 1000.0;
        assert!((sim.avg_penalty - want).abs() < 1e-12, "{}", sim.avg_penalty);
    }

    #[test]
    fn multiply_chain_penalty() {
        let lat = dp_cma_lat(); // to_mul = 4
        let sim = simulate(&lat, &Trace::multiply_chain(1000));
        let want = 3.0 * 999.0 / 1000.0;
        assert!((sim.avg_penalty - want).abs() < 1e-12);
    }

    #[test]
    fn fma_without_forwarding_slower() {
        let with = simulate(&fma5(true), &Trace::accumulation_chain(500));
        let without = simulate(&fma5(false), &Trace::accumulation_chain(500));
        assert!(without.avg_penalty > with.avg_penalty);
        // FMA5 w/ fwd: stall 3; w/o: stall 4.
        assert!((with.avg_penalty - 3.0 * 499.0 / 500.0).abs() < 1e-12);
        assert!((without.avg_penalty - 4.0 * 499.0 / 500.0).abs() < 1e-12);
    }

    #[test]
    fn cma_beats_fma_on_accumulation() {
        // The Fig. 2(c) mechanism in its purest form.
        let cma = simulate(&dp_cma_lat(), &Trace::accumulation_chain(500));
        let fma = simulate(&fma5(true), &Trace::accumulation_chain(500));
        assert!(cma.avg_penalty < 0.4 * fma.avg_penalty);
    }

    #[test]
    fn distance_covers_latency() {
        // Dependences farther than the tap latency cost nothing.
        let lat = dp_cma_lat();
        let ops: Vec<TraceOp> = (0..100)
            .map(|i| if i < 4 { TraceOp::INDEPENDENT } else { TraceOp::multiplier(4) })
            .collect();
        let sim = simulate(&lat, &Trace::new(ops));
        assert_eq!(sim.avg_penalty, 0.0);
    }

    #[test]
    fn penalty_monotonic_in_dependence_density() {
        let lat = dp_cma_lat();
        let mut prev = -1.0;
        for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let n = 400;
            let ops: Vec<TraceOp> = (0..n)
                .map(|i| {
                    if i > 0 && (i as f64 / n as f64) < frac {
                        TraceOp::accumulate(1)
                    } else {
                        TraceOp::INDEPENDENT
                    }
                })
                .collect();
            let sim = simulate(&lat, &Trace::new(ops));
            assert!(sim.avg_penalty >= prev, "frac {frac}");
            prev = sim.avg_penalty;
        }
    }

    #[test]
    fn histogram_accounts_every_op() {
        let sim = simulate(&dp_cma_lat(), &Trace::accumulation_chain(100));
        assert_eq!(sim.stall_histogram.iter().sum::<u64>(), 100);
        assert_eq!(sim.stall_histogram[0], 1); // first op
        assert_eq!(sim.stall_histogram[1], 99);
    }

    #[test]
    fn benchmarked_delay_scales_with_cycle_time() {
        let sim = simulate(&dp_cma_lat(), &Trace::accumulation_chain(100));
        let d = benchmarked_delay_ns(840.0, &sim);
        assert!((d - 0.840 * sim.avg_cycles_per_op).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_zero_cycles() {
        let sim = simulate(&dp_cma_lat(), &Trace::default());
        assert_eq!(sim.cycles, 0);
        assert_eq!(sim.ops, 0);
    }
}
