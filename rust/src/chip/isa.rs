//! The test-program instruction encoding (Fig. 5(b)) and the
//! repeat-buffer sequencer extension.
//!
//! The paper's figure shows a compact encoding that selects the FPU, the
//! operand sources (stimulus RAM or the forwarding network) and the
//! rounding mode, with a loop counter driven by the sequencer. The
//! published figure is too small to transcribe field-exactly, so this is
//! a faithful *reconstruction* with the same information content, packed
//! into 32 bits:
//!
//! ```text
//!  31..30  unit      (00 DP CMA, 01 DP FMA, 10 SP CMA, 11 SP FMA)
//!  29..28  op        (00 NOP, 01 FMAC, 10 MUL, 11 ADD)
//!  27..26  rounding  (00 RNE, 01 RZ, 10 RU, 11 RD)
//!  25..24  src_c sel (00 RAM, 01 forward result, 10 zero, 11 one)
//!  23..22  src_b sel
//!  21..20  src_a sel
//!  19..10  RAM base address (ops stream sequentially from here)
//!   9..0   repeat count − 1
//! ```
//!
//! ## Sequencer words (the repeat-buffer extension)
//!
//! Program RAM words are 64 bits wide but the base ISA above only ever
//! occupied the low 32 — the upper half was architecturally zero. The
//! repeat-buffer extension claims that headroom with a tag in the top
//! three bits, so every pre-extension program decodes unchanged:
//!
//! ```text
//! tag 000 (bits 63..32 all zero)  BASIC: bits 31..0 hold the classic
//!                                 32-bit instruction; the all-zero word
//!                                 stays the halt sentinel
//! tag 001                         REPEAT
//!    60..40  reserved (must be 0)
//!    39..8   count  (iterations, u32 ≥ 1)
//!     7..0   window (following program words to loop, u8 ≥ 1)
//! tag 010                         STREAM descriptor
//!    60..59  reserved (must be 0)
//!    58..47  stride1 (outer stride, words, 12-bit two's complement)
//!    46..35  stride0 (inner stride, words, 12-bit two's complement)
//!    34..19  len0    (inner length, elements; 0 disarms the port)
//!    18..3   base    (word address)
//!     2      bank    (0 = the port's stimulus RAM, 1 = result RAM)
//!     1..0   port    (00 a, 01 b, 10 c; 11 invalid)
//! ```
//!
//! `REPEAT { window, count }` executes the next `window` program words
//! (which must all be BASIC — a nested REPEAT or an embedded STREAM word
//! rejects as an overlapping window) `count` times out of a decoded
//! micro-op buffer, with a single pipeline drain at the end instead of
//! one per instruction. A STREAM word arms a *stream semantic register*
//! on one operand port: while armed, every `SrcSel::Ram` read on that
//! port takes its address from the descriptor's two-level affine walk
//! ([`StreamDesc::addr`]) instead of `base_addr + i`, advancing one
//! element per op — so looped micro-ops stream new operands without
//! being re-issued. Decoding is strict: reserved bits must be zero and
//! `decode(encode(w)) == w` holds exactly (the property-test contract).

use crate::arch::rounding::RoundMode;

/// Operand-source selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrcSel {
    Ram,
    Forward,
    Zero,
    One,
}

/// FPU operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Nop,
    Fmac,
    Mul,
    Add,
}

/// Unit selector, Table-I order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitSel {
    DpCma = 0,
    DpFma = 1,
    SpCma = 2,
    SpFma = 3,
}

impl UnitSel {
    /// All four fabricated units, Table-I order (the selector encoding).
    pub const ALL: [UnitSel; 4] = [UnitSel::DpCma, UnitSel::DpFma, UnitSel::SpCma, UnitSel::SpFma];

    pub fn name(self) -> &'static str {
        match self {
            UnitSel::DpCma => "dp-cma",
            UnitSel::DpFma => "dp-fma",
            UnitSel::SpCma => "sp-cma",
            UnitSel::SpFma => "sp-fma",
        }
    }

    /// The fabricated unit's word precision.
    pub fn precision(self) -> crate::arch::fp::Precision {
        match self {
            UnitSel::DpCma | UnitSel::DpFma => crate::arch::fp::Precision::Double,
            UnitSel::SpCma | UnitSel::SpFma => crate::arch::fp::Precision::Single,
        }
    }

    /// Whether the selected unit fuses the multiply-add (no intermediate
    /// rounding) — FMA presets; CMA presets round twice.
    pub fn fused(self) -> bool {
        matches!(self, UnitSel::DpFma | UnitSel::SpFma)
    }
}

/// One decoded test instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    pub unit: UnitSel,
    pub op: Op,
    pub rounding: RoundMode,
    pub src_a: SrcSel,
    pub src_b: SrcSel,
    pub src_c: SrcSel,
    pub base_addr: u16,
    pub repeat: u16,
}

fn sel_bits(s: SrcSel) -> u32 {
    match s {
        SrcSel::Ram => 0,
        SrcSel::Forward => 1,
        SrcSel::Zero => 2,
        SrcSel::One => 3,
    }
}

fn sel_from(b: u32) -> SrcSel {
    match b & 3 {
        0 => SrcSel::Ram,
        1 => SrcSel::Forward,
        2 => SrcSel::Zero,
        _ => SrcSel::One,
    }
}

impl Instruction {
    /// A plain FMAC burst from the stimulus RAM.
    pub fn fmac_burst(unit: UnitSel, base_addr: u16, count: u16) -> Instruction {
        assert!(count >= 1 && count <= 1024, "repeat out of range");
        Instruction {
            unit,
            op: Op::Fmac,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Ram,
            base_addr,
            repeat: count - 1,
        }
    }

    /// An accumulation burst: `c` comes from the forwarding network.
    pub fn accumulate_burst(unit: UnitSel, base_addr: u16, count: u16) -> Instruction {
        let mut i = Instruction::fmac_burst(unit, base_addr, count);
        i.src_c = SrcSel::Forward;
        i
    }

    /// Encode to the 32-bit word.
    pub fn encode(&self) -> u32 {
        assert!(self.base_addr < 1024 && self.repeat < 1024, "field overflow");
        let op = match self.op {
            Op::Nop => 0u32,
            Op::Fmac => 1,
            Op::Mul => 2,
            Op::Add => 3,
        };
        let rnd = match self.rounding {
            RoundMode::NearestEven => 0u32,
            RoundMode::TowardZero => 1,
            RoundMode::TowardPositive => 2,
            RoundMode::TowardNegative => 3,
        };
        ((self.unit as u32) << 30)
            | (op << 28)
            | (rnd << 26)
            | (sel_bits(self.src_c) << 24)
            | (sel_bits(self.src_b) << 22)
            | (sel_bits(self.src_a) << 20)
            | ((self.base_addr as u32) << 10)
            | (self.repeat as u32)
    }

    /// Decode from the 32-bit word.
    pub fn decode(w: u32) -> Instruction {
        let unit = match w >> 30 {
            0 => UnitSel::DpCma,
            1 => UnitSel::DpFma,
            2 => UnitSel::SpCma,
            _ => UnitSel::SpFma,
        };
        let op = match (w >> 28) & 3 {
            0 => Op::Nop,
            1 => Op::Fmac,
            2 => Op::Mul,
            _ => Op::Add,
        };
        let rounding = match (w >> 26) & 3 {
            0 => RoundMode::NearestEven,
            1 => RoundMode::TowardZero,
            2 => RoundMode::TowardPositive,
            _ => RoundMode::TowardNegative,
        };
        Instruction {
            unit,
            op,
            rounding,
            src_c: sel_from(w >> 24),
            src_b: sel_from(w >> 22),
            src_a: sel_from(w >> 20),
            base_addr: ((w >> 10) & 0x3ff) as u16,
            repeat: (w & 0x3ff) as u16,
        }
    }
}

/// Operand port a stream descriptor arms (the `SrcSel::Ram` slot it
/// re-addresses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamPort {
    A = 0,
    B = 1,
    C = 2,
}

impl StreamPort {
    pub const ALL: [StreamPort; 3] = [StreamPort::A, StreamPort::B, StreamPort::C];

    pub fn name(self) -> &'static str {
        match self {
            StreamPort::A => "a",
            StreamPort::B => "b",
            StreamPort::C => "c",
        }
    }
}

/// RAM bank a stream reads: the port's own stimulus bank, or the result
/// bank (pass-to-pass operand chaining for kernel programs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamBank {
    Stim = 0,
    Result = 1,
}

/// Inclusive range of the 12-bit two's-complement stride fields.
pub const STREAM_STRIDE_MIN: i16 = -2048;
pub const STREAM_STRIDE_MAX: i16 = 2047;

/// One stream semantic register descriptor: a two-level affine address
/// walk `base + (n mod len0)·stride0 + (n div len0)·stride1` over the
/// stream's element counter `n`. `len0 == 0` disarms the port;
/// `stride0 == stride1 == 0` with `len0 == 1` is a broadcast (scalar
/// weights); `stride1` carries the outer-loop hop a single stride
/// cannot express (GEMM row advance, interleaved reduction trees).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamDesc {
    pub port: StreamPort,
    pub bank: StreamBank,
    pub base: u16,
    pub stride0: i16,
    pub len0: u16,
    pub stride1: i16,
}

impl StreamDesc {
    /// Word address of stream element `n`. May be negative (the
    /// sequencer rejects it at fetch); only defined while armed
    /// (`len0 ≥ 1`).
    pub fn addr(&self, n: u64) -> i64 {
        debug_assert!(self.len0 >= 1, "addr() on a disarmed descriptor");
        let i0 = (n % self.len0 as u64) as i64;
        let i1 = (n / self.len0 as u64) as i64;
        self.base as i64 + i0 * self.stride0 as i64 + i1 * self.stride1 as i64
    }

    /// A disarm word for a port (`len0 = 0`).
    pub fn disarm(port: StreamPort) -> StreamDesc {
        StreamDesc { port, bank: StreamBank::Stim, base: 0, stride0: 0, len0: 0, stride1: 0 }
    }
}

/// Word-type tags in bits 63..61 of a sequencer word.
const TAG_REPEAT: u64 = 1;
const TAG_STREAM: u64 = 2;

fn s12_bits(v: i16) -> u64 {
    (v as u16 as u64) & 0xfff
}

fn s12_from(bits: u64) -> i16 {
    ((((bits & 0xfff) as u16) << 4) as i16) >> 4
}

/// One decoded 64-bit sequencer word: a classic instruction, a repeat
/// of the following window, or a stream descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqWord {
    Basic(Instruction),
    Repeat { window: u8, count: u32 },
    Stream(StreamDesc),
}

impl SeqWord {
    /// Encode to the 64-bit program word.
    pub fn encode(&self) -> u64 {
        match *self {
            SeqWord::Basic(ins) => ins.encode() as u64,
            SeqWord::Repeat { window, count } => {
                assert!(window >= 1, "repeat window must cover at least one word");
                assert!(count >= 1, "repeat count must be at least one iteration");
                (TAG_REPEAT << 61) | ((count as u64) << 8) | window as u64
            }
            SeqWord::Stream(d) => {
                assert!(
                    (STREAM_STRIDE_MIN..=STREAM_STRIDE_MAX).contains(&d.stride0)
                        && (STREAM_STRIDE_MIN..=STREAM_STRIDE_MAX).contains(&d.stride1),
                    "stream stride overflows the 12-bit field"
                );
                (TAG_STREAM << 61)
                    | (s12_bits(d.stride1) << 47)
                    | (s12_bits(d.stride0) << 35)
                    | ((d.len0 as u64) << 19)
                    | ((d.base as u64) << 3)
                    | ((d.bank as u64) << 2)
                    | d.port as u64
            }
        }
    }

    /// Strict decode: reserved bits must be zero, fields must be in
    /// range, and `decode(encode(w)) == w` exactly.
    pub fn decode(w: u64) -> crate::Result<SeqWord> {
        if w >> 32 == 0 {
            return Ok(SeqWord::Basic(Instruction::decode(w as u32)));
        }
        match w >> 61 {
            TAG_REPEAT => {
                anyhow::ensure!(
                    (w >> 40) & 0x1f_ffff == 0,
                    "repeat word has nonzero reserved bits: {w:#018x}"
                );
                let window = (w & 0xff) as u8;
                let count = ((w >> 8) & 0xffff_ffff) as u32;
                anyhow::ensure!(window >= 1, "repeat window of zero words: {w:#018x}");
                anyhow::ensure!(count >= 1, "repeat count of zero iterations: {w:#018x}");
                Ok(SeqWord::Repeat { window, count })
            }
            TAG_STREAM => {
                anyhow::ensure!(
                    (w >> 59) & 0x3 == 0,
                    "stream word has nonzero reserved bits: {w:#018x}"
                );
                let port = match w & 3 {
                    0 => StreamPort::A,
                    1 => StreamPort::B,
                    2 => StreamPort::C,
                    _ => anyhow::bail!("stream word addresses invalid port 3: {w:#018x}"),
                };
                let bank = if (w >> 2) & 1 == 0 { StreamBank::Stim } else { StreamBank::Result };
                Ok(SeqWord::Stream(StreamDesc {
                    port,
                    bank,
                    base: ((w >> 3) & 0xffff) as u16,
                    len0: ((w >> 19) & 0xffff) as u16,
                    stride0: s12_from(w >> 35),
                    stride1: s12_from(w >> 47),
                }))
            }
            tag => anyhow::bail!("unknown sequencer word tag {tag} in {w:#018x}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let cases = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 1),
            Instruction::fmac_burst(UnitSel::DpCma, 512, 1024),
            Instruction::accumulate_burst(UnitSel::SpCma, 100, 64),
            Instruction {
                unit: UnitSel::DpFma,
                op: Op::Mul,
                rounding: RoundMode::TowardNegative,
                src_a: SrcSel::One,
                src_b: SrcSel::Zero,
                src_c: SrcSel::Forward,
                base_addr: 1023,
                repeat: 1023,
            },
        ];
        for ins in cases {
            assert_eq!(Instruction::decode(ins.encode()), ins);
        }
    }

    #[test]
    fn roundtrip_all_field_extremes() {
        for unit in [UnitSel::DpCma, UnitSel::DpFma, UnitSel::SpCma, UnitSel::SpFma] {
            for op in [Op::Nop, Op::Fmac, Op::Mul, Op::Add] {
                for rnd in RoundMode::ALL {
                    let ins = Instruction {
                        unit,
                        op,
                        rounding: rnd,
                        src_a: SrcSel::Ram,
                        src_b: SrcSel::Forward,
                        src_c: SrcSel::One,
                        base_addr: 7,
                        repeat: 3,
                    };
                    assert_eq!(Instruction::decode(ins.encode()), ins);
                }
            }
        }
    }

    #[test]
    fn field_overflow_panics() {
        let mut ins = Instruction::fmac_burst(UnitSel::SpFma, 0, 1);
        ins.base_addr = 1024;
        assert!(std::panic::catch_unwind(|| ins.encode()).is_err());
    }

    #[test]
    fn burst_constructors() {
        let i = Instruction::fmac_burst(UnitSel::SpFma, 16, 256);
        assert_eq!(i.repeat, 255);
        assert_eq!(i.src_c, SrcSel::Ram);
        let a = Instruction::accumulate_burst(UnitSel::SpFma, 16, 256);
        assert_eq!(a.src_c, SrcSel::Forward);
        assert_eq!(a.src_a, SrcSel::Ram);
    }

    #[test]
    fn seq_word_roundtrip_directed() {
        let cases = [
            SeqWord::Basic(Instruction::fmac_burst(UnitSel::DpCma, 512, 1024)),
            SeqWord::Repeat { window: 1, count: 1 },
            SeqWord::Repeat { window: 255, count: u32::MAX },
            SeqWord::Stream(StreamDesc {
                port: StreamPort::A,
                bank: StreamBank::Stim,
                base: 0,
                stride0: 1,
                len0: 64,
                stride1: 0,
            }),
            SeqWord::Stream(StreamDesc {
                port: StreamPort::C,
                bank: StreamBank::Result,
                base: u16::MAX,
                stride0: STREAM_STRIDE_MIN,
                len0: u16::MAX,
                stride1: STREAM_STRIDE_MAX,
            }),
            SeqWord::Stream(StreamDesc::disarm(StreamPort::B)),
        ];
        for w in cases {
            let bits = w.encode();
            assert_eq!(SeqWord::decode(bits).unwrap(), w, "{w:?}");
            // Basic words keep the upper half architecturally zero.
            if let SeqWord::Basic(_) = w {
                assert_eq!(bits >> 32, 0);
            }
        }
    }

    #[test]
    fn seq_word_roundtrip_property() {
        // Satellite contract: seeded random fields over EVERY word kind —
        // classic instructions (all unit/op/rounding/src combinations and
        // the full base/repeat ranges), repeats, and stream descriptors —
        // survive encode→decode bit-exactly.
        use crate::util::check_cases;
        let units = [UnitSel::DpCma, UnitSel::DpFma, UnitSel::SpCma, UnitSel::SpFma];
        let ops = [Op::Nop, Op::Fmac, Op::Mul, Op::Add];
        let sels = [SrcSel::Ram, SrcSel::Forward, SrcSel::Zero, SrcSel::One];
        let ports = StreamPort::ALL;
        check_cases(
            0xf9ea_5eed,
            4096,
            |rng| match rng.below(3) {
                0 => SeqWord::Basic(Instruction {
                    unit: units[rng.below(4) as usize],
                    op: ops[rng.below(4) as usize],
                    rounding: RoundMode::ALL[rng.below(4) as usize],
                    src_a: sels[rng.below(4) as usize],
                    src_b: sels[rng.below(4) as usize],
                    src_c: sels[rng.below(4) as usize],
                    base_addr: rng.below(1024) as u16,
                    repeat: rng.below(1024) as u16,
                }),
                1 => SeqWord::Repeat {
                    window: 1 + rng.below(255) as u8,
                    count: 1 + rng.below(u32::MAX as u64) as u32,
                },
                _ => SeqWord::Stream(StreamDesc {
                    port: ports[rng.below(3) as usize],
                    bank: if rng.chance(0.5) { StreamBank::Stim } else { StreamBank::Result },
                    base: rng.below(1 << 16) as u16,
                    stride0: (rng.below(4096) as i64 + STREAM_STRIDE_MIN as i64) as i16,
                    len0: rng.below(1 << 16) as u16,
                    stride1: (rng.below(4096) as i64 + STREAM_STRIDE_MIN as i64) as i16,
                }),
            },
            |w| {
                let decoded = SeqWord::decode(w.encode())
                    .map_err(|e| format!("decode failed: {e}"))?;
                if decoded == *w {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {decoded:?}"))
                }
            },
        );
    }

    #[test]
    fn seq_word_rejects_malformed_bits() {
        // Reserved bits, zero window/count, invalid port, unknown tag.
        let repeat = SeqWord::Repeat { window: 2, count: 8 }.encode();
        assert!(SeqWord::decode(repeat | (1 << 45)).is_err(), "repeat reserved bits");
        assert!(SeqWord::decode((TAG_REPEAT << 61) | (8 << 8)).is_err(), "zero window");
        assert!(SeqWord::decode((TAG_REPEAT << 61) | 2).is_err(), "zero count");
        let stream = SeqWord::Stream(StreamDesc::disarm(StreamPort::A)).encode();
        assert!(SeqWord::decode(stream | (1 << 59)).is_err(), "stream reserved bits");
        assert!(SeqWord::decode((TAG_STREAM << 61) | 3).is_err(), "invalid port");
        assert!(SeqWord::decode(7 << 61).is_err(), "unknown tag");
        assert!(SeqWord::decode(3 << 61).is_err(), "unknown tag 3");
    }

    #[test]
    fn stream_desc_affine_walk() {
        // GEMM B-row shape: base k·N, inner stride 1 over N columns,
        // outer stride 0 (the row repeats for every output row).
        let b = StreamDesc {
            port: StreamPort::B,
            bank: StreamBank::Stim,
            base: 8,
            stride0: 1,
            len0: 4,
            stride1: 0,
        };
        let addrs: Vec<i64> = (0..8).map(|n| b.addr(n)).collect();
        assert_eq!(addrs, vec![8, 9, 10, 11, 8, 9, 10, 11]);
        // GEMM A-column shape: broadcast within a row (stride0 0 over N),
        // hop K to the next row's element.
        let a = StreamDesc {
            port: StreamPort::A,
            bank: StreamBank::Stim,
            base: 2,
            stride0: 0,
            len0: 4,
            stride1: 3,
        };
        let addrs: Vec<i64> = (0..8).map(|n| a.addr(n)).collect();
        assert_eq!(addrs, vec![2, 2, 2, 2, 5, 5, 5, 5]);
        // Negative strides walk down (and can go negative — the
        // sequencer's fetch guard owns that error).
        let down = StreamDesc { stride0: -2, len0: 8, base: 3, ..a };
        assert_eq!((0..4).map(|n| down.addr(n)).collect::<Vec<_>>(), vec![3, 1, -1, -3]);
    }
}
