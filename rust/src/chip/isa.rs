//! The test-program instruction encoding (Fig. 5(b)).
//!
//! The paper's figure shows a compact encoding that selects the FPU, the
//! operand sources (stimulus RAM or the forwarding network) and the
//! rounding mode, with a loop counter driven by the sequencer. The
//! published figure is too small to transcribe field-exactly, so this is
//! a faithful *reconstruction* with the same information content, packed
//! into 32 bits:
//!
//! ```text
//!  31..30  unit      (00 DP CMA, 01 DP FMA, 10 SP CMA, 11 SP FMA)
//!  29..28  op        (00 NOP, 01 FMAC, 10 MUL, 11 ADD)
//!  27..26  rounding  (00 RNE, 01 RZ, 10 RU, 11 RD)
//!  25..24  src_c sel (00 RAM, 01 forward result, 10 zero, 11 one)
//!  23..22  src_b sel
//!  21..20  src_a sel
//!  19..10  RAM base address (ops stream sequentially from here)
//!   9..0   repeat count − 1
//! ```

use crate::arch::rounding::RoundMode;

/// Operand-source selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrcSel {
    Ram,
    Forward,
    Zero,
    One,
}

/// FPU operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Nop,
    Fmac,
    Mul,
    Add,
}

/// Unit selector, Table-I order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitSel {
    DpCma = 0,
    DpFma = 1,
    SpCma = 2,
    SpFma = 3,
}

/// One decoded test instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    pub unit: UnitSel,
    pub op: Op,
    pub rounding: RoundMode,
    pub src_a: SrcSel,
    pub src_b: SrcSel,
    pub src_c: SrcSel,
    pub base_addr: u16,
    pub repeat: u16,
}

fn sel_bits(s: SrcSel) -> u32 {
    match s {
        SrcSel::Ram => 0,
        SrcSel::Forward => 1,
        SrcSel::Zero => 2,
        SrcSel::One => 3,
    }
}

fn sel_from(b: u32) -> SrcSel {
    match b & 3 {
        0 => SrcSel::Ram,
        1 => SrcSel::Forward,
        2 => SrcSel::Zero,
        _ => SrcSel::One,
    }
}

impl Instruction {
    /// A plain FMAC burst from the stimulus RAM.
    pub fn fmac_burst(unit: UnitSel, base_addr: u16, count: u16) -> Instruction {
        assert!(count >= 1 && count <= 1024, "repeat out of range");
        Instruction {
            unit,
            op: Op::Fmac,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Ram,
            base_addr,
            repeat: count - 1,
        }
    }

    /// An accumulation burst: `c` comes from the forwarding network.
    pub fn accumulate_burst(unit: UnitSel, base_addr: u16, count: u16) -> Instruction {
        let mut i = Instruction::fmac_burst(unit, base_addr, count);
        i.src_c = SrcSel::Forward;
        i
    }

    /// Encode to the 32-bit word.
    pub fn encode(&self) -> u32 {
        assert!(self.base_addr < 1024 && self.repeat < 1024, "field overflow");
        let op = match self.op {
            Op::Nop => 0u32,
            Op::Fmac => 1,
            Op::Mul => 2,
            Op::Add => 3,
        };
        let rnd = match self.rounding {
            RoundMode::NearestEven => 0u32,
            RoundMode::TowardZero => 1,
            RoundMode::TowardPositive => 2,
            RoundMode::TowardNegative => 3,
        };
        ((self.unit as u32) << 30)
            | (op << 28)
            | (rnd << 26)
            | (sel_bits(self.src_c) << 24)
            | (sel_bits(self.src_b) << 22)
            | (sel_bits(self.src_a) << 20)
            | ((self.base_addr as u32) << 10)
            | (self.repeat as u32)
    }

    /// Decode from the 32-bit word.
    pub fn decode(w: u32) -> Instruction {
        let unit = match w >> 30 {
            0 => UnitSel::DpCma,
            1 => UnitSel::DpFma,
            2 => UnitSel::SpCma,
            _ => UnitSel::SpFma,
        };
        let op = match (w >> 28) & 3 {
            0 => Op::Nop,
            1 => Op::Fmac,
            2 => Op::Mul,
            _ => Op::Add,
        };
        let rounding = match (w >> 26) & 3 {
            0 => RoundMode::NearestEven,
            1 => RoundMode::TowardZero,
            2 => RoundMode::TowardPositive,
            _ => RoundMode::TowardNegative,
        };
        Instruction {
            unit,
            op,
            rounding,
            src_c: sel_from(w >> 24),
            src_b: sel_from(w >> 22),
            src_a: sel_from(w >> 20),
            base_addr: ((w >> 10) & 0x3ff) as u16,
            repeat: (w & 0x3ff) as u16,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let cases = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 1),
            Instruction::fmac_burst(UnitSel::DpCma, 512, 1024),
            Instruction::accumulate_burst(UnitSel::SpCma, 100, 64),
            Instruction {
                unit: UnitSel::DpFma,
                op: Op::Mul,
                rounding: RoundMode::TowardNegative,
                src_a: SrcSel::One,
                src_b: SrcSel::Zero,
                src_c: SrcSel::Forward,
                base_addr: 1023,
                repeat: 1023,
            },
        ];
        for ins in cases {
            assert_eq!(Instruction::decode(ins.encode()), ins);
        }
    }

    #[test]
    fn roundtrip_all_field_extremes() {
        for unit in [UnitSel::DpCma, UnitSel::DpFma, UnitSel::SpCma, UnitSel::SpFma] {
            for op in [Op::Nop, Op::Fmac, Op::Mul, Op::Add] {
                for rnd in RoundMode::ALL {
                    let ins = Instruction {
                        unit,
                        op,
                        rounding: rnd,
                        src_a: SrcSel::Ram,
                        src_b: SrcSel::Forward,
                        src_c: SrcSel::One,
                        base_addr: 7,
                        repeat: 3,
                    };
                    assert_eq!(Instruction::decode(ins.encode()), ins);
                }
            }
        }
    }

    #[test]
    fn field_overflow_panics() {
        let mut ins = Instruction::fmac_burst(UnitSel::SpFma, 0, 1);
        ins.base_addr = 1024;
        assert!(std::panic::catch_unwind(|| ins.encode()).is_err());
    }

    #[test]
    fn burst_constructors() {
        let i = Instruction::fmac_burst(UnitSel::SpFma, 16, 256);
        assert_eq!(i.repeat, 255);
        assert_eq!(i.src_c, SrcSel::Ram);
        let a = Instruction::accumulate_burst(UnitSel::SpFma, 16, 256);
        assert_eq!(a.src_c, SrcSel::Forward);
        assert_eq!(a.src_a, SrcSel::Ram);
    }
}
