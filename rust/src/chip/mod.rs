//! The FPMax chip testbench of Fig. 5: stimulus/result RAM banks
//! ([`ram`]), the JTAG-like slow port ([`jtag`]), the test-program
//! instruction encoding ([`isa`]), and the at-speed sequencer
//! ([`tester`]).

pub mod isa;
pub mod jtag;
pub mod ram;
pub mod tester;

pub use isa::{Instruction, Op, SeqWord, SrcSel, StreamBank, StreamDesc, StreamPort, UnitSel};
pub use jtag::{JtagIr, JtagPort, IDCODE};
pub use ram::RamBank;
pub use tester::{expected_result, FpMaxChip, RunStats, BANK_PROGRAM, BANK_RESULT, BANK_STIM_A, BANK_STIM_B, BANK_STIM_C};
