//! The at-speed test sequencer: Fig. 5(a)'s datapath — stimulus RAMs →
//! selected FPU → result RAM — driven by the Fig. 5(b) instruction
//! stream, with the repeat-buffer / stream-register extension.
//!
//! `run()` executes the loaded program exactly as the silicon sequencer
//! would: one FMAC per cycle from the RAMs in burst mode, or one per
//! bypass-latency when an operand comes from the forwarding network
//! (accumulation tests), with cycle accounting per burst. All four
//! generated FPUs live on the chip simultaneously, as fabricated.
//!
//! A `REPEAT` word ([`super::isa::SeqWord`]) decodes its window once
//! into a small micro-op buffer and loops it, so the window's ops issue
//! back-to-back (one FPU op per cycle through the batched engine path)
//! with a *single* pipeline drain at the end — the Snitch-style FREP
//! story that lifts occupancy to ~1 inside kernel bursts. Armed stream
//! semantic registers re-address `SrcSel::Ram` operands through a
//! two-level affine walk (and may source the *result* bank, chaining
//! kernel passes), advancing one element per op without re-issue. To
//! keep gathered and op-at-a-time execution observationally identical,
//! a result-bank stream may only read below the result write pointer as
//! it stood when the current program word began — reading into the
//! window being written is a sequencing error, not silent staleness.

use crate::arch::engine::{
    add_batch, mul_batch, reference_fmac, ActivityAccumulator, ActivityTrace, Datapath,
};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::arch::rounding::RoundMode;
use crate::pipesim::sim::LatencyModel;
use crate::pipesim::trace::DepKind;
use crate::workloads::throughput::OperandTriple;

use super::isa::{Instruction, Op, SeqWord, SrcSel, StreamBank, StreamDesc, UnitSel};
use super::jtag::JtagPort;
use super::ram::RamBank;

/// RAM bank indices on the JTAG chain.
pub const BANK_STIM_A: usize = 0;
pub const BANK_STIM_B: usize = 1;
pub const BANK_STIM_C: usize = 2;
pub const BANK_RESULT: usize = 3;
pub const BANK_PROGRAM: usize = 4;

/// Statistics from one at-speed run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RunStats {
    pub instructions: u64,
    pub ops: u64,
    pub cycles: u64,
    pub results_written: u64,
    /// Ops issued from inside repeat-buffer windows.
    pub repeat_ops: u64,
    /// Cycles attributed to repeat-buffer bursts: in-window issue slots
    /// (including forwarding stalls and `Nop` bubbles), the one-cycle
    /// window decode, and the single post-repeat pipeline drain.
    /// `repeat_ops / repeat_cycles` is the in-burst occupancy the
    /// kernel gates check.
    pub repeat_cycles: u64,
}

impl RunStats {
    /// In-burst occupancy of the repeat-buffer cycles (0 when the
    /// program never repeated).
    pub fn repeat_occupancy(&self) -> f64 {
        if self.repeat_cycles == 0 {
            0.0
        } else {
            self.repeat_ops as f64 / self.repeat_cycles as f64
        }
    }
}

/// Live state of one armed stream semantic register.
#[derive(Debug, Clone, Copy)]
struct StreamState {
    desc: StreamDesc,
    /// Elements consumed so far.
    n: u64,
}

/// The `1.0` bit pattern of a unit's format (the `SrcSel::One`
/// constant).
fn one_bits(unit: &FpuUnit) -> u64 {
    match unit.config.precision {
        Precision::Single => 1.0f32.to_bits() as u64,
        Precision::Double => 1.0f64.to_bits(),
        p => crate::arch::softfloat::from_f64(p.format(), 1.0),
    }
}

/// Resolve and read one operand. `plain_addr` is the classic
/// `base_addr + i` sequential address, used when no stream is armed on
/// the port; an armed stream overrides it for `SrcSel::Ram`, advancing
/// one element per read. `guard_wptr` is the result write pointer as of
/// the start of the current program word: result-bank stream reads at
/// or above it would observe the window currently being written —
/// where gathered and scalar execution could diverge — so they reject.
fn fetch_operand(
    sel: SrcSel,
    port: usize,
    plain_addr: usize,
    stim: &mut RamBank,
    result: &mut RamBank,
    streams: &mut [Option<StreamState>; 3],
    one: u64,
    forward: u64,
    guard_wptr: usize,
) -> crate::Result<u64> {
    match sel {
        SrcSel::Forward => Ok(forward),
        SrcSel::Zero => Ok(0),
        SrcSel::One => Ok(one),
        SrcSel::Ram => match &mut streams[port] {
            None => stim.read(plain_addr),
            Some(st) => {
                let addr = st.desc.addr(st.n);
                st.n += 1;
                anyhow::ensure!(
                    addr >= 0,
                    "stream {} walked to negative address {addr} at element {}",
                    st.desc.port.name(),
                    st.n - 1
                );
                match st.desc.bank {
                    StreamBank::Stim => stim.read(addr as usize),
                    StreamBank::Result => {
                        anyhow::ensure!(
                            (addr as usize) < guard_wptr,
                            "stream {} reads result[{addr}] inside the window being \
                             written (write pointer was {guard_wptr} at issue)",
                            st.desc.port.name()
                        );
                        result.read(addr as usize)
                    }
                }
            }
        },
    }
}

/// The FPMax chip model.
pub struct FpMaxChip {
    units: [FpuUnit; 4],
    stim_a: RamBank,
    stim_b: RamBank,
    stim_c: RamBank,
    result: RamBank,
    program: RamBank,
    /// Pooled burst-gather scratch, reused across instructions and runs
    /// so steady-state sequencing allocates nothing.
    burst_triples: Vec<OperandTriple>,
    burst_bits: Vec<u64>,
    /// The decoded micro-op buffer a `REPEAT` window executes from.
    repeat_buf: Vec<Instruction>,
}

impl FpMaxChip {
    /// Instantiate the chip with the four fabricated units and RAMs of
    /// the given depth (words). Program RAM keeps the fabricated 256
    /// words; kernel-scale programs use [`FpMaxChip::with_depths`].
    pub fn new(ram_depth: usize) -> FpMaxChip {
        FpMaxChip::with_depths(ram_depth, 256)
    }

    /// Instantiate with explicit stimulus/result and program RAM depths
    /// (the kernel runner's unrolled reference programs outgrow the
    /// fabricated program RAM).
    pub fn with_depths(ram_depth: usize, program_depth: usize) -> FpMaxChip {
        FpMaxChip {
            units: [
                FpuUnit::generate(&FpuConfig::dp_cma()),
                FpuUnit::generate(&FpuConfig::dp_fma()),
                FpuUnit::generate(&FpuConfig::sp_cma()),
                FpuUnit::generate(&FpuConfig::sp_fma()),
            ],
            stim_a: RamBank::new("stim_a", ram_depth),
            stim_b: RamBank::new("stim_b", ram_depth),
            stim_c: RamBank::new("stim_c", ram_depth),
            result: RamBank::new("result", ram_depth),
            program: RamBank::new("program", program_depth),
            burst_triples: Vec::with_capacity(ram_depth),
            burst_bits: vec![0; ram_depth],
            repeat_buf: Vec::new(),
        }
    }

    /// The unit behind a selector.
    pub fn unit(&self, sel: UnitSel) -> &FpuUnit {
        &self.units[sel as usize]
    }

    /// Open the JTAG port over all banks (the only off-chip interface).
    pub fn jtag(&mut self) -> JtagPort<'_> {
        JtagPort::new(vec![
            &mut self.stim_a,
            &mut self.stim_b,
            &mut self.stim_c,
            &mut self.result,
            &mut self.program,
        ])
    }

    /// Execute the loaded program at speed.
    pub fn run(&mut self) -> crate::Result<RunStats> {
        self.run_inner(None)
    }

    /// Execute the loaded program at speed while emitting a
    /// time-resolved [`ActivityTrace`] of the sequencer's issue-slot
    /// timeline: every cycle of the run lands in a window — FMAC bursts
    /// as gate-level tracked ops, Mul/Add bursts as occupancy-only ops,
    /// forwarding stalls / pipeline drains / `Nop`s as idle slots. The
    /// trace's slot count equals the run's cycle count exactly, so the
    /// body-bias controller sees the program's real phase structure.
    pub fn run_traced(&mut self, window_slots: u64) -> crate::Result<(RunStats, ActivityTrace)> {
        let mut trace = ActivityTrace::new(window_slots);
        let stats = self.run_inner(Some(&mut trace))?;
        Ok((stats, trace))
    }

    fn run_inner(&mut self, mut trace: Option<&mut ActivityTrace>) -> crate::Result<RunStats> {
        let FpMaxChip {
            units,
            stim_a,
            stim_b,
            stim_c,
            result,
            program,
            burst_triples,
            burst_bits,
            repeat_buf,
        } = self;
        let mut env = SeqEnv { units, stim_a, stim_b, stim_c, result, burst_triples, burst_bits };
        let mut stats = RunStats::default();
        let mut result_wptr = 0usize;
        let mut streams: [Option<StreamState>; 3] = [None; 3];
        let mut pc = 0usize;
        while pc < program.depth() {
            let word = program.peek(pc).unwrap_or(0);
            if word == 0 {
                break; // end of program (all-zero word = halt)
            }
            let sw = SeqWord::decode(word)
                .map_err(|e| anyhow::anyhow!("program word {pc}: {e}"))?;
            stats.instructions += 1;
            match sw {
                SeqWord::Basic(ins) => {
                    env.exec_basic(&ins, &mut streams, &mut result_wptr, &mut stats, &mut trace)?;
                    pc += 1;
                }
                SeqWord::Stream(desc) => {
                    // One sequencer cycle to latch (or clear, when
                    // `len0 == 0`) the stream semantic register.
                    streams[desc.port as usize] =
                        if desc.len0 == 0 { None } else { Some(StreamState { desc, n: 0 }) };
                    stats.cycles += 1;
                    if let Some(t) = trace.as_deref_mut() {
                        t.push_idle(1);
                    }
                    pc += 1;
                }
                SeqWord::Repeat { window, count } => {
                    // Decode the window into the micro-op buffer once,
                    // rejecting anything a hardware repeat buffer could
                    // not loop: nested repeats, mid-window stream
                    // re-arms, and windows that run off the program.
                    let w = window as usize;
                    repeat_buf.clear();
                    for k in 0..w {
                        let wpc = pc + 1 + k;
                        let wword =
                            if wpc < program.depth() { program.peek(wpc).unwrap_or(0) } else { 0 };
                        anyhow::ensure!(
                            wword != 0,
                            "repeat window at word {pc} runs past the end of the program"
                        );
                        match SeqWord::decode(wword)
                            .map_err(|e| anyhow::anyhow!("program word {wpc}: {e}"))?
                        {
                            SeqWord::Basic(ins) => repeat_buf.push(ins),
                            SeqWord::Repeat { .. } => anyhow::bail!(
                                "overlapping repeat windows: word {wpc} is a Repeat inside \
                                 the window of the Repeat at word {pc}"
                            ),
                            SeqWord::Stream(_) => anyhow::bail!(
                                "stream descriptor at word {wpc} inside a repeat window \
                                 (arm streams before the Repeat)"
                            ),
                        }
                    }
                    stats.instructions += w as u64;
                    env.exec_repeat(
                        &repeat_buf[..],
                        count,
                        &mut streams,
                        &mut result_wptr,
                        &mut stats,
                        &mut trace,
                    )?;
                    pc += 1 + w;
                }
            }
        }
        stats.results_written = result_wptr as u64;
        Ok(stats)
    }

    /// Reset RAMs (not the units — they are combinational).
    pub fn reset(&mut self) {
        self.stim_a.clear();
        self.stim_b.clear();
        self.stim_c.clear();
        self.result.clear();
        self.program.clear();
    }
}

/// Cap on how many micro-op instances a repeat run gathers before it
/// flushes through the batch engine — bounds scratch growth on huge
/// `count` values without changing results or cycle accounting.
const REPEAT_FLUSH_OPS: usize = 1 << 16;

/// Identity of a batchable run of repeat-window micro-ops: instances
/// batch together only while the executing unit, op, and rounding mode
/// all match, so each flush is one homogeneous `fmac_batch`-style call.
#[derive(Clone, Copy, PartialEq)]
struct PendingRun {
    unit_idx: usize,
    op: Op,
    rounding: RoundMode,
}

/// The sequencer's execution context: split borrows of the chip's units,
/// RAM banks, and pooled burst scratch, so `run_inner` can hold the
/// program RAM and micro-op buffer separately while executing.
struct SeqEnv<'a> {
    units: &'a [FpuUnit; 4],
    stim_a: &'a mut RamBank,
    stim_b: &'a mut RamBank,
    stim_c: &'a mut RamBank,
    result: &'a mut RamBank,
    burst_triples: &'a mut Vec<OperandTriple>,
    burst_bits: &'a mut Vec<u64>,
}

impl SeqEnv<'_> {
    /// Execute one `Basic` program word with classic per-instruction
    /// timing (issue slots + a full pipeline drain), operands resolved
    /// through any armed stream registers.
    fn exec_basic(
        &mut self,
        ins: &Instruction,
        streams: &mut [Option<StreamState>; 3],
        result_wptr: &mut usize,
        stats: &mut RunStats,
        trace: &mut Option<&mut ActivityTrace>,
    ) -> crate::Result<()> {
        if matches!(ins.op, Op::Nop) {
            if let Some(t) = trace.as_deref_mut() {
                t.push_idle(ins.repeat as u64 + 1);
            }
            stats.cycles += (ins.repeat as u64) + 1;
            return Ok(());
        }
        let units = self.units;
        let unit = &units[ins.unit as usize];
        let lat = LatencyModel::of(unit);
        let one = one_bits(unit);
        let guard = *result_wptr;
        let mut forward: u64 = 0;
        // Per-op issue distance: 1 from RAM, or the bypass tap when an
        // operand comes from the forwarding network.
        let uses_fwd_c = ins.src_c == SrcSel::Forward;
        let uses_fwd_ab = ins.src_a == SrcSel::Forward || ins.src_b == SrcSel::Forward;
        let issue_dist = if uses_fwd_ab {
            lat.tap(DepKind::Multiplier).max(1) as u64
        } else if uses_fwd_c {
            lat.tap(DepKind::Accumulate).max(1) as u64
        } else {
            1
        };

        // Independent bursts (every operand from RAM or a constant)
        // have no sequential dependence: the sequencer gathers the
        // whole burst into pooled scratch and issues it through the
        // batched execution layer in one go, exactly as the silicon
        // streams one op per cycle. FMAC bursts batch at the unit's
        // default rounding; Mul/Add bursts batch at *any* rounding
        // mode (the explicit-rounding test programs), RNE through the
        // SoA lane kernels and directed modes through the scalar
        // spec. Forwarding bursts and explicit-rounding FMACs stay on
        // the scalar path below.
        let independent_burst = !uses_fwd_ab
            && !uses_fwd_c
            && match ins.op {
                Op::Fmac => ins.rounding == RoundMode::NearestEven,
                Op::Mul | Op::Add => true,
                Op::Nop => false,
            };
        if independent_burst {
            let count = ins.repeat as usize + 1;
            let base = ins.base_addr as usize;
            self.burst_triples.clear();
            for i in 0..count {
                let addr = base + i;
                let a = fetch_operand(
                    ins.src_a, 0, addr, self.stim_a, self.result, streams, one, 0, guard,
                )?;
                let b = fetch_operand(
                    ins.src_b, 1, addr, self.stim_b, self.result, streams, one, 0, guard,
                )?;
                let c = fetch_operand(
                    ins.src_c, 2, addr, self.stim_c, self.result, streams, one, 0, guard,
                )?;
                self.burst_triples.push(OperandTriple { a, b, c });
            }
            if self.burst_bits.len() < count {
                self.burst_bits.resize(count, 0);
            }
            let bits = &mut self.burst_bits[..count];
            match ins.op {
                Op::Fmac => match trace.as_deref_mut() {
                    // Traced FMAC bursts stream through the tracked
                    // gate-level op, landing one issue slot per op in
                    // the trace's windows (same bits either way).
                    Some(t) => t
                        .push_batch_tracked(unit, &self.burst_triples[..], bits)
                        .expect("burst scratch sized together"),
                    None => unit.fmac_batch(&self.burst_triples[..], bits),
                },
                Op::Mul => {
                    mul_batch(unit.format, ins.rounding, &self.burst_triples[..], bits);
                    if let Some(t) = trace.as_deref_mut() {
                        // Occupancy-only: Mul/Add bursts carry no
                        // FMAC activity record.
                        t.push_untracked_ops(count as u64);
                    }
                }
                Op::Add => {
                    add_batch(unit.format, ins.rounding, &self.burst_triples[..], bits);
                    if let Some(t) = trace.as_deref_mut() {
                        t.push_untracked_ops(count as u64);
                    }
                }
                Op::Nop => unreachable!("excluded above"),
            }
            if let Some(t) = trace.as_deref_mut() {
                // Pipeline drain between instructions.
                t.push_idle(lat.full as u64);
            }
            for &r in &self.burst_bits[..count] {
                self.result.write(*result_wptr, r)?;
                *result_wptr += 1;
            }
            stats.ops += count as u64;
            stats.cycles += issue_dist * count as u64;
            stats.cycles += lat.full as u64;
            return Ok(());
        }

        for i in 0..=(ins.repeat as usize) {
            let addr = ins.base_addr as usize + i;
            let a = fetch_operand(
                ins.src_a, 0, addr, self.stim_a, self.result, streams, one, forward, guard,
            )?;
            let b = fetch_operand(
                ins.src_b, 1, addr, self.stim_b, self.result, streams, one, forward, guard,
            )?;
            let c = fetch_operand(
                ins.src_c, 2, addr, self.stim_c, self.result, streams, one, forward, guard,
            )?;
            let r = match ins.op {
                Op::Fmac => {
                    let (r, act) = unit.fmac_mode(ins.rounding, a, b, c);
                    if let Some(t) = trace.as_deref_mut() {
                        let mut acc = ActivityAccumulator::default();
                        acc.record(&act);
                        t.push_op(&acc);
                    }
                    r
                }
                Op::Mul => {
                    let r = crate::arch::softfloat::mul(unit.format, ins.rounding, a, b);
                    if let Some(t) = trace.as_deref_mut() {
                        t.push_untracked_ops(1);
                    }
                    r
                }
                Op::Add => {
                    let r = crate::arch::softfloat::add(unit.format, ins.rounding, a, c);
                    if let Some(t) = trace.as_deref_mut() {
                        t.push_untracked_ops(1);
                    }
                    r
                }
                Op::Nop => unreachable!(),
            };
            if let Some(t) = trace.as_deref_mut() {
                // Bypass-throttled issue: the slots between
                // successive ops are stalls.
                t.push_idle(issue_dist - 1);
            }
            forward = r.bits;
            self.result.write(*result_wptr, r.bits)?;
            *result_wptr += 1;
            stats.ops += 1;
            stats.cycles += issue_dist;
        }
        // Pipeline drain between instructions.
        if let Some(t) = trace.as_deref_mut() {
            t.push_idle(lat.full as u64);
        }
        stats.cycles += lat.full as u64;
        Ok(())
    }

    /// Execute a decoded repeat window `count` times out of the micro-op
    /// buffer. Batchable micro-op instances (all-independent operands at
    /// batchable rounding) gather across iterations into homogeneous
    /// runs that issue one op per cycle through the batch engine path;
    /// the whole repeat pays one decode cycle up front and a *single*
    /// pipeline drain at the end, instead of one drain per instruction.
    /// The forwarding register resets on repeat entry and then persists
    /// across iterations, so a one-op accumulation window reduces across
    /// the entire repeat.
    fn exec_repeat(
        &mut self,
        micro: &[Instruction],
        count: u32,
        streams: &mut [Option<StreamState>; 3],
        result_wptr: &mut usize,
        stats: &mut RunStats,
        trace: &mut Option<&mut ActivityTrace>,
    ) -> crate::Result<()> {
        // One cycle to decode the window into the micro-op buffer.
        stats.cycles += 1;
        stats.repeat_cycles += 1;
        if let Some(t) = trace.as_deref_mut() {
            t.push_idle(1);
        }

        let guard = *result_wptr;
        let units = self.units;
        let mut forward: u64 = 0;
        let mut pending: Option<PendingRun> = None;
        self.burst_triples.clear();
        for _iter in 0..count {
            for ins in micro {
                if matches!(ins.op, Op::Nop) {
                    self.flush_repeat_run(&mut pending, result_wptr, &mut forward, stats, trace)?;
                    let bubbles = ins.repeat as u64 + 1;
                    if let Some(t) = trace.as_deref_mut() {
                        t.push_idle(bubbles);
                    }
                    stats.cycles += bubbles;
                    stats.repeat_cycles += bubbles;
                    continue;
                }
                let unit = &units[ins.unit as usize];
                let one = one_bits(unit);
                let uses_fwd_c = ins.src_c == SrcSel::Forward;
                let uses_fwd_ab = ins.src_a == SrcSel::Forward || ins.src_b == SrcSel::Forward;
                let scalar = uses_fwd_ab
                    || uses_fwd_c
                    || (matches!(ins.op, Op::Fmac) && ins.rounding != RoundMode::NearestEven);
                if scalar {
                    // Forwarding (or directed-rounding FMAC) micro-ops
                    // leave the batch path: flush what's gathered, then
                    // issue at the bypass tap distance.
                    self.flush_repeat_run(&mut pending, result_wptr, &mut forward, stats, trace)?;
                    let lat = LatencyModel::of(unit);
                    let issue_dist = if uses_fwd_ab {
                        lat.tap(DepKind::Multiplier).max(1) as u64
                    } else if uses_fwd_c {
                        lat.tap(DepKind::Accumulate).max(1) as u64
                    } else {
                        1
                    };
                    for i in 0..=(ins.repeat as usize) {
                        let addr = ins.base_addr as usize + i;
                        let a = fetch_operand(
                            ins.src_a, 0, addr, self.stim_a, self.result, streams, one, forward,
                            guard,
                        )?;
                        let b = fetch_operand(
                            ins.src_b, 1, addr, self.stim_b, self.result, streams, one, forward,
                            guard,
                        )?;
                        let c = fetch_operand(
                            ins.src_c, 2, addr, self.stim_c, self.result, streams, one, forward,
                            guard,
                        )?;
                        let r = match ins.op {
                            Op::Fmac => {
                                let (r, act) = unit.fmac_mode(ins.rounding, a, b, c);
                                if let Some(t) = trace.as_deref_mut() {
                                    let mut acc = ActivityAccumulator::default();
                                    acc.record(&act);
                                    t.push_op(&acc);
                                }
                                r
                            }
                            Op::Mul => {
                                let r =
                                    crate::arch::softfloat::mul(unit.format, ins.rounding, a, b);
                                if let Some(t) = trace.as_deref_mut() {
                                    t.push_untracked_ops(1);
                                }
                                r
                            }
                            Op::Add => {
                                let r =
                                    crate::arch::softfloat::add(unit.format, ins.rounding, a, c);
                                if let Some(t) = trace.as_deref_mut() {
                                    t.push_untracked_ops(1);
                                }
                                r
                            }
                            Op::Nop => unreachable!(),
                        };
                        if let Some(t) = trace.as_deref_mut() {
                            t.push_idle(issue_dist - 1);
                        }
                        forward = r.bits;
                        self.result.write(*result_wptr, r.bits)?;
                        *result_wptr += 1;
                        stats.ops += 1;
                        stats.repeat_ops += 1;
                        stats.cycles += issue_dist;
                        stats.repeat_cycles += issue_dist;
                    }
                    continue;
                }
                let key = PendingRun {
                    unit_idx: ins.unit as usize,
                    op: ins.op,
                    rounding: ins.rounding,
                };
                if pending != Some(key) || self.burst_triples.len() >= REPEAT_FLUSH_OPS {
                    self.flush_repeat_run(&mut pending, result_wptr, &mut forward, stats, trace)?;
                    pending = Some(key);
                }
                for i in 0..=(ins.repeat as usize) {
                    let addr = ins.base_addr as usize + i;
                    let a = fetch_operand(
                        ins.src_a, 0, addr, self.stim_a, self.result, streams, one, 0, guard,
                    )?;
                    let b = fetch_operand(
                        ins.src_b, 1, addr, self.stim_b, self.result, streams, one, 0, guard,
                    )?;
                    let c = fetch_operand(
                        ins.src_c, 2, addr, self.stim_c, self.result, streams, one, 0, guard,
                    )?;
                    self.burst_triples.push(OperandTriple { a, b, c });
                }
            }
        }
        self.flush_repeat_run(&mut pending, result_wptr, &mut forward, stats, trace)?;
        // A single pipeline drain for the whole repeat: back-to-back
        // issue keeps the pipe full across iterations, so only the tail
        // of the deepest unit in the window is exposed.
        let drain = micro
            .iter()
            .filter(|m| !matches!(m.op, Op::Nop))
            .map(|m| LatencyModel::of(&units[m.unit as usize]).full as u64)
            .max()
            .unwrap_or(0);
        if let Some(t) = trace.as_deref_mut() {
            t.push_idle(drain);
        }
        stats.cycles += drain;
        stats.repeat_cycles += drain;
        Ok(())
    }

    /// Issue the gathered run of batchable micro-op instances through
    /// the batch engine path: one op per cycle, results written in
    /// gather order, forwarding register left holding the last result
    /// (exactly what op-at-a-time execution would leave).
    fn flush_repeat_run(
        &mut self,
        pending: &mut Option<PendingRun>,
        result_wptr: &mut usize,
        forward: &mut u64,
        stats: &mut RunStats,
        trace: &mut Option<&mut ActivityTrace>,
    ) -> crate::Result<()> {
        let Some(run) = pending.take() else {
            return Ok(());
        };
        let n = self.burst_triples.len();
        if n == 0 {
            return Ok(());
        }
        if self.burst_bits.len() < n {
            self.burst_bits.resize(n, 0);
        }
        let units = self.units;
        let unit = &units[run.unit_idx];
        let bits = &mut self.burst_bits[..n];
        match run.op {
            Op::Fmac => match trace.as_deref_mut() {
                Some(t) => t
                    .push_batch_tracked(unit, &self.burst_triples[..], bits)
                    .expect("burst scratch sized together"),
                None => unit.fmac_batch(&self.burst_triples[..], bits),
            },
            Op::Mul => {
                mul_batch(unit.format, run.rounding, &self.burst_triples[..], bits);
                if let Some(t) = trace.as_deref_mut() {
                    t.push_untracked_ops(n as u64);
                }
            }
            Op::Add => {
                add_batch(unit.format, run.rounding, &self.burst_triples[..], bits);
                if let Some(t) = trace.as_deref_mut() {
                    t.push_untracked_ops(n as u64);
                }
            }
            Op::Nop => unreachable!("nop micro-ops are never batched"),
        }
        *forward = bits[n - 1];
        for &r in &self.burst_bits[..n] {
            self.result.write(*result_wptr, r)?;
            *result_wptr += 1;
        }
        stats.ops += n as u64;
        stats.repeat_ops += n as u64;
        stats.cycles += n as u64;
        stats.repeat_cycles += n as u64;
        self.burst_triples.clear();
        Ok(())
    }
}

/// Round-mode helper shared by self-test drivers: the expected result of
/// an instruction's op through the golden softfloat model. FMAC
/// expectations come from the engine's shared word-level spec
/// ([`reference_fmac`]), so chip, coordinator, and word-level tier can
/// never drift apart.
pub fn expected_result(unit: &FpuUnit, mode: RoundMode, a: u64, b: u64, c: u64, op: Op) -> u64 {
    use crate::arch::softfloat;
    match op {
        Op::Fmac => reference_fmac(unit.config.kind, unit.format, mode, a, b, c).bits,
        Op::Mul => softfloat::mul(unit.format, mode, a, b).bits,
        Op::Add => softfloat::add(unit.format, mode, a, c).bits,
        Op::Nop => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::isa::StreamPort;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    fn load_triples(chip: &mut FpMaxChip, triples: &[(u64, u64, u64)]) {
        let a: Vec<u64> = triples.iter().map(|t| t.0).collect();
        let b: Vec<u64> = triples.iter().map(|t| t.1).collect();
        let c: Vec<u64> = triples.iter().map(|t| t.2).collect();
        let mut port = chip.jtag();
        port.load_bank(BANK_STIM_A, &a).unwrap();
        port.load_bank(BANK_STIM_B, &b).unwrap();
        port.load_bank(BANK_STIM_C, &c).unwrap();
    }

    #[test]
    fn fmac_burst_correct_results() {
        let mut chip = FpMaxChip::new(64);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 21);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(32).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let prog = [Instruction::fmac_burst(UnitSel::SpFma, 0, 32).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.ops, 32);
        assert_eq!(stats.results_written, 32);
        // Burst from RAM: 1 op/cycle + drain.
        assert_eq!(stats.cycles, 32 + 4);
        let results = chip.jtag().read_bank(BANK_RESULT, 32).unwrap();
        for (i, &(a, b, c)) in triples.iter().enumerate() {
            let fa = f32::from_bits(a as u32);
            let fb = f32::from_bits(b as u32);
            let fc = f32::from_bits(c as u32);
            let want = fa.mul_add(fb, fc);
            let got = f32::from_bits(results[i] as u32);
            assert!(
                (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                "op {i}: {got:e} vs {want:e}"
            );
        }
    }

    #[test]
    fn accumulate_burst_uses_forwarding_and_stalls() {
        let mut chip = FpMaxChip::new(64);
        // a=1.0, b=x_i, c=forward: running sum of x_i (CMA semantics).
        let one = 1.0f32.to_bits() as u64;
        let xs: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        let triples: Vec<(u64, u64, u64)> =
            xs.iter().map(|x| (one, x.to_bits() as u64, 0)).collect();
        load_triples(&mut chip, &triples);
        let prog = [Instruction::accumulate_burst(UnitSel::SpCma, 0, 8).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        // Accumulation throttles to the bypass tap (SP CMA to_add = 2).
        let tap = chip.unit(UnitSel::SpCma).latency_to_add_input() as u64;
        assert_eq!(stats.cycles, 8 * tap + chip.unit(UnitSel::SpCma).latency_full() as u64);
        let results = chip.jtag().read_bank(BANK_RESULT, 8).unwrap();
        // First op: 1·1 + 0 = 1; then 1·2+1=3, 1·3+3=6 … triangular sums.
        let want: Vec<f32> = vec![1.0, 3.0, 6.0, 10.0, 15.0, 21.0, 28.0, 36.0];
        for (i, w) in want.iter().enumerate() {
            assert_eq!(f32::from_bits(results[i] as u32), *w, "op {i}");
        }
    }

    #[test]
    fn all_four_units_run_same_program() {
        for (sel, sp) in [
            (UnitSel::DpCma, false),
            (UnitSel::DpFma, false),
            (UnitSel::SpCma, true),
            (UnitSel::SpFma, true),
        ] {
            let mut chip = FpMaxChip::new(32);
            let prec = if sp { Precision::Single } else { Precision::Double };
            let mut stream = OperandStream::new(prec, OperandMix::Finite, 5);
            let triples: Vec<(u64, u64, u64)> =
                stream.batch(16).into_iter().map(|t| (t.a, t.b, t.c)).collect();
            load_triples(&mut chip, &triples);
            let prog = [Instruction::fmac_burst(sel, 0, 16).encode() as u64];
            chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
            let stats = chip.run().unwrap();
            assert_eq!(stats.ops, 16, "{sel:?}");
            let results = chip.jtag().read_bank(BANK_RESULT, 16).unwrap();
            for (i, &(a, b, c)) in triples.iter().enumerate() {
                let want = expected_result(
                    chip.unit(sel),
                    RoundMode::NearestEven,
                    a,
                    b,
                    c,
                    Op::Fmac,
                );
                let fmt = chip.unit(sel).format;
                let got = results[i];
                let both_nan = {
                    let d1 = crate::arch::fp::decode(fmt, got);
                    let d2 = crate::arch::fp::decode(fmt, want);
                    d1.class == crate::arch::fp::Class::Nan && d2.class == crate::arch::fp::Class::Nan
                };
                assert!(got == want || both_nan, "{sel:?} op {i}: {got:#x} vs {want:#x}");
            }
        }
    }

    #[test]
    fn mul_add_bursts_batch_with_explicit_rounding() {
        // Explicit-rounding Mul/Add programs now go through the batched
        // burst path (RNE via the lane kernels, directed modes scalar);
        // every mode must match the golden expectation bit-for-bit.
        for mode in RoundMode::ALL {
            for (op, sel, prec) in [
                (Op::Mul, UnitSel::SpFma, Precision::Single),
                (Op::Add, UnitSel::DpCma, Precision::Double),
            ] {
                let mut chip = FpMaxChip::new(64);
                let mut stream = OperandStream::new(prec, OperandMix::Anything, 31);
                let triples: Vec<(u64, u64, u64)> =
                    stream.batch(20).into_iter().map(|t| (t.a, t.b, t.c)).collect();
                load_triples(&mut chip, &triples);
                let ins = Instruction {
                    unit: sel,
                    op,
                    rounding: mode,
                    src_a: SrcSel::Ram,
                    src_b: SrcSel::Ram,
                    src_c: SrcSel::Ram,
                    base_addr: 0,
                    repeat: 19,
                };
                chip.jtag().load_bank(BANK_PROGRAM, &[ins.encode() as u64]).unwrap();
                let stats = chip.run().unwrap();
                assert_eq!(stats.ops, 20, "{op:?} {mode:?}");
                // Burst timing: one op per cycle plus the pipeline drain.
                let lat = chip.unit(sel).latency_full() as u64;
                assert_eq!(stats.cycles, 20 + lat, "{op:?} {mode:?}");
                let results = chip.jtag().read_bank(BANK_RESULT, 20).unwrap();
                for (i, &(a, b, c)) in triples.iter().enumerate() {
                    let want = expected_result(chip.unit(sel), mode, a, b, c, op);
                    assert_eq!(
                        results[i], want,
                        "{op:?} {mode:?} op {i}: a={a:#x} b={b:#x} c={c:#x}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_instruction_program() {
        let mut chip = FpMaxChip::new(64);
        let one = 1.0f32.to_bits() as u64;
        let two = 2.0f32.to_bits() as u64;
        load_triples(&mut chip, &[(one, two, one); 20]);
        let prog = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 4).encode() as u64,
            Instruction::fmac_burst(UnitSel::SpCma, 4, 4).encode() as u64,
            Instruction {
                op: Op::Nop,
                ..Instruction::fmac_burst(UnitSel::SpFma, 0, 8)
            }
            .encode() as u64,
            Instruction::fmac_burst(UnitSel::DpFma, 8, 2).encode() as u64,
        ];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.instructions, 4);
        assert_eq!(stats.ops, 10); // 4 + 4 + 0 + 2
        assert_eq!(stats.results_written, 10);
        // SP results: 1·2+1 = 3.
        let r = chip.jtag().read_bank(BANK_RESULT, 8).unwrap();
        assert!(r[..8].iter().all(|&w| f32::from_bits(w as u32) == 3.0));
    }

    #[test]
    fn traced_run_matches_untraced_and_covers_every_cycle() {
        // The sequencer's trace must cover the run's cycle count exactly
        // (one slot per cycle), count one op per executed op, and leave
        // the results bit-identical to an untraced run.
        let mut chip = FpMaxChip::new(64);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 77);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(48).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let prog = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 32).encode() as u64,
            Instruction {
                op: Op::Nop,
                ..Instruction::fmac_burst(UnitSel::SpFma, 0, 100)
            }
            .encode() as u64,
            Instruction::accumulate_burst(UnitSel::SpCma, 32, 8).encode() as u64,
        ];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let (stats, trace) = chip.run_traced(64).unwrap();
        assert_eq!(stats.ops, 40);
        assert_eq!(trace.total_slots(), stats.cycles, "one trace slot per sequencer cycle");
        assert_eq!(trace.total_ops(), stats.ops);
        assert_eq!(trace.aggregate().ops, stats.ops);
        // The FMAC burst ran gate-level tracked: real toggle counts.
        assert!(trace.aggregate().tree_fa_ops > 0);
        // The Nop + drain + forwarding stalls make the trace non-trivially
        // idle.
        assert!(trace.occupancy() < 1.0);
        let traced_results = chip.jtag().read_bank(BANK_RESULT, 40).unwrap();
        // Re-run untraced on a fresh chip: identical results and stats.
        let mut chip2 = FpMaxChip::new(64);
        load_triples(&mut chip2, &triples);
        chip2.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats2 = chip2.run().unwrap();
        assert_eq!(stats2, stats);
        assert_eq!(chip2.jtag().read_bank(BANK_RESULT, 40).unwrap(), traced_results);
    }

    #[test]
    fn traced_mul_burst_counts_occupancy_only() {
        let mut chip = FpMaxChip::new(32);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 3);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(16).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let ins = Instruction {
            unit: UnitSel::SpFma,
            op: Op::Mul,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Ram,
            base_addr: 0,
            repeat: 15,
        };
        chip.jtag().load_bank(BANK_PROGRAM, &[ins.encode() as u64]).unwrap();
        let (stats, trace) = chip.run_traced(8).unwrap();
        assert_eq!(stats.ops, 16);
        assert_eq!(trace.total_slots(), stats.cycles);
        assert_eq!(trace.total_ops(), 16);
        // Occupancy-only: ops counted, no datapath activity detail.
        let agg = trace.aggregate();
        assert_eq!(agg.tree_fa_ops, 0);
        assert_eq!(agg.digits, 0);
    }

    #[test]
    fn program_halts_on_zero_word() {
        let mut chip = FpMaxChip::new(16);
        let prog = [0u64; 4];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.instructions, 0);
        assert_eq!(stats.ops, 0);
    }

    #[test]
    fn ram_overflow_surfaces_as_error() {
        let mut chip = FpMaxChip::new(8);
        let prog = [Instruction::fmac_burst(UnitSel::SpFma, 4, 8).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        assert!(chip.run().is_err()); // reads addresses 4..12 in a depth-8 RAM
    }

    fn stream_word(d: StreamDesc) -> u64 {
        SeqWord::Stream(d).encode()
    }

    fn unit_stride(port: StreamPort, base: u16, len: u16) -> StreamDesc {
        StreamDesc { port, bank: StreamBank::Stim, base, stride0: 1, len0: len, stride1: 0 }
    }

    #[test]
    fn repeat_window_matches_unrolled_and_hits_occupancy() {
        // The same three armed streams feed a 1-word FMAC window either
        // looped by a Repeat or unrolled into n program words. Results
        // must be bit-identical; the repeat path must hit the kernel
        // gates (in-burst occupancy ≥ 0.9, ≥ 1.5× issue rate).
        let n: usize = 64;
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 9);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(n).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        let micro = Instruction {
            unit: UnitSel::SpFma,
            op: Op::Fmac,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Ram,
            base_addr: 0,
            repeat: 0,
        };
        let arm = |port| stream_word(unit_stride(port, 0, n as u16));
        let repeat_prog = [
            arm(StreamPort::A),
            arm(StreamPort::B),
            arm(StreamPort::C),
            SeqWord::Repeat { window: 1, count: n as u32 }.encode(),
            micro.encode() as u64,
        ];
        let mut unrolled_prog =
            vec![arm(StreamPort::A), arm(StreamPort::B), arm(StreamPort::C)];
        unrolled_prog.extend(std::iter::repeat(micro.encode() as u64).take(n));

        let mut chip = FpMaxChip::new(128);
        load_triples(&mut chip, &triples);
        chip.jtag().load_bank(BANK_PROGRAM, &repeat_prog).unwrap();
        let (stats, trace) = chip.run_traced(64).unwrap();
        assert_eq!(stats.ops, n as u64);
        assert_eq!(stats.repeat_ops, n as u64);
        assert_eq!(stats.results_written, n as u64);
        // Repeat burst: one decode cycle, one op per cycle, one drain.
        let lat = chip.unit(UnitSel::SpFma).latency_full() as u64;
        assert_eq!(stats.repeat_cycles, 1 + n as u64 + lat);
        // Whole run adds one latch cycle per stream word.
        assert_eq!(stats.cycles, 3 + stats.repeat_cycles);
        assert_eq!(trace.total_slots(), stats.cycles, "slots==cycles through the repeat path");
        assert!(
            stats.repeat_occupancy() >= 0.9,
            "in-burst occupancy {} below the kernel gate",
            stats.repeat_occupancy()
        );
        let repeat_results = chip.jtag().read_bank(BANK_RESULT, n).unwrap();
        for (i, &(a, b, c)) in triples.iter().enumerate() {
            let want =
                expected_result(chip.unit(UnitSel::SpFma), RoundMode::NearestEven, a, b, c, Op::Fmac);
            assert_eq!(repeat_results[i], want, "op {i}");
        }

        let mut chip2 = FpMaxChip::new(128);
        load_triples(&mut chip2, &triples);
        chip2.jtag().load_bank(BANK_PROGRAM, &unrolled_prog).unwrap();
        let stats2 = chip2.run().unwrap();
        assert_eq!(stats2.ops, n as u64);
        assert_eq!(stats2.repeat_ops, 0, "unrolled path never enters the repeat buffer");
        assert_eq!(
            chip2.jtag().read_bank(BANK_RESULT, n).unwrap(),
            repeat_results,
            "repeat and unrolled programs must be bit-identical"
        );
        // Unrolled pays a full pipeline drain per instruction; the
        // repeat path amortizes it to one.
        let speedup = stats2.cycles as f64 / stats.cycles as f64;
        assert!(speedup >= 1.5, "issue-rate speedup {speedup} below the kernel gate");
    }

    #[test]
    fn result_streams_chain_passes_and_guard_rejects_in_window_reads() {
        // Pass 1 writes r[0..n); pass 2 streams those results back in on
        // port C. Reading the result bank *inside* the window being
        // written is a sequencing error, not silent staleness.
        let n: usize = 16;
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 41);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(n).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        let micro1 = Instruction {
            unit: UnitSel::SpFma,
            op: Op::Fmac,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Zero,
            base_addr: 0,
            repeat: 0,
        };
        let micro2 = Instruction { src_b: SrcSel::One, src_c: SrcSel::Ram, ..micro1 };
        let result_c = StreamDesc {
            port: StreamPort::C,
            bank: StreamBank::Result,
            base: 0,
            stride0: 1,
            len0: n as u16,
            stride1: 0,
        };
        let prog = [
            stream_word(unit_stride(StreamPort::A, 0, n as u16)),
            stream_word(unit_stride(StreamPort::B, 0, n as u16)),
            SeqWord::Repeat { window: 1, count: n as u32 }.encode(),
            micro1.encode() as u64,
            // Pass 2: rewind A, chain C off pass 1's results.
            stream_word(unit_stride(StreamPort::A, 0, n as u16)),
            stream_word(result_c),
            SeqWord::Repeat { window: 1, count: n as u32 }.encode(),
            micro2.encode() as u64,
        ];
        let mut chip = FpMaxChip::new(64);
        load_triples(&mut chip, &triples);
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.ops, 2 * n as u64);
        assert_eq!(stats.results_written, 2 * n as u64);
        let results = chip.jtag().read_bank(BANK_RESULT, 2 * n).unwrap();
        for (i, &(a, b, _)) in triples.iter().enumerate() {
            let fa = f32::from_bits(a as u32);
            let fb = f32::from_bits(b as u32);
            let r1 = fa.mul_add(fb, 0.0);
            assert_eq!(results[i] as u32, r1.to_bits(), "pass 1 op {i}");
            let r2 = fa.mul_add(1.0, r1);
            assert_eq!(results[n + i] as u32, r2.to_bits(), "pass 2 op {i}");
        }

        // Guard: a result stream aimed at the region this same repeat is
        // writing must reject (write pointer was 0 at issue).
        let bad = [
            stream_word(unit_stride(StreamPort::A, 0, n as u16)),
            stream_word(result_c),
            SeqWord::Repeat { window: 1, count: n as u32 }.encode(),
            micro2.encode() as u64,
        ];
        let mut chip2 = FpMaxChip::new(64);
        load_triples(&mut chip2, &triples);
        chip2.jtag().load_bank(BANK_PROGRAM, &bad).unwrap();
        let err = chip2.run().unwrap_err().to_string();
        assert!(err.contains("inside the window being written"), "got: {err}");
    }

    #[test]
    fn repeat_forwarding_accumulates_across_iterations() {
        // A one-op accumulation window (c = Forward) looped by a Repeat
        // reduces across the whole repeat: the forwarding register
        // resets on entry and persists across iterations, throttled to
        // the bypass tap like the classic accumulate burst.
        let xs: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        let triples: Vec<(u64, u64, u64)> =
            xs.iter().map(|x| (0, x.to_bits() as u64, 0)).collect();
        let micro = Instruction {
            unit: UnitSel::SpCma,
            op: Op::Fmac,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::One,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Forward,
            base_addr: 0,
            repeat: 0,
        };
        let prog = [
            stream_word(unit_stride(StreamPort::B, 0, 8)),
            SeqWord::Repeat { window: 1, count: 8 }.encode(),
            micro.encode() as u64,
        ];
        let mut chip = FpMaxChip::new(32);
        load_triples(&mut chip, &triples);
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let (stats, trace) = chip.run_traced(16).unwrap();
        assert_eq!(stats.ops, 8);
        assert_eq!(stats.repeat_ops, 8);
        let tap = chip.unit(UnitSel::SpCma).latency_to_add_input() as u64;
        let lat = chip.unit(UnitSel::SpCma).latency_full() as u64;
        assert_eq!(stats.repeat_cycles, 1 + 8 * tap + lat);
        assert_eq!(stats.cycles, 1 + stats.repeat_cycles);
        assert_eq!(trace.total_slots(), stats.cycles);
        let results = chip.jtag().read_bank(BANK_RESULT, 8).unwrap();
        let want = [1.0f32, 3.0, 6.0, 10.0, 15.0, 21.0, 28.0, 36.0];
        for (i, w) in want.iter().enumerate() {
            assert_eq!(f32::from_bits(results[i] as u32), *w, "op {i}");
        }
    }

    #[test]
    fn stream_disarm_restores_sequential_addressing() {
        let n: usize = 8;
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 13);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(2 * n).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        let prog = [
            // Burst 1: port A streams a[n..2n) while B/C walk 0..n.
            stream_word(unit_stride(StreamPort::A, n as u16, n as u16)),
            Instruction::fmac_burst(UnitSel::SpFma, 0, n as u16).encode() as u64,
            // Burst 2: disarm A; plain sequential a[0..n) again.
            stream_word(StreamDesc::disarm(StreamPort::A)),
            Instruction::fmac_burst(UnitSel::SpFma, 0, n as u16).encode() as u64,
        ];
        let mut chip = FpMaxChip::new(32);
        load_triples(&mut chip, &triples);
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.ops, 2 * n as u64);
        // Plain bursts outside a repeat never count as repeat cycles.
        assert_eq!(stats.repeat_cycles, 0);
        let results = chip.jtag().read_bank(BANK_RESULT, 2 * n).unwrap();
        let unit = chip.unit(UnitSel::SpFma);
        for i in 0..n {
            let (_, b, c) = triples[i];
            let streamed = triples[n + i].0;
            let want1 = expected_result(unit, RoundMode::NearestEven, streamed, b, c, Op::Fmac);
            assert_eq!(results[i], want1, "streamed op {i}");
            let plain = triples[i].0;
            let want2 = expected_result(unit, RoundMode::NearestEven, plain, b, c, Op::Fmac);
            assert_eq!(results[n + i], want2, "plain op {i}");
        }
    }

    #[test]
    fn malformed_repeat_windows_reject() {
        let micro = Instruction::fmac_burst(UnitSel::SpFma, 0, 1).encode() as u64;
        let run_prog = |prog: &[u64]| -> String {
            let mut chip = FpMaxChip::new(16);
            load_triples(&mut chip, &[(0, 0, 0); 8]);
            chip.jtag().load_bank(BANK_PROGRAM, prog).unwrap();
            chip.run().unwrap_err().to_string()
        };
        // A Repeat inside another Repeat's window overlaps.
        let nested = [
            SeqWord::Repeat { window: 2, count: 2 }.encode(),
            SeqWord::Repeat { window: 1, count: 1 }.encode(),
            micro,
        ];
        let err = run_prog(&nested);
        assert!(err.contains("overlapping repeat windows"), "got: {err}");
        // A stream descriptor cannot be re-armed mid-window.
        let midstream = [
            SeqWord::Repeat { window: 1, count: 1 }.encode(),
            stream_word(unit_stride(StreamPort::A, 0, 4)),
        ];
        let err = run_prog(&midstream);
        assert!(err.contains("inside a repeat window"), "got: {err}");
        // A window may not run past the loaded program.
        let overrun = [SeqWord::Repeat { window: 2, count: 1 }.encode(), micro];
        let err = run_prog(&overrun);
        assert!(err.contains("runs past the end of the program"), "got: {err}");
    }
}
