//! The at-speed test sequencer: Fig. 5(a)'s datapath — stimulus RAMs →
//! selected FPU → result RAM — driven by the Fig. 5(b) instruction
//! stream.
//!
//! `run()` executes the loaded program exactly as the silicon sequencer
//! would: one FMAC per cycle from the RAMs in burst mode, or one per
//! bypass-latency when an operand comes from the forwarding network
//! (accumulation tests), with cycle accounting per burst. All four
//! generated FPUs live on the chip simultaneously, as fabricated.

use crate::arch::engine::{
    add_batch, mul_batch, reference_fmac, ActivityAccumulator, ActivityTrace, Datapath,
};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::arch::rounding::RoundMode;
use crate::pipesim::sim::LatencyModel;
use crate::pipesim::trace::DepKind;
use crate::workloads::throughput::OperandTriple;

use super::isa::{Instruction, Op, SrcSel, UnitSel};
use super::jtag::JtagPort;
use super::ram::RamBank;

/// RAM bank indices on the JTAG chain.
pub const BANK_STIM_A: usize = 0;
pub const BANK_STIM_B: usize = 1;
pub const BANK_STIM_C: usize = 2;
pub const BANK_RESULT: usize = 3;
pub const BANK_PROGRAM: usize = 4;

/// Statistics from one at-speed run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RunStats {
    pub instructions: u64,
    pub ops: u64,
    pub cycles: u64,
    pub results_written: u64,
}

/// The FPMax chip model.
pub struct FpMaxChip {
    units: [FpuUnit; 4],
    stim_a: RamBank,
    stim_b: RamBank,
    stim_c: RamBank,
    result: RamBank,
    program: RamBank,
    /// Pooled burst-gather scratch, reused across instructions and runs
    /// so steady-state sequencing allocates nothing.
    burst_triples: Vec<OperandTriple>,
    burst_bits: Vec<u64>,
}

impl FpMaxChip {
    /// Instantiate the chip with the four fabricated units and RAMs of
    /// the given depth (words).
    pub fn new(ram_depth: usize) -> FpMaxChip {
        FpMaxChip {
            units: [
                FpuUnit::generate(&FpuConfig::dp_cma()),
                FpuUnit::generate(&FpuConfig::dp_fma()),
                FpuUnit::generate(&FpuConfig::sp_cma()),
                FpuUnit::generate(&FpuConfig::sp_fma()),
            ],
            stim_a: RamBank::new("stim_a", ram_depth),
            stim_b: RamBank::new("stim_b", ram_depth),
            stim_c: RamBank::new("stim_c", ram_depth),
            result: RamBank::new("result", ram_depth),
            program: RamBank::new("program", 256),
            burst_triples: Vec::with_capacity(ram_depth),
            burst_bits: vec![0; ram_depth],
        }
    }

    /// The unit behind a selector.
    pub fn unit(&self, sel: UnitSel) -> &FpuUnit {
        &self.units[sel as usize]
    }

    /// Open the JTAG port over all banks (the only off-chip interface).
    pub fn jtag(&mut self) -> JtagPort<'_> {
        JtagPort::new(vec![
            &mut self.stim_a,
            &mut self.stim_b,
            &mut self.stim_c,
            &mut self.result,
            &mut self.program,
        ])
    }

    /// Execute the loaded program at speed.
    pub fn run(&mut self) -> crate::Result<RunStats> {
        self.run_inner(None)
    }

    /// Execute the loaded program at speed while emitting a
    /// time-resolved [`ActivityTrace`] of the sequencer's issue-slot
    /// timeline: every cycle of the run lands in a window — FMAC bursts
    /// as gate-level tracked ops, Mul/Add bursts as occupancy-only ops,
    /// forwarding stalls / pipeline drains / `Nop`s as idle slots. The
    /// trace's slot count equals the run's cycle count exactly, so the
    /// body-bias controller sees the program's real phase structure.
    pub fn run_traced(&mut self, window_slots: u64) -> crate::Result<(RunStats, ActivityTrace)> {
        let mut trace = ActivityTrace::new(window_slots);
        let stats = self.run_inner(Some(&mut trace))?;
        Ok((stats, trace))
    }

    fn run_inner(&mut self, mut trace: Option<&mut ActivityTrace>) -> crate::Result<RunStats> {
        let mut stats = RunStats::default();
        let mut result_wptr = 0usize;
        for pc in 0..self.program.depth() {
            let word = self.program.peek(pc).unwrap_or(0);
            if word == 0 {
                break; // end of program (all-zero word = halt)
            }
            let ins = Instruction::decode(word as u32);
            stats.instructions += 1;
            if matches!(ins.op, Op::Nop) {
                if let Some(t) = trace.as_deref_mut() {
                    t.push_idle(ins.repeat as u64 + 1);
                }
                stats.cycles += (ins.repeat as u64) + 1;
                continue;
            }
            let unit = &self.units[ins.unit as usize];
            let lat = LatencyModel::of(unit);
            let one = match unit.config.precision {
                Precision::Single => 1.0f32.to_bits() as u64,
                Precision::Double => 1.0f64.to_bits(),
                p => crate::arch::softfloat::from_f64(p.format(), 1.0),
            };
            let mut forward: u64 = 0;
            // Per-op issue distance: 1 from RAM, or the bypass tap when an
            // operand comes from the forwarding network.
            let uses_fwd_c = ins.src_c == SrcSel::Forward;
            let uses_fwd_ab = ins.src_a == SrcSel::Forward || ins.src_b == SrcSel::Forward;
            let issue_dist = if uses_fwd_ab {
                lat.tap(DepKind::Multiplier).max(1) as u64
            } else if uses_fwd_c {
                lat.tap(DepKind::Accumulate).max(1) as u64
            } else {
                1
            };

            // Independent bursts (every operand from RAM or a constant)
            // have no sequential dependence: the sequencer gathers the
            // whole burst into pooled scratch and issues it through the
            // batched execution layer in one go, exactly as the silicon
            // streams one op per cycle. FMAC bursts batch at the unit's
            // default rounding; Mul/Add bursts batch at *any* rounding
            // mode (the explicit-rounding test programs), RNE through the
            // SoA lane kernels and directed modes through the scalar
            // spec. Forwarding bursts and explicit-rounding FMACs stay on
            // the scalar path below.
            let independent_burst = !uses_fwd_ab
                && !uses_fwd_c
                && match ins.op {
                    Op::Fmac => ins.rounding == RoundMode::NearestEven,
                    Op::Mul | Op::Add => true,
                    Op::Nop => false,
                };
            if independent_burst {
                let count = ins.repeat as usize + 1;
                let base = ins.base_addr as usize;
                self.burst_triples.clear();
                for i in 0..count {
                    let addr = base + i;
                    let a = match ins.src_a {
                        SrcSel::Ram => self.stim_a.read(addr)?,
                        SrcSel::Zero => 0,
                        SrcSel::One => one,
                        SrcSel::Forward => unreachable!("excluded above"),
                    };
                    let b = match ins.src_b {
                        SrcSel::Ram => self.stim_b.read(addr)?,
                        SrcSel::Zero => 0,
                        SrcSel::One => one,
                        SrcSel::Forward => unreachable!("excluded above"),
                    };
                    let c = match ins.src_c {
                        SrcSel::Ram => self.stim_c.read(addr)?,
                        SrcSel::Zero => 0,
                        SrcSel::One => one,
                        SrcSel::Forward => unreachable!("excluded above"),
                    };
                    self.burst_triples.push(OperandTriple { a, b, c });
                }
                if self.burst_bits.len() < count {
                    self.burst_bits.resize(count, 0);
                }
                let bits = &mut self.burst_bits[..count];
                match ins.op {
                    Op::Fmac => match trace.as_deref_mut() {
                        // Traced FMAC bursts stream through the tracked
                        // gate-level op, landing one issue slot per op in
                        // the trace's windows (same bits either way).
                        Some(t) => t
                            .push_batch_tracked(unit, &self.burst_triples, bits)
                            .expect("burst scratch sized together"),
                        None => unit.fmac_batch(&self.burst_triples, bits),
                    },
                    Op::Mul => {
                        mul_batch(unit.format, ins.rounding, &self.burst_triples, bits);
                        if let Some(t) = trace.as_deref_mut() {
                            // Occupancy-only: Mul/Add bursts carry no
                            // FMAC activity record.
                            t.push_untracked_ops(count as u64);
                        }
                    }
                    Op::Add => {
                        add_batch(unit.format, ins.rounding, &self.burst_triples, bits);
                        if let Some(t) = trace.as_deref_mut() {
                            t.push_untracked_ops(count as u64);
                        }
                    }
                    Op::Nop => unreachable!("excluded above"),
                }
                if let Some(t) = trace.as_deref_mut() {
                    // Pipeline drain between instructions.
                    t.push_idle(lat.full as u64);
                }
                for &r in &self.burst_bits[..count] {
                    self.result.write(result_wptr, r)?;
                    result_wptr += 1;
                }
                stats.ops += count as u64;
                stats.cycles += issue_dist * count as u64;
                stats.cycles += lat.full as u64;
                continue;
            }

            for i in 0..=(ins.repeat as usize) {
                let addr = ins.base_addr as usize + i;
                let fetch = |ram: &mut RamBank, sel: SrcSel, fwd: u64| -> crate::Result<u64> {
                    Ok(match sel {
                        SrcSel::Ram => ram.read(addr)?,
                        SrcSel::Forward => fwd,
                        SrcSel::Zero => 0,
                        SrcSel::One => one,
                    })
                };
                let a = fetch(&mut self.stim_a, ins.src_a, forward)?;
                let b = fetch(&mut self.stim_b, ins.src_b, forward)?;
                let c = fetch(&mut self.stim_c, ins.src_c, forward)?;
                let r = match ins.op {
                    Op::Fmac => {
                        let (r, act) = unit.fmac_mode(ins.rounding, a, b, c);
                        if let Some(t) = trace.as_deref_mut() {
                            let mut acc = ActivityAccumulator::default();
                            acc.record(&act);
                            t.push_op(&acc);
                        }
                        r
                    }
                    Op::Mul => {
                        let r = crate::arch::softfloat::mul(unit.format, ins.rounding, a, b);
                        if let Some(t) = trace.as_deref_mut() {
                            t.push_untracked_ops(1);
                        }
                        r
                    }
                    Op::Add => {
                        let r = crate::arch::softfloat::add(unit.format, ins.rounding, a, c);
                        if let Some(t) = trace.as_deref_mut() {
                            t.push_untracked_ops(1);
                        }
                        r
                    }
                    Op::Nop => unreachable!(),
                };
                if let Some(t) = trace.as_deref_mut() {
                    // Bypass-throttled issue: the slots between
                    // successive ops are stalls.
                    t.push_idle(issue_dist - 1);
                }
                forward = r.bits;
                self.result.write(result_wptr, r.bits)?;
                result_wptr += 1;
                stats.ops += 1;
                stats.cycles += issue_dist;
            }
            // Pipeline drain between instructions.
            if let Some(t) = trace.as_deref_mut() {
                t.push_idle(lat.full as u64);
            }
            stats.cycles += lat.full as u64;
        }
        stats.results_written = result_wptr as u64;
        Ok(stats)
    }

    /// Reset RAMs (not the units — they are combinational).
    pub fn reset(&mut self) {
        self.stim_a.clear();
        self.stim_b.clear();
        self.stim_c.clear();
        self.result.clear();
        self.program.clear();
    }
}

/// Round-mode helper shared by self-test drivers: the expected result of
/// an instruction's op through the golden softfloat model. FMAC
/// expectations come from the engine's shared word-level spec
/// ([`reference_fmac`]), so chip, coordinator, and word-level tier can
/// never drift apart.
pub fn expected_result(unit: &FpuUnit, mode: RoundMode, a: u64, b: u64, c: u64, op: Op) -> u64 {
    use crate::arch::softfloat;
    match op {
        Op::Fmac => reference_fmac(unit.config.kind, unit.format, mode, a, b, c).bits,
        Op::Mul => softfloat::mul(unit.format, mode, a, b).bits,
        Op::Add => softfloat::add(unit.format, mode, a, c).bits,
        Op::Nop => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    fn load_triples(chip: &mut FpMaxChip, triples: &[(u64, u64, u64)]) {
        let a: Vec<u64> = triples.iter().map(|t| t.0).collect();
        let b: Vec<u64> = triples.iter().map(|t| t.1).collect();
        let c: Vec<u64> = triples.iter().map(|t| t.2).collect();
        let mut port = chip.jtag();
        port.load_bank(BANK_STIM_A, &a).unwrap();
        port.load_bank(BANK_STIM_B, &b).unwrap();
        port.load_bank(BANK_STIM_C, &c).unwrap();
    }

    #[test]
    fn fmac_burst_correct_results() {
        let mut chip = FpMaxChip::new(64);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 21);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(32).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let prog = [Instruction::fmac_burst(UnitSel::SpFma, 0, 32).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.ops, 32);
        assert_eq!(stats.results_written, 32);
        // Burst from RAM: 1 op/cycle + drain.
        assert_eq!(stats.cycles, 32 + 4);
        let results = chip.jtag().read_bank(BANK_RESULT, 32).unwrap();
        for (i, &(a, b, c)) in triples.iter().enumerate() {
            let fa = f32::from_bits(a as u32);
            let fb = f32::from_bits(b as u32);
            let fc = f32::from_bits(c as u32);
            let want = fa.mul_add(fb, fc);
            let got = f32::from_bits(results[i] as u32);
            assert!(
                (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                "op {i}: {got:e} vs {want:e}"
            );
        }
    }

    #[test]
    fn accumulate_burst_uses_forwarding_and_stalls() {
        let mut chip = FpMaxChip::new(64);
        // a=1.0, b=x_i, c=forward: running sum of x_i (CMA semantics).
        let one = 1.0f32.to_bits() as u64;
        let xs: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        let triples: Vec<(u64, u64, u64)> =
            xs.iter().map(|x| (one, x.to_bits() as u64, 0)).collect();
        load_triples(&mut chip, &triples);
        let prog = [Instruction::accumulate_burst(UnitSel::SpCma, 0, 8).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        // Accumulation throttles to the bypass tap (SP CMA to_add = 2).
        let tap = chip.unit(UnitSel::SpCma).latency_to_add_input() as u64;
        assert_eq!(stats.cycles, 8 * tap + chip.unit(UnitSel::SpCma).latency_full() as u64);
        let results = chip.jtag().read_bank(BANK_RESULT, 8).unwrap();
        // First op: 1·1 + 0 = 1; then 1·2+1=3, 1·3+3=6 … triangular sums.
        let want: Vec<f32> = vec![1.0, 3.0, 6.0, 10.0, 15.0, 21.0, 28.0, 36.0];
        for (i, w) in want.iter().enumerate() {
            assert_eq!(f32::from_bits(results[i] as u32), *w, "op {i}");
        }
    }

    #[test]
    fn all_four_units_run_same_program() {
        for (sel, sp) in [
            (UnitSel::DpCma, false),
            (UnitSel::DpFma, false),
            (UnitSel::SpCma, true),
            (UnitSel::SpFma, true),
        ] {
            let mut chip = FpMaxChip::new(32);
            let prec = if sp { Precision::Single } else { Precision::Double };
            let mut stream = OperandStream::new(prec, OperandMix::Finite, 5);
            let triples: Vec<(u64, u64, u64)> =
                stream.batch(16).into_iter().map(|t| (t.a, t.b, t.c)).collect();
            load_triples(&mut chip, &triples);
            let prog = [Instruction::fmac_burst(sel, 0, 16).encode() as u64];
            chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
            let stats = chip.run().unwrap();
            assert_eq!(stats.ops, 16, "{sel:?}");
            let results = chip.jtag().read_bank(BANK_RESULT, 16).unwrap();
            for (i, &(a, b, c)) in triples.iter().enumerate() {
                let want = expected_result(
                    chip.unit(sel),
                    RoundMode::NearestEven,
                    a,
                    b,
                    c,
                    Op::Fmac,
                );
                let fmt = chip.unit(sel).format;
                let got = results[i];
                let both_nan = {
                    let d1 = crate::arch::fp::decode(fmt, got);
                    let d2 = crate::arch::fp::decode(fmt, want);
                    d1.class == crate::arch::fp::Class::Nan && d2.class == crate::arch::fp::Class::Nan
                };
                assert!(got == want || both_nan, "{sel:?} op {i}: {got:#x} vs {want:#x}");
            }
        }
    }

    #[test]
    fn mul_add_bursts_batch_with_explicit_rounding() {
        // Explicit-rounding Mul/Add programs now go through the batched
        // burst path (RNE via the lane kernels, directed modes scalar);
        // every mode must match the golden expectation bit-for-bit.
        for mode in RoundMode::ALL {
            for (op, sel, prec) in [
                (Op::Mul, UnitSel::SpFma, Precision::Single),
                (Op::Add, UnitSel::DpCma, Precision::Double),
            ] {
                let mut chip = FpMaxChip::new(64);
                let mut stream = OperandStream::new(prec, OperandMix::Anything, 31);
                let triples: Vec<(u64, u64, u64)> =
                    stream.batch(20).into_iter().map(|t| (t.a, t.b, t.c)).collect();
                load_triples(&mut chip, &triples);
                let ins = Instruction {
                    unit: sel,
                    op,
                    rounding: mode,
                    src_a: SrcSel::Ram,
                    src_b: SrcSel::Ram,
                    src_c: SrcSel::Ram,
                    base_addr: 0,
                    repeat: 19,
                };
                chip.jtag().load_bank(BANK_PROGRAM, &[ins.encode() as u64]).unwrap();
                let stats = chip.run().unwrap();
                assert_eq!(stats.ops, 20, "{op:?} {mode:?}");
                // Burst timing: one op per cycle plus the pipeline drain.
                let lat = chip.unit(sel).latency_full() as u64;
                assert_eq!(stats.cycles, 20 + lat, "{op:?} {mode:?}");
                let results = chip.jtag().read_bank(BANK_RESULT, 20).unwrap();
                for (i, &(a, b, c)) in triples.iter().enumerate() {
                    let want = expected_result(chip.unit(sel), mode, a, b, c, op);
                    assert_eq!(
                        results[i], want,
                        "{op:?} {mode:?} op {i}: a={a:#x} b={b:#x} c={c:#x}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_instruction_program() {
        let mut chip = FpMaxChip::new(64);
        let one = 1.0f32.to_bits() as u64;
        let two = 2.0f32.to_bits() as u64;
        load_triples(&mut chip, &[(one, two, one); 20]);
        let prog = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 4).encode() as u64,
            Instruction::fmac_burst(UnitSel::SpCma, 4, 4).encode() as u64,
            Instruction {
                op: Op::Nop,
                ..Instruction::fmac_burst(UnitSel::SpFma, 0, 8)
            }
            .encode() as u64,
            Instruction::fmac_burst(UnitSel::DpFma, 8, 2).encode() as u64,
        ];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.instructions, 4);
        assert_eq!(stats.ops, 10); // 4 + 4 + 0 + 2
        assert_eq!(stats.results_written, 10);
        // SP results: 1·2+1 = 3.
        let r = chip.jtag().read_bank(BANK_RESULT, 8).unwrap();
        assert!(r[..8].iter().all(|&w| f32::from_bits(w as u32) == 3.0));
    }

    #[test]
    fn traced_run_matches_untraced_and_covers_every_cycle() {
        // The sequencer's trace must cover the run's cycle count exactly
        // (one slot per cycle), count one op per executed op, and leave
        // the results bit-identical to an untraced run.
        let mut chip = FpMaxChip::new(64);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 77);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(48).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let prog = [
            Instruction::fmac_burst(UnitSel::SpFma, 0, 32).encode() as u64,
            Instruction {
                op: Op::Nop,
                ..Instruction::fmac_burst(UnitSel::SpFma, 0, 100)
            }
            .encode() as u64,
            Instruction::accumulate_burst(UnitSel::SpCma, 32, 8).encode() as u64,
        ];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let (stats, trace) = chip.run_traced(64).unwrap();
        assert_eq!(stats.ops, 40);
        assert_eq!(trace.total_slots(), stats.cycles, "one trace slot per sequencer cycle");
        assert_eq!(trace.total_ops(), stats.ops);
        assert_eq!(trace.aggregate().ops, stats.ops);
        // The FMAC burst ran gate-level tracked: real toggle counts.
        assert!(trace.aggregate().tree_fa_ops > 0);
        // The Nop + drain + forwarding stalls make the trace non-trivially
        // idle.
        assert!(trace.occupancy() < 1.0);
        let traced_results = chip.jtag().read_bank(BANK_RESULT, 40).unwrap();
        // Re-run untraced on a fresh chip: identical results and stats.
        let mut chip2 = FpMaxChip::new(64);
        load_triples(&mut chip2, &triples);
        chip2.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats2 = chip2.run().unwrap();
        assert_eq!(stats2, stats);
        assert_eq!(chip2.jtag().read_bank(BANK_RESULT, 40).unwrap(), traced_results);
    }

    #[test]
    fn traced_mul_burst_counts_occupancy_only() {
        let mut chip = FpMaxChip::new(32);
        let mut stream = OperandStream::new(Precision::Single, OperandMix::Finite, 3);
        let triples: Vec<(u64, u64, u64)> =
            stream.batch(16).into_iter().map(|t| (t.a, t.b, t.c)).collect();
        load_triples(&mut chip, &triples);
        let ins = Instruction {
            unit: UnitSel::SpFma,
            op: Op::Mul,
            rounding: RoundMode::NearestEven,
            src_a: SrcSel::Ram,
            src_b: SrcSel::Ram,
            src_c: SrcSel::Ram,
            base_addr: 0,
            repeat: 15,
        };
        chip.jtag().load_bank(BANK_PROGRAM, &[ins.encode() as u64]).unwrap();
        let (stats, trace) = chip.run_traced(8).unwrap();
        assert_eq!(stats.ops, 16);
        assert_eq!(trace.total_slots(), stats.cycles);
        assert_eq!(trace.total_ops(), 16);
        // Occupancy-only: ops counted, no datapath activity detail.
        let agg = trace.aggregate();
        assert_eq!(agg.tree_fa_ops, 0);
        assert_eq!(agg.digits, 0);
    }

    #[test]
    fn program_halts_on_zero_word() {
        let mut chip = FpMaxChip::new(16);
        let prog = [0u64; 4];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        let stats = chip.run().unwrap();
        assert_eq!(stats.instructions, 0);
        assert_eq!(stats.ops, 0);
    }

    #[test]
    fn ram_overflow_surfaces_as_error() {
        let mut chip = FpMaxChip::new(8);
        let prog = [Instruction::fmac_burst(UnitSel::SpFma, 4, 8).encode() as u64];
        chip.jtag().load_bank(BANK_PROGRAM, &prog).unwrap();
        assert!(chip.run().is_err()); // reads addresses 4..12 in a depth-8 RAM
    }
}
