//! On-chip test RAMs (Fig. 5(a)).
//!
//! The FPMax chip feeds each FPU from high-speed stimulus RAMs and
//! captures results into a result RAM at full FPU speed; the JTAG port
//! reads and writes the RAMs at its own slow clock. The model mirrors
//! that: word-addressed banks with separate at-speed and test-port
//! access paths, plus access counters so the testbench can report
//! bandwidth.

/// One word-addressed RAM bank.
#[derive(Debug, Clone)]
pub struct RamBank {
    name: &'static str,
    words: Vec<u64>,
    /// At-speed accesses (FPU side).
    pub reads: u64,
    pub writes: u64,
}

impl RamBank {
    pub fn new(name: &'static str, depth: usize) -> RamBank {
        RamBank { name, words: vec![0; depth], reads: 0, writes: 0 }
    }

    pub fn depth(&self) -> usize {
        self.words.len()
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// At-speed read (FPU side).
    pub fn read(&mut self, addr: usize) -> crate::Result<u64> {
        let v = *self
            .words
            .get(addr)
            .ok_or_else(|| anyhow::anyhow!("{}: read past depth ({addr} ≥ {})", self.name, self.words.len()))?;
        self.reads += 1;
        Ok(v)
    }

    /// At-speed write (FPU side).
    pub fn write(&mut self, addr: usize, value: u64) -> crate::Result<()> {
        let len = self.words.len();
        let slot = self
            .words
            .get_mut(addr)
            .ok_or_else(|| anyhow::anyhow!("{}: write past depth ({addr} ≥ {len})", self.name))?;
        *slot = value;
        self.writes += 1;
        Ok(())
    }

    /// Test-port (JTAG-side) access: no at-speed counters.
    pub fn peek(&self, addr: usize) -> Option<u64> {
        self.words.get(addr).copied()
    }

    pub fn poke(&mut self, addr: usize, value: u64) -> crate::Result<()> {
        let len = self.words.len();
        let slot = self
            .words
            .get_mut(addr)
            .ok_or_else(|| anyhow::anyhow!("{}: poke past depth ({addr} ≥ {len})", self.name))?;
        *slot = value;
        Ok(())
    }

    /// Bulk test-port load starting at address 0.
    pub fn load(&mut self, data: &[u64]) -> crate::Result<()> {
        if data.len() > self.words.len() {
            anyhow::bail!("{}: load of {} words exceeds depth {}", self.name, data.len(), self.words.len());
        }
        self.words[..data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Reset contents and counters.
    pub fn clear(&mut self) {
        self.words.fill(0);
        self.reads = 0;
        self.writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip() {
        let mut r = RamBank::new("stim", 16);
        r.write(3, 0xdead_beef).unwrap();
        assert_eq!(r.read(3).unwrap(), 0xdead_beef);
        assert_eq!(r.reads, 1);
        assert_eq!(r.writes, 1);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut r = RamBank::new("stim", 4);
        assert!(r.read(4).is_err());
        assert!(r.write(100, 1).is_err());
        assert!(r.poke(4, 1).is_err());
        assert_eq!(r.peek(4), None);
    }

    #[test]
    fn bulk_load_and_peek() {
        let mut r = RamBank::new("stim", 8);
        r.load(&[1, 2, 3]).unwrap();
        assert_eq!(r.peek(0), Some(1));
        assert_eq!(r.peek(2), Some(3));
        assert_eq!(r.peek(3), Some(0));
        // Test-port traffic doesn't count as at-speed.
        assert_eq!(r.reads + r.writes, 0);
        assert!(r.load(&[0; 9]).is_err());
    }

    #[test]
    fn clear_resets_everything() {
        let mut r = RamBank::new("res", 4);
        r.write(0, 7).unwrap();
        r.clear();
        assert_eq!(r.peek(0), Some(0));
        assert_eq!(r.writes, 0);
    }
}
