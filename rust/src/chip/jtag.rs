//! JTAG-like slow test port (Fig. 5(a): "A JTAG interface is used to
//! load and check values in the RAMs at a lower speed").
//!
//! Modelled at the shift-register level: an instruction register (IR)
//! selects a data register (DR); data moves one bit per TCK through
//! `shift_dr`. The port is deliberately the *only* path to the RAMs
//! besides the at-speed sequencer, exactly like silicon — the
//! coordinator talks to the chip exclusively through this interface,
//! and the tests count TCK cycles to verify the "lower speed" property.

use super::ram::RamBank;

/// IR opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JtagIr {
    /// Read the fixed identification word.
    IdCode,
    /// Select (bank, address) for subsequent data shifts.
    SetAddress,
    /// Shift a 64-bit word into the selected RAM location.
    WriteData,
    /// Shift the selected RAM location out.
    ReadData,
    /// Bypass (1-bit pass-through).
    Bypass,
}

/// The FPMax identification word (reconstruction: "FPMX" + version).
pub const IDCODE: u64 = 0x4650_4d58_0001_2016;

/// Address-register layout: high 8 bits bank id, low 24 bits word
/// address.
fn split_addr(dr: u64) -> (usize, usize) {
    (((dr >> 24) & 0xff) as usize, (dr & 0xff_ffff) as usize)
}

/// The JTAG port wrapped around a set of RAM banks.
pub struct JtagPort<'a> {
    banks: Vec<&'a mut RamBank>,
    ir: JtagIr,
    /// Selected (bank, addr).
    addr: (usize, usize),
    /// TCK cycles consumed (the slow-port cost metric).
    pub tck_cycles: u64,
}

impl<'a> JtagPort<'a> {
    pub fn new(banks: Vec<&'a mut RamBank>) -> JtagPort<'a> {
        JtagPort { banks, ir: JtagIr::Bypass, addr: (0, 0), tck_cycles: 0 }
    }

    /// Shift a new IR value (costs the IR length in TCK plus state
    /// transitions — 8 cycles in this model).
    pub fn shift_ir(&mut self, ir: JtagIr) {
        self.ir = ir;
        self.tck_cycles += 8;
    }

    /// Shift `bits` of data through the DR, returning the bits captured
    /// on the way out (LSB-first, like a real scan chain).
    pub fn shift_dr(&mut self, data_in: u64, bits: u32) -> crate::Result<u64> {
        assert!(bits >= 1 && bits <= 64);
        self.tck_cycles += bits as u64 + 4; // data + capture/update states
        match self.ir {
            JtagIr::Bypass => Ok(data_in & 1),
            JtagIr::IdCode => Ok(IDCODE & mask(bits)),
            JtagIr::SetAddress => {
                self.addr = split_addr(data_in & mask(bits));
                Ok(0)
            }
            JtagIr::WriteData => {
                let (bank, addr) = self.addr;
                let b = self
                    .banks
                    .get_mut(bank)
                    .ok_or_else(|| anyhow::anyhow!("jtag: no bank {bank}"))?;
                b.poke(addr, data_in & mask(bits))?;
                // Auto-increment for streaming loads (standard DFT trick).
                self.addr.1 += 1;
                Ok(0)
            }
            JtagIr::ReadData => {
                let (bank, addr) = self.addr;
                let b = self.banks.get(bank).ok_or_else(|| anyhow::anyhow!("jtag: no bank {bank}"))?;
                let v = b
                    .peek(addr)
                    .ok_or_else(|| anyhow::anyhow!("jtag: bank {bank} addr {addr} out of range"))?;
                self.addr.1 += 1;
                Ok(v & mask(bits))
            }
        }
    }

    /// Convenience: stream a slice into a bank starting at address 0.
    pub fn load_bank(&mut self, bank: usize, data: &[u64]) -> crate::Result<()> {
        self.shift_ir(JtagIr::SetAddress);
        self.shift_dr((bank as u64) << 24, 32)?;
        self.shift_ir(JtagIr::WriteData);
        for &w in data {
            self.shift_dr(w, 64)?;
        }
        Ok(())
    }

    /// Convenience: stream `n` words out of a bank starting at address 0.
    pub fn read_bank(&mut self, bank: usize, n: usize) -> crate::Result<Vec<u64>> {
        self.shift_ir(JtagIr::SetAddress);
        self.shift_dr((bank as u64) << 24, 32)?;
        self.shift_ir(JtagIr::ReadData);
        (0..n).map(|_| self.shift_dr(0, 64)).collect()
    }
}

fn mask(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idcode_readable() {
        let mut bank = RamBank::new("stim", 4);
        let mut port = JtagPort::new(vec![&mut bank]);
        port.shift_ir(JtagIr::IdCode);
        assert_eq!(port.shift_dr(0, 64).unwrap(), IDCODE);
        assert_eq!(port.shift_dr(0, 16).unwrap(), IDCODE & 0xffff);
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut bank = RamBank::new("stim", 16);
        {
            let mut port = JtagPort::new(vec![&mut bank]);
            port.load_bank(0, &[11, 22, 33]).unwrap();
            let back = port.read_bank(0, 3).unwrap();
            assert_eq!(back, vec![11, 22, 33]);
        }
        // JTAG traffic must not count as at-speed accesses.
        assert_eq!(bank.reads + bank.writes, 0);
    }

    #[test]
    fn tck_accounting_shows_slow_port() {
        let mut bank = RamBank::new("stim", 1024);
        let mut port = JtagPort::new(vec![&mut bank]);
        let data: Vec<u64> = (0..1024).collect();
        port.load_bank(0, &data).unwrap();
        // 1024 words × (64+4) TCK plus setup: ≥ 68k cycles for 64 kbit —
        // three orders slower than the at-speed port's word/cycle.
        assert!(port.tck_cycles > 68_000, "{}", port.tck_cycles);
    }

    #[test]
    fn bad_bank_and_overflow_errors() {
        let mut bank = RamBank::new("stim", 2);
        let mut port = JtagPort::new(vec![&mut bank]);
        assert!(port.load_bank(3, &[1]).is_err());
        assert!(port.load_bank(0, &[1, 2, 3]).is_err()); // autoincrement past end
    }

    #[test]
    fn bypass_passes_one_bit() {
        let mut bank = RamBank::new("stim", 2);
        let mut port = JtagPort::new(vec![&mut bank]);
        port.shift_ir(JtagIr::Bypass);
        assert_eq!(port.shift_dr(0b1011, 4).unwrap(), 1);
    }

    #[test]
    fn multiple_banks_addressable() {
        let mut stim = RamBank::new("stim", 8);
        let mut res = RamBank::new("res", 8);
        let mut port = JtagPort::new(vec![&mut stim, &mut res]);
        port.load_bank(1, &[99]).unwrap();
        assert_eq!(port.read_bank(1, 1).unwrap(), vec![99]);
        assert_eq!(port.read_bank(0, 1).unwrap(), vec![0]);
    }
}
