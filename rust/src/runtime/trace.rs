//! Seeded multi-tenant trace workloads: the replayable load shapes
//! that drive — and judge — the fleet's routing policies.
//!
//! The paper's energy story is a *duty-cycle* story: the BB controller
//! recovers ~20% at full activity and ~2× in the 10%-activity regime,
//! so which policy serves a fleet best depends entirely on what the
//! offered load looks like over a day. A single uniform firehose (what
//! the routed bench offers) cannot distinguish the static Table-1
//! policy from a feedback policy; realistic traffic can. This module
//! generates that traffic deterministically:
//!
//! * **Multi-tenant** — every tenant is an independent seeded arrival
//!   process (one producer thread each at replay time).
//! * **Diurnal duty cycle** — arrival rate follows a cosine day shape
//!   around `duty_mean` with swing `duty_swing`; troughs produce the
//!   long idle gaps the idle-bias physics rewards consolidating.
//! * **Bursty, heavy-tailed arrivals** — exponential inter-arrival
//!   gaps modulated by the duty cycle, Pareto batch sizes
//!   (`burst_alpha` close to 1 ⇒ wild bursts).
//! * **Mix shift mid-run** — the SP share of traffic moves from
//!   `sp_frac_start` to `sp_frac_end` at the `shift_at` fraction of
//!   each tenant's budget, so a policy is judged on how it re-biases
//!   when the workload changes shape under it.
//! * **Transprecision tenants** — `small_fracs` routes a share of
//!   every tenant's traffic into the FP16/BF16/FP8 tiers of the
//!   12-class [`WorkloadClass`] matrix (the `transprecision` preset
//!   exercises all four small formats); all-zero shares reproduce the
//!   legacy two-class SP/DP draw bit-for-bit, so the original presets
//!   keep their fingerprints.
//!
//! Time is *virtual*: a trace is a sorted sequence of [`TraceEvent`]s
//! on an integer slot axis. The replay harness
//! ([`crate::coordinator::serve_trace`]) maps slots to submissions and
//! idle accounting, and advances a replay clock that slot-anchored
//! chaos triggers ([`super::chaos::FaultTrigger::TraceSlot`]) fire
//! against. Nothing here touches a wall clock or an OS thread: same
//! [`TraceConfig`] ⇒ bit-identical event stream and fingerprint,
//! which is the foundation of the replay determinism gate.

use crate::arch::fp::Precision;
use crate::runtime::chaos::{fnv1a_fold, FNV_OFFSET};
use crate::runtime::router::{ServiceClass, WorkloadClass};
use crate::util::Rng;

/// Batch sizes are clamped into this range: small enough that a single
/// event never monopolizes a shard queue, large enough that the Pareto
/// tail is visible.
pub const MIN_BATCH_OPS: u64 = 8;
pub const MAX_BATCH_OPS: u64 = 2048;

/// Shape parameters for a seeded trace. All randomness derives from
/// `seed`; everything else is deterministic structure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceConfig {
    pub seed: u64,
    /// Independent arrival processes (and replay producer threads).
    pub tenants: usize,
    /// Exact total ops across all tenants (the last event of each
    /// tenant is truncated so budgets are met exactly).
    pub total_ops: u64,
    /// Slots per diurnal period. The trace spans however many slots
    /// the arrival processes need — typically one to a few "days".
    pub slots_per_day: u64,
    /// Mean duty (0, 1]: fraction of slots carrying traffic at the
    /// day's average.
    pub duty_mean: f64,
    /// Relative swing of the cosine day shape: duty ranges over
    /// `duty_mean * (1 ± duty_swing)`, clamped to (0, 1].
    pub duty_swing: f64,
    /// Mean Pareto batch size (ops per event, before clamping).
    pub burst_mean_ops: f64,
    /// Pareto tail index; smaller ⇒ heavier bursts. Must be > 1 so
    /// the mean exists.
    pub burst_alpha: f64,
    /// Fraction of events in the latency service class (the rest are
    /// bulk).
    pub latency_frac: f64,
    /// SP share of traffic before / after the shift point.
    pub sp_frac_start: f64,
    pub sp_frac_end: f64,
    /// Fraction of each tenant's op budget at which the SP share
    /// shifts (1.0 ⇒ no shift).
    pub shift_at: f64,
    /// Share of traffic in each transprecision tier, in
    /// [`SMALL_TIERS`] order (fp16, bf16, fp8e4m3, fp8e5m2). These are
    /// carved off *before* the SP/DP split; the remaining
    /// `1 − Σ small_fracs` is divided by `sp_frac`. All-zero keeps the
    /// draw (and therefore every legacy preset's fingerprint)
    /// bit-identical to the two-class generator.
    pub small_fracs: [f64; 4],
}

/// The transprecision tiers `small_fracs` indexes, in order.
pub const SMALL_TIERS: [Precision; 4] =
    [Precision::Half, Precision::Bfloat16, Precision::Fp8E4M3, Precision::Fp8E5M2];

impl TraceConfig {
    /// The null hypothesis: flat duty, no bursts to speak of, balanced
    /// class mix, no shift. Static and dynamic policies should tie
    /// here — the "within 1% of static" guard-rail trace.
    pub fn uniform(seed: u64, total_ops: u64) -> TraceConfig {
        TraceConfig {
            seed,
            tenants: 4,
            total_ops,
            slots_per_day: 512,
            duty_mean: 0.9,
            duty_swing: 0.0,
            burst_mean_ops: 64.0,
            burst_alpha: 8.0,
            latency_frac: 0.5,
            sp_frac_start: 0.5,
            sp_frac_end: 0.5,
            shift_at: 1.0,
            small_fracs: [0.0; 4],
        }
    }

    /// The dominance trace: latency-heavy (the paper's Table-1
    /// affinity pins this to the CMA shards, which are the *less*
    /// efficient pipelines) with a deep diurnal trough. A feedback
    /// policy wins twice — spilling queued latency work onto the idle,
    /// efficiency-optimized FMA shards, and parking trough idle so the
    /// 2× low-activity recovery actually materializes.
    pub fn diurnal_skew(seed: u64, total_ops: u64) -> TraceConfig {
        TraceConfig {
            seed,
            tenants: 4,
            total_ops,
            slots_per_day: 512,
            duty_mean: 0.45,
            duty_swing: 0.8,
            burst_mean_ops: 96.0,
            burst_alpha: 2.5,
            latency_frac: 0.75,
            sp_frac_start: 0.5,
            sp_frac_end: 0.5,
            shift_at: 1.0,
            small_fracs: [0.0; 4],
        }
    }

    /// The adaptation trace: heavy-tailed bursts plus an SP→DP mix
    /// shift two-thirds of the way through — exercises EWMA decay and
    /// the re-bias rule under a moving target.
    pub fn burst_shift(seed: u64, total_ops: u64) -> TraceConfig {
        TraceConfig {
            seed,
            tenants: 6,
            total_ops,
            slots_per_day: 384,
            duty_mean: 0.6,
            duty_swing: 0.5,
            burst_mean_ops: 128.0,
            burst_alpha: 1.6,
            latency_frac: 0.6,
            sp_frac_start: 0.8,
            sp_frac_end: 0.2,
            shift_at: 0.66,
            small_fracs: [0.0; 4],
        }
    }

    /// The format-fleet trace: half the traffic rides the
    /// transprecision tiers (fp16-heavy, with bf16 and both FP8
    /// flavors present), and the SP share of the *remaining* wide
    /// traffic shifts from 0.6 to 0.4 halfway through — so a policy is
    /// judged on a fleet where every class of the 12-class matrix is
    /// live at once.
    pub fn transprecision(seed: u64, total_ops: u64) -> TraceConfig {
        TraceConfig {
            seed,
            tenants: 5,
            total_ops,
            slots_per_day: 448,
            duty_mean: 0.55,
            duty_swing: 0.6,
            burst_mean_ops: 96.0,
            burst_alpha: 2.0,
            latency_frac: 0.5,
            sp_frac_start: 0.6,
            sp_frac_end: 0.4,
            shift_at: 0.5,
            small_fracs: [0.25, 0.15, 0.05, 0.05],
        }
    }

    /// Canned preset names (CLI `fpmax replay --trace <name>` and the
    /// CI smoke step).
    pub const PRESETS: [&'static str; 4] =
        ["uniform", "diurnal-skew", "burst-shift", "transprecision"];

    /// Resolve a preset by name.
    pub fn preset(name: &str, seed: u64, total_ops: u64) -> Option<TraceConfig> {
        match name {
            "uniform" => Some(TraceConfig::uniform(seed, total_ops)),
            "diurnal-skew" => Some(TraceConfig::diurnal_skew(seed, total_ops)),
            "burst-shift" => Some(TraceConfig::burst_shift(seed, total_ops)),
            "transprecision" => Some(TraceConfig::transprecision(seed, total_ops)),
            _ => None,
        }
    }

    /// Instantaneous duty at a slot: the cosine day shape, clamped so
    /// rate stays positive and bounded.
    pub fn duty_at(&self, slot: u64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * (slot % self.slots_per_day) as f64
            / self.slots_per_day as f64;
        (self.duty_mean * (1.0 + self.duty_swing * phase.cos())).clamp(0.02, 1.0)
    }
}

/// One arrival: `ops` operations of `class`, from `tenant`, at virtual
/// time `slot`, preceded by `idle_before` slots of that tenant's
/// silence (the replay harness turns the gap into idle accounting so
/// the BB controllers see the duty cycle, not just the work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub tenant: usize,
    pub slot: u64,
    pub idle_before: u64,
    pub class: WorkloadClass,
    pub ops: u64,
    /// Seed for the event's operand stream — part of the trace, so a
    /// replay submits bit-identical operands.
    pub op_seed: u64,
}

/// A generated trace: the config it came from, the merged event
/// stream (sorted by `(slot, tenant, sequence)`), and an FNV-1a
/// fingerprint over every event field — the identity a replay digest
/// is anchored to.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub config: TraceConfig,
    pub events: Vec<TraceEvent>,
    pub fingerprint: u64,
}

impl Trace {
    /// Generate the trace. Pure: same config ⇒ bit-identical output.
    pub fn generate(config: TraceConfig) -> crate::Result<Trace> {
        anyhow::ensure!(config.tenants > 0, "trace needs at least one tenant");
        anyhow::ensure!(config.total_ops > 0, "trace needs a positive op budget");
        anyhow::ensure!(config.slots_per_day > 0, "slots_per_day must be positive");
        anyhow::ensure!(
            config.burst_alpha > 1.0,
            "burst_alpha must exceed 1 (Pareto mean must exist)"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&config.latency_frac)
                && (0.0..=1.0).contains(&config.sp_frac_start)
                && (0.0..=1.0).contains(&config.sp_frac_end)
                && (0.0..=1.0).contains(&config.shift_at),
            "trace fractions must lie in [0, 1]"
        );
        anyhow::ensure!(
            config.duty_mean > 0.0 && config.duty_mean <= 1.0 && config.duty_swing >= 0.0,
            "duty_mean must lie in (0, 1] and duty_swing must be non-negative"
        );
        anyhow::ensure!(
            config.small_fracs.iter().all(|f| (0.0..=1.0).contains(f))
                && config.small_fracs.iter().sum::<f64>() <= 1.0,
            "small_fracs must lie in [0, 1] and sum to at most 1"
        );

        let per_tenant = config.total_ops / config.tenants as u64;
        let remainder = config.total_ops % config.tenants as u64;
        let mut events: Vec<TraceEvent> = Vec::new();
        for tenant in 0..config.tenants {
            // Same derivation shape as the chaos harness's
            // producer_seeds: golden-ratio stride keeps tenant streams
            // decorrelated under nearby seeds.
            let mut rng = Rng::new(
                config
                    .seed
                    .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(tenant as u64 + 1)),
            );
            // Spread the integer-division remainder over the first
            // tenants so the fleet total is exact.
            let budget = per_tenant + u64::from((tenant as u64) < remainder);
            let shift_ops = (budget as f64 * config.shift_at) as u64;
            let mut emitted = 0u64;
            let mut slot = 0u64;
            while emitted < budget {
                // Exponential inter-arrival, shortened where the day
                // is busy: mean gap = 1 / duty(slot).
                let u = rng.f64();
                let gap = (-(1.0 - u).ln() / config.duty_at(slot)).ceil() as u64;
                let gap = gap.clamp(1, 4 * config.slots_per_day);
                slot += gap;
                // Pareto batch: mean burst_mean_ops at tail index
                // burst_alpha (scale = mean * (alpha-1)/alpha).
                let scale = config.burst_mean_ops * (config.burst_alpha - 1.0)
                    / config.burst_alpha;
                let u = rng.f64();
                let raw = scale * (1.0 - u).powf(-1.0 / config.burst_alpha);
                let ops = (raw as u64).clamp(MIN_BATCH_OPS, MAX_BATCH_OPS).min(budget - emitted);
                let sp_frac = if emitted < shift_ops {
                    config.sp_frac_start
                } else {
                    config.sp_frac_end
                };
                // One uniform draw partitions [0, 1) into the four
                // small tiers, then SP, then DP. With all-zero
                // small_fracs this is exactly `rng.chance(sp_frac)` —
                // same draw count, same comparison — so the legacy
                // presets keep their fingerprints.
                let u = rng.f64();
                let small_sum: f64 = config.small_fracs.iter().sum();
                let mut acc = 0.0;
                let mut small = None;
                for (tier, &frac) in SMALL_TIERS.iter().zip(&config.small_fracs) {
                    acc += frac;
                    if u < acc {
                        small = Some(*tier);
                        break;
                    }
                }
                let precision = small.unwrap_or(
                    if u < small_sum + (1.0 - small_sum) * sp_frac {
                        Precision::Single
                    } else {
                        Precision::Double
                    },
                );
                let service = if rng.chance(config.latency_frac) {
                    ServiceClass::Latency
                } else {
                    ServiceClass::Bulk
                };
                events.push(TraceEvent {
                    tenant,
                    slot,
                    idle_before: gap.saturating_sub(1),
                    class: WorkloadClass { precision, service },
                    ops,
                    op_seed: rng.next_u64(),
                });
                emitted += ops;
            }
        }
        // Merge to global virtual-time order. Per-tenant order is
        // already by slot; the stable sort keeps each tenant's
        // sequence intact under ties, and the tenant key makes the
        // merged order independent of generation order.
        events.sort_by_key(|e| (e.slot, e.tenant));

        let mut h = FNV_OFFSET;
        for e in &events {
            h = fnv1a_fold(h, e.tenant as u64);
            h = fnv1a_fold(h, e.slot);
            h = fnv1a_fold(h, e.idle_before);
            h = fnv1a_fold(h, e.class.index() as u64);
            h = fnv1a_fold(h, e.ops);
            h = fnv1a_fold(h, e.op_seed);
        }
        Ok(Trace { config, events, fingerprint: h })
    }

    /// Total ops across all events — always exactly
    /// `config.total_ops`.
    pub fn total_ops(&self) -> u64 {
        self.events.iter().map(|e| e.ops).sum()
    }

    /// The last event's slot (the replay clock's final value).
    pub fn last_slot(&self) -> u64 {
        self.events.last().map(|e| e.slot).unwrap_or(0)
    }

    /// Per-class op totals in [`WorkloadClass::index`] order — the
    /// deterministic class-mix histogram the replay digest folds in.
    pub fn class_ops(&self) -> [u64; WorkloadClass::COUNT] {
        let mut out = [0u64; WorkloadClass::COUNT];
        for e in &self.events {
            out[e.class.index()] += e.ops;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_trace() {
        let a = Trace::generate(TraceConfig::diurnal_skew(42, 50_000)).unwrap();
        let b = Trace::generate(TraceConfig::diurnal_skew(42, 50_000)).unwrap();
        // Bit-identical: every event field, the order, the fingerprint.
        assert_eq!(a, b);
        let c = Trace::generate(TraceConfig::diurnal_skew(43, 50_000)).unwrap();
        assert_ne!(a.fingerprint, c.fingerprint);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn budgets_are_exact_and_order_is_global_virtual_time() {
        for name in TraceConfig::PRESETS {
            let cfg = TraceConfig::preset(name, 7, 30_000).unwrap();
            let t = Trace::generate(cfg).unwrap();
            assert_eq!(t.total_ops(), 30_000, "{name}: budget not exact");
            assert_eq!(t.class_ops().iter().sum::<u64>(), 30_000);
            assert!(
                t.events.windows(2).all(|w| (w[0].slot, w[0].tenant) <= (w[1].slot, w[1].tenant)),
                "{name}: events out of virtual-time order"
            );
            let tenants: std::collections::HashSet<usize> =
                t.events.iter().map(|e| e.tenant).collect();
            assert_eq!(tenants.len(), cfg.tenants, "{name}: silent tenant");
            for e in &t.events {
                assert!(e.ops >= 1 && e.ops <= MAX_BATCH_OPS);
            }
        }
    }

    #[test]
    fn presets_shape_the_mix_as_documented() {
        let skew = Trace::generate(TraceConfig::diurnal_skew(11, 60_000)).unwrap();
        let [spl, spb, dpl, dpb, rest @ ..] = skew.class_ops();
        assert_eq!(
            rest.iter().sum::<u64>(),
            0,
            "SP/DP presets (small_fracs all zero) draw SP/DP classes only"
        );
        let latency_share = (spl + dpl) as f64 / 60_000.0;
        assert!(
            latency_share > 0.6,
            "diurnal-skew should be latency-heavy, got {latency_share:.2}"
        );
        assert!(spb + dpb > 0, "bulk classes must not vanish");

        // burst-shift: SP-heavy before the shift point, DP-heavy after.
        let shift = Trace::generate(TraceConfig::burst_shift(11, 60_000)).unwrap();
        let mid = shift.last_slot() / 2;
        let sp_ops = |evs: &[&TraceEvent]| {
            evs.iter()
                .filter(|e| e.class.precision == Precision::Single)
                .map(|e| e.ops)
                .sum::<u64>() as f64
                / evs.iter().map(|e| e.ops).sum::<u64>().max(1) as f64
        };
        let early: Vec<&TraceEvent> = shift.events.iter().filter(|e| e.slot < mid).collect();
        let late: Vec<&TraceEvent> = shift.events.iter().filter(|e| e.slot >= mid).collect();
        assert!(
            sp_ops(&early) > sp_ops(&late),
            "burst-shift must move the mix from SP toward DP"
        );

        // uniform: flat duty ⇒ duty_at is constant.
        let u = TraceConfig::uniform(1, 1_000);
        assert_eq!(u.duty_at(0), u.duty_at(u.slots_per_day / 2));
        // diurnal: trough is genuinely quieter than the peak.
        let d = TraceConfig::diurnal_skew(1, 1_000);
        assert!(d.duty_at(d.slots_per_day / 2) < d.duty_at(0) / 2.0);
    }

    #[test]
    fn transprecision_preset_lights_the_whole_class_matrix() {
        let t = Trace::generate(TraceConfig::transprecision(11, 120_000)).unwrap();
        let ops = t.class_ops();
        // Every class of the 12-class matrix carries traffic: both
        // service classes of SP, DP, and all four small tiers.
        for (i, &n) in ops.iter().enumerate() {
            assert!(n > 0, "class {i} drew no ops");
        }
        // The small tiers take roughly their configured half of the
        // traffic (event-level shares land op-weighted, so allow slack).
        let small: u64 = ops[4..].iter().sum();
        let share = small as f64 / t.total_ops() as f64;
        assert!(
            (0.35..0.65).contains(&share),
            "small tiers should carry ~0.5 of traffic, got {share:.2}"
        );
        // fp16 dominates the small tiers as configured (0.25 of total).
        let fp16 = ops[4] + ops[5];
        assert!(fp16 > ops[6] + ops[7], "fp16 should outweigh bf16");
        assert!(fp16 > ops[8] + ops[9] + ops[10] + ops[11], "fp16 should outweigh both FP8 tiers");
        // The wide-precision share still shifts SP→DP at the midpoint.
        let mid = t.last_slot() / 2;
        let wide_sp_share = |pred: &dyn Fn(&&TraceEvent) -> bool| {
            let wide: Vec<&TraceEvent> = t
                .events
                .iter()
                .filter(pred)
                .filter(|e| {
                    matches!(e.class.precision, Precision::Single | Precision::Double)
                })
                .collect();
            wide.iter()
                .filter(|e| e.class.precision == Precision::Single)
                .map(|e| e.ops)
                .sum::<u64>() as f64
                / wide.iter().map(|e| e.ops).sum::<u64>().max(1) as f64
        };
        let early = wide_sp_share(&|e: &&TraceEvent| e.slot < mid);
        let late = wide_sp_share(&|e: &&TraceEvent| e.slot >= mid);
        assert!(early > late, "wide mix must shift SP→DP ({early:.2} vs {late:.2})");
    }

    #[test]
    fn small_fracs_replicate_the_legacy_two_class_draw_when_disarmed() {
        // The unified draw consumes exactly one uniform per event
        // (like the old two-class `chance(sp_frac)`), so arming a
        // small tier may relabel events but must not re-time them:
        // slots, gaps, op counts and op seeds stay identical, only
        // precision labels (and thus the fingerprint) move.
        let base = Trace::generate(TraceConfig::diurnal_skew(42, 50_000)).unwrap();
        let mut with_small = TraceConfig::diurnal_skew(42, 50_000);
        with_small.small_fracs = [0.1, 0.0, 0.0, 0.0];
        let c = Trace::generate(with_small).unwrap();
        assert_ne!(base.fingerprint, c.fingerprint, "armed small tiers must change the trace");
        assert_eq!(base.events.len(), c.events.len());
        for (a, b) in base.events.iter().zip(&c.events) {
            assert_eq!(
                (a.tenant, a.slot, a.idle_before, a.ops, a.op_seed, a.class.service),
                (b.tenant, b.slot, b.idle_before, b.ops, b.op_seed, b.class.service),
                "arming a small tier may only relabel precisions"
            );
        }

        assert!(
            Trace::generate(TraceConfig {
                small_fracs: [0.5, 0.4, 0.2, 0.0],
                ..TraceConfig::uniform(1, 100)
            })
            .is_err(),
            "small_fracs summing past 1 must be rejected"
        );
    }

    #[test]
    fn idle_gaps_reflect_the_duty_trough() {
        // Average idle_before in the trough half of the day should
        // exceed the peak half — the structural fact the idle-parking
        // policy feeds on.
        let t = Trace::generate(TraceConfig::diurnal_skew(3, 80_000)).unwrap();
        let day = t.config.slots_per_day;
        let (mut peak_gap, mut peak_n, mut trough_gap, mut trough_n) = (0u64, 0u64, 0u64, 0u64);
        for e in &t.events {
            let phase = e.slot % day;
            if phase < day / 4 || phase >= 3 * day / 4 {
                peak_gap += e.idle_before;
                peak_n += 1;
            } else {
                trough_gap += e.idle_before;
                trough_n += 1;
            }
        }
        assert!(peak_n > 0 && trough_n > 0);
        assert!(
            trough_gap as f64 / trough_n as f64 > peak_gap as f64 / peak_n as f64,
            "trough gaps should be longer than peak gaps"
        );
    }

    #[test]
    fn generate_rejects_bad_shapes() {
        assert!(Trace::generate(TraceConfig { tenants: 0, ..TraceConfig::uniform(1, 100) })
            .is_err());
        assert!(Trace::generate(TraceConfig { total_ops: 0, ..TraceConfig::uniform(1, 100) })
            .is_err());
        assert!(Trace::generate(TraceConfig {
            burst_alpha: 1.0,
            ..TraceConfig::uniform(1, 100)
        })
        .is_err());
        assert!(Trace::generate(TraceConfig {
            latency_frac: 1.5,
            ..TraceConfig::uniform(1, 100)
        })
        .is_err());
        assert!(TraceConfig::preset("no-such-trace", 1, 100).is_none());
    }
}
