//! The streaming serve layer: an asynchronous submission queue over the
//! persistent [`BatchExecutor`] with **mid-run body-bias re-biasing**.
//!
//! This is the piece that turns the batch engine into a serving
//! architecture. Producers (request handlers, workload drivers, the
//! `fpmax serve` CLI) submit variable-sized op slices from many threads;
//! a dispatcher coalesces them into fidelity-tiered batches and drives
//! the engine's persistent worker pool through **per-worker
//! work-stealing queues** of window-aligned chunk ranges (each queue is
//! drained by the atomic-cursor claim idiom the chunked runs use; a
//! worker that runs dry turns thief and claims ranges off another
//! worker's cursor — lock-free in both roles). Completed
//! [`ActivityWindow`]s are published in order into a bounded SPSC
//! [`window_ring`], where a [`StreamingController`] consumes them
//! **while the run is still executing** and emits a live bias schedule —
//! the sub-microsecond reaction the FPMax adaptive body bias needs to
//! recover its ~2× saving at 10% activity in a serving context, instead
//! of scoring the trace after the fact.
//!
//! Correctness contract (asserted per run and pinned by
//! `rust/tests/serve.rs`):
//!
//! * results are bit-identical to a serial pass, guarded by the same
//!   sampled gate-level cross-check the batch paths use;
//! * the streamed bias schedule and energies are **bit-identical** to
//!   the post-hoc [`crate::bb::window_bias_schedule`] /
//!   [`crate::bb::run_energy_trace`] pair on the same master trace
//!   whenever the ring never overflowed;
//! * ring overflow degrades gracefully: windows coalesce (losing
//!   granularity, keeping every slot and toggle count), so the
//!   controller's energy accounting never drops an op;
//! * every ticket resolves: a dispatcher that dies mid-run errors all
//!   outstanding submissions (queued and mid-batch) instead of hanging
//!   their producers;
//! * faults are **contained and typed**: a panicking lane kernel errors
//!   only its batch's tickets ([`ServeError::WorkerPanic`], the batch is
//!   never published so the streamed-BB bit-identity contract is
//!   untouched), a dead dispatcher is salvageable
//!   ([`ServeQueue::finish_salvaging`] recovers the partial
//!   [`ServeReport`] — exact ops/energy/latency accounting up to the
//!   moment of death — so fleet supervision can respawn the shard and
//!   keep conservation exact across incarnations), and every error a
//!   producer can see downcasts to a [`ServeError`] that says whether a
//!   resubmission is safe.
//!
//! One `ServeQueue` serves one unit. The multi-unit serving surface —
//! one shard per (unit preset × precision × fidelity tier) behind a
//! workload-aware dispatch policy — is [`crate::runtime::router`],
//! which composes queues started through
//! [`ServeQueue::start_with_executor`] so the fleet shares one worker
//! budget.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arch::engine::{
    calibration_key, chunk_from_per_op, window_ring, ActivityAccumulator, ActivityTrace,
    ActivityWindow, BatchExecutor, Datapath, Fidelity, SendPtr, UnitDatapath, WindowProducer,
    WorkerPanicked, CALIBRATION_OPS, RECAL_RATIO, SERIAL_CUTOFF,
};
use crate::arch::generator::{FpuConfig, FpuUnit};
use crate::bb::{run_energy_trace, window_bias_schedule, BbPolicy, BbRunEnergy, StreamedBb,
    StreamingController};
use crate::energy::tech::Technology;
use crate::timing;
use crate::util::stats::{percentile, Ewma};
use crate::workloads::throughput::OperandTriple;

/// Cap on reported cross-check mismatch indices.
const MISMATCH_CAP: usize = 8;

/// Typed fault classification of the serve layer. Every error a
/// producer-facing call can return on a *fault path* (as opposed to a
/// misuse or invariant path) carries one of these as its source, so
/// retry logic can downcast ([`ServeError::classify`]) and decide
/// whether a resubmission is safe instead of string-matching messages.
///
/// Ops are pure — resubmitting a dropped or failed batch can never
/// double-apply an effect — so the only *unsafe* retries are the ones
/// that would paper over a caller bug ([`ServeError::ResultTaken`]) or
/// a blown latency budget ([`ServeError::DeadlineExceeded`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// The dispatcher died (or the queue was torn down) before this
    /// submission completed; the shard may be respawned by a supervisor.
    ShardFailed,
    /// A worker panicked while executing this submission's batch; the
    /// batch was discarded whole (never published), the shard survives.
    WorkerPanic,
    /// The queue is closed to new work (shutdown, or a dead dispatcher's
    /// teardown guard) — a router-level retry may find a respawned shard.
    QueueClosed,
    /// This ticket's result was already taken by an earlier wait
    /// (results are handed out exactly once) — a caller bug, not a fault.
    ResultTaken,
    /// A deadline-bounded wait ran out before the submission completed
    /// ([`crate::runtime::router::ServeRouter::submit_with_deadline`]).
    DeadlineExceeded,
    /// The routing policy's SLO-class admission control turned this
    /// submission away: every candidate shard for its class was over
    /// the policy's admission pressure bound. Nothing was enqueued —
    /// retrying after backoff is safe and may find a drained fleet.
    AdmissionDenied,
}

impl ServeError {
    /// Whether a fresh submission of the same ops is safe and useful.
    pub fn retryable(self) -> bool {
        match self {
            ServeError::ShardFailed
            | ServeError::WorkerPanic
            | ServeError::QueueClosed
            | ServeError::AdmissionDenied => true,
            ServeError::ResultTaken | ServeError::DeadlineExceeded => false,
        }
    }

    /// Downcast an error chain to its serve-layer classification, if any.
    pub fn classify(err: &anyhow::Error) -> Option<ServeError> {
        err.chain().find_map(|e| e.downcast_ref::<ServeError>().copied())
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            ServeError::ShardFailed => {
                "serve dispatcher dropped this submission (dispatcher died or the queue was torn down)"
            }
            ServeError::WorkerPanic => {
                "engine worker panicked executing this submission's batch (batch discarded whole)"
            }
            ServeError::QueueClosed => "serve queue is closed to new work",
            ServeError::ResultTaken => "serve result already taken by an earlier wait",
            ServeError::DeadlineExceeded => "submission deadline exceeded",
            ServeError::AdmissionDenied => {
                "admission control rejected this submission (every candidate shard over the policy's pressure bound)"
            }
        };
        f.write_str(msg)
    }
}

impl std::error::Error for ServeError {}

/// Lock, tolerating poison. The serve layer's shared maps are only
/// mutated in short, panic-free critical sections; a poisoned flag
/// therefore means *another* thread died while holding the guard — the
/// data behind it is still consistent, and fault/teardown paths must
/// keep accounting (chaos gate: zero lost ops) instead of aborting on
/// the flag.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// [`Condvar::wait`] with the same poison tolerance as [`lock_unpoisoned`].
fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|p| p.into_inner())
}

/// Configuration of a [`ServeQueue`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Engine pool workers.
    pub workers: usize,
    /// Trace window width, in ops/slots.
    pub window_ops: usize,
    /// Coalescing cap: a dispatched batch never exceeds this many ops.
    pub max_batch_ops: usize,
    /// Backpressure bound: producers block while this many ops queue.
    pub max_queue_ops: usize,
    /// Capacity (in windows) of the engine→controller ring.
    pub ring_windows: usize,
    /// Sampled gate-level cross-check stride (0 disables; ignored on the
    /// gate tier, which is the reference).
    pub crosscheck_every: usize,
    /// Body-bias policy the streaming controller runs.
    pub policy: BbPolicy,
    /// Supply voltage the energy accounting is scored at.
    pub vdd: f64,
    /// Weight of the per-shard completed-latency EWMA published to
    /// [`ShardFeedback`] (in `(0, 1]`; each completed submission's
    /// latency is folded in with this weight).
    pub ewma_alpha: f64,
    /// Warm-start for the latency estimator: a prior incarnation's
    /// `(value_s, count)` snapshot, replayed by the router's respawn
    /// path so the feedback signal survives a shard death. `None`
    /// starts cold.
    pub ewma_seed: Option<(f64, u64)>,
}

impl ServeConfig {
    /// Nominal serving configuration for a unit: its Table-I operating
    /// point, the paper's adaptive (or static) policy at the nominal
    /// clock, one worker per hardware thread.
    pub fn nominal(cfg: &FpuConfig, adaptive: bool) -> crate::Result<ServeConfig> {
        let tech = Technology::fdsoi28();
        let op = timing::nominal_op(cfg);
        let freq = timing::timing(cfg, &tech, op)
            .ok_or_else(|| anyhow::anyhow!("nominal operating point not operable"))?
            .freq_ghz;
        let policy = if adaptive {
            BbPolicy::adaptive_nominal(freq)
        } else {
            BbPolicy::static_nominal()
        };
        let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        Ok(ServeConfig {
            workers,
            window_ops: 4_096,
            max_batch_ops: 1 << 16,
            max_queue_ops: 1 << 20,
            ring_windows: 1_024,
            // Sparse by default: a gate-level re-execution costs ~100×
            // a word-simd op, so the serving hot path samples lightly
            // (still dozens of samples over any real run; `fpmax verify`
            // remains the dense cross-check surface).
            crosscheck_every: 9_973,
            policy,
            vdd: op.vdd,
            // Heavy enough smoothing to ride out batch-coalescing noise,
            // light enough that a degrading shard shows within ~10
            // completions.
            ewma_alpha: 0.25,
            ewma_seed: None,
        })
    }
}

/// Lock-free feedback signals one shard publishes for the router's
/// dynamic routing policies: the completed-latency EWMA (dispatcher
/// side, updated once per batch) and the live streamed pJ/op snapshot
/// (controller side, updated once per consumed window). The router owns
/// one `Arc<ShardFeedback>` per shard *slot* and hands it to every
/// incarnation ([`ServeQueue::start_with_feedback`]), so the signal is
/// continuous across respawns — a policy never routes blind just
/// because a shard died.
///
/// Both f64 cells store raw bits in an `AtomicU64`; a NaN pattern means
/// "no observation yet" (NaN is never a legitimate value of either
/// signal, and [`Ewma`] can never produce one from finite latencies).
#[derive(Debug)]
pub struct ShardFeedback {
    ewma_bits: AtomicU64,
    ewma_count: AtomicU64,
    live_pj_bits: AtomicU64,
}

impl ShardFeedback {
    /// A cold cell: no latency or energy signal yet.
    pub fn new() -> ShardFeedback {
        ShardFeedback {
            ewma_bits: AtomicU64::new(f64::NAN.to_bits()),
            ewma_count: AtomicU64::new(0),
            live_pj_bits: AtomicU64::new(f64::NAN.to_bits()),
        }
    }

    /// Current latency-EWMA estimate, seconds; `None` before the first
    /// completed submission (and before any seeded-in prior).
    pub fn latency_ewma_s(&self) -> Option<f64> {
        let v = f64::from_bits(self.ewma_bits.load(Ordering::Relaxed));
        (!v.is_nan()).then_some(v)
    }

    /// Observations folded into the latency EWMA, prior incarnations
    /// included.
    pub fn ewma_count(&self) -> u64 {
        self.ewma_count.load(Ordering::Relaxed)
    }

    /// Live streamed pJ/op as of the last window the shard's
    /// [`StreamingController`] consumed; `None` until the first op's
    /// window lands (the integrator reports infinity before any op, and
    /// non-finite snapshots are filtered here so cost scores stay
    /// well-defined).
    pub fn live_pj_per_op(&self) -> Option<f64> {
        let v = f64::from_bits(self.live_pj_bits.load(Ordering::Relaxed));
        v.is_finite().then_some(v)
    }

    fn publish_latency(&self, value_s: f64, count: u64) {
        self.ewma_bits.store(value_s.to_bits(), Ordering::Relaxed);
        self.ewma_count.store(count, Ordering::Relaxed);
    }

    fn publish_live_pj(&self, pj_per_op: f64) {
        self.live_pj_bits.store(pj_per_op.to_bits(), Ordering::Relaxed);
    }
}

impl Default for ShardFeedback {
    fn default() -> ShardFeedback {
        ShardFeedback::new()
    }
}

/// A synthetic serving workload for [`crate::coordinator::serve_datapath`]:
/// `producers` threads submit `total_ops` ops in variable-sized chunks
/// around `sub_ops`, weaving in idle phases to hit `duty` occupancy —
/// the serving-shaped analogue of the Fig. 4 duty-cycle profiles.
#[derive(Debug, Clone, Copy)]
pub struct ServeLoad {
    /// Total ops across all producers.
    pub total_ops: usize,
    /// Producer threads.
    pub producers: usize,
    /// Mean submission size; actual sizes vary in `[sub_ops/2, 3·sub_ops/2)`.
    pub sub_ops: usize,
    /// Target occupancy in `(0, 1]`; `< 1` interleaves idle-slot
    /// submissions (accounting only — no wall-clock) whose gaps the
    /// adaptive controller re-biases through.
    pub duty: f64,
    /// Operand/size stream seed.
    pub seed: u64,
}

/// Completion slot a submission's [`Ticket`] waits on.
#[derive(Default)]
struct Completion {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

#[derive(Default)]
struct CompletionState {
    bits: Option<Vec<u64>>,
    /// Set instead of `bits` when the dispatcher dropped or failed the
    /// submission (it died mid-run, a worker panicked executing the
    /// batch, or the queue was torn down under it).
    err: Option<ServeError>,
    done: bool,
}

impl CompletionState {
    fn take(&mut self) -> crate::Result<Vec<u64>> {
        match self.err {
            Some(e) => Err(anyhow::Error::new(e)),
            // The dispatcher always sets `bits` on completion (empty
            // submissions complete with an empty vec), so a done ticket
            // with no bits means an earlier wait already consumed them —
            // distinct from a legitimate empty result.
            None => match self.bits.take() {
                Some(bits) => Ok(bits),
                None => Err(anyhow::Error::new(ServeError::ResultTaken)),
            },
        }
    }
}

/// Handle to one in-flight submission.
///
/// Every ticket resolves: the dispatcher completes it with the result
/// bits, or — if the dispatcher dies mid-run — the teardown path
/// completes it with an error. A producer blocked in [`Ticket::wait`]
/// therefore never hangs on a dead serve loop; bounded-patience callers
/// can use [`Ticket::wait_timeout`] / [`Ticket::try_wait`] instead.
pub struct Ticket {
    done: Arc<Completion>,
}

impl Ticket {
    /// Block until the submission's batch has executed; returns the
    /// result bits, one per submitted triple, in submission order, or an
    /// error if the dispatcher dropped the submission.
    pub fn wait(self) -> crate::Result<Vec<u64>> {
        let mut st = lock_unpoisoned(&self.done.state);
        while !st.done {
            st = wait_unpoisoned(&self.done.cv, st);
        }
        st.take()
    }

    /// Like [`Ticket::wait`], but gives up after `timeout`: `Ok(None)`
    /// means the submission is still in flight (the ticket stays valid —
    /// wait again or keep polling), `Ok(Some(bits))` is completion, and
    /// `Err` means the dispatcher dropped the submission — or an earlier
    /// wait on this ticket already took the bits (the result is handed
    /// out exactly once).
    pub fn wait_timeout(&self, timeout: Duration) -> crate::Result<Option<Vec<u64>>> {
        // A timeout too large to represent as a deadline (Duration::MAX
        // as a wait-forever sentinel) degrades to an untimed wait
        // instead of panicking on Instant overflow.
        let deadline = Instant::now().checked_add(timeout);
        let mut st = lock_unpoisoned(&self.done.state);
        while !st.done {
            match deadline {
                None => st = wait_unpoisoned(&self.done.cv, st),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    let (g, _timed_out) = self
                        .done
                        .cv
                        .wait_timeout(st, d - now)
                        .unwrap_or_else(|p| p.into_inner());
                    st = g;
                }
            }
        }
        st.take().map(Some)
    }

    /// Non-blocking poll: `Ok(None)` while the submission is in flight.
    pub fn try_wait(&self) -> crate::Result<Option<Vec<u64>>> {
        let mut st = lock_unpoisoned(&self.done.state);
        if !st.done {
            return Ok(None);
        }
        st.take().map(Some)
    }
}

/// One queued work item.
enum Work {
    Ops(OpsSub),
    /// Explicit idle issue slots (a low-utilization phase): published as
    /// idle windows so the streaming controller can re-bias through the
    /// gap, exactly like the post-hoc Fig. 4 weaves.
    Idle { slots: u64 },
    /// Fault injection ([`SubmitHandle::inject_fault`]): the dispatcher
    /// panics when it dequeues this, exercising the ticket-teardown path.
    Fault,
    /// Fault injection ([`SubmitHandle::inject_worker_panic`]): the next
    /// ops batch's parallel region panics — a stand-in for a lane-kernel
    /// bug — exercising the containment path: that batch's tickets error
    /// with [`ServeError::WorkerPanic`], the shard survives.
    WorkerFault,
    /// Fault injection ([`SubmitHandle::inject_latency`]): the
    /// dispatcher stalls this long before processing further work — a
    /// stand-in for a degraded shard backing up its queue.
    Latency(Duration),
}

struct OpsSub {
    tier: Fidelity,
    triples: Vec<OperandTriple>,
    /// Result buffer, allocated by the submitting producer (so the
    /// dispatcher hot path never allocates per submission) and handed
    /// to the ticket whole once the batch completes — zero copies.
    out: Vec<u64>,
    done: Arc<Completion>,
    submitted: Instant,
    /// The queue's in-flight op counter; decremented exactly once, when
    /// this submission is dropped (completed or errored).
    pressure: Arc<AtomicUsize>,
}

impl Drop for OpsSub {
    /// Every submission resolves its ticket exactly once. The normal
    /// path completes it with result bits before the `OpsSub` drops;
    /// any drop that finds the ticket still open — the dispatcher
    /// unwinding mid-batch, or the teardown guard draining the queue
    /// after a dispatcher death — errors it, so producers blocked in
    /// [`Ticket::wait`] never hang.
    fn drop(&mut self) {
        // Saturating decrement: `pressure` is the router's lock-free
        // load/spill signal, and this drop can run on fault paths (a
        // teardown drain racing a respawn, a submission dropped between
        // enqueue and dispatch). An unbalanced decrement must clamp at
        // zero, not wrap to usize::MAX and freeze the shard out of every
        // routing decision.
        let n = self.triples.len();
        let mut cur = self.pressure.load(Ordering::Relaxed);
        while let Err(seen) = self.pressure.compare_exchange_weak(
            cur,
            cur.saturating_sub(n),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            cur = seen;
        }
        let mut st = lock_unpoisoned(&self.done.state);
        if !st.done {
            st.err = Some(ServeError::ShardFailed);
            st.done = true;
            drop(st);
            self.done.cv.notify_all();
        }
    }
}

struct QueueState {
    items: VecDeque<Work>,
    queued_ops: usize,
    closed: bool,
}

struct QueueShared {
    q: Mutex<QueueState>,
    /// Producers park here while the queue is at its ops bound.
    space: Condvar,
    /// The dispatcher parks here while the queue is empty.
    work: Condvar,
    /// Ops submitted but not yet resolved (completed or errored) — the
    /// queue's load-pressure signal, readable lock-free by the router's
    /// spill policy while the owning shard is mid-batch.
    pressure: Arc<AtomicUsize>,
}

/// Cloneable producer handle onto a [`ServeQueue`].
#[derive(Clone)]
pub struct SubmitHandle {
    shared: Arc<QueueShared>,
}

impl SubmitHandle {
    /// Submit a variable-sized op slice at a fidelity tier. Blocks while
    /// the queue is at its backpressure bound; the returned [`Ticket`]
    /// resolves to the result bits once the dispatcher has executed the
    /// batch the submission was coalesced into. Submission latency is
    /// measured from entry here (queue wait included) to completion.
    pub fn submit(
        &self,
        tier: Fidelity,
        triples: Vec<OperandTriple>,
        max_queue_ops: usize,
    ) -> crate::Result<Ticket> {
        let submitted = Instant::now();
        let done = Arc::new(Completion::default());
        let n = triples.len();
        // The producer pays the result-buffer allocation, not the
        // dispatcher: workers write straight into it (zero-copy) and
        // the ticket receives it whole.
        let out = vec![0u64; n];
        let mut st = lock_unpoisoned(&self.shared.q);
        while !st.closed && st.queued_ops > 0 && st.queued_ops + n > max_queue_ops {
            st = wait_unpoisoned(&self.shared.space, st);
        }
        if st.closed {
            return Err(anyhow::Error::new(ServeError::QueueClosed));
        }
        st.queued_ops += n;
        self.shared.pressure.fetch_add(n, Ordering::Relaxed);
        st.items.push_back(Work::Ops(OpsSub {
            tier,
            triples,
            out,
            done: Arc::clone(&done),
            submitted,
            pressure: Arc::clone(&self.shared.pressure),
        }));
        drop(st);
        self.shared.work.notify_one();
        Ok(Ticket { done })
    }

    /// Ops submitted through this queue and not yet resolved (queued or
    /// mid-batch). Lock-free; the router's load-aware spill policy reads
    /// it per dispatch decision.
    pub fn pressure_ops(&self) -> usize {
        self.shared.pressure.load(Ordering::Relaxed)
    }

    /// Fault injection: make the dispatcher panic when it reaches this
    /// point of the queue. Exists for tests and chaos drills of the
    /// ticket-teardown contract — every outstanding ticket must resolve
    /// with an error instead of hanging its producer.
    pub fn inject_fault(&self) -> crate::Result<()> {
        self.push_work(Work::Fault)
    }

    /// Fault injection: make the next coalesced ops batch panic inside
    /// its parallel region (a stand-in for a lane-kernel bug). Unlike
    /// [`SubmitHandle::inject_fault`] the dispatcher *survives*: the
    /// batch's tickets error with [`ServeError::WorkerPanic`], the batch
    /// is never published, and the shard keeps serving.
    pub fn inject_worker_panic(&self) -> crate::Result<()> {
        self.push_work(Work::WorkerFault)
    }

    /// Fault injection: stall the dispatcher for `dur` when it reaches
    /// this point of the queue (a degraded-shard drill for the router's
    /// load-aware spill and the chaos harness's deadline paths).
    pub fn inject_latency(&self, dur: Duration) -> crate::Result<()> {
        self.push_work(Work::Latency(dur))
    }

    /// Submit an idle phase of `slots` issue slots (accounting only — no
    /// wall-clock is consumed). The dispatcher publishes it as idle
    /// windows in queue order, giving the streaming controller the gaps
    /// the adaptive policy re-biases through.
    pub fn submit_idle(&self, slots: u64) -> crate::Result<()> {
        if slots == 0 {
            return Ok(());
        }
        self.push_work(Work::Idle { slots })
    }

    fn push_work(&self, w: Work) -> crate::Result<()> {
        let mut st = lock_unpoisoned(&self.shared.q);
        if st.closed {
            return Err(anyhow::Error::new(ServeError::QueueClosed));
        }
        st.items.push_back(w);
        drop(st);
        self.shared.work.notify_one();
        Ok(())
    }
}

/// Per-worker work-stealing queues of window-range chunks.
///
/// Every queue is a pre-seeded contiguous share of the batch's windows,
/// cut into chunk-sized ranges and drained by a per-queue atomic cursor
/// (the unique-claim `fetch_add` idiom of the engine's chunked runs — the
/// intra-batch fast path). A worker that exhausts its own queue scans the
/// others round-robin and claims ranges off their cursors: stealing is
/// the same lock-free `fetch_add`, just on a victim's cursor, so owner
/// and thief never need a lock and every range is executed exactly once.
struct StealQueues {
    ranges: Vec<Vec<(u32, u32)>>,
    cursors: Vec<AtomicUsize>,
}

impl StealQueues {
    fn new(workers: usize) -> StealQueues {
        StealQueues {
            ranges: (0..workers).map(|_| Vec::new()).collect(),
            cursors: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Reseed for a batch covering windows `[start_window, n_windows)`,
    /// `chunk_windows` windows per claimable range. Reuses the range
    /// vectors' capacity — allocation-free once warm.
    fn seed(&mut self, start_window: usize, n_windows: usize, chunk_windows: usize) {
        let workers = self.ranges.len();
        for c in &self.cursors {
            c.store(0, Ordering::Relaxed);
        }
        let total = n_windows.saturating_sub(start_window);
        let per = total.div_ceil(workers.max(1)).max(1);
        for (w, q) in self.ranges.iter_mut().enumerate() {
            q.clear();
            let lo = start_window + w * per;
            let hi = (lo + per).min(n_windows);
            let mut s = lo;
            while s < hi {
                let e = (s + chunk_windows).min(hi);
                q.push((s as u32, e as u32));
                s = e;
            }
        }
    }

    /// Claim the next window range for worker `w`: own queue first, then
    /// round-robin theft. `None` once every queue is drained.
    fn next(&self, w: usize) -> Option<(usize, usize)> {
        let workers = self.cursors.len();
        for k in 0..workers {
            let v = (w + k) % workers;
            let i = self.cursors[v].fetch_add(1, Ordering::Relaxed);
            let q = &self.ranges[v];
            if i < q.len() {
                let (a, b) = q[i];
                return Some((a as usize, b as usize));
            }
        }
        None
    }
}

/// Shared read-only companion of the engine's `SendPtr`.
#[derive(Clone, Copy)]
struct SendConst<T>(*const T);
unsafe impl<T> Send for SendConst<T> {}
unsafe impl<T> Sync for SendConst<T> {}

/// One submission's slice of the logical batch. The dispatcher never
/// gathers operands into a contiguous scratch buffer: workers execute
/// **zero-copy** straight out of each submission's own operand and
/// result vectors, addressed through the concatenated op index space.
struct Segment {
    /// Start in the concatenated op index space.
    start: usize,
    len: usize,
    tri: SendConst<OperandTriple>,
    out: SendPtr<u64>,
}

/// Execute ops `[lo, hi)` of the concatenated batch through `dp`,
/// walking the overlapping submission segments.
///
/// # Safety
/// The caller must guarantee `[lo, hi)` is claimed by exactly one
/// executor (no other thread touches these output ops) and that the
/// segments' backing vectors outlive the call.
unsafe fn exec_span(
    dp: &UnitDatapath,
    segs: &[Segment],
    lo: usize,
    hi: usize,
    acc: &mut ActivityAccumulator,
) {
    let mut si = segs.partition_point(|s| s.start + s.len <= lo);
    let mut pos = lo;
    while pos < hi {
        let s = &segs[si];
        let off = pos - s.start;
        let take = (s.len - off).min(hi - pos);
        let tri = std::slice::from_raw_parts(s.tri.0.add(off), take);
        let os = std::slice::from_raw_parts_mut(s.out.0.add(off), take);
        dp.fmac_batch_tracked(tri, os, acc);
        pos += take;
        si += 1;
    }
}

fn tier_index(tier: Fidelity) -> usize {
    match tier {
        Fidelity::GateLevel => 0,
        Fidelity::WordLevel => 1,
        Fidelity::WordSimd => 2,
    }
}

/// The dispatcher's running accounting, shared with the owning
/// [`ServeQueue`] behind a mutex so it **survives dispatcher death**: the
/// dispatcher syncs it at every publish point (once per batch / idle gap
/// — never inside the execution hot path), so when an injected fault or
/// a real bug unwinds the dispatcher thread, [`ServeQueue::finish_salvaging`]
/// still recovers exact ops/energy/latency accounting up to the last
/// completed batch. That is what lets fleet supervision respawn a shard
/// and keep `FleetReport` conservation exact across incarnations.
#[derive(Clone)]
struct DispatchStats {
    master: ActivityTrace,
    ops: u64,
    batches: u64,
    /// Batches discarded whole because a worker panicked executing them
    /// (their submissions are in `errored_submissions`, their windows
    /// were never published).
    failed_batches: u64,
    submissions: u64,
    /// Submissions resolved with an error instead of bits.
    errored_submissions: u64,
    latencies: Vec<f64>,
    /// Completed-latency EWMA, updated with every latency pushed above
    /// and mirrored into the shard's [`ShardFeedback`] once per batch.
    /// Lives in the salvageable stats so a respawn can seed the next
    /// incarnation from the dead one's exact `(value, count)`.
    latency_ewma: Ewma,
    crosscheck_sampled: u64,
    crosscheck_mismatches: u64,
    mismatch_indices: Vec<usize>,
    first_batch: Option<Instant>,
    busy_until: Option<Instant>,
    /// Refreshed after every publish, so it is exact even at panic time
    /// (no windows are published after the last sync).
    ring_coalesced: u64,
    /// Saved (chunk_hint, calibrated_ops) per tier, synced on every tier
    /// swap — a respawned incarnation re-seeds from this so it does not
    /// pay cold calibration again.
    tier_cal: [(usize, usize); 3],
}

impl DispatchStats {
    fn new(window_ops: usize, tier_cal: [(usize, usize); 3], latency_ewma: Ewma) -> DispatchStats {
        DispatchStats {
            master: ActivityTrace::from_raw_windows(window_ops as u64, Vec::new()),
            ops: 0,
            batches: 0,
            failed_batches: 0,
            submissions: 0,
            errored_submissions: 0,
            latencies: Vec::new(),
            latency_ewma,
            crosscheck_sampled: 0,
            crosscheck_mismatches: 0,
            mismatch_indices: Vec::new(),
            first_batch: None,
            busy_until: None,
            ring_coalesced: 0,
            tier_cal,
        }
    }
}

/// The dispatcher: owns the engine side of the serve loop.
struct Dispatcher {
    shared: Arc<QueueShared>,
    exec: BatchExecutor,
    /// The unit at all three fidelity tiers (index = [`tier_index`]).
    dps: [UnitDatapath; 3],
    /// Gate-level reference for the sampled cross-check.
    unit: FpuUnit,
    window_ops: usize,
    max_batch_ops: usize,
    crosscheck_every: usize,
    producer: WindowProducer,
    /// Saved (chunk_hint, calibrated_ops) per tier — one pool, per-tier
    /// calibration (per-op costs differ ~10× between tiers). Seeded back
    /// under the tier's [`calibration_key`], so a hint that somehow
    /// crossed tiers — or came from the other lane-kernel build — is
    /// dropped by the staleness check instead of trusted.
    tier_cal: [(usize, usize); 3],
    cur_tier: Option<usize>,
    /// The next ops batch panics its parallel region (containment drill).
    force_worker_panic: bool,
    // Reused scratch (allocation-free once grown to the batch shape).
    batch_items: Vec<OpsSub>,
    segs: Vec<Segment>,
    accs: Vec<ActivityAccumulator>,
    queues: StealQueues,
    /// Shared accounting (see [`DispatchStats`]).
    stats: Arc<Mutex<DispatchStats>>,
    /// Routing-feedback cell (latency side; the controller thread owns
    /// the energy side).
    feedback: Arc<ShardFeedback>,
}

enum Action {
    Ops(Fidelity),
    Idle,
    Fault,
    WorkerFault,
    Latency(Duration),
    Done,
}

/// Teardown net under the dispatcher thread: when the dispatcher exits
/// — normally (queue already closed and drained) or by unwinding — the
/// guard closes the queue, wakes blocked producers, and drains whatever
/// is still queued. Dropping the drained [`Work::Ops`] items errors
/// their tickets ([`OpsSub::drop`]), so a dispatcher death resolves
/// every outstanding submission instead of hanging its producers.
struct DispatchGuard {
    shared: Arc<QueueShared>,
}

impl Drop for DispatchGuard {
    fn drop(&mut self) {
        let drained: Vec<Work> = {
            let mut st = match self.shared.q.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            st.closed = true;
            st.queued_ops = 0;
            st.items.drain(..).collect()
        };
        // Ticket errors fire outside the queue lock.
        drop(drained);
        self.shared.space.notify_all();
        self.shared.work.notify_all();
    }
}

impl Dispatcher {
    fn run(mut self) {
        // Spawn the pool before the first submission arrives so the
        // O(workers) thread-spawn cost never lands inside a batch (and
        // never inside the sustained-throughput window).
        self.exec.run_region(|_| {});
        loop {
            let mut st = lock_unpoisoned(&self.shared.q);
            let action = loop {
                match st.items.front() {
                    Some(Work::Ops(s)) => break Action::Ops(s.tier),
                    Some(Work::Idle { .. }) => break Action::Idle,
                    Some(Work::Fault) => break Action::Fault,
                    Some(Work::WorkerFault) => break Action::WorkerFault,
                    Some(Work::Latency(d)) => break Action::Latency(*d),
                    None if st.closed => break Action::Done,
                    None => st = wait_unpoisoned(&self.shared.work, st),
                }
            };
            match action {
                Action::Done => {
                    drop(st);
                    break;
                }
                Action::Fault => {
                    // Pop before unwinding so the queue mutex is never
                    // poisoned; the DispatchGuard + OpsSub teardown then
                    // errors every outstanding ticket.
                    st.items.pop_front();
                    drop(st);
                    panic!("injected serve dispatcher fault");
                }
                Action::WorkerFault => {
                    st.items.pop_front();
                    drop(st);
                    self.force_worker_panic = true;
                }
                Action::Latency(d) => {
                    st.items.pop_front();
                    drop(st);
                    std::thread::sleep(d);
                }
                Action::Idle => {
                    // Merge consecutive idle phases into one gap.
                    let mut slots = 0u64;
                    loop {
                        let take = match st.items.front() {
                            Some(Work::Idle { slots: s }) => Some(*s),
                            _ => None,
                        };
                        match take {
                            Some(s) => {
                                slots += s;
                                st.items.pop_front();
                            }
                            None => break,
                        }
                    }
                    drop(st);
                    self.run_idle(slots);
                }
                Action::Ops(tier) => {
                    // Coalesce consecutive same-tier submissions up to
                    // the batch cap (the first one is admitted whatever
                    // its size, so oversized submissions still run).
                    let mut ops = 0usize;
                    loop {
                        let take = match st.items.front() {
                            Some(Work::Ops(s)) => {
                                s.tier == tier
                                    && (ops == 0
                                        || ops + s.triples.len() <= self.max_batch_ops)
                            }
                            _ => false,
                        };
                        if !take {
                            break;
                        }
                        let Some(Work::Ops(s)) = st.items.pop_front() else {
                            unreachable!("invariant: queue front was just matched as Work::Ops")
                        };
                        ops += s.triples.len();
                        st.queued_ops -= s.triples.len();
                        self.batch_items.push(s);
                    }
                    drop(st);
                    self.shared.space.notify_all();
                    self.run_ops_batch(tier);
                }
            }
        }
        let ring_coalesced = self.producer.close();
        let mut stats = lock_unpoisoned(&self.stats);
        stats.ring_coalesced = ring_coalesced;
        if let Some(ti) = self.cur_tier {
            self.tier_cal[ti] = (self.exec.chunk_hint(), self.exec.calibrated_ops());
        }
        stats.tier_cal = self.tier_cal;
    }

    /// Publish an idle gap as window-width idle windows (queue order —
    /// the master trace and the ring see the identical sequence).
    fn run_idle(&mut self, mut slots: u64) {
        let mut stats = lock_unpoisoned(&self.stats);
        let window = self.window_ops as u64;
        while slots > 0 {
            let take = slots.min(window);
            let w = ActivityWindow { slots: take, acc: ActivityAccumulator::default() };
            stats.master.push_window(w);
            self.producer.publish(w);
            slots -= take;
        }
        stats.ring_coalesced = self.producer.coalesced();
    }

    /// Execute one coalesced batch: map the submissions into zero-copy
    /// segments, run (stealing scheduler over the persistent pool),
    /// publish windows, cross-check, and complete every submission in
    /// it — result buffers move to their tickets whole, nothing is
    /// gathered or scattered.
    fn run_ops_batch(&mut self, tier: Fidelity) {
        let t_batch = Instant::now();
        // Map submissions onto the concatenated op index space. The
        // backing vectors stay in `batch_items`, untouched until the
        // completions below, so the raw pointers are stable.
        self.segs.clear();
        let mut n = 0usize;
        for s in &mut self.batch_items {
            let m = s.triples.len();
            if m == 0 {
                continue; // completes with empty bits; no segment
            }
            debug_assert_eq!(s.out.len(), m, "producer-allocated buffer is sized with the ops");
            self.segs.push(Segment {
                start: n,
                len: m,
                tri: SendConst(s.triples.as_ptr()),
                out: SendPtr(s.out.as_mut_ptr()),
            });
            n += m;
        }
        let window = self.window_ops.max(1);
        let n_windows = n.div_ceil(window);
        self.accs.clear();
        self.accs.resize(n_windows, ActivityAccumulator::default());

        let mut panicked = false;
        if n > 0 {
            let ti = tier_index(tier);
            // Per-tier calibration swap: one pool, per-tier chunk hints.
            if self.cur_tier != Some(ti) {
                if let Some(prev) = self.cur_tier {
                    self.tier_cal[prev] = (self.exec.chunk_hint(), self.exec.calibrated_ops());
                }
                let (chunk, cal) = self.tier_cal[ti];
                self.exec.seed_calibration(chunk, cal, calibration_key(tier));
                self.cur_tier = Some(ti);
                let mut stats = lock_unpoisoned(&self.stats);
                stats.tier_cal = self.tier_cal;
            }
            // The staleness rules, applied through the public API: a
            // hint calibrated on a much larger batch, or under another
            // tier/lane-kernel key, is dropped.
            if self.exec.calibrated_ops() != 0
                && (n.saturating_mul(RECAL_RATIO) < self.exec.calibrated_ops()
                    || self.exec.calibration_key() != calibration_key(tier))
            {
                self.exec.recalibrate();
            }
            let run = if std::mem::take(&mut self.force_worker_panic) {
                // Containment drill: drive a real panic through the same
                // pool path a lane-kernel bug would take.
                self.exec
                    .run_region_checked(|_| panic!("injected serve worker fault"))
            } else {
                self.execute_windows(ti, n, window, n_windows)
            };
            match run {
                Ok(()) => {
                    self.publish_windows(n, window, n_windows);
                    self.crosscheck(tier, n);
                }
                Err(_) => {
                    // Containment: the batch is discarded whole. Nothing
                    // was published, so the master trace, the ring, and
                    // the streamed-BB bit-identity contract only ever
                    // see completed batches; the partially-written
                    // result buffers die with their errored tickets.
                    panicked = true;
                }
            }
        }

        // Resolve every submission exactly once: on success its result
        // buffer moves to the ticket whole (`take` rather than a field
        // move — `OpsSub` has a `Drop` teardown for the dropped path);
        // on a contained worker panic it errors as `WorkerPanic`.
        let mut stats = lock_unpoisoned(&self.stats);
        if stats.first_batch.is_none() {
            stats.first_batch = Some(t_batch);
        }
        for mut sub in self.batch_items.drain(..) {
            let mut st = lock_unpoisoned(&sub.done.state);
            if panicked {
                st.err = Some(ServeError::WorkerPanic);
            } else {
                st.bits = Some(std::mem::take(&mut sub.out));
            }
            st.done = true;
            drop(st);
            sub.done.cv.notify_all();
            if panicked {
                stats.errored_submissions += 1;
            } else {
                let lat = sub.submitted.elapsed().as_secs_f64();
                stats.latencies.push(lat);
                stats.latency_ewma.observe(lat);
                stats.submissions += 1;
            }
        }
        if panicked {
            stats.failed_batches += 1;
        } else {
            stats.ops += n as u64;
            stats.batches += 1;
        }
        // Mirror the estimator once per batch (not per submission) so
        // the routing feedback stays a cheap relaxed store off the hot
        // completion loop.
        if let Some(v) = stats.latency_ewma.value() {
            self.feedback.publish_latency(v, stats.latency_ewma.count());
        }
        stats.busy_until = Some(Instant::now());
    }

    /// Run the batch's windows through the stealing scheduler (or
    /// serially under the engine's cutoff), each window computed whole by
    /// one worker so the trace is deterministic. A panicking kernel —
    /// on any pool worker, or on the dispatcher thread itself on the
    /// serial path — is contained into an `Err` so the caller can fail
    /// just this batch.
    fn execute_windows(
        &mut self,
        ti: usize,
        n: usize,
        window: usize,
        n_windows: usize,
    ) -> Result<(), WorkerPanicked> {
        let dp = &self.dps[ti];
        let segs = &self.segs[..];
        let accs = &mut self.accs[..n_windows];
        let workers = self.exec.workers();
        if workers <= 1 || n <= SERIAL_CUTOFF {
            return std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for (w, acc) in accs.iter_mut().enumerate() {
                    let lo = w * window;
                    let hi = ((w + 1) * window).min(n);
                    // SAFETY: the dispatcher is the only executor here and
                    // the segment vectors live in `batch_items`.
                    unsafe { exec_span(dp, segs, lo, hi, acc) };
                }
            }))
            .map_err(|_| WorkerPanicked { workers: 1 });
        }
        // One-shot per-tier calibration on the stealing path: time the
        // first few windows serially (their accumulators are final —
        // windows are computed whole either way) and persist the derived
        // chunk through the executor, same formula as the engine's own
        // calibration pass.
        let mut start_window = 0usize;
        if self.exec.chunk_hint() == 0 {
            let t0 = Instant::now();
            let done_ops = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut done_ops = 0usize;
                while done_ops < CALIBRATION_OPS && start_window < n_windows {
                    let lo = start_window * window;
                    let hi = ((start_window + 1) * window).min(n);
                    // SAFETY: no worker is running yet; exclusive access.
                    unsafe { exec_span(dp, segs, lo, hi, &mut accs[start_window]) };
                    done_ops += hi - lo;
                    start_window += 1;
                }
                done_ops
            }))
            .map_err(|_| WorkerPanicked { workers: 1 })?;
            let per_op = t0.elapsed().as_secs_f64() / done_ops.max(1) as f64;
            self.exec.seed_calibration(
                chunk_from_per_op(per_op),
                n,
                calibration_key(dp.fidelity()),
            );
        }
        if start_window >= n_windows {
            return Ok(());
        }
        let chunk_windows = (self.exec.chunk_hint() / window).max(1);
        self.queues.seed(start_window, n_windows, chunk_windows);
        let queues = &self.queues;
        let accs_ptr = SendPtr(accs.as_mut_ptr());
        self.exec.run_region_checked(|w| {
            while let Some((w0, w1)) = queues.next(w) {
                for win in w0..w1 {
                    let lo = win * window;
                    let hi = ((win + 1) * window).min(n);
                    // SAFETY: window `win` sits in a range claimed by
                    // exactly one `fetch_add` winner, so its output ops
                    // and accumulator slot are unaliased; the dispatcher
                    // keeps the submission buffers and `accs` alive
                    // until run_region_checked returns (pool barrier —
                    // held through panics too: a panicking worker still
                    // reports done before the barrier releases).
                    unsafe {
                        let acc = &mut *accs_ptr.0.add(win);
                        exec_span(dp, segs, lo, hi, acc);
                    }
                }
            }
        })
    }

    /// Publish the batch's windows, in window order, to both the master
    /// trace and the ring — the two sides of the bit-identity assert.
    fn publish_windows(&mut self, n: usize, window: usize, n_windows: usize) {
        let mut stats = lock_unpoisoned(&self.stats);
        for win in 0..n_windows {
            let lo = win * window;
            let hi = ((win + 1) * window).min(n);
            let w = ActivityWindow { slots: (hi - lo) as u64, acc: self.accs[win] };
            stats.master.push_window(w);
            self.producer.publish(w);
        }
        stats.ring_coalesced = self.producer.coalesced();
    }

    /// Sampled gate-level cross-check of the word tiers' results (the
    /// gate tier is the reference and reports no sampling). Sample
    /// indices are resolved through the segment map — by now the batch
    /// is complete, so the dispatcher reads the submissions' buffers
    /// directly.
    fn crosscheck(&mut self, tier: Fidelity, n: usize) {
        if self.crosscheck_every == 0 || tier == Fidelity::GateLevel {
            return;
        }
        let step = self.crosscheck_every;
        let mut sampled = 0u64;
        let mut mismatches = Vec::new();
        let mut si = 0usize;
        let mut i = 0usize;
        while i < n {
            while self.segs[si].start + self.segs[si].len <= i {
                si += 1;
            }
            let s = &self.segs[si];
            let off = i - s.start;
            // SAFETY: the region barrier has passed; the dispatcher is
            // the only thread touching the submission buffers now.
            let (t, got) = unsafe { (*s.tri.0.add(off), *s.out.0.add(off)) };
            if self.unit.fmac_one(t.a, t.b, t.c) != got {
                mismatches.push(i);
            }
            sampled += 1;
            i += step;
        }
        // Gate-level re-execution is expensive; the stats lock is taken
        // once per batch, after the sampling loop.
        let mut stats = lock_unpoisoned(&self.stats);
        let base = stats.master.total_ops() as usize - n;
        stats.crosscheck_sampled += sampled;
        stats.crosscheck_mismatches += mismatches.len() as u64;
        for i in mismatches {
            if stats.mismatch_indices.len() >= MISMATCH_CAP {
                break;
            }
            let idx = base + i;
            stats.mismatch_indices.push(idx);
        }
    }
}

/// Outcome of one serve run ([`ServeQueue::finish`]).
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Ops executed.
    pub ops: u64,
    /// Batches dispatched (after coalescing).
    pub batches: u64,
    /// Batches discarded whole by a contained worker panic (their ops
    /// are *not* in `ops` and their windows were never published).
    pub failed_batches: u64,
    /// Submissions completed.
    pub submissions: u64,
    /// Submissions resolved with an error instead of bits (worker
    /// panic containment; teardown-errored tickets are not counted here
    /// — their `OpsSub` never reached the dispatcher).
    pub errored_submissions: u64,
    /// Ops per second over the busy window (first batch start → last
    /// batch end). 0.0 when nothing ran.
    pub sustained_ops_per_s: f64,
    /// Submission latency percentiles, seconds (submit entry →
    /// completion, queue wait included). 0.0 when nothing ran.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Final latency-EWMA snapshot `(value_s, count)`, prior-incarnation
    /// observations included — the router's respawn path seeds the
    /// replacement shard's estimator from this so routing feedback is
    /// continuous across deaths. `None` if nothing ever completed.
    pub latency_ewma: Option<(f64, u64)>,
    /// Every completed submission's latency, seconds, sorted ascending —
    /// the raw distribution fleet-level reports merge before taking
    /// cross-shard percentiles.
    pub latencies_s: Vec<f64>,
    /// Busy-window endpoints (first batch start / last batch end) on the
    /// shared monotonic clock, so a fleet of shards can compute its
    /// union busy span. `None` when nothing ran.
    pub first_batch: Option<Instant>,
    pub busy_until: Option<Instant>,
    /// Sampled gate-level cross-check totals.
    pub crosscheck_sampled: u64,
    pub crosscheck_mismatches: u64,
    pub mismatch_indices: Vec<usize>,
    /// Windows merged by ring overflow (0 = the controller saw the
    /// pristine window sequence).
    pub ring_coalesced: u64,
    /// The live controller's outcome.
    pub streamed: StreamedBb,
    /// Post-hoc schedule/energy on the master trace — the comparison
    /// target of the bit-identity contract.
    pub posthoc_schedule: Vec<f64>,
    pub posthoc_energy: BbRunEnergy,
    /// Streamed schedule == post-hoc schedule on the master trace
    /// (guaranteed whenever `ring_coalesced == 0`).
    pub schedule_matches: bool,
    /// Streamed energies == post-hoc energies, bit for bit.
    pub energy_matches: bool,
    /// Streamed schedule == post-hoc schedule of the window sequence the
    /// controller actually received — holds under ANY interleaving,
    /// overflow included.
    pub received_schedule_matches: bool,
    /// No ops/activity dropped between engine and controller (holds
    /// overflow included).
    pub activity_preserved: bool,
    /// Occupancy of the master trace (ops / slots).
    pub occupancy: f64,
    /// The master trace itself (window sequence as published).
    pub master: ActivityTrace,
    /// Per-tier (chunk_hint, calibrated_ops) at the end of the run — the
    /// router's respawn path seeds a dead shard's replacement from this
    /// so a fresh incarnation skips cold calibration.
    pub(crate) tier_cal: [(usize, usize); 3],
}

impl ServeReport {
    /// The acceptance contract: clean cross-checks and a streamed
    /// controller bit-identical to the post-hoc pass.
    pub fn bb_consistent(&self) -> bool {
        self.schedule_matches && self.energy_matches && self.activity_preserved
    }

    /// The per-run hard gate, overflow-aware: on a pristine stream
    /// (`ring_coalesced == 0`) the streamed controller must be
    /// bit-identical to the post-hoc pass on the master trace; after
    /// overflow — the *documented* graceful degradation — it must still
    /// be exact on the window sequence it actually received and must
    /// not have dropped any accounting.
    pub fn bb_gate_ok(&self) -> bool {
        if self.ring_coalesced == 0 {
            self.bb_consistent()
        } else {
            self.received_schedule_matches && self.activity_preserved
        }
    }
}

/// The streaming serve queue (see the module docs). Construct with
/// [`ServeQueue::start`], submit through [`ServeQueue::handle`] clones
/// from any number of producer threads, then call [`ServeQueue::finish`]
/// to drain, join, and collect the [`ServeReport`].
pub struct ServeQueue {
    shared: Arc<QueueShared>,
    max_queue_ops: usize,
    dispatcher: std::thread::JoinHandle<()>,
    controller: std::thread::JoinHandle<(StreamedBb, Vec<ActivityWindow>, u64)>,
    /// The dispatcher's accounting, shared so it survives dispatcher
    /// death (see [`DispatchStats`]).
    stats: Arc<Mutex<DispatchStats>>,
    feedback: Arc<ShardFeedback>,
    unit: FpuUnit,
    tech: Technology,
    policy: BbPolicy,
    vdd: f64,
    window_ops: usize,
}

/// What [`ServeQueue::finish_salvaging`] recovers: the report (exact up
/// to the moment of death when `died`) plus whether the dispatcher died
/// before the queue was drained.
pub struct SalvagedRun {
    pub report: ServeReport,
    /// The dispatcher thread panicked (injected fault or real bug). The
    /// report covers everything it completed before dying; every
    /// then-outstanding ticket was errored by the teardown guard.
    pub died: bool,
}

impl ServeQueue {
    /// Spin up the serve loop for `unit`: the dispatcher (engine side,
    /// single ring producer) and the streaming body-bias controller
    /// (single ring consumer). Fails if the unit cannot operate at the
    /// configured voltage under the policy's active bias.
    pub fn start(unit: &FpuUnit, cfg: ServeConfig) -> crate::Result<ServeQueue> {
        let exec = BatchExecutor::new(cfg.workers);
        ServeQueue::start_with_executor(unit, cfg, exec)
    }

    /// [`ServeQueue::start_with_executor`] with a caller-owned
    /// [`ShardFeedback`] cell — the router's path: the cell belongs to
    /// the shard *slot* and outlives any one incarnation, so the
    /// dynamic routing policies keep their latency/energy signal
    /// across a respawn.
    pub fn start_with_feedback(
        unit: &FpuUnit,
        cfg: ServeConfig,
        exec: BatchExecutor,
        feedback: Arc<ShardFeedback>,
    ) -> crate::Result<ServeQueue> {
        ServeQueue::start_inner(unit, cfg, exec, feedback)
    }

    /// [`ServeQueue::start`] with a caller-provided executor — the shard
    /// path: the router sizes each shard's pool from one fleet-wide
    /// [`crate::arch::engine::ExecutorRegistry`] budget instead of
    /// letting every shard claim `cfg.workers` threads for itself. The
    /// executor is owned exclusively by this queue, which is what keeps
    /// chunk-size calibration per-shard (a gate-tier shard can never
    /// poison a word-tier sibling's hint).
    pub fn start_with_executor(
        unit: &FpuUnit,
        cfg: ServeConfig,
        exec: BatchExecutor,
    ) -> crate::Result<ServeQueue> {
        ServeQueue::start_inner(unit, cfg, exec, Arc::new(ShardFeedback::new()))
    }

    fn start_inner(
        unit: &FpuUnit,
        cfg: ServeConfig,
        exec: BatchExecutor,
        feedback: Arc<ShardFeedback>,
    ) -> crate::Result<ServeQueue> {
        anyhow::ensure!(cfg.window_ops >= 1, "window width must be at least 1 op");
        anyhow::ensure!(cfg.max_batch_ops >= 1, "batch cap must be at least 1 op");
        anyhow::ensure!(cfg.ring_windows >= 1, "ring needs at least one window slot");
        anyhow::ensure!(
            cfg.ewma_alpha > 0.0 && cfg.ewma_alpha <= 1.0,
            "latency EWMA alpha must be in (0, 1], got {}",
            cfg.ewma_alpha
        );
        let latency_ewma = match cfg.ewma_seed {
            Some((v, c)) => Ewma::seeded(cfg.ewma_alpha, v, c),
            None => Ewma::new(cfg.ewma_alpha),
        };
        // A seeded estimator is visible to routing immediately — a
        // respawned shard must not look "cold" (and thus maximally
        // attractive) while it warms back up.
        if let Some(v) = latency_ewma.value() {
            feedback.publish_latency(v, latency_ewma.count());
        }
        let tech = Technology::fdsoi28();
        let ctrl = StreamingController::new(unit, &tech, cfg.vdd, cfg.policy).ok_or_else(|| {
            anyhow::anyhow!(
                "unit not operable at vdd {} under the policy's active bias",
                cfg.vdd
            )
        })?;
        let (producer, mut consumer) = window_ring(cfg.ring_windows);
        let shared = Arc::new(QueueShared {
            q: Mutex::new(QueueState {
                items: VecDeque::new(),
                queued_ops: 0,
                closed: false,
            }),
            space: Condvar::new(),
            work: Condvar::new(),
            pressure: Arc::new(AtomicUsize::new(0)),
        });
        let ctrl_feedback = Arc::clone(&feedback);
        let controller = std::thread::Builder::new()
            .name("fpmax-serve-bb".to_string())
            .spawn(move || {
                let mut ctrl = ctrl;
                let mut received = Vec::new();
                let mut merged_in = 0u64;
                while let Some(e) = consumer.recv() {
                    received.push(e.window);
                    merged_in += (e.coalesced as u64).saturating_sub(1);
                    ctrl.push_window(&e.window);
                    // Live energy signal for the routing policies: one
                    // relaxed store per consumed window, charging any
                    // open gap conservatively (see
                    // [`StreamingController::live_pj_per_op`]).
                    ctrl_feedback.publish_live_pj(ctrl.live_pj_per_op());
                }
                (ctrl.finish(), received, merged_in)
            })?;
        let steal_workers = exec.workers().max(1);
        // If the caller pre-seeded the executor's calibration under a
        // tier's key (the router's respawn path replaying a dead
        // incarnation's hints), adopt it as that tier's starting hint so
        // the new incarnation skips cold calibration.
        let mut tier_cal = [(0usize, 0usize); 3];
        for (i, t) in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd]
            .into_iter()
            .enumerate()
        {
            if exec.calibration_key() == calibration_key(t) {
                tier_cal[i] = (exec.chunk_hint(), exec.calibrated_ops());
            }
        }
        let stats =
            Arc::new(Mutex::new(DispatchStats::new(cfg.window_ops, tier_cal, latency_ewma)));
        let dispatcher = Dispatcher {
            shared: Arc::clone(&shared),
            exec,
            dps: [
                UnitDatapath::new(unit, Fidelity::GateLevel),
                UnitDatapath::new(unit, Fidelity::WordLevel),
                UnitDatapath::new(unit, Fidelity::WordSimd),
            ],
            unit: unit.clone(),
            window_ops: cfg.window_ops,
            max_batch_ops: cfg.max_batch_ops,
            crosscheck_every: cfg.crosscheck_every,
            producer,
            tier_cal,
            cur_tier: None,
            force_worker_panic: false,
            batch_items: Vec::new(),
            segs: Vec::new(),
            accs: Vec::new(),
            queues: StealQueues::new(steal_workers),
            stats: Arc::clone(&stats),
            feedback: Arc::clone(&feedback),
        };
        let guard_shared = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("fpmax-serve-dispatch".to_string())
            .spawn(move || {
                // Runs at thread exit, normal or unwinding: closes the
                // queue and errors anything still queued, so a
                // dispatcher death never strands a producer.
                let _teardown = DispatchGuard { shared: guard_shared };
                dispatcher.run()
            })?;
        Ok(ServeQueue {
            shared,
            max_queue_ops: cfg.max_queue_ops,
            dispatcher,
            controller,
            stats,
            feedback,
            unit: unit.clone(),
            tech,
            policy: cfg.policy,
            vdd: cfg.vdd,
            window_ops: cfg.window_ops,
        })
    }

    /// A producer handle (clone freely across threads).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle { shared: Arc::clone(&self.shared) }
    }

    /// The shard's routing-feedback cell (the same `Arc` passed to
    /// [`ServeQueue::start_with_feedback`], or the queue's own for the
    /// plain start paths).
    pub fn feedback(&self) -> Arc<ShardFeedback> {
        Arc::clone(&self.feedback)
    }

    /// The backpressure bound handed to [`SubmitHandle::submit`].
    pub fn max_queue_ops(&self) -> usize {
        self.max_queue_ops
    }

    /// Convenience: submit through the queue's own bound.
    pub fn submit(&self, tier: Fidelity, triples: Vec<OperandTriple>) -> crate::Result<Ticket> {
        self.handle().submit(tier, triples, self.max_queue_ops)
    }

    /// Whether the dispatcher thread is still running. `false` during
    /// serving means it died (injected fault or real bug) — the signal
    /// the router's supervisor polls; after [`ServeQueue::finish`] has
    /// been called this is trivially `false`.
    pub fn dispatcher_alive(&self) -> bool {
        !self.dispatcher.is_finished()
    }

    /// Close the queue, drain everything still in flight, join both
    /// threads, and assemble the report — including the post-hoc
    /// bias-schedule and energy comparison on the master trace.
    ///
    /// Errors if the dispatcher died mid-run (the PR 5 contract: a dead
    /// shard is an error to its direct owner). Supervision code that
    /// wants the partial accounting instead uses
    /// [`ServeQueue::finish_salvaging`].
    pub fn finish(self) -> crate::Result<ServeReport> {
        let fin = self.finish_salvaging()?;
        if fin.died {
            return Err(anyhow::Error::new(ServeError::ShardFailed)
                .context("serve dispatcher panicked"));
        }
        Ok(fin.report)
    }

    /// [`ServeQueue::finish`] that survives a dead dispatcher: always
    /// recovers the [`ServeReport`] covering everything the dispatcher
    /// completed (exact ops, latencies, energy accounting, master trace
    /// — the dispatcher syncs its shared stats at every publish point),
    /// with `died` saying whether the run ended by death. The streamed
    /// BB gate holds for dead incarnations too: the ring closes when
    /// the dying dispatcher drops its producer handle, so the
    /// controller received exactly the published prefix.
    ///
    /// Errors only if report *assembly* fails (controller panicked,
    /// post-hoc energy not evaluable) — never because the dispatcher died.
    pub fn finish_salvaging(self) -> crate::Result<SalvagedRun> {
        {
            let mut st = lock_unpoisoned(&self.shared.q);
            st.closed = true;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        let died = self.dispatcher.join().is_err();
        let (streamed, received, _merged_in) = self
            .controller
            .join()
            .map_err(|_| anyhow::anyhow!("serve BB controller panicked"))?;
        // The dispatcher thread is gone, so this Arc is the last user
        // (fall back to a clone if a handle is somehow still alive).
        let d = match Arc::try_unwrap(self.stats) {
            Ok(m) => m.into_inner().unwrap_or_else(|p| p.into_inner()),
            Err(arc) => lock_unpoisoned(&arc).clone(),
        };

        let posthoc_schedule = window_bias_schedule(self.policy, &d.master);
        let posthoc_energy =
            run_energy_trace(&self.unit, &self.tech, self.vdd, self.policy, &d.master)
                .ok_or_else(|| anyhow::anyhow!("post-hoc energy not evaluable"))?;
        let received_trace = ActivityTrace::from_raw_windows(self.window_ops as u64, received);
        let received_schedule = window_bias_schedule(self.policy, &received_trace);

        let mut lat = d.latencies;
        lat.sort_by(|a, b| {
            a.partial_cmp(b).expect("invariant: submission latencies are never NaN")
        });
        let (p50, p99) = if lat.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&lat, 0.50), percentile(&lat, 0.99))
        };
        let busy_secs = match (d.first_batch, d.busy_until) {
            (Some(t0), Some(t1)) => t1.duration_since(t0).as_secs_f64(),
            _ => 0.0,
        };
        let master_agg = d.master.aggregate();
        let report = ServeReport {
            ops: d.ops,
            batches: d.batches,
            failed_batches: d.failed_batches,
            submissions: d.submissions,
            errored_submissions: d.errored_submissions,
            sustained_ops_per_s: if busy_secs > 0.0 { d.ops as f64 / busy_secs } else { 0.0 },
            p50_latency_s: p50,
            p99_latency_s: p99,
            latency_ewma: d.latency_ewma.value().map(|v| (v, d.latency_ewma.count())),
            latencies_s: lat,
            first_batch: d.first_batch,
            busy_until: d.busy_until,
            crosscheck_sampled: d.crosscheck_sampled,
            crosscheck_mismatches: d.crosscheck_mismatches,
            mismatch_indices: d.mismatch_indices,
            ring_coalesced: d.ring_coalesced,
            schedule_matches: streamed.schedule == posthoc_schedule,
            energy_matches: streamed.energy == posthoc_energy,
            received_schedule_matches: streamed.schedule == received_schedule,
            activity_preserved: streamed.aggregate == master_agg
                && streamed.ops == d.master.total_ops(),
            occupancy: d.master.occupancy(),
            posthoc_schedule,
            posthoc_energy,
            streamed,
            master: d.master,
            tier_cal: d.tier_cal,
        };
        Ok(SalvagedRun { report, died })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression (PR 7 satellite): the drop-path pressure decrement is
    /// saturating. A submission dropped on a fault path after its queue
    /// counter was already zeroed (teardown drain racing a respawn)
    /// must clamp the load signal at zero, not wrap to usize::MAX.
    #[test]
    fn pressure_decrement_saturates_instead_of_underflowing() {
        let pressure = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(Completion::default());
        let sub = OpsSub {
            tier: Fidelity::WordLevel,
            triples: vec![OperandTriple { a: 0, b: 0, c: 0 }; 7],
            out: vec![0u64; 7],
            done: Arc::clone(&done),
            submitted: Instant::now(),
            pressure: Arc::clone(&pressure),
        };
        // The counter holds fewer ops than the submission carries — the
        // unbalanced case a mid-dispatch fault can produce.
        pressure.store(3, Ordering::Relaxed);
        drop(sub);
        assert_eq!(pressure.load(Ordering::Relaxed), 0, "clamped, not wrapped");
        // The drop also errored the open ticket, typed.
        let err = Ticket { done }.wait().unwrap_err();
        assert_eq!(ServeError::classify(&err), Some(ServeError::ShardFailed));
    }

    /// The balanced case stays exact: drop removes exactly the
    /// submission's ops.
    #[test]
    fn pressure_decrement_balanced_path_is_exact() {
        let pressure = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(Completion::default());
        let sub = OpsSub {
            tier: Fidelity::WordLevel,
            triples: vec![OperandTriple { a: 0, b: 0, c: 0 }; 5],
            out: vec![0u64; 5],
            done,
            submitted: Instant::now(),
            pressure: Arc::clone(&pressure),
        };
        pressure.store(12, Ordering::Relaxed);
        drop(sub);
        assert_eq!(pressure.load(Ordering::Relaxed), 7);
    }

    /// The feedback cell's NaN sentinel separates "no signal yet" from
    /// any measured value, and the pre-first-op infinite pJ/op snapshot
    /// is filtered rather than leaking into cost scores.
    #[test]
    fn shard_feedback_distinguishes_cold_from_measured() {
        let f = ShardFeedback::new();
        assert_eq!(f.latency_ewma_s(), None);
        assert_eq!(f.ewma_count(), 0);
        assert_eq!(f.live_pj_per_op(), None);
        f.publish_latency(0.25e-3, 3);
        assert_eq!(f.latency_ewma_s(), Some(0.25e-3));
        assert_eq!(f.ewma_count(), 3);
        f.publish_live_pj(f64::INFINITY);
        assert_eq!(f.live_pj_per_op(), None, "no op executed yet means no energy signal");
        f.publish_live_pj(9.5);
        assert_eq!(f.live_pj_per_op(), Some(9.5));
    }

    #[test]
    fn serve_error_retryability_classification() {
        assert!(ServeError::ShardFailed.retryable());
        assert!(ServeError::WorkerPanic.retryable());
        assert!(ServeError::QueueClosed.retryable());
        // Admission denial enqueued nothing; retry-after-backoff is the
        // intended producer response to a saturated fleet.
        assert!(ServeError::AdmissionDenied.retryable());
        assert!(!ServeError::ResultTaken.retryable());
        assert!(!ServeError::DeadlineExceeded.retryable());
        // classify() walks context chains.
        let wrapped = anyhow::Error::new(ServeError::QueueClosed).context("submit failed");
        assert_eq!(ServeError::classify(&wrapped), Some(ServeError::QueueClosed));
        assert_eq!(ServeError::classify(&anyhow::anyhow!("unrelated")), None);
    }
}
