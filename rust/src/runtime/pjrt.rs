//! The real PJRT-backed runtime (`--features pjrt`).
//!
//! If this module fails to build with "can't find crate for `xla`", the
//! `pjrt` feature was enabled without its dependency: the feature pulls
//! in no crates by itself (the offline image cannot carry the
//! xla_extension native libraries), so `xla` must be added to
//! `[dependencies]` by hand — see the `[features]` notes in
//! `rust/Cargo.toml`.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py`:
//! serialized protos from jax ≥ 0.5 carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns them).

use std::path::{Path, PathBuf};

use crate::arch::fp::Precision;

use super::{parse_batch, FmacOutput};

/// A PJRT client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// One loaded FMAC artifact: a compiled executable with a fixed batch.
pub struct FmacArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Batch size baked into the artifact's shapes.
    pub batch: usize,
    pub precision: Precision,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<name>.hlo.txt` for the given precision.
    pub fn load_fmac(&self, name: &str, precision: Precision) -> crate::Result<FmacArtifact> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let batch = parse_batch(&text, precision)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: cannot find batch shape in HLO"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-UTF8 path"))?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(FmacArtifact { exe, batch, precision, name: name.to_string() })
    }
}

impl FmacArtifact {
    /// Execute the artifact over an arbitrary-length operand stream,
    /// chunking to the baked batch and padding the tail with zeros.
    pub fn fmac(&self, a: &[u64], b: &[u64], c: &[u64]) -> crate::Result<FmacOutput> {
        anyhow::ensure!(a.len() == b.len() && b.len() == c.len(), "operand length mismatch");
        let mut bits = Vec::with_capacity(a.len());
        let mut toggles = 0u64;
        for start in (0..a.len()).step_by(self.batch) {
            let end = (start + self.batch).min(a.len());
            let (chunk_bits, t) = self.run_chunk(&a[start..end], &b[start..end], &c[start..end])?;
            bits.extend_from_slice(&chunk_bits[..end - start]);
            toggles += t;
        }
        Ok(FmacOutput { bits, toggles })
    }

    fn run_chunk(&self, a: &[u64], b: &[u64], c: &[u64]) -> crate::Result<(Vec<u64>, u64)> {
        let (la, lb, lc) = match self.precision {
            Precision::Double => {
                (lit_u64(a, self.batch), lit_u64(b, self.batch), lit_u64(c, self.batch))
            }
            // Sub-64-bit storage rides in u32 literals (aot.py emits
            // u32 operand tensors for every non-DP format).
            _ => (lit_u32(a, self.batch), lit_u32(b, self.batch), lit_u32(c, self.batch)),
        };
        let result = self.exe.execute::<xla::Literal>(&[la, lb, lc]).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: (results, toggles).
        let (bits_lit, tog_lit) = out.to_tuple2().map_err(wrap)?;
        let bits = match self.precision {
            Precision::Double => bits_lit.to_vec::<u64>().map_err(wrap)?,
            _ => bits_lit
                .to_vec::<u32>()
                .map_err(wrap)?
                .into_iter()
                .map(|v| v as u64)
                .collect(),
        };
        let toggles = tog_lit.to_vec::<u64>().map_err(wrap)?;
        Ok((bits, toggles.first().copied().unwrap_or(0)))
    }
}

fn lit_u32(vals: &[u64], batch: usize) -> xla::Literal {
    let mut v: Vec<u32> = vals.iter().map(|&x| x as u32).collect();
    v.resize(batch, 0);
    xla::Literal::vec1(&v)
}

fn lit_u64(vals: &[u64], batch: usize) -> xla::Literal {
    let mut v = vals.to_vec();
    v.resize(batch, 0);
    xla::Literal::vec1(&v)
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}
