//! The deterministic chaos engine: seeded fault plans for the serve
//! fleet, and the report + hard gates a chaos run is judged by.
//!
//! A chaos run is the fleet's trust argument: the paper's numbers
//! assume the FPU computes through every duty-cycle regime, and a
//! production fleet must additionally compute through *failures* —
//! dead dispatchers, panicking lane kernels, overflowing window rings,
//! stalled shards, special-heavy operand storms. This module makes
//! those failures **reproducible**: a [`FaultPlan`] is derived from a
//! seed, so the same seed always yields the same typed fault sequence
//! at the same trigger points, in tests and in CI alike. A trigger is
//! either a fleet-wide submitted-op count or — so chaos drills compose
//! with trace replay instead of needing a second fault layer — a
//! replay-clock trace slot ([`FaultTrigger`]).
//!
//! The plan only *schedules* faults; firing them is the
//! [`crate::coordinator::serve_chaos`] harness's job (it owns the
//! router and the producer threads). The split keeps this module pure
//! and deterministic — no threads, no clocks — which is what makes
//! same-seed ⇒ same-plan trivially true.
//!
//! A run's outcome is a [`ChaosReport`] with four hard gates
//! ([`ChaosReport::gates_ok`]):
//!
//! 1. **Zero hung tickets** — every producer wait resolved within its
//!    deadline (a hang is the one failure mode retry cannot paper
//!    over).
//! 2. **Zero lost ops** — completed + errored ops equal submitted ops,
//!    at the producer side of the retry layer: every submission's fate
//!    is known.
//! 3. **Crosscheck clean on surviving work** — the sampled gate-level
//!    cross-check found zero mismatches across every incarnation that
//!    reported.
//! 4. **Conservation across incarnations** — the [`FleetReport`]'s
//!    fleet ops/energy/latency totals are the exact sum of every
//!    incarnation's (dead ones included), per
//!    [`FleetReport::conservation_ok`].
//!
//! plus the plan-coverage check that every scheduled fault actually
//! fired.

use crate::runtime::router::FleetReport;
use crate::util::Rng;

/// One typed fault. `shard` indexes the routed fleet's spec order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Kill the shard's dispatcher thread mid-queue
    /// ([`crate::runtime::serve::SubmitHandle::inject_fault`]): every
    /// outstanding ticket errors, the supervisor quarantines, salvages
    /// and respawns the shard.
    KillDispatcher { shard: usize },
    /// Panic the next batch's parallel region on the shard
    /// ([`crate::runtime::serve::SubmitHandle::inject_worker_panic`]):
    /// the batch's tickets error, the dispatcher and its pool survive.
    WorkerPanic { shard: usize },
    /// Force the shard's window ring to overflow by flooding it with
    /// `windows` windows' worth of idle slots faster than the
    /// controller drains — exercises the coalescing path and the
    /// overflow-aware BB gate under fault load.
    RingFlood { shard: usize, windows: u64 },
    /// Stall the shard's dispatcher for `micros` when the fault is
    /// reached — a degraded-shard drill for deadline and spill paths.
    Latency { shard: usize, micros: u64 },
    /// A special-heavy submission burst
    /// ([`crate::workloads::throughput::OperandMix::SpecialHeavy`]):
    /// `ops` ops of the class at `class_idx` (a
    /// [`crate::runtime::router::WorkloadClass::ALL`] index) routed
    /// normally — NaN/Inf/subnormal storms must flow through routing,
    /// serving and cross-checking like any other traffic.
    NanStorm { class_idx: usize, ops: usize },
}

impl FaultKind {
    /// Stable JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::KillDispatcher { .. } => "kill_dispatcher",
            FaultKind::WorkerPanic { .. } => "worker_panic",
            FaultKind::RingFlood { .. } => "ring_flood",
            FaultKind::Latency { .. } => "latency",
            FaultKind::NanStorm { .. } => "nan_storm",
        }
    }
}

/// When a [`ScheduledFault`] fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTrigger {
    /// Once the fleet-wide submitted-op counter reaches this — the
    /// classic chaos anchor, workload-shape independent.
    SubmittedOps(u64),
    /// Once the trace-replay clock reaches this trace slot — the anchor
    /// that composes with [`crate::runtime::trace`] replays: "kill the
    /// SP CMA shard at the diurnal trough" is a slot, not an op count.
    /// Only the replay harness advances a replay clock, so op-stream
    /// harnesses reject plans carrying these.
    TraceSlot(u64),
}

impl FaultTrigger {
    /// The trigger's scalar position on its own axis (plans never mix
    /// axes, so this is also the plan's sort key).
    pub fn at(self) -> u64 {
        match self {
            FaultTrigger::SubmittedOps(v) | FaultTrigger::TraceSlot(v) => v,
        }
    }

    /// Stable JSON name of the axis.
    pub fn axis(self) -> &'static str {
        match self {
            FaultTrigger::SubmittedOps(_) => "submitted_ops",
            FaultTrigger::TraceSlot(_) => "trace_slot",
        }
    }
}

/// A fault armed at a trigger point: it fires once its trigger's axis
/// (submitted-op counter, or the replay clock) reaches the armed value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduledFault {
    pub trigger: FaultTrigger,
    pub kind: FaultKind,
}

/// A seeded, ordered fault schedule. Same seed (and shape arguments)
/// ⇒ the same faults at the same trigger points, every time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Sorted by trigger point (ties keep construction order).
    pub faults: Vec<ScheduledFault>,
}

impl FaultPlan {
    /// The empty plan: a chaos run under it must be indistinguishable
    /// from a plain routed run — the no-fault bit-identity gate.
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: Vec::new() }
    }

    /// The acceptance-gate plan: kill every shard of the fleet exactly
    /// once, at seeded points spread across the middle of the op stream
    /// (10%–80% of `total_ops`, so every kill lands under live load —
    /// never before traffic starts or after it drains).
    pub fn kill_each_shard_once(seed: u64, shards: usize, total_ops: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xC4A0_5C4A_05C4_A05C);
        let lo = total_ops / 10;
        let span = (total_ops * 8 / 10).saturating_sub(lo).max(1);
        let mut faults: Vec<ScheduledFault> = (0..shards)
            .map(|shard| ScheduledFault {
                trigger: FaultTrigger::SubmittedOps(lo + rng.below(span)),
                kind: FaultKind::KillDispatcher { shard },
            })
            .collect();
        faults.sort_by_key(|f| f.trigger.at());
        FaultPlan { seed, faults }
    }

    /// The replay-composed variant of [`FaultPlan::kill_each_shard_once`]:
    /// every shard killed exactly once, anchored to seeded **trace
    /// slots** in the middle of the replay window (10%–80% of
    /// `total_slots`) instead of op counts — so a diurnal trace drives
    /// the load shape and the kill lands at a reproducible point of the
    /// day regardless of how many ops the duty cycle put there. Only
    /// [`crate::coordinator::serve_trace`] can fire these; op-stream
    /// harnesses reject the plan.
    pub fn kill_each_shard_once_at_slots(seed: u64, shards: usize, total_slots: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xC4A0_5C4A_05C4_A05C);
        let lo = total_slots / 10;
        let span = (total_slots * 8 / 10).saturating_sub(lo).max(1);
        let mut faults: Vec<ScheduledFault> = (0..shards)
            .map(|shard| ScheduledFault {
                trigger: FaultTrigger::TraceSlot(lo + rng.below(span)),
                kind: FaultKind::KillDispatcher { shard },
            })
            .collect();
        faults.sort_by_key(|f| f.trigger.at());
        FaultPlan { seed, faults }
    }

    /// The full drill: every shard killed once, plus one of each other
    /// fault kind at seeded points — the widest coverage a single run
    /// exercises. `classes` is the workload-class count (4 for the
    /// standard fleet).
    pub fn full_drill(seed: u64, shards: usize, classes: usize, total_ops: u64) -> FaultPlan {
        let mut plan = FaultPlan::kill_each_shard_once(seed, shards, total_ops);
        let mut rng = Rng::new(seed ^ 0xD511_D511_D511_D511);
        let lo = total_ops / 10;
        let span = (total_ops * 8 / 10).saturating_sub(lo).max(1);
        let shard = |rng: &mut Rng| rng.below(shards.max(1) as u64) as usize;
        let extra = [
            FaultKind::WorkerPanic { shard: shard(&mut rng) },
            FaultKind::RingFlood { shard: shard(&mut rng), windows: 8 + rng.below(8) },
            FaultKind::Latency { shard: shard(&mut rng), micros: 500 + rng.below(1500) },
            FaultKind::NanStorm {
                class_idx: rng.below(classes.max(1) as u64) as usize,
                ops: 256 + rng.below(256) as usize,
            },
        ];
        plan.faults.extend(extra.into_iter().map(|kind| ScheduledFault {
            trigger: FaultTrigger::SubmittedOps(lo + rng.below(span)),
            kind,
        }));
        plan.faults.sort_by_key(|f| f.trigger.at());
        plan
    }

    /// Kills scheduled in this plan (the respawn count a clean run must
    /// reach).
    pub fn kills(&self) -> usize {
        self.faults
            .iter()
            .filter(|f| matches!(f.kind, FaultKind::KillDispatcher { .. }))
            .count()
    }

    /// True if any fault is anchored to the replay clock
    /// ([`FaultTrigger::TraceSlot`]) — such a plan only makes sense
    /// under trace replay, and the op-stream chaos harness rejects it.
    pub fn needs_replay_clock(&self) -> bool {
        self.faults.iter().any(|f| matches!(f.trigger, FaultTrigger::TraceSlot(_)))
    }
}

/// Producer-side accounting from a chaos run, at the *logical
/// submission* level (above the retry layer): every submission ends in
/// exactly one of the three outcome columns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProducerStats {
    /// Logical submissions issued.
    pub submitted_subs: u64,
    /// … that delivered bits.
    pub completed_subs: u64,
    /// … that resolved with an error (retries exhausted or
    /// non-retryable).
    pub errored_subs: u64,
    /// … whose wait hit its deadline without resolving — the hung
    /// tickets. Must be zero.
    pub hung_subs: u64,
    /// Op-level versions of the same three columns.
    pub submitted_ops: u64,
    pub completed_ops: u64,
    pub errored_ops: u64,
    pub hung_ops: u64,
    /// Retry attempts beyond first tries, across all submissions.
    pub retries: u64,
    /// FNV-1a checksum per producer (producer-index order) over the
    /// result bits of its *completed* submissions, in submission order —
    /// the no-fault bit-identity witness.
    pub checksums: Vec<u64>,
}

impl ProducerStats {
    pub fn absorb(&mut self, other: &ProducerStats) {
        self.submitted_subs += other.submitted_subs;
        self.completed_subs += other.completed_subs;
        self.errored_subs += other.errored_subs;
        self.hung_subs += other.hung_subs;
        self.submitted_ops += other.submitted_ops;
        self.completed_ops += other.completed_ops;
        self.errored_ops += other.errored_ops;
        self.hung_ops += other.hung_ops;
        self.retries += other.retries;
        self.checksums.extend(other.checksums.iter().copied());
    }
}

/// FNV-1a fold step over one result-bit word — the chaos checksum
/// primitive (order-sensitive, cheap, dependency-free).
pub fn fnv1a_fold(h: u64, word: u64) -> u64 {
    let mut h = h;
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The FNV-1a offset basis — seed for [`fnv1a_fold`] chains.
pub const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Outcome of one chaos run: the plan, what actually fired, the
/// producer-side ledger, and the fleet's own merged report.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub seed: u64,
    pub tier_name: &'static str,
    pub shards: usize,
    /// Faults scheduled / actually fired (coverage gate: equal).
    pub faults_planned: usize,
    pub faults_fired: usize,
    /// Fired-fault counts by kind, JSON-stable order.
    pub kills: u64,
    pub worker_panics: u64,
    pub ring_floods: u64,
    pub latency_injections: u64,
    pub nan_storms: u64,
    pub producer: ProducerStats,
    /// Fleet totals pulled from the [`FleetReport`] (which holds the
    /// full per-shard, per-incarnation detail).
    pub respawns: u64,
    pub rerouted_on_failure: u64,
    pub fleet_ops: u64,
    pub crosscheck_sampled: u64,
    pub crosscheck_mismatches: u64,
    pub fleet_pj_per_op: f64,
    pub conservation_ok: bool,
    pub wall_secs: f64,
}

impl ChaosReport {
    /// Assemble from the harness's raw outputs.
    pub fn new(
        seed: u64,
        tier_name: &'static str,
        plan: &FaultPlan,
        fired: &[FaultKind],
        producer: ProducerStats,
        fleet: &FleetReport,
        wall_secs: f64,
    ) -> ChaosReport {
        let count = |pred: fn(&FaultKind) -> bool| fired.iter().filter(|k| pred(k)).count() as u64;
        ChaosReport {
            seed,
            tier_name,
            shards: fleet.shards.len(),
            faults_planned: plan.faults.len(),
            faults_fired: fired.len(),
            kills: count(|k| matches!(k, FaultKind::KillDispatcher { .. })),
            worker_panics: count(|k| matches!(k, FaultKind::WorkerPanic { .. })),
            ring_floods: count(|k| matches!(k, FaultKind::RingFlood { .. })),
            latency_injections: count(|k| matches!(k, FaultKind::Latency { .. })),
            nan_storms: count(|k| matches!(k, FaultKind::NanStorm { .. })),
            producer,
            respawns: fleet.respawns(),
            rerouted_on_failure: fleet.rerouted_on_failure,
            fleet_ops: fleet.ops,
            crosscheck_sampled: fleet.crosscheck_sampled(),
            crosscheck_mismatches: fleet.crosscheck_mismatches(),
            fleet_pj_per_op: fleet.fleet_energy.pj_per_op,
            conservation_ok: fleet.conservation_ok(),
            wall_secs,
        }
    }

    /// Gate 1: zero hung tickets.
    pub fn zero_hung(&self) -> bool {
        self.producer.hung_subs == 0 && self.producer.hung_ops == 0
    }

    /// Gate 2: zero lost ops — completed + errored == submitted, at
    /// both the submission and the op ledger.
    pub fn zero_lost(&self) -> bool {
        self.producer.completed_subs + self.producer.errored_subs + self.producer.hung_subs
            == self.producer.submitted_subs
            && self.producer.completed_ops + self.producer.errored_ops + self.producer.hung_ops
                == self.producer.submitted_ops
    }

    /// Gate 3: crosscheck clean on surviving work.
    pub fn crosscheck_clean(&self) -> bool {
        self.crosscheck_mismatches == 0
    }

    /// Gate 4: every scheduled fault fired.
    pub fn coverage_ok(&self) -> bool {
        self.faults_fired == self.faults_planned
    }

    /// All hard gates (including [`FleetReport::conservation_ok`],
    /// captured at construction).
    pub fn gates_ok(&self) -> bool {
        self.zero_hung()
            && self.zero_lost()
            && self.crosscheck_clean()
            && self.coverage_ok()
            && self.conservation_ok
    }

    /// The machine-readable artifact (manual JSON, like the benches —
    /// no serde in the dependency set). Schema documented in
    /// `docs/serving.md`.
    pub fn render_json(&self) -> String {
        let p = &self.producer;
        let checksums: Vec<String> =
            p.checksums.iter().map(|c| format!("\"{c:016x}\"")).collect();
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"chaos\",\n",
                "  \"measured\": true,\n",
                "  \"seed\": {seed},\n",
                "  \"tier\": \"{tier}\",\n",
                "  \"shards\": {shards},\n",
                "  \"wall_secs\": {wall:.3},\n",
                "  \"faults\": {{\n",
                "    \"planned\": {planned},\n",
                "    \"fired\": {fired},\n",
                "    \"kills\": {kills},\n",
                "    \"worker_panics\": {wp},\n",
                "    \"ring_floods\": {rf},\n",
                "    \"latency_injections\": {li},\n",
                "    \"nan_storms\": {ns}\n",
                "  }},\n",
                "  \"producer\": {{\n",
                "    \"submitted_subs\": {ssub},\n",
                "    \"completed_subs\": {csub},\n",
                "    \"errored_subs\": {esub},\n",
                "    \"hung_subs\": {hsub},\n",
                "    \"submitted_ops\": {sops},\n",
                "    \"completed_ops\": {cops},\n",
                "    \"errored_ops\": {eops},\n",
                "    \"hung_ops\": {hops},\n",
                "    \"retries\": {retries},\n",
                "    \"checksums\": [{checksums}]\n",
                "  }},\n",
                "  \"fleet\": {{\n",
                "    \"ops\": {fops},\n",
                "    \"respawns\": {respawns},\n",
                "    \"rerouted_on_failure\": {rerouted},\n",
                "    \"crosscheck_sampled\": {xs},\n",
                "    \"crosscheck_mismatches\": {xm},\n",
                "    \"pj_per_op\": {pj:.6}\n",
                "  }},\n",
                "  \"gates\": {{\n",
                "    \"zero_hung\": {g_hung},\n",
                "    \"zero_lost\": {g_lost},\n",
                "    \"crosscheck_clean\": {g_x},\n",
                "    \"coverage_ok\": {g_cov},\n",
                "    \"conservation_ok\": {g_cons},\n",
                "    \"all\": {g_all}\n",
                "  }}\n",
                "}}\n",
            ),
            seed = self.seed,
            tier = self.tier_name,
            shards = self.shards,
            wall = self.wall_secs,
            planned = self.faults_planned,
            fired = self.faults_fired,
            kills = self.kills,
            wp = self.worker_panics,
            rf = self.ring_floods,
            li = self.latency_injections,
            ns = self.nan_storms,
            ssub = p.submitted_subs,
            csub = p.completed_subs,
            esub = p.errored_subs,
            hsub = p.hung_subs,
            sops = p.submitted_ops,
            cops = p.completed_ops,
            eops = p.errored_ops,
            hops = p.hung_ops,
            retries = p.retries,
            checksums = checksums.join(", "),
            fops = self.fleet_ops,
            respawns = self.respawns,
            rerouted = self.rerouted_on_failure,
            xs = self.crosscheck_sampled,
            xm = self.crosscheck_mismatches,
            pj = self.fleet_pj_per_op,
            g_hung = self.zero_hung(),
            g_lost = self.zero_lost(),
            g_x = self.crosscheck_clean(),
            g_cov = self.coverage_ok(),
            g_cons = self.conservation_ok,
            g_all = self.gates_ok(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_plan() {
        let a = FaultPlan::kill_each_shard_once(42, 4, 100_000);
        let b = FaultPlan::kill_each_shard_once(42, 4, 100_000);
        assert_eq!(a, b);
        let c = FaultPlan::full_drill(42, 4, 4, 100_000);
        let d = FaultPlan::full_drill(42, 4, 4, 100_000);
        assert_eq!(c, d);
        // And a different seed genuinely moves the plan.
        let e = FaultPlan::kill_each_shard_once(43, 4, 100_000);
        assert_ne!(a.faults, e.faults);
    }

    #[test]
    fn kill_plan_covers_every_shard_once_inside_the_live_window() {
        let plan = FaultPlan::kill_each_shard_once(7, 4, 100_000);
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(plan.kills(), 4);
        let mut shards: Vec<usize> = plan
            .faults
            .iter()
            .map(|f| match f.kind {
                FaultKind::KillDispatcher { shard } => shard,
                other => panic!("unexpected fault {other:?}"),
            })
            .collect();
        shards.sort_unstable();
        assert_eq!(shards, vec![0, 1, 2, 3]);
        for f in &plan.faults {
            let FaultTrigger::SubmittedOps(at) = f.trigger else {
                panic!("op-anchored plan produced {:?}", f.trigger);
            };
            assert!(
                (10_000..90_000).contains(&at),
                "kill at {at} is outside the live window"
            );
        }
        // Sorted by trigger point.
        assert!(plan.faults.windows(2).all(|w| w[0].trigger.at() <= w[1].trigger.at()));
    }

    #[test]
    fn slot_anchored_plan_uses_the_replay_clock() {
        let plan = FaultPlan::kill_each_shard_once_at_slots(7, 4, 2_000);
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(plan.kills(), 4);
        assert!(plan.needs_replay_clock());
        for f in &plan.faults {
            let FaultTrigger::TraceSlot(slot) = f.trigger else {
                panic!("slot-anchored plan produced {:?}", f.trigger);
            };
            assert!((200..1_800).contains(&slot), "kill at slot {slot} outside live window");
            assert_eq!(f.trigger.axis(), "trace_slot");
        }
        assert!(plan.faults.windows(2).all(|w| w[0].trigger.at() <= w[1].trigger.at()));
        // Same seed ⇒ same plan, on this axis too.
        assert_eq!(plan, FaultPlan::kill_each_shard_once_at_slots(7, 4, 2_000));
        // And the op-anchored plans stay clock-free.
        assert!(!FaultPlan::kill_each_shard_once(7, 4, 100_000).needs_replay_clock());
        assert!(!FaultPlan::full_drill(7, 4, 4, 100_000).needs_replay_clock());
    }

    #[test]
    fn full_drill_schedules_every_fault_kind() {
        let plan = FaultPlan::full_drill(11, 4, 4, 50_000);
        assert_eq!(plan.faults.len(), 8); // 4 kills + one of each other kind
        for name in ["kill_dispatcher", "worker_panic", "ring_flood", "latency", "nan_storm"] {
            assert!(
                plan.faults.iter().any(|f| f.kind.name() == name),
                "missing {name}"
            );
        }
        assert_eq!(plan.kills(), 4);
    }

    #[test]
    fn fnv_checksum_is_order_sensitive() {
        let a = fnv1a_fold(fnv1a_fold(FNV_OFFSET, 1), 2);
        let b = fnv1a_fold(fnv1a_fold(FNV_OFFSET, 2), 1);
        assert_ne!(a, b);
        assert_eq!(a, fnv1a_fold(fnv1a_fold(FNV_OFFSET, 1), 2));
    }

    #[test]
    fn gates_read_the_ledger() {
        let mut p = ProducerStats::default();
        p.submitted_subs = 10;
        p.completed_subs = 8;
        p.errored_subs = 2;
        p.submitted_ops = 1000;
        p.completed_ops = 800;
        p.errored_ops = 200;
        let mk = |producer: ProducerStats, fired: usize| ChaosReport {
            seed: 1,
            tier_name: "word",
            shards: 4,
            faults_planned: 4,
            faults_fired: fired,
            kills: fired as u64,
            worker_panics: 0,
            ring_floods: 0,
            latency_injections: 0,
            nan_storms: 0,
            producer,
            respawns: fired as u64,
            rerouted_on_failure: 0,
            fleet_ops: 1000,
            crosscheck_sampled: 10,
            crosscheck_mismatches: 0,
            fleet_pj_per_op: 10.0,
            conservation_ok: true,
            wall_secs: 0.1,
        };
        let good = mk(p.clone(), 4);
        assert!(good.zero_hung() && good.zero_lost() && good.gates_ok());
        // A hung ticket fails gate 1 (and keeps the ledger balanced so
        // gate 2 isolates *loss*, not hangs).
        let mut hung = p.clone();
        hung.completed_subs = 7;
        hung.hung_subs = 1;
        hung.completed_ops = 700;
        hung.hung_ops = 100;
        let r = mk(hung, 4);
        assert!(!r.zero_hung() && r.zero_lost() && !r.gates_ok());
        // A lost op fails gate 2.
        let mut lost = p.clone();
        lost.completed_ops = 799;
        let r = mk(lost, 4);
        assert!(!r.zero_lost() && !r.gates_ok());
        // An unfired fault fails coverage.
        let r = mk(p, 3);
        assert!(!r.coverage_ok() && !r.gates_ok());
    }

    #[test]
    fn chaos_json_shape() {
        let report = ChaosReport {
            seed: 42,
            tier_name: "word",
            shards: 4,
            faults_planned: 4,
            faults_fired: 4,
            kills: 4,
            worker_panics: 0,
            ring_floods: 0,
            latency_injections: 0,
            nan_storms: 0,
            producer: ProducerStats {
                submitted_subs: 2,
                completed_subs: 2,
                submitted_ops: 100,
                completed_ops: 100,
                checksums: vec![0xdead_beef],
                ..ProducerStats::default()
            },
            respawns: 4,
            rerouted_on_failure: 3,
            fleet_ops: 100,
            crosscheck_sampled: 5,
            crosscheck_mismatches: 0,
            fleet_pj_per_op: 12.5,
            conservation_ok: true,
            wall_secs: 1.0,
        };
        let json = report.render_json();
        for needle in [
            "\"bench\": \"chaos\"",
            "\"measured\": true",
            "\"kills\": 4",
            "\"hung_subs\": 0",
            "\"retries\": 0",
            "\"conservation_ok\": true",
            "\"all\": true",
            "\"00000000deadbeef\"",
        ] {
            assert!(json.contains(needle), "missing {needle} in\n{json}");
        }
        // Balanced braces — the cheapest structural sanity check
        // available without a JSON parser on the Rust side.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}
