//! The sharded multi-unit serve router.
//!
//! FPMax's whole point is that there is no single best FPU: the chip
//! fabricates **four** units — latency-optimized CMA and
//! throughput-optimized FMA pipelines, in both SP and DP — and the right
//! one depends on the workload. One [`ServeQueue`] drives exactly one
//! unit; this module is the serving surface that drives the fleet:
//!
//! ```text
//!  producers                router                    shards
//!  ────────────┐   ┌─────────────────────┐   ┌──────────────────────┐
//!  submit(     │   │ WorkloadClass ──────┼──▶│ SP CMA  (latency)    │
//!    class,   ─┼──▶│   Table-1 affinity  │   │ SP FMA  (bulk)       │
//!    ops)      │   │ + load-aware spill  │   │ DP CMA  (latency)    │
//!  ────────────┘   │   (pressure probe)  │   │ DP FMA  (bulk)       │
//!                  │ + health-aware      │   └──────────────────────┘
//!                  │   failover          │      each: own ServeQueue,
//!                  └─────────────────────┘      own BatchExecutor pool,
//!                        ▲        │             own window ring + live
//!                        │ respawn│             bb::StreamingController
//!                  ┌─────┴────────▼─────┐
//!                  │     supervisor      │  (detects dead dispatchers,
//!                  └─────────────────────┘   salvages + respawns shards)
//! ```
//!
//! * A **shard** is one (unit preset × precision × fidelity tier)
//!   [`ServeQueue`]: its own persistent executor pool (sized from one
//!   fleet-wide [`ExecutorRegistry`] budget, so co-resident shards never
//!   oversubscribe the host), its own window ring, its own streaming
//!   body-bias controller, its own chunk-size calibration.
//! * Submissions carry a [`WorkloadClass`] — latency-sensitive vs
//!   bulk-throughput, SP vs DP — and the **static affinity policy** maps
//!   it per the paper's Table 1: latency classes to the CMA (cascade)
//!   pipelines, bulk classes to the FMA (fused) pipelines of the same
//!   precision.
//! * **Load-aware spill**: when the affinity shard's in-flight pressure
//!   crosses the configured threshold and a compatible sibling (same
//!   precision, same tier) is strictly less loaded, the submission
//!   spills there. A spilled submission is computed in the *receiving*
//!   unit's own Table-I semantics — fused and cascade round differently,
//!   exactly as on the real heterogeneous chip — so callers that need
//!   one fixed rounding semantics run with spill disabled. Either way
//!   the result is bit-exact for the unit that executed it, and the
//!   sampled gate cross-check rides along per shard.
//!
//! # Fault tolerance (PR 7)
//!
//! Each shard carries a health state machine — **Healthy → Degraded →
//! Quarantined** — driven by a supervisor thread:
//!
//! * A shard whose dispatcher died is **Quarantined**: the supervisor
//!   salvages its partial [`ServeReport`] (exact accounting up to the
//!   moment of death, via [`ServeQueue::finish_salvaging`]), records it
//!   as a *prior incarnation*, and respawns the shard as a fresh
//!   [`ServeQueue`] on a new executor with the same worker grant —
//!   re-seeded from the dead incarnation's chunk calibration under the
//!   shard's own [`calibration_key`], so the replacement skips cold
//!   calibration.
//! * A respawned shard is **Degraded** until a seeded probe submission
//!   round-trips through it; only then is it re-admitted to routing
//!   (probe-based re-admission). Quarantined/Degraded shards take no
//!   routed traffic: their would-be submissions divert through the same
//!   compatible-sibling machinery spill uses, counted separately as
//!   `rerouted_on_failure` (they are failovers, not policy violations —
//!   `misrouted` still means what it meant in a healthy fleet).
//! * [`ServeRouter::finish`] merges every incarnation: `FleetReport`
//!   ops / latency distributions / energy are exact sums across prior
//!   incarnations and the final one, so killing a shard mid-run loses
//!   no accounting ([`crate::bb::merge_run_energies`] over every
//!   incarnation's streamed energy). A fleet that saw **no** faults
//!   produces a report identical to the pre-supervision router: the
//!   supervisor is passive (it only polls thread liveness) until
//!   something actually dies.
//!
//! Producer-side resilience rides on top:
//! [`ServeRouter::submit_with_deadline`] bounds the wait on one
//! submission, and [`ServeRouter::submit_with_retry`] adds bounded
//! capped-exponential-backoff retry on retryable faults
//! ([`ServeError::retryable`]) — safe because ops are pure and a ticket
//! hands its result out exactly once, so a retried submission can never
//! alias or double-count (the abandoned attempt's ticket is simply
//! dropped; its completion slot dies with it).
//!
//! The per-class shard histogram is recorded per dispatch, so a report
//! can show that latency-class traffic measurably landed on
//! latency-optimized shards (`misrouted == 0` under the static policy
//! with no spill pressure).
//!
//! # Dynamic routing (PR 8)
//!
//! Placement is pluggable through [`RoutePolicy`]. The router surveys
//! the healthy candidates for each submission — in-flight pressure,
//! the shard's completed-latency EWMA, and the live streamed pJ/op its
//! [`crate::bb::StreamingController`] publishes through
//! [`ShardFeedback`] — and hands the survey to the policy:
//!
//! * [`StaticAffinity`] (the default) reproduces the Table-1 +
//!   spill/failover decision tree above, bit-for-bit; it stays the
//!   comparison baseline.
//! * [`EnergyAware`] scores every candidate by
//!   `w_lat·latency + w_pj·pJ/op + w_press·pressure (+ off-affinity
//!   penalty)` and takes the minimum — so a backlogged CMA shard spills
//!   its latency-class work onto the *more efficient* FMA pipeline
//!   instead of queueing, a degrading shard (rising EWMA) sheds load
//!   before its tail blows up, SLO-class admission control turns bulk
//!   work away at saturation ([`ServeError::AdmissionDenied`]), and at
//!   low fleet utilization idle phases are *parked* on one quiet shard
//!   per precision — consolidated long gaps are what the adaptive
//!   body-bias converts into the paper's ~2× low-activity recovery,
//!   where scattered short gaps would leak at the active level.
//!
//! Off-affinity placements an energy policy chooses deliberately are
//! counted as `policy_routed`, not `misrouted` — the latter keeps
//! meaning "static-policy violation" so its zero-gate stays meaningful.
//! Cross-kind placement computes in the receiving unit's own Table-I
//! rounding semantics, exactly like spill always has.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::arch::engine::{calibration_key, BatchExecutor, ExecutorRegistry, Fidelity};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use crate::bb::{merge_run_energies, BbRunEnergy};
use crate::runtime::serve::{
    ServeConfig, ServeError, ServeQueue, ServeReport, ShardFeedback, SubmitHandle, Ticket,
};
use crate::util::stats::percentile;
use crate::util::Rng;
use crate::workloads::throughput::{OperandMix, OperandStream, OperandTriple};

/// What a submission is optimized for — the paper's workload axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServiceClass {
    /// Latency-sensitive: dependent chains, short deadlines.
    Latency,
    /// Bulk throughput: abundant independent parallelism.
    Bulk,
}

impl ServiceClass {
    /// The Table 1 unit-affinity mapping: latency-sensitive work to the
    /// latency-optimized cascade (CMA) pipelines, bulk work to the
    /// throughput-optimized fused (FMA) pipelines.
    pub fn affinity_kind(self) -> FpuKind {
        match self {
            ServiceClass::Latency => FpuKind::Cma,
            ServiceClass::Bulk => FpuKind::Fma,
        }
    }
}

/// The workload taxonomy a submission declares: precision × service
/// class. The four SP/DP classes cover the paper's four fabricated
/// units; the transprecision tiers (FP16/BF16/FP8) extend the same
/// taxonomy to the full format fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadClass {
    pub precision: Precision,
    pub service: ServiceClass,
}

impl WorkloadClass {
    /// The four fabricated-unit classes, in [`WorkloadClass::index`]
    /// order — the default Table-1 fleet's taxonomy (SP/DP only).
    pub const ALL: [WorkloadClass; 4] = [
        WorkloadClass { precision: Precision::Single, service: ServiceClass::Latency },
        WorkloadClass { precision: Precision::Single, service: ServiceClass::Bulk },
        WorkloadClass { precision: Precision::Double, service: ServiceClass::Latency },
        WorkloadClass { precision: Precision::Double, service: ServiceClass::Bulk },
    ];

    /// Total distinct classes (every precision × both service classes).
    /// Histogram/count arrays are sized by this; the first four indices
    /// are the SP/DP classes of [`WorkloadClass::ALL`], unchanged.
    pub const COUNT: usize = Precision::ALL.len() * 2;

    /// Every class across the full format fleet, in index order.
    pub fn all_formats() -> [WorkloadClass; WorkloadClass::COUNT] {
        let mut out = [WorkloadClass::ALL[0]; WorkloadClass::COUNT];
        for (i, p) in Precision::ALL.into_iter().enumerate() {
            out[2 * i] = WorkloadClass { precision: p, service: ServiceClass::Latency };
            out[2 * i + 1] = WorkloadClass { precision: p, service: ServiceClass::Bulk };
        }
        out
    }

    /// Dense index in `0..COUNT` (histogram axis); SP/DP keep 0..4.
    pub fn index(self) -> usize {
        let p = match self.precision {
            Precision::Single => 0,
            Precision::Double => 1,
            Precision::Half => 2,
            Precision::Bfloat16 => 3,
            Precision::Fp8E4M3 => 4,
            Precision::Fp8E5M2 => 5,
        };
        let s = match self.service {
            ServiceClass::Latency => 0,
            ServiceClass::Bulk => 1,
        };
        p * 2 + s
    }

    pub fn name(self) -> &'static str {
        match (self.precision, self.service) {
            (Precision::Single, ServiceClass::Latency) => "sp-latency",
            (Precision::Single, ServiceClass::Bulk) => "sp-bulk",
            (Precision::Double, ServiceClass::Latency) => "dp-latency",
            (Precision::Double, ServiceClass::Bulk) => "dp-bulk",
            (Precision::Half, ServiceClass::Latency) => "fp16-latency",
            (Precision::Half, ServiceClass::Bulk) => "fp16-bulk",
            (Precision::Bfloat16, ServiceClass::Latency) => "bf16-latency",
            (Precision::Bfloat16, ServiceClass::Bulk) => "bf16-bulk",
            (Precision::Fp8E4M3, ServiceClass::Latency) => "fp8e4m3-latency",
            (Precision::Fp8E4M3, ServiceClass::Bulk) => "fp8e4m3-bulk",
            (Precision::Fp8E5M2, ServiceClass::Latency) => "fp8e5m2-latency",
            (Precision::Fp8E5M2, ServiceClass::Bulk) => "fp8e5m2-bulk",
        }
    }
}

/// One shard of the fleet: a unit preset served at one fidelity tier
/// under one [`ServeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    pub config: FpuConfig,
    pub tier: Fidelity,
    pub serve: ServeConfig,
}

/// A shard's health, as the supervisor sees it.
///
/// ```text
///  Healthy ──dispatcher died──▶ Quarantined ──respawned──▶ Degraded
///     ▲                                                        │
///     └────────────────── probe round-tripped ─────────────────┘
/// ```
///
/// Only Healthy shards take routed traffic; a class whose affinity
/// shard is Quarantined/Degraded fails over to a Healthy compatible
/// sibling (`rerouted_on_failure`), or — when no sibling serves the
/// class — gets a retryable [`ServeError::ShardFailed`] so producer
/// retry can outwait the respawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving; dispatcher alive.
    Healthy,
    /// Freshly respawned; awaiting probe-based re-admission.
    Degraded,
    /// Dispatcher dead; salvage + respawn pending (or respawn failed and
    /// will be retried).
    Quarantined,
}

const HEALTH_HEALTHY: u8 = 0;
const HEALTH_DEGRADED: u8 = 1;
const HEALTH_QUARANTINED: u8 = 2;

fn health_of(v: u8) -> ShardHealth {
    match v {
        HEALTH_HEALTHY => ShardHealth::Healthy,
        HEALTH_DEGRADED => ShardHealth::Degraded,
        _ => ShardHealth::Quarantined,
    }
}

/// Router-level policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Fleet-wide worker budget, portioned across the shard executors by
    /// an [`ExecutorRegistry`] (each shard's `serve.workers` request is
    /// clamped to what remains).
    pub workers_budget: usize,
    /// In-flight ops on the affinity shard above which a submission may
    /// spill to a strictly-less-loaded compatible sibling.
    /// `usize::MAX` disables spill — the pure static policy.
    pub spill_pressure_ops: usize,
    /// Run the supervisor (dead-dispatcher detection, salvage, respawn,
    /// probe re-admission). On by default; a no-fault run is unaffected
    /// either way — the supervisor only polls thread liveness until a
    /// dispatcher actually dies.
    pub supervise: bool,
    /// Supervisor liveness-poll interval.
    pub supervision_poll: Duration,
    /// Ops in the seeded probe submission a respawned shard must
    /// round-trip before re-admission.
    pub probe_ops: usize,
    /// How long one probe attempt waits before the supervisor re-probes
    /// on its next pass (the shard stays Degraded in between).
    pub probe_timeout: Duration,
}

impl RouterConfig {
    /// Static affinity only, no spill; supervision on.
    pub fn no_spill(workers_budget: usize) -> RouterConfig {
        RouterConfig {
            workers_budget,
            spill_pressure_ops: usize::MAX,
            supervise: true,
            supervision_poll: Duration::from_micros(500),
            probe_ops: 64,
            probe_timeout: Duration::from_secs(10),
        }
    }

    /// Affinity with load-aware spill above `pressure_ops` in-flight ops.
    pub fn with_spill(workers_budget: usize, pressure_ops: usize) -> RouterConfig {
        RouterConfig { spill_pressure_ops: pressure_ops, ..RouterConfig::no_spill(workers_budget) }
    }

    /// Disable the supervisor — the pre-PR-7 router: a dead shard stays
    /// dead, and [`ServeRouter::finish`] errors on it.
    pub fn without_supervision(mut self) -> RouterConfig {
        self.supervise = false;
        self
    }
}

/// Bounded retry with capped exponential backoff, for
/// [`ServeRouter::submit_with_retry`]. Attempt `k` (0-based) sleeps
/// `min(base_backoff · 2^k, max_backoff)` before retrying.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries *after* the first attempt (0 = fail fast).
    pub max_retries: u32,
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl RetryPolicy {
    /// No retries — the plain submit path with deadline support.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
        }
    }

    /// `retries` retries starting at `base` backoff, capped at `cap`.
    pub fn bounded(retries: u32, base: Duration, cap: Duration) -> RetryPolicy {
        RetryPolicy { max_retries: retries, base_backoff: base, max_backoff: cap }
    }

    fn backoff(self, attempt: u32) -> Duration {
        let mult = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base_backoff.saturating_mul(mult).min(self.max_backoff)
    }

    /// Deterministically-jittered backoff: the capped exponential delay
    /// for `attempt`, scaled by a factor in `[0.5, 1.0)` derived purely
    /// from `(seed, attempt)` — desynchronizing colliding retriers like
    /// wall-clock jitter would, but reproducing bit-identically on
    /// replay. The same `(policy, seed)` always yields the same backoff
    /// sequence, which is what lets trace replays and chaos runs pin
    /// their retry timing.
    pub fn backoff_jittered(self, attempt: u32, seed: u64) -> Duration {
        let base = self.backoff(attempt);
        // One SplitMix64 draw keyed by (seed, attempt): stateless, so
        // retry loops need not thread an Rng through.
        let mut rng = Rng::new(seed ^ ((u64::from(attempt) + 1) << 17));
        base.mul_f64(0.5 + 0.5 * rng.f64())
    }
}

/// Outcome of a resilient submission ([`ServeRouter::submit_with_retry`]).
#[derive(Debug, Clone)]
pub struct SubmitOutcome {
    /// The shard whose result was delivered (the last attempt's shard).
    pub shard: usize,
    /// Result bits, one per submitted triple, in submission order.
    pub bits: Vec<u64>,
    /// Attempts beyond the first that were needed.
    pub retries: u32,
}

/// Where a dispatch decision landed. Returned by [`RoutePolicy::place`];
/// the router's fleet counters are keyed off it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// The class's affinity shard.
    Affinity,
    /// Diverted off-affinity by backlog pressure (the static policy's
    /// spill rule).
    Spill,
    /// No affinity shard exists for the class at this tier; any
    /// compatible shard took it.
    Fallback,
    /// Diverted off the (existing) affinity shard because it is
    /// quarantined or awaiting probe re-admission.
    Failover,
    /// A dynamic policy chose an off-affinity shard on its cost score
    /// while the affinity shard was healthy and available — deliberate
    /// placement, counted as `policy_routed`, never `misrouted`.
    Policy,
}

/// One healthy shard's routing survey, as a [`RoutePolicy`] sees it:
/// identity, load, and the two feedback signals the shard publishes
/// through its [`ShardFeedback`] cell.
#[derive(Debug, Clone, Copy)]
pub struct RouteCandidate {
    /// Fleet slot index ([`ServeRouter`] shard order).
    pub shard: usize,
    /// The unit's pipeline kind (CMA = latency-optimized cascade,
    /// FMA = throughput/efficiency-optimized fused).
    pub kind: FpuKind,
    /// This shard is the submission class's Table-1 affinity kind.
    pub affinity: bool,
    /// In-flight ops (queued or mid-batch) at survey time.
    pub pressure: usize,
    /// The shard's backpressure bound — normalizes `pressure`.
    pub max_queue_ops: usize,
    /// Completed-submission latency EWMA, seconds; `None` before the
    /// shard (or any prior incarnation) completed anything.
    pub ewma_latency_s: Option<f64>,
    /// Live streamed pJ/op as of the shard controller's last consumed
    /// window; `None` before the first op's window landed.
    pub live_pj_per_op: Option<f64>,
}

/// Fleet-scope context shared by every candidate in one placement
/// decision.
#[derive(Debug, Clone, Copy)]
pub struct RouteContext {
    /// The router's spill threshold (the static policy's divert
    /// trigger; `usize::MAX` = spill disabled).
    pub spill_pressure_ops: usize,
    /// The class's affinity shard exists but is quarantined or awaiting
    /// probe re-admission (so an off-affinity pick is a failover, not a
    /// policy choice).
    pub unhealthy_affinity: bool,
    /// Fleet-wide in-flight ops over fleet-wide queue capacity across
    /// every *healthy* shard — the utilization signal for the
    /// low-activity re-bias rule. In `[0, 1]`-ish (pressure can
    /// transiently exceed a queue's bound by one submission).
    pub fleet_utilization: f64,
}

/// A pluggable placement policy. The router surveys the healthy
/// candidates matching a submission's precision and tier (never empty —
/// empty surveys error before the policy is consulted) and the policy
/// picks one.
///
/// Policies must be deterministic functions of their inputs: routing
/// under load is inherently timing-dependent (pressure and feedback
/// move), but a policy that added its own entropy would make even the
/// trace-replay invariants (per-class op conservation, ledger totals)
/// unreproducible.
pub trait RoutePolicy: Send + Sync {
    /// Short stable name, recorded in the [`FleetReport`] and the bench
    /// artifacts.
    fn name(&self) -> &'static str;

    /// Choose among `candidates` (at least one): returns an index
    /// **into `candidates`** plus the placement label to account the
    /// dispatch under. `Err` refuses the submission — admission
    /// control; wrap a [`ServeError`] so producers can classify it.
    fn place(
        &self,
        class: WorkloadClass,
        candidates: &[RouteCandidate],
        ctx: &RouteContext,
    ) -> crate::Result<(usize, Placement)>;

    /// Which candidate absorbs an idle phase for `class`. `None` (the
    /// default) keeps the static rule — idle lands on the class's
    /// affinity shard. A dynamic policy may consolidate fleet idle onto
    /// one quiet shard per precision at low utilization: long
    /// contiguous gaps are what the adaptive body-bias recovers ~2×
    /// from, where the same slots scattered across shards leak at the
    /// active level.
    fn place_idle(
        &self,
        _class: WorkloadClass,
        _candidates: &[RouteCandidate],
        _ctx: &RouteContext,
    ) -> Option<usize> {
        None
    }

    /// True if the policy never *chooses* to cross pipeline kinds
    /// (FMA↔CMA) while the affinity shard is healthy and unpressured.
    /// Cross-kind placement changes result bits (fused vs cascade
    /// rounding), so the trace-replay digest includes per-tenant result
    /// checksums only when the run's policy is kind-preserving *and*
    /// spill is disabled *and* no faults were planned. Default `false`
    /// (the conservative direction for the digest).
    fn kind_preserving(&self) -> bool {
        false
    }
}

/// The default policy: the paper's Table-1 affinity with load-aware
/// spill and health failover — the exact decision tree the router used
/// before policies were pluggable, preserved bit-for-bit (first
/// strict-minimum tie-break in shard order included). The comparison
/// baseline every dynamic policy is judged against.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticAffinity;

impl RoutePolicy for StaticAffinity {
    fn name(&self) -> &'static str {
        "static"
    }

    fn place(
        &self,
        _class: WorkloadClass,
        candidates: &[RouteCandidate],
        ctx: &RouteContext,
    ) -> crate::Result<(usize, Placement)> {
        // (candidate index, pressure), first strict minimum wins —
        // identical tie-break to the pre-policy router.
        let mut preferred: Option<(usize, usize)> = None;
        let mut alt: Option<(usize, usize)> = None;
        for (ci, c) in candidates.iter().enumerate() {
            let slot = if c.affinity { &mut preferred } else { &mut alt };
            let better = match *slot {
                None => true,
                Some((_, best)) => c.pressure < best,
            };
            if better {
                *slot = Some((ci, c.pressure));
            }
        }
        Ok(match (preferred, alt) {
            (Some((_, pp)), Some((a, ap))) if pp > ctx.spill_pressure_ops && ap < pp => {
                (a, Placement::Spill)
            }
            (Some((p, _)), _) => (p, Placement::Affinity),
            (None, Some((a, _))) if ctx.unhealthy_affinity => (a, Placement::Failover),
            (None, Some((a, _))) => (a, Placement::Fallback),
            (None, None) => unreachable!("place() is never called with an empty survey"),
        })
    }

    fn kind_preserving(&self) -> bool {
        // Affinity placement never crosses kinds by choice; spill and
        // fallback only occur under spill pressure / missing shards,
        // which the replay digest conditions exclude separately.
        true
    }
}

/// The energy-aware feedback policy (ROADMAP item 4): each submission
/// goes to the candidate minimizing
///
/// ```text
/// w_latency · (EWMA / best EWMA)  +  w_energy · (pJ/op / best pJ/op)
///   +  w_pressure · (pressure / max_queue_ops)
///   +  off_affinity_penalty  (iff not the class's Table-1 kind)
/// ```
///
/// Feedback terms a candidate has not produced yet score neutral (1.0),
/// so a cold fleet behaves like pressure-balanced affinity. The penalty
/// keeps ties on the Table-1 shard when the fleet is quiet — which is
/// what holds the uniform routed bench within 1% of [`StaticAffinity`]
/// — while under skewed load the pressure and energy terms overcome it
/// and latency-class work spills onto the *more efficient* fused
/// pipelines instead of queueing on the cascade shard.
#[derive(Debug, Clone, Copy)]
pub struct EnergyAware {
    /// Weight of the normalized latency-EWMA term.
    pub w_latency: f64,
    /// Weight of the normalized live-pJ/op term.
    pub w_energy: f64,
    /// Weight of the pressure (queue-fill fraction) term.
    pub w_pressure: f64,
    /// Flat score penalty for leaving the class's affinity kind
    /// (cross-kind placement changes rounding semantics — worth paying
    /// under load, not for free).
    pub off_affinity_penalty: f64,
    /// SLO-class admission control: refuse a *bulk* submission when
    /// every candidate is over this many in-flight ops, keeping queue
    /// room for the latency SLO class. `usize::MAX` disables.
    pub admit_pressure_ops: usize,
    /// Fleet-utilization threshold for the low-activity re-bias rule:
    /// below it, idle phases are parked on the precision's CMA shard
    /// (the quiet one under this policy) instead of scattering.
    pub park_below_utilization: f64,
}

impl EnergyAware {
    /// Balanced nominal weights: pressure dominates (it is the
    /// congestion signal), latency and energy weigh equally, and a
    /// quarter-point affinity penalty keeps the quiet-fleet behavior on
    /// Table 1. Admission control off.
    pub fn nominal() -> EnergyAware {
        EnergyAware {
            w_latency: 1.0,
            w_energy: 1.0,
            w_pressure: 4.0,
            off_affinity_penalty: 0.25,
            admit_pressure_ops: usize::MAX,
            park_below_utilization: 0.10,
        }
    }

    /// Enable bulk admission control above `ops` in-flight ops per
    /// candidate.
    pub fn with_admission(mut self, ops: usize) -> EnergyAware {
        self.admit_pressure_ops = ops;
        self
    }
}

impl RoutePolicy for EnergyAware {
    fn name(&self) -> &'static str {
        "energy-aware"
    }

    fn place(
        &self,
        class: WorkloadClass,
        candidates: &[RouteCandidate],
        ctx: &RouteContext,
    ) -> crate::Result<(usize, Placement)> {
        // Admission control first: bulk is the best-effort class; when
        // every candidate is saturated, refusing it (retryable) keeps
        // queue room for the latency SLO class instead of letting bulk
        // backlog inflate everyone's tail.
        if class.service == ServiceClass::Bulk
            && self.admit_pressure_ops != usize::MAX
            && candidates.iter().all(|c| c.pressure > self.admit_pressure_ops)
        {
            return Err(anyhow::Error::new(ServeError::AdmissionDenied).context(format!(
                "bulk admission refused: every {} candidate above {} in-flight ops",
                class.name(),
                self.admit_pressure_ops
            )));
        }
        // Normalize the feedback terms by the best candidate so the
        // score is scale-free; a candidate without a signal yet scores
        // neutral rather than free.
        let lat_floor = candidates
            .iter()
            .filter_map(|c| c.ewma_latency_s)
            .fold(f64::INFINITY, f64::min);
        let pj_floor = candidates
            .iter()
            .filter_map(|c| c.live_pj_per_op)
            .fold(f64::INFINITY, f64::min);
        let mut best: Option<(usize, f64)> = None;
        let mut any_affinity = false;
        for (ci, c) in candidates.iter().enumerate() {
            any_affinity |= c.affinity;
            let lat = match c.ewma_latency_s {
                Some(v) if lat_floor.is_finite() => v / lat_floor.max(1e-300),
                _ => 1.0,
            };
            let pj = match c.live_pj_per_op {
                Some(v) if pj_floor.is_finite() => v / pj_floor.max(1e-300),
                _ => 1.0,
            };
            let fill = c.pressure as f64 / c.max_queue_ops.max(1) as f64;
            let mut score =
                self.w_latency * lat + self.w_energy * pj + self.w_pressure * fill;
            if !c.affinity {
                score += self.off_affinity_penalty;
            }
            let better = match best {
                None => true,
                Some((_, b)) => score < b,
            };
            if better {
                best = Some((ci, score));
            }
        }
        let (ci, _) = best.expect("place() is never called with an empty survey");
        let placement = if candidates[ci].affinity {
            Placement::Affinity
        } else if any_affinity {
            Placement::Policy
        } else if ctx.unhealthy_affinity {
            Placement::Failover
        } else {
            Placement::Fallback
        };
        Ok((ci, placement))
    }

    fn place_idle(
        &self,
        _class: WorkloadClass,
        candidates: &[RouteCandidate],
        ctx: &RouteContext,
    ) -> Option<usize> {
        if ctx.fleet_utilization >= self.park_below_utilization {
            return None;
        }
        // Park on the precision's CMA shard: this policy pushes loaded
        // latency work toward the efficient FMA pipes, so the cascade
        // shard is the quiet one — consolidating every idle phase there
        // turns scattered short gaps (which leak at the active level)
        // into the long contiguous gaps the adaptive controller's idle
        // bias actually recovers from.
        candidates
            .iter()
            .position(|c| c.kind == FpuKind::Cma)
            .or(Some(0))
    }
}

/// The mutable part of a shard slot: swapped whole on respawn, behind a
/// read-mostly lock (routing takes read; only the supervisor writes).
struct ShardRuntime {
    /// `None` only transiently while the supervisor swaps incarnations.
    queue: Option<ServeQueue>,
    handle: SubmitHandle,
    /// Completed reports of dead incarnations, oldest first — merged
    /// into the shard's fleet accounting at finish.
    prior: Vec<ServeReport>,
}

/// One fleet slot: immutable identity + the respawnable runtime.
struct ShardSlot {
    config: FpuConfig,
    tier: Fidelity,
    /// Workers granted by the fleet registry at start; every respawned
    /// incarnation reuses exactly this grant (the dead executor's pool
    /// threads are joined before the new one spawns, so the fleet never
    /// exceeds its budget).
    workers: usize,
    max_queue_ops: usize,
    /// The spec's serve config with `workers` clamped to the grant —
    /// what a respawn boots the replacement queue from.
    serve: ServeConfig,
    rt: RwLock<ShardRuntime>,
    /// The slot's routing-feedback cell — owned here, not by the queue,
    /// so the latency/energy signal survives incarnation swaps (every
    /// respawn publishes into the same cell).
    feedback: Arc<ShardFeedback>,
    health: AtomicU8,
    /// Submissions landed here, by [`WorkloadClass::index`].
    class_counts: [AtomicU64; WorkloadClass::COUNT],
    /// Submissions that arrived here via spill.
    spilled_in: AtomicU64,
    /// Submissions whose affinity was this shard but were diverted to a
    /// sibling because this shard was quarantined/degraded.
    rerouted_on_failure: AtomicU64,
    /// Incarnations spawned beyond the first.
    respawns: AtomicU64,
}

fn read_rt(slot: &ShardSlot) -> std::sync::RwLockReadGuard<'_, ShardRuntime> {
    slot.rt.read().unwrap_or_else(|p| p.into_inner())
}

fn write_rt(slot: &ShardSlot) -> std::sync::RwLockWriteGuard<'_, ShardRuntime> {
    slot.rt.write().unwrap_or_else(|p| p.into_inner())
}

fn serve_tier_index(tier: Fidelity) -> usize {
    match tier {
        Fidelity::GateLevel => 0,
        Fidelity::WordLevel => 1,
        Fidelity::WordSimd => 2,
    }
}

/// The fleet dispatcher (see the module docs). Construct with
/// [`ServeRouter::start`], submit classified work from any number of
/// producer threads, then [`ServeRouter::finish`] to drain every shard
/// and assemble the [`FleetReport`].
pub struct ServeRouter {
    slots: Arc<Vec<ShardSlot>>,
    spill_pressure_ops: usize,
    policy: Arc<dyn RoutePolicy>,
    submissions: AtomicU64,
    spilled: AtomicU64,
    misrouted: AtomicU64,
    rerouted_on_failure: AtomicU64,
    policy_routed: AtomicU64,
    admission_denied: AtomicU64,
    supervisor: Option<Supervisor>,
}

struct Supervisor {
    handle: std::thread::JoinHandle<()>,
    stop: Arc<AtomicBool>,
}

impl ServeRouter {
    /// The paper's Table 1 fleet at one fidelity tier: all four
    /// fabricated units, each at its own nominal operating point, the
    /// worker budget split fairly four ways. Tweak the returned specs
    /// (window, ring, per-shard workers) before [`ServeRouter::start`]
    /// if the defaults don't fit.
    pub fn fleet_nominal(
        tier: Fidelity,
        adaptive: bool,
        workers_budget: usize,
        window_ops: usize,
        ring_windows: usize,
    ) -> crate::Result<Vec<ShardSpec>> {
        // Split the budget without discarding the remainder: the first
        // `budget % 4` shards get one extra worker, so the whole budget
        // the registry portions is actually requested.
        let base = workers_budget / 4;
        let rem = workers_budget % 4;
        FpuConfig::fpmax_units()
            .into_iter()
            .enumerate()
            .map(|(i, config)| {
                let mut serve = ServeConfig::nominal(&config, adaptive)?;
                serve.workers = (base + usize::from(i < rem)).max(1);
                serve.window_ops = window_ops;
                serve.ring_windows = ring_windows;
                Ok(ShardSpec { config, tier, serve })
            })
            .collect()
    }

    /// Spin up one [`ServeQueue`] per spec, pools sized through a shared
    /// [`ExecutorRegistry`] over `cfg.workers_budget`, plus (by default)
    /// the supervisor thread that keeps the fleet serving through shard
    /// deaths.
    pub fn start(specs: &[ShardSpec], cfg: RouterConfig) -> crate::Result<ServeRouter> {
        ServeRouter::start_with_policy(specs, cfg, Arc::new(StaticAffinity))
    }

    /// [`ServeRouter::start`] with an explicit [`RoutePolicy`] — the
    /// dynamic-routing entry point. [`StaticAffinity`] here is exactly
    /// `start` (and the baseline any other policy is compared against).
    pub fn start_with_policy(
        specs: &[ShardSpec],
        cfg: RouterConfig,
        policy: Arc<dyn RoutePolicy>,
    ) -> crate::Result<ServeRouter> {
        anyhow::ensure!(!specs.is_empty(), "a router needs at least one shard");
        let registry = ExecutorRegistry::new(cfg.workers_budget);
        let mut slots: Vec<ShardSlot> = Vec::with_capacity(specs.len());
        for spec in specs {
            let exec = registry.shard(spec.serve.workers);
            let workers = exec.workers();
            let unit = FpuUnit::generate(&spec.config);
            let feedback = Arc::new(ShardFeedback::new());
            let queue = match ServeQueue::start_with_feedback(
                &unit,
                spec.serve,
                exec,
                Arc::clone(&feedback),
            ) {
                Ok(q) => q,
                Err(e) => {
                    // Close the shards already started before bailing —
                    // a dropped ServeQueue is never shut down, so
                    // propagating here directly would strand their
                    // dispatcher/controller/pool threads forever.
                    for s in slots {
                        if let Some(q) = write_rt(&s).queue.take() {
                            let _ = q.finish();
                        }
                    }
                    return Err(e.context(format!(
                        "starting shard {} at the {} tier",
                        spec.config.name(),
                        spec.tier.name()
                    )));
                }
            };
            let mut serve = spec.serve;
            serve.workers = workers;
            slots.push(ShardSlot {
                config: spec.config,
                tier: spec.tier,
                workers,
                max_queue_ops: spec.serve.max_queue_ops,
                serve,
                rt: RwLock::new(ShardRuntime {
                    handle: queue.handle(),
                    queue: Some(queue),
                    prior: Vec::new(),
                }),
                feedback,
                health: AtomicU8::new(HEALTH_HEALTHY),
                class_counts: Default::default(),
                spilled_in: AtomicU64::new(0),
                rerouted_on_failure: AtomicU64::new(0),
                respawns: AtomicU64::new(0),
            });
        }
        let slots = Arc::new(slots);
        let supervisor = if cfg.supervise {
            let stop = Arc::new(AtomicBool::new(false));
            let handle = {
                let slots = Arc::clone(&slots);
                let stop = Arc::clone(&stop);
                std::thread::Builder::new()
                    .name("fpmax-fleet-supervisor".to_string())
                    .spawn(move || supervise(&slots, &stop, cfg))?
            };
            Some(Supervisor { handle, stop })
        } else {
            None
        };
        Ok(ServeRouter {
            slots,
            spill_pressure_ops: cfg.spill_pressure_ops,
            policy,
            submissions: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            misrouted: AtomicU64::new(0),
            rerouted_on_failure: AtomicU64::new(0),
            policy_routed: AtomicU64::new(0),
            admission_denied: AtomicU64::new(0),
            supervisor,
        })
    }

    /// Shard count.
    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    /// In-flight pressure of shard `idx` (ops submitted, not yet
    /// resolved) — the current incarnation's.
    pub fn shard_pressure(&self, idx: usize) -> usize {
        read_rt(&self.slots[idx]).handle.pressure_ops()
    }

    /// Shard `idx`'s health as last set by the supervisor (always
    /// Healthy when supervision is off).
    pub fn shard_health(&self, idx: usize) -> ShardHealth {
        health_of(self.slots[idx].health.load(Ordering::Relaxed))
    }

    /// Respawned incarnations of shard `idx` so far.
    pub fn shard_respawns(&self, idx: usize) -> u64 {
        self.slots[idx].respawns.load(Ordering::Relaxed)
    }

    /// Shard `idx`'s routing-feedback cell (latency EWMA + live pJ/op)
    /// — the slot's persistent cell, continuous across incarnations.
    pub fn shard_feedback(&self, idx: usize) -> Arc<ShardFeedback> {
        Arc::clone(&self.slots[idx].feedback)
    }

    /// Admissions refused so far by the policy's admission control.
    pub fn admission_denied_count(&self) -> u64 {
        self.admission_denied.load(Ordering::Relaxed)
    }

    /// Shard `idx`'s window size in ops (the chaos ring-flood fault
    /// sizes its idle burst in windows, not raw slots).
    pub fn shard_window_ops(&self, idx: usize) -> usize {
        self.slots[idx].serve.window_ops
    }

    /// Survey the fleet for one placement decision: the healthy
    /// candidates matching the class precision and tier (slot order —
    /// policies' first-minimum tie-breaks key off it), the fleet
    /// context, and whether *any* shard (healthy or not) serves the
    /// class at all.
    fn survey(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
    ) -> (Vec<RouteCandidate>, RouteContext, bool) {
        let mut candidates = Vec::new();
        let mut unhealthy_affinity = false;
        let mut any_match = false;
        let mut fleet_pressure = 0usize;
        let mut fleet_capacity = 0usize;
        for (i, s) in self.slots.iter().enumerate() {
            let matches = s.config.precision == class.precision && s.tier == tier;
            any_match |= matches;
            let affinity = matches && s.config.kind == class.service.affinity_kind();
            if s.health.load(Ordering::Relaxed) != HEALTH_HEALTHY {
                unhealthy_affinity |= affinity;
                continue;
            }
            let pressure = read_rt(s).handle.pressure_ops();
            fleet_pressure += pressure;
            fleet_capacity += s.max_queue_ops;
            if matches {
                candidates.push(RouteCandidate {
                    shard: i,
                    kind: s.config.kind,
                    affinity,
                    pressure,
                    max_queue_ops: s.max_queue_ops,
                    ewma_latency_s: s.feedback.latency_ewma_s(),
                    live_pj_per_op: s.feedback.live_pj_per_op(),
                });
            }
        }
        let ctx = RouteContext {
            spill_pressure_ops: self.spill_pressure_ops,
            unhealthy_affinity,
            fleet_utilization: if fleet_capacity > 0 {
                fleet_pressure as f64 / fleet_capacity as f64
            } else {
                0.0
            },
        };
        (candidates, ctx, any_match)
    }

    /// The dispatch decision, read-only: the configured [`RoutePolicy`]
    /// picks among the **healthy** shards matching the class precision
    /// and the requested tier (under [`StaticAffinity`]: the affinity
    /// shard, least-loaded if several, unless spill pressure diverts to
    /// a strictly-less-loaded compatible sibling, with failover off an
    /// unhealthy affinity shard). If *no* healthy candidate serves the
    /// class, the error is a retryable [`ServeError::ShardFailed`] so
    /// producer retry can outwait a respawn in flight.
    fn route(&self, class: WorkloadClass, tier: Fidelity) -> crate::Result<(usize, Placement)> {
        let (candidates, ctx, any_match) = self.survey(class, tier);
        if candidates.is_empty() {
            if any_match {
                return Err(anyhow::Error::new(ServeError::ShardFailed).context(format!(
                    "every shard serving {} at the {} tier is quarantined or degraded",
                    class.name(),
                    tier.name()
                )));
            }
            anyhow::bail!("no shard serves {} at the {} tier", class.name(), tier.name());
        }
        let (ci, placement) = self.policy.place(class, &candidates, &ctx)?;
        Ok((candidates[ci].shard, placement))
    }

    /// Dispatch one classified submission; returns the shard index it
    /// landed on and the completion ticket. The operands must be in the
    /// class's precision (each shard computes its own unit's Table-I
    /// semantics on them, bit-exactly, wherever the submission lands).
    pub fn submit(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: Vec<OperandTriple>,
    ) -> crate::Result<(usize, Ticket)> {
        let (idx, placement) = match self.route(class, tier) {
            Ok(v) => v,
            Err(e) => {
                if ServeError::classify(&e) == Some(ServeError::AdmissionDenied) {
                    self.admission_denied.fetch_add(1, Ordering::Relaxed);
                }
                return Err(e);
            }
        };
        let slot = &self.slots[idx];
        // Dispatch first, count after: a submission the shard rejected
        // (closed queue, dead dispatcher) must not skew the histogram or
        // the misrouted/spilled counters the acceptance gates read —
        // and a retry must not double-count.
        let handle = read_rt(slot).handle.clone();
        let ticket = handle.submit(tier, triples, slot.max_queue_ops)?;
        self.submissions.fetch_add(1, Ordering::Relaxed);
        slot.class_counts[class.index()].fetch_add(1, Ordering::Relaxed);
        match placement {
            Placement::Affinity => {}
            Placement::Spill => {
                self.spilled.fetch_add(1, Ordering::Relaxed);
                self.misrouted.fetch_add(1, Ordering::Relaxed);
                slot.spilled_in.fetch_add(1, Ordering::Relaxed);
            }
            Placement::Fallback => {
                self.misrouted.fetch_add(1, Ordering::Relaxed);
            }
            Placement::Policy => {
                // Deliberate off-affinity placement by a dynamic policy:
                // its own axis — `misrouted` keeps meaning "static-policy
                // violation" so the existing zero-gates stay meaningful.
                self.policy_routed.fetch_add(1, Ordering::Relaxed);
                slot.spilled_in.fetch_add(1, Ordering::Relaxed);
            }
            Placement::Failover => {
                // A failover is not a policy violation — the policy shard
                // is down — so it is counted on its own axis, charged to
                // the shard that *should* have taken the work.
                self.rerouted_on_failure.fetch_add(1, Ordering::Relaxed);
                slot.spilled_in.fetch_add(1, Ordering::Relaxed);
                for s in self.slots.iter() {
                    if s.config.precision == class.precision
                        && s.tier == tier
                        && s.config.kind == class.service.affinity_kind()
                    {
                        s.rerouted_on_failure.fetch_add(1, Ordering::Relaxed);
                        break;
                    }
                }
            }
        }
        Ok((idx, ticket))
    }

    /// [`ServeRouter::submit`] + a bounded wait: `Ok` with the result
    /// bits if the submission completes within `deadline`, otherwise a
    /// non-retryable [`ServeError::DeadlineExceeded`]. An abandoned
    /// submission still executes (ops are pure; its ticket is dropped
    /// and the result dies with it) — the deadline bounds the
    /// *producer's* wait, it does not cancel queued work.
    pub fn submit_with_deadline(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: Vec<OperandTriple>,
        deadline: Duration,
    ) -> crate::Result<(usize, Vec<u64>)> {
        let (idx, ticket) = self.submit(class, tier, triples)?;
        match ticket.wait_timeout(deadline)? {
            Some(bits) => Ok((idx, bits)),
            None => Err(anyhow::Error::new(ServeError::DeadlineExceeded)),
        }
    }

    /// Resilient submission: submit, wait (bounded by `deadline` when
    /// given), and retry per `policy` — capped exponential backoff —
    /// while the failure is a retryable serve fault
    /// ([`ServeError::retryable`]: shard died, worker panicked, queue
    /// closed under the submission). Deadline misses and caller bugs are
    /// never retried.
    ///
    /// Exactly-once delivery is preserved across retries: each attempt
    /// is an independent submission whose ticket hands its result out
    /// once; a failed attempt's ticket resolved to an error (never
    /// bits), so at most one attempt's bits are ever returned.
    pub fn submit_with_retry(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: &[OperandTriple],
        deadline: Option<Duration>,
        policy: RetryPolicy,
    ) -> crate::Result<SubmitOutcome> {
        self.submit_retry_inner(class, tier, triples, deadline, policy, None)
    }

    /// [`ServeRouter::submit_with_retry`] with deterministically-seeded
    /// backoff jitter ([`RetryPolicy::backoff_jittered`]): colliding
    /// retriers desynchronize, but the same `(seed, attempt)` always
    /// sleeps the same duration — the trace-replay and chaos paths use
    /// this so a replayed run reproduces its retry timing decisions
    /// instead of deriving jitter from the wall clock.
    pub fn submit_with_retry_seeded(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: &[OperandTriple],
        deadline: Option<Duration>,
        policy: RetryPolicy,
        seed: u64,
    ) -> crate::Result<SubmitOutcome> {
        self.submit_retry_inner(class, tier, triples, deadline, policy, Some(seed))
    }

    fn submit_retry_inner(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: &[OperandTriple],
        deadline: Option<Duration>,
        policy: RetryPolicy,
        seed: Option<u64>,
    ) -> crate::Result<SubmitOutcome> {
        let mut attempt = 0u32;
        loop {
            let r: crate::Result<(usize, Vec<u64>)> = (|| {
                let (idx, ticket) = self.submit(class, tier, triples.to_vec())?;
                match deadline {
                    None => Ok((idx, ticket.wait()?)),
                    Some(d) => match ticket.wait_timeout(d)? {
                        Some(bits) => Ok((idx, bits)),
                        None => Err(anyhow::Error::new(ServeError::DeadlineExceeded)),
                    },
                }
            })();
            match r {
                Ok((shard, bits)) => {
                    return Ok(SubmitOutcome { shard, bits, retries: attempt })
                }
                Err(e) => {
                    let retryable =
                        ServeError::classify(&e).map(ServeError::retryable).unwrap_or(false);
                    if !retryable || attempt >= policy.max_retries {
                        return Err(e.context(format!(
                            "submission failed after {attempt} retr{}",
                            if attempt == 1 { "y" } else { "ies" }
                        )));
                    }
                    let backoff = match seed {
                        Some(s) => policy.backoff_jittered(attempt, s),
                        None => policy.backoff(attempt),
                    };
                    std::thread::sleep(backoff);
                    attempt += 1;
                }
            }
        }
    }

    /// Dispatch an idle phase (accounting-only issue slots). Under the
    /// static policy idle goes to the class's affinity shard — it is
    /// the shard's own low-utilization gap, the thing its adaptive
    /// controller re-biases through. A dynamic policy may override via
    /// [`RoutePolicy::place_idle`] (e.g. [`EnergyAware`] parks fleet
    /// idle on one quiet shard per precision at low utilization, so the
    /// gaps consolidate into spans the idle bias actually recovers
    /// from). Returns the shard index. Idle submitted while the target
    /// shard is down is dropped with a retryable error (an idle gap on
    /// a dead shard is not accounting anyone needs).
    pub fn submit_idle(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        slots: u64,
    ) -> crate::Result<usize> {
        let (candidates, ctx, _) = self.survey(class, tier);
        if !candidates.is_empty() {
            if let Some(ci) = self.policy.place_idle(class, &candidates, &ctx) {
                let idx = candidates[ci.min(candidates.len() - 1)].shard;
                let handle = read_rt(&self.slots[idx]).handle.clone();
                handle.submit_idle(slots)?;
                return Ok(idx);
            }
        }
        // Pure affinity: ignore pressure entirely.
        let mut pick = None;
        for (i, s) in self.slots.iter().enumerate() {
            if s.config.precision != class.precision || s.tier != tier {
                continue;
            }
            if s.config.kind == class.service.affinity_kind() {
                pick = Some(i);
                break;
            }
            pick.get_or_insert(i);
        }
        let idx = pick.ok_or_else(|| {
            anyhow::anyhow!("no shard serves {} at the {} tier", class.name(), tier.name())
        })?;
        let handle = read_rt(&self.slots[idx]).handle.clone();
        handle.submit_idle(slots)?;
        Ok(idx)
    }

    /// A producer handle onto shard `idx`'s current incarnation — test
    /// and chaos hook (fault injection wants a specific shard, not a
    /// routing decision).
    pub fn shard_handle(&self, idx: usize) -> SubmitHandle {
        read_rt(&self.slots[idx]).handle.clone()
    }

    /// Close every shard, drain, join, and assemble the fleet report.
    /// Shard order in the report matches the spec order given to
    /// [`ServeRouter::start`].
    ///
    /// Accounting is merged **across incarnations**: a shard that died
    /// and was respawned contributes every incarnation's ops, latencies
    /// and streamed energy to the fleet totals (exact sums — nothing a
    /// dead incarnation completed is lost). A shard that is dead *at
    /// finish time* with supervision off errors, exactly as before
    /// supervision existed.
    pub fn finish(self) -> crate::Result<FleetReport> {
        // Stop the supervisor first so no respawn races the teardown.
        if let Some(sup) = self.supervisor {
            sup.stop.store(true, Ordering::Relaxed);
            let _ = sup.handle.join();
        }
        let spilled = self.spilled.load(Ordering::Relaxed);
        let misrouted = self.misrouted.load(Ordering::Relaxed);
        let submissions = self.submissions.load(Ordering::Relaxed);
        let rerouted_on_failure = self.rerouted_on_failure.load(Ordering::Relaxed);
        let policy_routed = self.policy_routed.load(Ordering::Relaxed);
        let admission_denied = self.admission_denied.load(Ordering::Relaxed);
        let policy_name = self.policy.name();
        let slots = Arc::try_unwrap(self.slots).map_err(|_| {
            anyhow::anyhow!("invariant: supervisor joined but the shard table is still shared")
        })?;
        // Finish EVERY shard before propagating any error: each finish()
        // closes that shard's queue and joins its dispatcher/controller
        // threads, so bailing on the first failure would leak the
        // siblings' threads for the life of the process.
        let mut first_err: Option<anyhow::Error> = None;
        let mut shards = Vec::with_capacity(slots.len());
        for s in slots {
            let rt = s.rt.into_inner().unwrap_or_else(|p| p.into_inner());
            let final_report = match rt.queue {
                Some(q) => match q.finish() {
                    Ok(report) => Some(report),
                    Err(e) => {
                        let e =
                            e.context(format!("shard {} failed to finish", s.config.name()));
                        first_err.get_or_insert(e);
                        None
                    }
                },
                // Dead at finish with the respawn incomplete: the prior
                // incarnations were salvaged, but the shard has no live
                // incarnation to report — surface it instead of quietly
                // under-reporting the fleet.
                None => {
                    first_err.get_or_insert(
                        anyhow::Error::new(ServeError::ShardFailed).context(format!(
                            "shard {} was down at finish with its respawn incomplete",
                            s.config.name()
                        )),
                    );
                    None
                }
            };
            if let Some(report) = final_report {
                shards.push(ShardReport {
                    unit: s.config.name(),
                    config: s.config,
                    tier: s.tier,
                    workers: s.workers,
                    class_counts: s.class_counts.map(|c| c.into_inner()),
                    spilled_in: s.spilled_in.into_inner(),
                    rerouted_on_failure: s.rerouted_on_failure.into_inner(),
                    respawns: s.respawns.into_inner(),
                    health: health_of(s.health.into_inner()),
                    prior: rt.prior,
                    report,
                });
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let ops = shards.iter().map(ShardReport::total_ops).sum();
        // Fleet latency distribution: every incarnation's (sorted)
        // latencies merged, then re-sorted once.
        let mut latencies: Vec<f64> = shards
            .iter()
            .flat_map(|s| {
                s.report
                    .latencies_s
                    .iter()
                    .chain(s.prior.iter().flat_map(|p| p.latencies_s.iter()))
                    .copied()
            })
            .collect();
        latencies.sort_by(|a, b| {
            a.partial_cmp(b).expect("invariant: submission latencies are never NaN")
        });
        let (p50, p99) = if latencies.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&latencies, 0.50), percentile(&latencies, 0.99))
        };
        // Union busy span on the shared monotonic clock, incarnations
        // included.
        let first = shards
            .iter()
            .flat_map(|s| {
                s.report.first_batch.into_iter().chain(s.prior.iter().filter_map(|p| p.first_batch))
            })
            .min();
        let last = shards
            .iter()
            .flat_map(|s| {
                s.report.busy_until.into_iter().chain(s.prior.iter().filter_map(|p| p.busy_until))
            })
            .max();
        let busy_secs = match (first, last) {
            (Some(t0), Some(t1)) => t1.duration_since(t0).as_secs_f64(),
            _ => 0.0,
        };
        let energy = merge_run_energies(shards.iter().flat_map(|s| {
            s.prior
                .iter()
                .map(|p| &p.streamed.energy)
                .chain(std::iter::once(&s.report.streamed.energy))
        }));
        Ok(FleetReport {
            spilled,
            misrouted,
            rerouted_on_failure,
            policy_routed,
            admission_denied,
            policy_name,
            submissions,
            ops,
            fleet_energy: energy,
            fleet_p50_latency_s: p50,
            fleet_p99_latency_s: p99,
            fleet_busy_secs: busy_secs,
            fleet_sustained_ops_per_s: if busy_secs > 0.0 { ops as f64 / busy_secs } else { 0.0 },
            shards,
        })
    }
}

/// The supervisor loop: poll every shard's dispatcher liveness; on a
/// death, quarantine → salvage the incarnation's accounting → respawn
/// on the same worker grant (calibration re-seeded from the salvage) →
/// probe → re-admit. Runs until `stop` is set by
/// [`ServeRouter::finish`].
fn supervise(slots: &[ShardSlot], stop: &AtomicBool, cfg: RouterConfig) {
    while !stop.load(Ordering::Relaxed) {
        for slot in slots {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match health_of(slot.health.load(Ordering::Relaxed)) {
                ShardHealth::Healthy | ShardHealth::Quarantined => {
                    // 0 = alive, 1 = dead (salvage + respawn),
                    // 2 = no incarnation at all (a previous respawn
                    // failed to boot — retry it).
                    let state = {
                        let rt = read_rt(slot);
                        match rt.queue.as_ref() {
                            Some(q) if q.dispatcher_alive() => 0u8,
                            Some(_) => 1,
                            None => 2,
                        }
                    };
                    match state {
                        1 => {
                            slot.health.store(HEALTH_QUARANTINED, Ordering::Relaxed);
                            respawn(slot);
                        }
                        2 => {
                            let mut rt = write_rt(slot);
                            if rt.queue.is_none() {
                                let cal = rt.prior.last().map(|p| p.tier_cal);
                                boot(slot, &mut rt, cal);
                            }
                        }
                        _ => {}
                    }
                }
                ShardHealth::Degraded => probe(slot, &cfg),
            }
        }
        std::thread::sleep(cfg.supervision_poll);
    }
}

/// Salvage a dead incarnation's accounting and boot its replacement.
/// On success the slot is Degraded (awaiting probe); on failure it
/// stays Quarantined and the next supervisor pass retries.
fn respawn(slot: &ShardSlot) {
    let mut rt = write_rt(slot);
    let Some(queue) = rt.queue.take() else {
        return;
    };
    // The dispatcher is dead, so this joins immediately; the salvaged
    // report is exact up to the moment of death.
    let salvaged = match queue.finish_salvaging() {
        Ok(s) => s,
        Err(_) => {
            // Report assembly itself failed (controller died too) — the
            // incarnation's accounting is unrecoverable, but the shard
            // can still be respawned; the slot just loses that
            // incarnation's prior entry.
            boot(slot, &mut rt, None);
            return;
        }
    };
    let tier_cal = salvaged.report.tier_cal;
    rt.prior.push(salvaged.report);
    boot(slot, &mut rt, Some(tier_cal));
}

/// Start a fresh incarnation into `rt` (the slot's write lock is held).
fn boot(
    slot: &ShardSlot,
    rt: &mut ShardRuntime,
    tier_cal: Option<[(usize, usize); 3]>,
) {
    let exec = BatchExecutor::new(slot.workers);
    if let Some(cal) = tier_cal {
        // Reuse the dead incarnation's chunk calibration for the shard's
        // tier, under the tier's own key — the staleness rules still
        // apply, so a bogus hint is re-timed, not trusted.
        let (chunk, cal_ops) = cal[serve_tier_index(slot.tier)];
        if chunk != 0 {
            exec.seed_calibration(chunk, cal_ops, calibration_key(slot.tier));
        }
    }
    let unit = FpuUnit::generate(&slot.config);
    // Warm-start the replacement's latency estimator from the dead
    // incarnation's exact (value, count) snapshot, so the dynamic
    // routing policies never see a respawned shard as deceptively cold
    // (the feedback cell itself is the slot's and persists regardless —
    // the seed keeps the *dispatcher-side* estimator continuous too).
    let mut serve = slot.serve;
    if let Some(snap) = rt.prior.last().and_then(|p| p.latency_ewma) {
        serve.ewma_seed = Some(snap);
    }
    match ServeQueue::start_with_feedback(&unit, serve, exec, Arc::clone(&slot.feedback)) {
        Ok(queue) => {
            rt.handle = queue.handle();
            rt.queue = Some(queue);
            slot.respawns.fetch_add(1, Ordering::Relaxed);
            slot.health.store(HEALTH_DEGRADED, Ordering::Relaxed);
        }
        Err(_) => {
            // Stay quarantined; the next pass retries the respawn.
            slot.health.store(HEALTH_QUARANTINED, Ordering::Relaxed);
        }
    }
}

/// Probe-based re-admission: a seeded submission must round-trip
/// through the respawned shard before it takes routed traffic again.
fn probe(slot: &ShardSlot, cfg: &RouterConfig) {
    let handle = read_rt(slot).handle.clone();
    let respawns = slot.respawns.load(Ordering::Relaxed);
    // Deterministic probe operands: keyed by the incarnation number so
    // a re-probe never replays the previous probe's stream.
    let triples = OperandStream::new(slot.config.precision, OperandMix::Finite, 0xF9 + respawns)
        .batch(cfg.probe_ops.max(1));
    let ticket = match handle.submit(slot.tier, triples, slot.max_queue_ops) {
        Ok(t) => t,
        Err(_) => {
            // The fresh incarnation is already dead — back to quarantine;
            // the liveness check will respawn again.
            slot.health.store(HEALTH_QUARANTINED, Ordering::Relaxed);
            return;
        }
    };
    match ticket.wait_timeout(cfg.probe_timeout) {
        Ok(Some(_bits)) => slot.health.store(HEALTH_HEALTHY, Ordering::Relaxed),
        // Still in flight: stay Degraded, re-probe next pass.
        Ok(None) => {}
        Err(_) => slot.health.store(HEALTH_QUARANTINED, Ordering::Relaxed),
    }
}

/// One shard's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Table-I unit name ("SP FMA", …).
    pub unit: String,
    pub config: FpuConfig,
    pub tier: Fidelity,
    /// Workers granted by the fleet registry (≤ the spec's request).
    pub workers: usize,
    /// Submissions landed here, by [`WorkloadClass::index`].
    pub class_counts: [u64; WorkloadClass::COUNT],
    /// How many of those arrived via spill or failover.
    pub spilled_in: u64,
    /// Submissions whose affinity was this shard, diverted to a sibling
    /// while this shard was quarantined/degraded.
    pub rerouted_on_failure: u64,
    /// Incarnations spawned beyond the first (0 = never died).
    pub respawns: u64,
    /// Health at finish time.
    pub health: ShardHealth,
    /// Dead incarnations' salvaged reports, oldest first — exact
    /// accounting up to each death; merged into the fleet totals.
    pub prior: Vec<ServeReport>,
    /// The final incarnation's own [`ServeReport`] — streamed-vs-post-hoc
    /// BB identity, cross-check, latency percentiles, master trace —
    /// exactly as a single-unit serve run would have produced on this
    /// shard's stream.
    pub report: ServeReport,
}

impl ShardReport {
    /// Ops across every incarnation of this shard.
    pub fn total_ops(&self) -> u64 {
        self.report.ops + self.prior.iter().map(|p| p.ops).sum::<u64>()
    }

    /// Exact-sum energy across every incarnation.
    pub fn total_energy(&self) -> BbRunEnergy {
        merge_run_energies(
            self.prior
                .iter()
                .map(|p| &p.streamed.energy)
                .chain(std::iter::once(&self.report.streamed.energy)),
        )
    }

    /// The BB identity gate across incarnations: the live incarnation
    /// passes its full overflow-aware gate; dead incarnations must be
    /// exact on the window sequence their controller actually received
    /// (a dispatcher that dies with a coalesced window still pending
    /// cannot flush it — that one window's *granularity* is lost with
    /// the incarnation, never its ops or energy, which are salvaged from
    /// the master trace).
    pub fn bb_gate_ok(&self) -> bool {
        self.report.bb_gate_ok()
            && self.prior.iter().all(|p| p.received_schedule_matches)
    }
}

/// Outcome of one routed serve run ([`ServeRouter::finish`]).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-shard reports, in spec order.
    pub shards: Vec<ShardReport>,
    /// Dispatches diverted off-affinity by backlog pressure.
    pub spilled: u64,
    /// Dispatches that landed on an off-affinity shard for any
    /// *policy* reason (spill or missing-affinity fallback). Zero under
    /// the static policy with no spill pressure — health failovers are
    /// counted in `rerouted_on_failure`, not here.
    pub misrouted: u64,
    /// Dispatches diverted off a quarantined/degraded affinity shard.
    pub rerouted_on_failure: u64,
    /// Off-affinity placements a dynamic policy chose deliberately on
    /// its cost score (always 0 under [`StaticAffinity`]).
    pub policy_routed: u64,
    /// Submissions refused by the policy's SLO-class admission control
    /// (nothing was enqueued for them; always 0 under
    /// [`StaticAffinity`]).
    pub admission_denied: u64,
    /// The routing policy that produced this report
    /// ([`RoutePolicy::name`]).
    pub policy_name: &'static str,
    /// Total op submissions dispatched.
    pub submissions: u64,
    /// Total ops executed across the fleet, every incarnation included.
    pub ops: u64,
    /// Exact sum of the shards' streamed energy accounting across every
    /// incarnation ([`crate::bb::merge_run_energies`]); each
    /// incarnation's own numbers remain bit-identical to its post-hoc
    /// single-shard path.
    pub fleet_energy: BbRunEnergy,
    /// Cross-shard submission-latency percentiles (merged distribution,
    /// every incarnation included).
    pub fleet_p50_latency_s: f64,
    pub fleet_p99_latency_s: f64,
    /// Union busy span: earliest shard first-batch → latest shard
    /// last-batch.
    pub fleet_busy_secs: f64,
    /// Total ops over the union busy span.
    pub fleet_sustained_ops_per_s: f64,
}

impl FleetReport {
    /// The fleet-level hard gate: every shard passes its own
    /// overflow-aware streamed-vs-post-hoc BB identity gate, dead
    /// incarnations included (see [`ShardReport::bb_gate_ok`]).
    pub fn bb_gate_ok(&self) -> bool {
        self.shards.iter().all(ShardReport::bb_gate_ok)
    }

    /// Sampled gate cross-check totals across the fleet (every
    /// incarnation).
    pub fn crosscheck_sampled(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.report.crosscheck_sampled
                    + s.prior.iter().map(|p| p.crosscheck_sampled).sum::<u64>()
            })
            .sum()
    }

    pub fn crosscheck_mismatches(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.report.crosscheck_mismatches
                    + s.prior.iter().map(|p| p.crosscheck_mismatches).sum::<u64>()
            })
            .sum()
    }

    /// Respawned incarnations across the fleet (0 = nothing ever died).
    pub fn respawns(&self) -> u64 {
        self.shards.iter().map(|s| s.respawns).sum()
    }

    /// Fraction of dispatches that landed off-affinity (0.0 when nothing
    /// was dispatched).
    pub fn misrouted_fraction(&self) -> f64 {
        if self.submissions == 0 {
            0.0
        } else {
            self.misrouted as f64 / self.submissions as f64
        }
    }

    /// The best single shard's sustained throughput — the baseline the
    /// routed-sustained CI gate compares against.
    pub fn best_shard_ops_per_s(&self) -> f64 {
        self.shards.iter().map(|s| s.report.sustained_ops_per_s).fold(0.0, f64::max)
    }

    /// Fleet sustained over the best single shard — the quantity the
    /// `min-sustained-ratio` gate and the bench threshold compare. One
    /// definition here so the CLI gate and the CI artifact can never
    /// diverge.
    pub fn fleet_vs_best_shard_ratio(&self) -> f64 {
        self.fleet_sustained_ops_per_s / self.best_shard_ops_per_s().max(1e-12)
    }

    /// Fleet p99 over p50 on the merged latency distribution (1.0 when
    /// nothing ran — a degenerate run trivially meets any tail budget).
    pub fn fleet_p99_over_p50(&self) -> f64 {
        if self.fleet_p50_latency_s > 0.0 {
            self.fleet_p99_latency_s / self.fleet_p50_latency_s
        } else {
            1.0
        }
    }

    /// `hist[class][shard]` — the per-class shard histogram the
    /// acceptance gate inspects.
    pub fn class_histogram(&self) -> [Vec<u64>; WorkloadClass::COUNT] {
        let mut hist: [Vec<u64>; WorkloadClass::COUNT] = Default::default();
        for (c, row) in hist.iter_mut().enumerate() {
            *row = self.shards.iter().map(|s| s.class_counts[c]).collect();
        }
        hist
    }

    /// The conservation identity the chaos harness gates on: fleet ops
    /// equal the sum over every shard of every incarnation's ops, and
    /// the fleet energy equals the exact re-merge of the same
    /// incarnations' streamed energies. True by construction — exposed
    /// so an external report consumer can re-verify from the parts.
    pub fn conservation_ok(&self) -> bool {
        let ops_sum: u64 = self.shards.iter().map(ShardReport::total_ops).sum();
        let energy_sum = merge_run_energies(self.shards.iter().flat_map(|s| {
            s.prior
                .iter()
                .map(|p| &p.streamed.energy)
                .chain(std::iter::once(&s.report.streamed.energy))
        }));
        let lat_count: usize = self
            .shards
            .iter()
            .map(|s| {
                s.report.latencies_s.len()
                    + s.prior.iter().map(|p| p.latencies_s.len()).sum::<usize>()
            })
            .sum();
        let completed: u64 = self
            .shards
            .iter()
            .map(|s| {
                s.report.submissions + s.prior.iter().map(|p| p.submissions).sum::<u64>()
            })
            .sum();
        ops_sum == self.ops && energy_sum == self.fleet_energy && lat_count as u64 == completed
    }
}
