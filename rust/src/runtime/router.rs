//! The sharded multi-unit serve router.
//!
//! FPMax's whole point is that there is no single best FPU: the chip
//! fabricates **four** units — latency-optimized CMA and
//! throughput-optimized FMA pipelines, in both SP and DP — and the right
//! one depends on the workload. One [`ServeQueue`] drives exactly one
//! unit; this module is the serving surface that drives the fleet:
//!
//! ```text
//!  producers                router                    shards
//!  ────────────┐   ┌─────────────────────┐   ┌──────────────────────┐
//!  submit(     │   │ WorkloadClass ──────┼──▶│ SP CMA  (latency)    │
//!    class,   ─┼──▶│   Table-1 affinity  │   │ SP FMA  (bulk)       │
//!    tier,     │   │ + load-aware spill  │   │ DP CMA  (latency)    │
//!    ops)      │   │   (pressure probe)  │   │ DP FMA  (bulk)       │
//!  ────────────┘   └─────────────────────┘   └──────────────────────┘
//!                                               each: own ServeQueue,
//!                                               own BatchExecutor pool,
//!                                               own window ring + live
//!                                               bb::StreamingController
//! ```
//!
//! * A **shard** is one (unit preset × precision × fidelity tier)
//!   [`ServeQueue`]: its own persistent executor pool (sized from one
//!   fleet-wide [`ExecutorRegistry`] budget, so co-resident shards never
//!   oversubscribe the host), its own window ring, its own streaming
//!   body-bias controller, its own chunk-size calibration.
//! * Submissions carry a [`WorkloadClass`] — latency-sensitive vs
//!   bulk-throughput, SP vs DP — and the **static affinity policy** maps
//!   it per the paper's Table 1: latency classes to the CMA (cascade)
//!   pipelines, bulk classes to the FMA (fused) pipelines of the same
//!   precision.
//! * **Load-aware spill**: when the affinity shard's in-flight pressure
//!   crosses the configured threshold and a compatible sibling (same
//!   precision, same tier) is strictly less loaded, the submission
//!   spills there. A spilled submission is computed in the *receiving*
//!   unit's own Table-I semantics — fused and cascade round differently,
//!   exactly as on the real heterogeneous chip — so callers that need
//!   one fixed rounding semantics run with spill disabled. Either way
//!   the result is bit-exact for the unit that executed it, and the
//!   sampled gate cross-check rides along per shard.
//! * [`ServeRouter::finish`] lifts the per-shard accounting into a
//!   [`FleetReport`]: each shard's streamed schedule + energies stay
//!   **bit-identical** to the post-hoc single-shard path on that shard's
//!   own window stream (the PR 4 `EnergyIntegrator` identity gates,
//!   unchanged), and the fleet totals are exact sums on top
//!   ([`crate::bb::merge_run_energies`]).
//!
//! The per-class shard histogram is recorded per dispatch, so a report
//! can show that latency-class traffic measurably landed on
//! latency-optimized shards (`misrouted == 0` under the static policy
//! with no spill pressure).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::arch::engine::{ExecutorRegistry, Fidelity};
use crate::arch::fp::Precision;
use crate::arch::generator::{FpuConfig, FpuKind, FpuUnit};
use crate::bb::{merge_run_energies, BbRunEnergy};
use crate::runtime::serve::{ServeConfig, ServeQueue, ServeReport, SubmitHandle, Ticket};
use crate::util::stats::percentile;
use crate::workloads::throughput::OperandTriple;

/// What a submission is optimized for — the paper's workload axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServiceClass {
    /// Latency-sensitive: dependent chains, short deadlines.
    Latency,
    /// Bulk throughput: abundant independent parallelism.
    Bulk,
}

impl ServiceClass {
    /// The Table 1 unit-affinity mapping: latency-sensitive work to the
    /// latency-optimized cascade (CMA) pipelines, bulk work to the
    /// throughput-optimized fused (FMA) pipelines.
    pub fn affinity_kind(self) -> FpuKind {
        match self {
            ServiceClass::Latency => FpuKind::Cma,
            ServiceClass::Bulk => FpuKind::Fma,
        }
    }
}

/// The workload taxonomy a submission declares: precision × service
/// class. Four classes cover the paper's four fabricated units.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadClass {
    pub precision: Precision,
    pub service: ServiceClass,
}

impl WorkloadClass {
    /// All four classes, in [`WorkloadClass::index`] order.
    pub const ALL: [WorkloadClass; 4] = [
        WorkloadClass { precision: Precision::Single, service: ServiceClass::Latency },
        WorkloadClass { precision: Precision::Single, service: ServiceClass::Bulk },
        WorkloadClass { precision: Precision::Double, service: ServiceClass::Latency },
        WorkloadClass { precision: Precision::Double, service: ServiceClass::Bulk },
    ];

    /// Dense index in `0..4` (histogram axis).
    pub fn index(self) -> usize {
        let p = match self.precision {
            Precision::Single => 0,
            Precision::Double => 1,
        };
        let s = match self.service {
            ServiceClass::Latency => 0,
            ServiceClass::Bulk => 1,
        };
        p * 2 + s
    }

    pub fn name(self) -> &'static str {
        match (self.precision, self.service) {
            (Precision::Single, ServiceClass::Latency) => "sp-latency",
            (Precision::Single, ServiceClass::Bulk) => "sp-bulk",
            (Precision::Double, ServiceClass::Latency) => "dp-latency",
            (Precision::Double, ServiceClass::Bulk) => "dp-bulk",
        }
    }
}

/// One shard of the fleet: a unit preset served at one fidelity tier
/// under one [`ServeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    pub config: FpuConfig,
    pub tier: Fidelity,
    pub serve: ServeConfig,
}

/// Router-level policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Fleet-wide worker budget, portioned across the shard executors by
    /// an [`ExecutorRegistry`] (each shard's `serve.workers` request is
    /// clamped to what remains).
    pub workers_budget: usize,
    /// In-flight ops on the affinity shard above which a submission may
    /// spill to a strictly-less-loaded compatible sibling.
    /// `usize::MAX` disables spill — the pure static policy.
    pub spill_pressure_ops: usize,
}

impl RouterConfig {
    /// Static affinity only, no spill.
    pub fn no_spill(workers_budget: usize) -> RouterConfig {
        RouterConfig { workers_budget, spill_pressure_ops: usize::MAX }
    }

    /// Affinity with load-aware spill above `pressure_ops` in-flight ops.
    pub fn with_spill(workers_budget: usize, pressure_ops: usize) -> RouterConfig {
        RouterConfig { workers_budget, spill_pressure_ops: pressure_ops }
    }
}

/// Where a dispatch decision landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Placement {
    /// The class's affinity shard.
    Affinity,
    /// Diverted off-affinity by backlog pressure.
    Spill,
    /// No affinity shard exists for the class at this tier; any
    /// compatible shard took it.
    Fallback,
}

struct Shard {
    config: FpuConfig,
    tier: Fidelity,
    workers: usize,
    max_queue_ops: usize,
    handle: SubmitHandle,
    queue: ServeQueue,
    /// Submissions landed here, by [`WorkloadClass::index`].
    class_counts: [AtomicU64; 4],
    /// Submissions that arrived here via spill.
    spilled_in: AtomicU64,
}

/// The fleet dispatcher (see the module docs). Construct with
/// [`ServeRouter::start`], submit classified work from any number of
/// producer threads, then [`ServeRouter::finish`] to drain every shard
/// and assemble the [`FleetReport`].
pub struct ServeRouter {
    shards: Vec<Shard>,
    spill_pressure_ops: usize,
    submissions: AtomicU64,
    spilled: AtomicU64,
    misrouted: AtomicU64,
}

impl ServeRouter {
    /// The paper's Table 1 fleet at one fidelity tier: all four
    /// fabricated units, each at its own nominal operating point, the
    /// worker budget split fairly four ways. Tweak the returned specs
    /// (window, ring, per-shard workers) before [`ServeRouter::start`]
    /// if the defaults don't fit.
    pub fn fleet_nominal(
        tier: Fidelity,
        adaptive: bool,
        workers_budget: usize,
        window_ops: usize,
        ring_windows: usize,
    ) -> crate::Result<Vec<ShardSpec>> {
        // Split the budget without discarding the remainder: the first
        // `budget % 4` shards get one extra worker, so the whole budget
        // the registry portions is actually requested.
        let base = workers_budget / 4;
        let rem = workers_budget % 4;
        FpuConfig::fpmax_units()
            .into_iter()
            .enumerate()
            .map(|(i, config)| {
                let mut serve = ServeConfig::nominal(&config, adaptive)?;
                serve.workers = (base + usize::from(i < rem)).max(1);
                serve.window_ops = window_ops;
                serve.ring_windows = ring_windows;
                Ok(ShardSpec { config, tier, serve })
            })
            .collect()
    }

    /// Spin up one [`ServeQueue`] per spec, pools sized through a shared
    /// [`ExecutorRegistry`] over `cfg.workers_budget`.
    pub fn start(specs: &[ShardSpec], cfg: RouterConfig) -> crate::Result<ServeRouter> {
        anyhow::ensure!(!specs.is_empty(), "a router needs at least one shard");
        let registry = ExecutorRegistry::new(cfg.workers_budget);
        let mut shards: Vec<Shard> = Vec::with_capacity(specs.len());
        for spec in specs {
            let exec = registry.shard(spec.serve.workers);
            let workers = exec.workers();
            let unit = FpuUnit::generate(&spec.config);
            let queue = match ServeQueue::start_with_executor(&unit, spec.serve, exec) {
                Ok(q) => q,
                Err(e) => {
                    // Close the shards already started before bailing —
                    // a dropped ServeQueue is never shut down, so
                    // propagating here directly would strand their
                    // dispatcher/controller/pool threads forever.
                    for s in shards {
                        let _ = s.queue.finish();
                    }
                    return Err(e.context(format!(
                        "starting shard {} at the {} tier",
                        spec.config.name(),
                        spec.tier.name()
                    )));
                }
            };
            shards.push(Shard {
                config: spec.config,
                tier: spec.tier,
                workers,
                max_queue_ops: spec.serve.max_queue_ops,
                handle: queue.handle(),
                queue,
                class_counts: Default::default(),
                spilled_in: AtomicU64::new(0),
            });
        }
        Ok(ServeRouter {
            shards,
            spill_pressure_ops: cfg.spill_pressure_ops,
            submissions: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            misrouted: AtomicU64::new(0),
        })
    }

    /// Shard count.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// In-flight pressure of shard `idx` (ops submitted, not yet
    /// resolved).
    pub fn shard_pressure(&self, idx: usize) -> usize {
        self.shards[idx].handle.pressure_ops()
    }

    /// The dispatch decision, read-only: candidates are shards matching
    /// the class precision and the requested tier; the affinity shard
    /// (least-loaded, if several) wins unless spill pressure diverts to
    /// a strictly-less-loaded compatible sibling.
    fn route(&self, class: WorkloadClass, tier: Fidelity) -> crate::Result<(usize, Placement)> {
        let mut preferred: Option<(usize, usize)> = None;
        let mut alt: Option<(usize, usize)> = None;
        for (i, s) in self.shards.iter().enumerate() {
            if s.config.precision != class.precision || s.tier != tier {
                continue;
            }
            let pressure = s.handle.pressure_ops();
            let slot = if s.config.kind == class.service.affinity_kind() {
                &mut preferred
            } else {
                &mut alt
            };
            let better = match *slot {
                None => true,
                Some((_, best)) => pressure < best,
            };
            if better {
                *slot = Some((i, pressure));
            }
        }
        match (preferred, alt) {
            (Some((_, pp)), Some((a, ap)))
                if pp > self.spill_pressure_ops && ap < pp =>
            {
                Ok((a, Placement::Spill))
            }
            (Some((p, _)), _) => Ok((p, Placement::Affinity)),
            (None, Some((a, _))) => Ok((a, Placement::Fallback)),
            (None, None) => anyhow::bail!(
                "no shard serves {} at the {} tier",
                class.name(),
                tier.name()
            ),
        }
    }

    /// Dispatch one classified submission; returns the shard index it
    /// landed on and the completion ticket. The operands must be in the
    /// class's precision (each shard computes its own unit's Table-I
    /// semantics on them, bit-exactly, wherever the submission lands).
    pub fn submit(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        triples: Vec<OperandTriple>,
    ) -> crate::Result<(usize, Ticket)> {
        let (idx, placement) = self.route(class, tier)?;
        let shard = &self.shards[idx];
        // Dispatch first, count after: a submission the shard rejected
        // (closed queue, dead dispatcher) must not skew the histogram or
        // the misrouted/spilled counters the acceptance gates read —
        // and a retry must not double-count.
        let ticket = shard.handle.submit(tier, triples, shard.max_queue_ops)?;
        self.submissions.fetch_add(1, Ordering::Relaxed);
        shard.class_counts[class.index()].fetch_add(1, Ordering::Relaxed);
        match placement {
            Placement::Affinity => {}
            Placement::Spill => {
                self.spilled.fetch_add(1, Ordering::Relaxed);
                self.misrouted.fetch_add(1, Ordering::Relaxed);
                shard.spilled_in.fetch_add(1, Ordering::Relaxed);
            }
            Placement::Fallback => {
                self.misrouted.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok((idx, ticket))
    }

    /// Dispatch an idle phase (accounting-only issue slots) to the
    /// class's affinity shard — idle never spills; it is the shard's own
    /// low-utilization gap, the thing its adaptive controller re-biases
    /// through. Returns the shard index.
    pub fn submit_idle(
        &self,
        class: WorkloadClass,
        tier: Fidelity,
        slots: u64,
    ) -> crate::Result<usize> {
        // Pure affinity: ignore pressure entirely.
        let mut pick = None;
        for (i, s) in self.shards.iter().enumerate() {
            if s.config.precision != class.precision || s.tier != tier {
                continue;
            }
            if s.config.kind == class.service.affinity_kind() {
                pick = Some(i);
                break;
            }
            pick.get_or_insert(i);
        }
        let idx = pick.ok_or_else(|| {
            anyhow::anyhow!("no shard serves {} at the {} tier", class.name(), tier.name())
        })?;
        self.shards[idx].handle.submit_idle(slots)?;
        Ok(idx)
    }

    /// Close every shard, drain, join, and assemble the fleet report.
    /// Shard order in the report matches the spec order given to
    /// [`ServeRouter::start`].
    pub fn finish(self) -> crate::Result<FleetReport> {
        let spilled = self.spilled.load(Ordering::Relaxed);
        let misrouted = self.misrouted.load(Ordering::Relaxed);
        let submissions = self.submissions.load(Ordering::Relaxed);
        // Finish EVERY shard before propagating any error: each finish()
        // closes that shard's queue and joins its dispatcher/controller
        // threads, so bailing on the first failure would leak the
        // siblings' threads for the life of the process.
        let mut first_err: Option<anyhow::Error> = None;
        let mut shards = Vec::with_capacity(self.shards.len());
        for s in self.shards {
            match s.queue.finish() {
                Ok(report) => shards.push(ShardReport {
                    unit: s.config.name(),
                    config: s.config,
                    tier: s.tier,
                    workers: s.workers,
                    class_counts: s.class_counts.map(|c| c.into_inner()),
                    spilled_in: s.spilled_in.into_inner(),
                    report,
                }),
                Err(e) => {
                    let e = e.context(format!("shard {} failed to finish", s.config.name()));
                    first_err.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let ops = shards.iter().map(|s| s.report.ops).sum();
        // Fleet latency distribution: every shard's (sorted) latencies
        // merged, then re-sorted once.
        let mut latencies: Vec<f64> =
            shards.iter().flat_map(|s| s.report.latencies_s.iter().copied()).collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
        let (p50, p99) = if latencies.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&latencies, 0.50), percentile(&latencies, 0.99))
        };
        // Union busy span on the shared monotonic clock.
        let first = shards.iter().filter_map(|s| s.report.first_batch).min();
        let last = shards.iter().filter_map(|s| s.report.busy_until).max();
        let busy_secs = match (first, last) {
            (Some(t0), Some(t1)) => t1.duration_since(t0).as_secs_f64(),
            _ => 0.0,
        };
        let energy = merge_run_energies(shards.iter().map(|s| &s.report.streamed.energy));
        Ok(FleetReport {
            spilled,
            misrouted,
            submissions,
            ops,
            fleet_energy: energy,
            fleet_p50_latency_s: p50,
            fleet_p99_latency_s: p99,
            fleet_busy_secs: busy_secs,
            fleet_sustained_ops_per_s: if busy_secs > 0.0 { ops as f64 / busy_secs } else { 0.0 },
            shards,
        })
    }
}

/// One shard's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Table-I unit name ("SP FMA", …).
    pub unit: String,
    pub config: FpuConfig,
    pub tier: Fidelity,
    /// Workers granted by the fleet registry (≤ the spec's request).
    pub workers: usize,
    /// Submissions landed here, by [`WorkloadClass::index`].
    pub class_counts: [u64; 4],
    /// How many of those arrived via spill.
    pub spilled_in: u64,
    /// The shard's own [`ServeReport`] — streamed-vs-post-hoc BB
    /// identity, cross-check, latency percentiles, master trace — exactly
    /// as a single-unit serve run would have produced on this shard's
    /// stream.
    pub report: ServeReport,
}

/// Outcome of one routed serve run ([`ServeRouter::finish`]).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-shard reports, in spec order.
    pub shards: Vec<ShardReport>,
    /// Dispatches diverted off-affinity by backlog pressure.
    pub spilled: u64,
    /// Dispatches that landed on an off-affinity shard for any reason
    /// (spill or missing-affinity fallback). Zero under the static
    /// policy with no spill pressure.
    pub misrouted: u64,
    /// Total op submissions dispatched.
    pub submissions: u64,
    /// Total ops executed across the fleet.
    pub ops: u64,
    /// Exact sum of the shards' streamed energy accounting
    /// ([`crate::bb::merge_run_energies`]); each shard's own numbers
    /// remain bit-identical to its post-hoc single-shard path.
    pub fleet_energy: BbRunEnergy,
    /// Cross-shard submission-latency percentiles (merged distribution).
    pub fleet_p50_latency_s: f64,
    pub fleet_p99_latency_s: f64,
    /// Union busy span: earliest shard first-batch → latest shard
    /// last-batch.
    pub fleet_busy_secs: f64,
    /// Total ops over the union busy span.
    pub fleet_sustained_ops_per_s: f64,
}

impl FleetReport {
    /// The fleet-level hard gate: every shard passes its own
    /// overflow-aware streamed-vs-post-hoc BB identity gate.
    pub fn bb_gate_ok(&self) -> bool {
        self.shards.iter().all(|s| s.report.bb_gate_ok())
    }

    /// Sampled gate cross-check totals across the fleet.
    pub fn crosscheck_sampled(&self) -> u64 {
        self.shards.iter().map(|s| s.report.crosscheck_sampled).sum()
    }

    pub fn crosscheck_mismatches(&self) -> u64 {
        self.shards.iter().map(|s| s.report.crosscheck_mismatches).sum()
    }

    /// Fraction of dispatches that landed off-affinity (0.0 when nothing
    /// was dispatched).
    pub fn misrouted_fraction(&self) -> f64 {
        if self.submissions == 0 {
            0.0
        } else {
            self.misrouted as f64 / self.submissions as f64
        }
    }

    /// The best single shard's sustained throughput — the baseline the
    /// routed-sustained CI gate compares against.
    pub fn best_shard_ops_per_s(&self) -> f64 {
        self.shards.iter().map(|s| s.report.sustained_ops_per_s).fold(0.0, f64::max)
    }

    /// Fleet sustained over the best single shard — the quantity the
    /// `min-sustained-ratio` gate and the bench threshold compare. One
    /// definition here so the CLI gate and the CI artifact can never
    /// diverge.
    pub fn fleet_vs_best_shard_ratio(&self) -> f64 {
        self.fleet_sustained_ops_per_s / self.best_shard_ops_per_s().max(1e-12)
    }

    /// Fleet p99 over p50 on the merged latency distribution (1.0 when
    /// nothing ran — a degenerate run trivially meets any tail budget).
    pub fn fleet_p99_over_p50(&self) -> f64 {
        if self.fleet_p50_latency_s > 0.0 {
            self.fleet_p99_latency_s / self.fleet_p50_latency_s
        } else {
            1.0
        }
    }

    /// `hist[class][shard]` — the per-class shard histogram the
    /// acceptance gate inspects.
    pub fn class_histogram(&self) -> [Vec<u64>; 4] {
        let mut hist: [Vec<u64>; 4] = Default::default();
        for (c, row) in hist.iter_mut().enumerate() {
            *row = self.shards.iter().map(|s| s.class_counts[c]).collect();
        }
        hist
    }
}
