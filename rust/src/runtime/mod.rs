//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! This is the only place the `xla` crate is touched. The interchange
//! format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md: serialized protos from jax ≥ 0.5 carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns them).
//!
//! Python never runs here: artifacts are compiled once by
//! `make artifacts`, and the resulting executables are pure XLA:CPU
//! programs fed with raw bit patterns.

use std::path::{Path, PathBuf};

use crate::arch::fp::Precision;

/// A PJRT client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// One loaded FMAC artifact: a compiled executable with a fixed batch.
pub struct FmacArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Batch size baked into the artifact's shapes.
    pub batch: usize,
    pub precision: Precision,
    pub name: String,
}

/// Output of one artifact invocation over an operand stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FmacOutput {
    /// Result bit patterns, one per input op (padding stripped).
    pub bits: Vec<u64>,
    /// Toggle count reported by the L2 graph (activity proxy), summed
    /// over all executed chunks including padding.
    pub toggles: u64,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<name>.hlo.txt` for the given precision.
    pub fn load_fmac(&self, name: &str, precision: Precision) -> crate::Result<FmacArtifact> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let batch = parse_batch(&text, precision)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: cannot find batch shape in HLO"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-UTF8 path"))?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(FmacArtifact { exe, batch, precision, name: name.to_string() })
    }
}

impl FmacArtifact {
    /// Execute the artifact over an arbitrary-length operand stream,
    /// chunking to the baked batch and padding the tail with zeros.
    pub fn fmac(&self, a: &[u64], b: &[u64], c: &[u64]) -> crate::Result<FmacOutput> {
        anyhow::ensure!(a.len() == b.len() && b.len() == c.len(), "operand length mismatch");
        let mut bits = Vec::with_capacity(a.len());
        let mut toggles = 0u64;
        for start in (0..a.len()).step_by(self.batch) {
            let end = (start + self.batch).min(a.len());
            let (chunk_bits, t) = self.run_chunk(&a[start..end], &b[start..end], &c[start..end])?;
            bits.extend_from_slice(&chunk_bits[..end - start]);
            toggles += t;
        }
        Ok(FmacOutput { bits, toggles })
    }

    fn run_chunk(&self, a: &[u64], b: &[u64], c: &[u64]) -> crate::Result<(Vec<u64>, u64)> {
        let (la, lb, lc) = match self.precision {
            Precision::Single => {
                (lit_u32(a, self.batch), lit_u32(b, self.batch), lit_u32(c, self.batch))
            }
            Precision::Double => {
                (lit_u64(a, self.batch), lit_u64(b, self.batch), lit_u64(c, self.batch))
            }
        };
        let result = self.exe.execute::<xla::Literal>(&[la, lb, lc]).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: (results, toggles).
        let (bits_lit, tog_lit) = out.to_tuple2().map_err(wrap)?;
        let bits = match self.precision {
            Precision::Single => bits_lit
                .to_vec::<u32>()
                .map_err(wrap)?
                .into_iter()
                .map(|v| v as u64)
                .collect(),
            Precision::Double => bits_lit.to_vec::<u64>().map_err(wrap)?,
        };
        let toggles = tog_lit.to_vec::<u64>().map_err(wrap)?;
        Ok((bits, toggles.first().copied().unwrap_or(0)))
    }
}

fn lit_u32(vals: &[u64], batch: usize) -> xla::Literal {
    let mut v: Vec<u32> = vals.iter().map(|&x| x as u32).collect();
    v.resize(batch, 0);
    xla::Literal::vec1(&v)
}

fn lit_u64(vals: &[u64], batch: usize) -> xla::Literal {
    let mut v = vals.to_vec();
    v.resize(batch, 0);
    xla::Literal::vec1(&v)
}

/// Extract the batch size from the HLO entry parameter shapes, e.g.
/// `u32[4096]` / `u64[4096]`.
fn parse_batch(hlo_text: &str, precision: Precision) -> Option<usize> {
    let needle = match precision {
        Precision::Single => "u32[",
        Precision::Double => "u64[",
    };
    let pos = hlo_text.find(needle)?;
    let rest = &hlo_text[pos + needle.len()..];
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_batch_from_hlo() {
        let text = "HloModule foo\nENTRY main { p0 = u32[4096]{0} parameter(0) }";
        assert_eq!(parse_batch(text, Precision::Single), Some(4096));
        assert_eq!(parse_batch(text, Precision::Double), None);
        let text = "ENTRY m { p = u64[256]{0} parameter(0) }";
        assert_eq!(parse_batch(text, Precision::Double), Some(256));
        assert_eq!(parse_batch("", Precision::Single), None);
    }

    // Artifact-dependent tests live in rust/tests/ (they need
    // `make artifacts` to have run); this module keeps the pure parsing
    // logic testable without the PJRT plugin.
}
