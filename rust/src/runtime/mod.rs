//! Run-time services: the streaming [`serve`] layer (an async
//! submission queue over the persistent batch engine with mid-run
//! body-bias re-biasing — see [`serve::ServeQueue`]), the sharded
//! multi-unit [`router`] (one serve shard per unit preset × precision ×
//! fidelity tier behind workload-aware dispatch — see
//! [`router::ServeRouter`]), the deterministic [`chaos`] fault engine
//! that proves the fleet serves through failures, the seeded
//! multi-tenant [`trace`] workload generator that drives and judges the
//! dynamic routing policies, and the PJRT artifact runtime.
//!
//! PJRT side: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! The real implementation ([`pjrt`], behind the `pjrt` cargo feature) is
//! the only place the `xla` crate is touched; the offline default build
//! compiles the API-identical [`stub`] instead, whose `Runtime::cpu`
//! constructor reports PJRT as unavailable. Every caller already treats
//! that as a soft failure (the chip self-test and the benches print a
//! skip notice), so the rest of the system — including the
//! [`crate::coordinator`] cross-check plumbing, which only needs the
//! [`FmacArtifact`] API surface — builds and runs without the native XLA
//! libraries.
//!
//! Python never runs here either way: artifacts are compiled once by
//! `make artifacts`, and the resulting executables are pure XLA:CPU
//! programs fed with raw bit patterns.

pub mod chaos;
pub mod router;
pub mod serve;
pub mod trace;

pub use chaos::{ChaosReport, FaultKind, FaultPlan, FaultTrigger, ScheduledFault};
pub use router::{
    EnergyAware, FleetReport, Placement, RetryPolicy, RouteCandidate, RouteContext, RoutePolicy,
    RouterConfig, ServeRouter, ServiceClass, ShardHealth, ShardReport, ShardSpec, StaticAffinity,
    SubmitOutcome, WorkloadClass,
};
pub use serve::{
    SalvagedRun, ServeConfig, ServeError, ServeLoad, ServeQueue, ServeReport, ShardFeedback,
    SubmitHandle, Ticket,
};
pub use trace::{Trace, TraceConfig, TraceEvent};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{FmacArtifact, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{FmacArtifact, Runtime};

/// Output of one artifact invocation over an operand stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FmacOutput {
    /// Result bit patterns, one per input op (padding stripped).
    pub bits: Vec<u64>,
    /// Toggle count reported by the L2 graph (activity proxy), summed
    /// over all executed chunks including padding.
    pub toggles: u64,
}

/// Extract the batch size from the HLO entry parameter shapes, e.g.
/// `u32[4096]` / `u64[4096]`. (Public so the pure parsing logic stays
/// testable — and tested — without the PJRT plugin.)
pub fn parse_batch(hlo_text: &str, precision: crate::arch::fp::Precision) -> Option<usize> {
    // Needle follows the storage width, so the parser extends to any
    // interchange format an artifact pipeline might emit.
    let needle = match precision.format().width() {
        64 => "u64[",
        32 => "u32[",
        16 => "u16[",
        _ => "u8[",
    };
    let pos = hlo_text.find(needle)?;
    let rest = &hlo_text[pos + needle.len()..];
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::fp::Precision;

    #[test]
    fn parse_batch_from_hlo() {
        let text = "HloModule foo\nENTRY main { p0 = u32[4096]{0} parameter(0) }";
        assert_eq!(parse_batch(text, Precision::Single), Some(4096));
        assert_eq!(parse_batch(text, Precision::Double), None);
        let text = "ENTRY m { p = u64[256]{0} parameter(0) }";
        assert_eq!(parse_batch(text, Precision::Double), Some(256));
        assert_eq!(parse_batch("", Precision::Single), None);
    }

    // Artifact-dependent tests live in rust/tests/ (they need
    // `make artifacts` to have run); this module keeps the pure parsing
    // logic testable without the PJRT plugin.
}
