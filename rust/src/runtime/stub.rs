//! Offline stand-in for the PJRT runtime (default build, no `pjrt`
//! feature).
//!
//! API-identical to [`super::pjrt`], but [`Runtime::cpu`] always fails
//! with a descriptive error. Callers that probe for PJRT availability
//! (the chip self-test, the hotpath bench, the integration tests) take
//! their documented skip path; code that merely needs the
//! [`FmacArtifact`] type — the coordinator's cross-check plumbing —
//! compiles unchanged.

use std::path::Path;

use crate::arch::fp::Precision;

use super::FmacOutput;

/// Placeholder for the PJRT client. No constructor succeeds, so the
/// instance methods below are statically unreachable.
pub struct Runtime {
    #[allow(dead_code)]
    sealed: std::convert::Infallible,
}

/// Placeholder artifact with the same public surface as the PJRT-backed
/// one. The stub exposes no way to obtain one.
pub struct FmacArtifact {
    #[allow(dead_code)]
    sealed: std::convert::Infallible,
    /// Batch size baked into the artifact's shapes.
    pub batch: usize,
    pub precision: Precision,
    pub name: String,
}

impl Runtime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn cpu(_artifact_dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        anyhow::bail!(
            "PJRT support not compiled in (build with `--features pjrt` and the xla crate)"
        )
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Load and compile `<name>.hlo.txt` for the given precision.
    pub fn load_fmac(&self, _name: &str, _precision: Precision) -> crate::Result<FmacArtifact> {
        unreachable!("stub Runtime cannot be constructed")
    }
}

impl FmacArtifact {
    /// Execute the artifact over an arbitrary-length operand stream.
    pub fn fmac(&self, _a: &[u64], _b: &[u64], _c: &[u64]) -> crate::Result<FmacOutput> {
        unreachable!("stub FmacArtifact cannot be constructed")
    }
}
