//! Pipeline partitioning and achievable clock frequency.
//!
//! The generator cuts a unit's critical path into `stages` pieces; the
//! cycle time is the deepest piece plus register overhead, times the
//! technology's FO4 at the operating point, times a **design-style
//! sizing factor κ**:
//!
//! * latency-optimized designs (the CMAs) are sized aggressively — large
//!   drive, more parallel prefix, logical effort near the theoretical
//!   optimum → small κ;
//! * throughput-optimized designs (the FMAs) sit at a low-EDP sizing
//!   point — smaller gates, relaxed margins → larger κ, cheaper energy.
//!
//! κ per style is the only fitted timing constant (see
//! [`crate::energy::calibrate`]); everything else is structural.

use crate::arch::generator::{FpuConfig, FpuKind};
use crate::energy::tech::{OperatingPoint, Technology};

use super::fo4::{depth, REG_OVERHEAD_FO4};

/// Sizing style, derived from what the unit was optimized for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignStyle {
    /// Delay-optimal sizing (latency units).
    Latency,
    /// Energy-optimal sizing (throughput units).
    Throughput,
}

impl DesignStyle {
    /// Style of a configuration: CMAs are the latency designs, FMAs the
    /// throughput designs (paper §FPU Architectures).
    pub fn of(cfg: &FpuConfig) -> DesignStyle {
        match cfg.kind {
            FpuKind::Cma => DesignStyle::Latency,
            FpuKind::Fma => DesignStyle::Throughput,
        }
    }

    /// Sizing factor κ (dimensionless multiplier on logic depth).
    /// Calibrated against Table I's four (V_DD, V_BB, f) points — see
    /// `energy::calibrate` (geomean of the per-style implied values).
    pub fn kappa(self) -> f64 {
        match self {
            DesignStyle::Latency => 2.74,
            DesignStyle::Throughput => 4.03,
        }
    }
}

/// Timing summary of a pipelined unit at a given operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timing {
    /// Cycle time in ps.
    pub cycle_ps: f64,
    /// Clock frequency in GHz.
    pub freq_ghz: f64,
    /// Per-stage logic depth (FO4, before κ).
    pub stage_fo4: f64,
    /// Total path depth (FO4, before κ).
    pub total_fo4: f64,
}

/// Per-stage logic depth for a configuration (balanced partition of the
/// critical path plus register overhead).
pub fn stage_depth_fo4(cfg: &FpuConfig) -> f64 {
    depth(cfg).total() / cfg.stages as f64 + REG_OVERHEAD_FO4
}

/// Achievable timing at an operating point; `None` if the point is not
/// operable in this technology.
pub fn timing(cfg: &FpuConfig, tech: &Technology, op: OperatingPoint) -> Option<Timing> {
    let fo4_ps = tech.fo4_ps(op)?;
    let stage = stage_depth_fo4(cfg);
    let cycle_ps = stage * DesignStyle::of(cfg).kappa() * fo4_ps;
    Some(Timing {
        cycle_ps,
        freq_ghz: 1000.0 / cycle_ps,
        stage_fo4: stage,
        total_fo4: depth(cfg).total(),
    })
}

/// The chip's nominal operating points per unit (Table I rows "Supply
/// Voltage" / "Body-bias").
pub fn nominal_op(cfg: &FpuConfig) -> OperatingPoint {
    use crate::arch::fp::Precision;
    let vdd = match (cfg.precision, cfg.kind) {
        (Precision::Double, FpuKind::Cma) => 0.9,
        (Precision::Double, FpuKind::Fma) => 0.8,
        (Precision::Single, FpuKind::Cma) => 0.8,
        (Precision::Single, FpuKind::Fma) => 0.9,
        // Transprecision tiers weren't fabricated; they inherit the SP
        // rows' operating points (the small formats' shallower logic
        // only clocks faster at the same supply).
        (_, FpuKind::Cma) => 0.8,
        (_, FpuKind::Fma) => 0.9,
    };
    OperatingPoint::new(vdd, Technology::NOMINAL_VBB)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_diff;

    /// Table I frequencies at the nominal operating points.
    const TABLE1_FREQ_GHZ: [(fn() -> FpuConfig, f64); 4] = [
        (FpuConfig::dp_cma as fn() -> FpuConfig, 1.19),
        (FpuConfig::dp_fma, 0.91),
        (FpuConfig::sp_cma, 1.36),
        (FpuConfig::sp_fma, 0.91),
    ];

    #[test]
    fn nominal_frequencies_match_table1() {
        let tech = Technology::fdsoi28();
        for (mk, want) in TABLE1_FREQ_GHZ {
            let cfg = mk();
            let t = timing(&cfg, &tech, nominal_op(&cfg)).unwrap();
            let rel = rel_diff(t.freq_ghz, want);
            assert!(
                rel < 0.15,
                "{}: model {:.2} GHz vs silicon {want} GHz (rel {rel:.2})",
                cfg.name(),
                t.freq_ghz
            );
        }
    }

    #[test]
    fn frequency_ordering_matches_silicon() {
        // SP CMA > DP CMA > {FMAs}: the latency designs clock faster.
        let tech = Technology::fdsoi28();
        let f = |cfg: FpuConfig| timing(&cfg, &tech, nominal_op(&cfg)).unwrap().freq_ghz;
        assert!(f(FpuConfig::sp_cma()) > f(FpuConfig::dp_cma()));
        assert!(f(FpuConfig::dp_cma()) > f(FpuConfig::dp_fma()));
        assert!(f(FpuConfig::dp_cma()) > f(FpuConfig::sp_fma()));
    }

    #[test]
    fn body_bias_buys_frequency() {
        // Fig. 3/4's lever: at fixed V_DD, forward bias shortens the cycle.
        let tech = Technology::fdsoi28();
        let cfg = FpuConfig::sp_fma();
        let slow = timing(&cfg, &tech, OperatingPoint::new(0.8, 0.0)).unwrap();
        let fast = timing(&cfg, &tech, OperatingPoint::new(0.8, 1.2)).unwrap();
        assert!(fast.freq_ghz > slow.freq_ghz * 1.05);
    }

    #[test]
    fn vdd_scaling_spans_useful_range() {
        // The Fig. 3 V_DD sweep: frequency must scale by ≥3× from 0.45 V
        // to 1.1 V.
        let tech = Technology::fdsoi28();
        let cfg = FpuConfig::sp_fma();
        let lo = timing(&cfg, &tech, OperatingPoint::new(0.45, 1.2)).unwrap();
        let hi = timing(&cfg, &tech, OperatingPoint::new(1.1, 1.2)).unwrap();
        assert!(hi.freq_ghz / lo.freq_ghz > 3.0);
    }

    #[test]
    fn inoperable_points_rejected() {
        let tech = Technology::fdsoi28();
        assert!(timing(&FpuConfig::sp_fma(), &tech, OperatingPoint::new(0.3, 0.0)).is_none());
    }

    #[test]
    fn more_stages_faster_clock() {
        let tech = Technology::fdsoi28();
        let mut shallow = FpuConfig::sp_fma();
        let mut deep = shallow;
        shallow.stages = 4;
        deep.stages = 8;
        let op = OperatingPoint::new(0.9, 1.2);
        let f_shallow = timing(&shallow, &tech, op).unwrap().freq_ghz;
        let f_deep = timing(&deep, &tech, op).unwrap().freq_ghz;
        assert!(f_deep > f_shallow * 1.3);
    }
}
