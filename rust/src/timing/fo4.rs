//! Per-component logic depth in FO4 — the technology-independent delay
//! currency FPGen's own models use ([1], [2]).
//!
//! Each structural block of a generated FMAC is assigned a depth in FO4
//! inverter delays from its size parameters. The constants are standard
//! datapath figures (parallel-prefix adders ≈ 2·log₂w, a 3:2 row ≈ 4
//! FO4 including local wiring, mux-tree shifters ≈ 1.4 FO4 per level);
//! a per-design-style sizing factor κ (see
//! [`crate::timing::pipeline::DesignStyle`]) absorbs cell sizing and
//! global wiring, and is the single calibrated timing parameter.

use crate::arch::booth::BoothRadix;
use crate::arch::generator::{FpuConfig, FpuKind};
use crate::arch::tree::TreeKind;

/// Depth of one 3:2 compressor level including wiring, by topology: a
/// Wallace tree's cross-column wires add ~50% to the cell delay, while
/// array and ZM rows talk only to their neighbours (this is why an
/// n-row array is nowhere near n/log(n) times slower than Wallace in
/// silicon, and why the throughput units can afford it).
pub fn csa_level_fo4(tree: TreeKind) -> f64 {
    match tree {
        TreeKind::Wallace => 4.2,
        TreeKind::Array => 2.8,
        TreeKind::Zm => 3.2,
    }
}

/// Depth of the addend-merge 3:2 row in an FMA (Wallace-class wiring).
pub const CSA_LEVEL_FO4: f64 = 4.2;

/// Register overhead per pipeline stage (setup + clk-to-Q + margin).
pub const REG_OVERHEAD_FO4: f64 = 3.0;

/// Parallel-prefix carry-propagate adder of width `w`.
pub fn cpa_fo4(w: u32) -> f64 {
    2.0 * (w.max(2) as f64).log2() + 2.0
}

/// Barrel shifter over `w` positions (mux tree).
pub fn shifter_fo4(w: u32) -> f64 {
    1.4 * (w.max(2) as f64).log2().ceil() + 1.0
}

/// Leading-zero anticipator over `w` bits.
pub fn lza_fo4(w: u32) -> f64 {
    1.5 * (w.max(2) as f64).log2() + 2.0
}

/// Rounder (increment + select) over `w` result bits.
pub fn rounder_fo4(w: u32) -> f64 {
    0.8 * (w.max(2) as f64).log2() + 3.0
}

/// Booth recode + partial-product mux depth. Booth-3 must also generate
/// the ×3 hard multiple through a short CPA; that pre-add runs mostly in
/// parallel with recoding, so its exposed depth is ~70% of the CPA.
pub fn booth_fo4(radix: BoothRadix, sig_bits: u32) -> f64 {
    match radix {
        BoothRadix::Booth2 => 4.0,
        BoothRadix::Booth3 => (5.0f64).max(0.7 * cpa_fo4(sig_bits + 2)),
    }
}

/// Logic-depth breakdown of one FPU configuration, in FO4 (before the
/// design-style sizing factor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DepthBreakdown {
    /// Multiplier: Booth + tree (+ CPA + rounder for CMA).
    pub multiply: f64,
    /// Add/merge path: align, wide add, LZA, normalize, round.
    pub add: f64,
}

impl DepthBreakdown {
    /// Total critical-path depth.
    pub fn total(&self) -> f64 {
        self.multiply + self.add
    }
}

/// Critical-path depth of a configuration.
///
/// FMA: Booth → tree → (3:2 merge with the pre-aligned addend — the
/// alignment itself overlaps the multiply) → wide CPA → normalize →
/// round. LZA overlaps the CPA; only ~30% of it is exposed.
///
/// CMA: a complete rounded multiplier followed by a complete FP adder —
/// longer in total, but each half is shallow, which is what lets the
/// CMA pipeline to a faster clock and expose the short accumulate path.
pub fn depth(cfg: &FpuConfig) -> DepthBreakdown {
    let m = cfg.precision.format().sig_bits;
    let mul_cfg = cfg.multiplier();
    let tree = mul_cfg.tree_depth() as f64 * csa_level_fo4(cfg.tree);
    let booth = booth_fo4(cfg.booth, m);
    match cfg.kind {
        FpuKind::Fma => {
            let w = 3 * m + 5;
            let multiply = booth + tree;
            let add = CSA_LEVEL_FO4            // 3:2 merge of addend
                + cpa_fo4(w)                   // wide completion add
                + 0.3 * lza_fo4(w)             // LZA mostly hidden under CPA
                + shifter_fo4(w)               // normalizer
                + rounder_fo4(m);              // single rounder
            DepthBreakdown { multiply, add }
        }
        FpuKind::Cma => {
            let multiply = booth
                + tree
                + cpa_fo4(mul_cfg.window())    // multiplier's own CPA
                + rounder_fo4(m);              // first rounder
            let aw = m + 4;
            let add = 3.0                      // exponent compare
                + shifter_fo4(aw)              // align
                + cpa_fo4(aw)                  // significand add
                + 0.3 * lza_fo4(aw)
                + shifter_fo4(aw)              // normalize
                + rounder_fo4(m);              // second rounder
            DepthBreakdown { multiply, add }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::generator::FpuConfig;

    #[test]
    fn component_models_monotone_in_width() {
        assert!(cpa_fo4(108) > cpa_fo4(50));
        assert!(shifter_fo4(164) > shifter_fo4(77));
        assert!(lza_fo4(164) > lza_fo4(77));
        assert!(rounder_fo4(53) > rounder_fo4(24));
    }

    #[test]
    fn booth3_pays_triple_generation() {
        assert!(booth_fo4(BoothRadix::Booth3, 53) > booth_fo4(BoothRadix::Booth2, 53));
        // ... and the cost grows with width (the ×3 CPA is wider).
        assert!(booth_fo4(BoothRadix::Booth3, 53) > booth_fo4(BoothRadix::Booth3, 24));
    }

    #[test]
    fn cma_total_longer_but_accumulate_path_shorter() {
        // Fig. 1's trade: CMA total latency > FMA total latency, but a
        // dependent *accumulation* only traverses the CMA's add half —
        // far less than the FMA's full path.
        let sp_fma = depth(&FpuConfig::sp_fma());
        let mut cma_like = FpuConfig::sp_cma();
        // Compare like-for-like (same booth/tree as the FMA).
        cma_like.booth = FpuConfig::sp_fma().booth;
        cma_like.tree = FpuConfig::sp_fma().tree;
        let sp_cma = depth(&cma_like);
        assert!(sp_cma.total() > sp_fma.total(), "cascade has longer total path");
        assert!(sp_cma.add < 0.7 * sp_fma.total(), "cascade accumulation path is shorter");
    }

    #[test]
    fn dp_deeper_than_sp() {
        for (dp, sp) in [
            (FpuConfig::dp_fma(), FpuConfig::sp_fma()),
            (FpuConfig::dp_cma(), FpuConfig::sp_cma()),
        ] {
            assert!(depth(&dp).total() > depth(&sp).total());
        }
    }

    #[test]
    fn paper_units_depth_sanity() {
        // All four units must land in the plausible FMAC-depth window
        // (50–150 FO4 of raw logic).
        for cfg in FpuConfig::fpmax_units() {
            let d = depth(&cfg).total();
            assert!((50.0..150.0).contains(&d), "{}: {d:.1} FO4", cfg.name());
        }
    }

    #[test]
    fn wallace_shortens_multiplier_path() {
        let mut wallace = FpuConfig::dp_fma();
        wallace.tree = crate::arch::tree::TreeKind::Wallace;
        let array = FpuConfig::dp_fma();
        assert!(depth(&wallace).multiply < depth(&array).multiply);
    }
}
