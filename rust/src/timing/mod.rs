//! Timing models: per-component FO4 logic depth ([`fo4`]) and pipeline
//! partitioning / achievable frequency at an operating point
//! ([`pipeline`]).

pub mod fo4;
pub mod pipeline;

pub use fo4::{depth, DepthBreakdown};
pub use pipeline::{nominal_op, stage_depth_fo4, timing, DesignStyle, Timing};
