//! Architecture-level cross-implementation test suites (compiled only
//! under `cfg(test)` via the declaration in `arch/mod.rs`).
//!
//! * [`edge_vectors`] — the cranelift `fma.clif` run-test vectors
//!   (±0, ±Inf, NaN propagation, subnormals, and the six x86_64
//!   regression cases), executed through all four Table I presets at
//!   both engine fidelity tiers.
//! * [`small_formats`] — hand-built transprecision edge vectors
//!   (subnormal-heavy, NaN-payload, near-overflow, FP8 saturation)
//!   through the scalar spec, the SoA lane blocks, and the packed-SWAR
//!   word ops for FP16/BF16/FP8.

mod edge_vectors;
mod small_formats;
