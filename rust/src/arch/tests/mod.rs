//! Architecture-level cross-implementation test suites (compiled only
//! under `cfg(test)` via the declaration in `arch/mod.rs`).
//!
//! * [`edge_vectors`] — the cranelift `fma.clif` run-test vectors
//!   (±0, ±Inf, NaN propagation, subnormals, and the six x86_64
//!   regression cases), executed through all four Table I presets at
//!   both engine fidelity tiers.

mod edge_vectors;
