//! FMA edge-case vectors ported from cranelift's `fma.clif` run-tests
//! (retrieved via the wasmtime / PKU-ASAL band0 file sets): exact-zero
//! sign rules, infinity arithmetic, NaN propagation, subnormal inputs and
//! outputs, and the six x86_64-pc-windows-gnu regression triples from
//! bytecodealliance/wasmtime#4512.
//!
//! Each vector runs through **all four Table I presets at both engine
//! fidelity tiers**. The clif file states its expectations for *fused*
//! f32 semantics, so those constants are asserted bit-exactly on the SP
//! FMA preset; the CMA presets are asserted against the two-rounding
//! cascade reference, and the DP presets against the exactly-widened f64
//! references — on the regression vectors, which were chosen to stress
//! single rounding, fused and cascade genuinely disagree, and that
//! disagreement is part of what is being checked.

use crate::arch::engine::{Datapath, Fidelity, UnitDatapath};
use crate::arch::generator::{FpuConfig, FpuKind};
use crate::arch::{softfloat, Precision};

/// Build an f32 bit pattern from an integer hex significand and a power
/// of two: `(-1)^neg · mant · 2^exp`. Rust has no hex-float literals, so
/// `0x1.3b88e6p14` is written `hx(false, 0x13b88e6, 14 - 24)` (six
/// fraction digits shift the point by 24 bits). The helper asserts the
/// value is exactly representable, so a transcription slip cannot pass
/// silently.
fn hx(neg: bool, mant: u64, exp: i32) -> u32 {
    let v = mant as f64 * 2f64.powi(exp);
    let f = v as f32;
    assert_eq!(f as f64, v, "constant {mant:#x}·2^{exp} is not an exact f32");
    let f = if neg { -f } else { f };
    f.to_bits()
}

/// One ported run-test: operands and the clif-stated fused-f32 result.
struct ClifVector {
    a: u32,
    b: u32,
    c: u32,
    fused: u32,
}

fn v(a: u32, b: u32, c: u32, fused: u32) -> ClifVector {
    ClifVector { a, b, c, fused }
}

#[rustfmt::skip]
fn clif_vectors() -> Vec<ClifVector> {
    let inf = f32::INFINITY.to_bits();
    let ninf = f32::NEG_INFINITY.to_bits();
    let pz = 0u32;
    let nz = (-0.0f32).to_bits();
    vec![
        // Plain values.
        // %fma_f32(0x9.0, 0x9.0, 0x9.0) == 0x1.680000p6
        v(hx(false, 0x9, 0), hx(false, 0x9, 0), hx(false, 0x9, 0), hx(false, 0x168, -2)),
        // %fma_f32(0x83.0, 0x2.68091p6, 0x9.88721p1) == 0x1.3b88e6p14
        v(hx(false, 0x83, 0), hx(false, 0x268091, 6 - 20), hx(false, 0x988721, 1 - 20),
          hx(false, 0x13b88e6, 14 - 24)),
        // Zero sign rules.
        v(pz, pz, pz, pz),
        v(pz, pz, nz, pz),
        v(pz, nz, pz, pz),
        v(nz, pz, pz, pz),
        // Infinity arithmetic.
        v(ninf, ninf, pz, inf),
        v(inf, ninf, pz, ninf),
        v(ninf, inf, pz, ninf),
        v(inf, ninf, ninf, ninf),
        v(ninf, inf, ninf, ninf),
        // F32 epsilon / max / min-positive.
        // eps·eps + eps == 0x1.000002p-23
        v(hx(false, 1, -23), hx(false, 1, -23), hx(false, 1, -23), hx(false, 0x1000002, -23 - 24)),
        v(pz, pz, hx(false, 1, -23), hx(false, 1, -23)),
        // max·max + max overflows to +Inf.
        v(f32::MAX.to_bits(), f32::MAX.to_bits(), f32::MAX.to_bits(), inf),
        v(pz, pz, f32::MAX.to_bits(), f32::MAX.to_bits()),
        v(hx(false, 1, -126), hx(false, 1, -126), hx(false, 1, -126), hx(false, 1, -126)),
        v(pz, pz, hx(false, 1, -126), hx(false, 1, -126)),
        // F32 subnormals. 0x0.800000p-126 = 2^-127; 0x0.000002p-126 = 2^-149.
        v(hx(false, 1, -127), hx(false, 1, -127), hx(false, 1, -127), hx(false, 1, -127)),
        v(hx(false, 1, -127), hx(false, 1, -127), pz, pz),
        v(pz, pz, hx(false, 1, -127), hx(false, 1, -127)),
        v(hx(false, 1, -149), hx(false, 1, -149), hx(false, 1, -149), hx(false, 1, -149)),
        v(hx(false, 1, -149), hx(false, 1, -149), pz, pz),
        v(pz, pz, hx(false, 1, -149), hx(false, 1, -149)),
        // x86_64-pc-windows-gnu regression vectors (wasmtime #4512).
        v(hx(false, 1, 100), hx(false, 1, 100), ninf, ninf),
        v(hx(false, 0x1fffffe, -1), hx(false, 0x1000004, 28 - 24), hx(false, 0x1fc, 5 - 8),
          hx(false, 0x1000002, 52 - 24)),
        v(hx(false, 0x184ae3, 125 - 20), hx(false, 0x16, -141 - 4), hx(false, 1, -149),
          hx(false, 0x10b37c2, -15 - 24)),
        v(hx(false, 0x100001, 50 - 20), hx(false, 0x11, 50 - 4), hx(false, 1, -149),
          hx(false, 0x1100012, 100 - 24)),
        v(hx(false, 0x1000002, 50 - 24), hx(false, 0x18, 50 - 4), hx(true, 1, -149),
          hx(false, 0x1800002, 100 - 24)),
        v(hx(false, 0x183bd78, 4 - 24), hx(true, 0x1c, 118 - 4), hx(true, 0x1344108, -2 - 24),
          hx(true, 0x15345ca, 123 - 24)),
    ]
}

/// The `%fma_is_nan_f32` vectors: any result is acceptable as long as it
/// is a NaN.
fn clif_nan_vectors() -> Vec<(u32, u32, u32)> {
    let inf = f32::INFINITY.to_bits();
    let ninf = f32::NEG_INFINITY.to_bits();
    let nan = f32::NAN.to_bits();
    let nnan = (-f32::NAN).to_bits();
    vec![
        (inf, ninf, inf),
        (ninf, inf, inf),
        (ninf, ninf, ninf),
        (nan, 0, 0),
        (0, nan, 0),
        (0, 0, nan),
        (nnan, 0, 0),
        (0, nnan, 0),
        (0, 0, nnan),
    ]
}

/// Every preset at every fidelity tier.
fn all_datapaths() -> Vec<(FpuConfig, UnitDatapath)> {
    let mut out = Vec::new();
    for cfg in FpuConfig::fpmax_units() {
        for fidelity in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd] {
            out.push((cfg, UnitDatapath::generate(&cfg, fidelity)));
        }
    }
    out
}

/// Host-computed reference for one preset on (widened) clif operands.
fn preset_reference(cfg: &FpuConfig, a: u32, b: u32, c: u32) -> u64 {
    let (fa, fb, fc) = (f32::from_bits(a), f32::from_bits(b), f32::from_bits(c));
    match (cfg.precision, cfg.kind) {
        (Precision::Single, FpuKind::Fma) => fa.mul_add(fb, fc).to_bits() as u64,
        (Precision::Single, FpuKind::Cma) => (fa * fb + fc).to_bits() as u64,
        // Widening f32 → f64 is exact, so the DP references are the same
        // mathematical operands.
        (Precision::Double, FpuKind::Fma) => {
            (fa as f64).mul_add(fb as f64, fc as f64).to_bits()
        }
        (Precision::Double, FpuKind::Cma) => ((fa as f64) * (fb as f64) + (fc as f64)).to_bits(),
        // Small formats: the unit consumes the *narrowed* operands (see
        // `widen`), so the reference narrows first, computes exactly in
        // f64 (products of ≤11-bit significands are exact), and narrows
        // the result — the same double-rounding-innocuous host path the
        // fuzz harness uses.
        (_, kind) => {
            let fmt = cfg.precision.format();
            let nf = |x: f64| softfloat::to_f64(fmt, softfloat::from_f64(fmt, x));
            let (a, b, c) = (nf(fa as f64), nf(fb as f64), nf(fc as f64));
            match kind {
                FpuKind::Fma => softfloat::from_f64(fmt, a.mul_add(b, c)),
                FpuKind::Cma => softfloat::from_f64(fmt, nf(a * b) + c),
            }
        }
    }
}

/// Lift f32 operand bits into the operand encoding a preset consumes.
fn widen(cfg: &FpuConfig, bits: u32) -> u64 {
    match cfg.precision {
        Precision::Single => bits as u64,
        Precision::Double => (f32::from_bits(bits) as f64).to_bits(),
        // Small formats narrow (round-to-nearest-even) — lossy, which is
        // fine: the reference consumes the identical narrowed operands.
        _ => softfloat::from_f64(cfg.precision.format(), f32::from_bits(bits) as f64),
    }
}

#[test]
fn clif_fused_expectations_hold_on_sp_fma_both_tiers() {
    let cfg = FpuConfig::sp_fma();
    for fidelity in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd] {
        let dp = UnitDatapath::generate(&cfg, fidelity);
        for (i, t) in clif_vectors().iter().enumerate() {
            let got = dp.fmac_one(t.a as u64, t.b as u64, t.c as u64) as u32;
            assert_eq!(
                got, t.fused,
                "vector {i} ({fidelity:?}): fma({:#x},{:#x},{:#x}) = {got:#x}, clif says {:#x}",
                t.a, t.b, t.c, t.fused
            );
        }
    }
}

#[test]
fn clif_vectors_all_presets_both_tiers_match_references() {
    for (cfg, dp) in all_datapaths() {
        for (i, t) in clif_vectors().iter().enumerate() {
            let (a, b, c) = (widen(&cfg, t.a), widen(&cfg, t.b), widen(&cfg, t.c));
            let got = dp.fmac_one(a, b, c);
            let want = preset_reference(&cfg, t.a, t.b, t.c);
            assert_eq!(
                got,
                want,
                "vector {i} on {} at {:?}",
                cfg.name(),
                dp.fidelity()
            );
        }
    }
}

#[test]
fn clif_regression_vectors_discriminate_fused_from_cascade() {
    // The #4512 triple below was constructed so that a double rounding
    // gives a different answer — confirm our CMA presets actually take
    // the cascade result, not the fused one.
    let a = hx(false, 0x1fffffe, -1);
    let b = hx(false, 0x1000004, 28 - 24);
    let c = hx(false, 0x1fc, 5 - 8);
    let fused = f32::from_bits(a).mul_add(f32::from_bits(b), f32::from_bits(c));
    let cascade = f32::from_bits(a) * f32::from_bits(b) + f32::from_bits(c);
    assert_ne!(fused.to_bits(), cascade.to_bits(), "vector no longer discriminates");
    let sp_cma = UnitDatapath::generate(&FpuConfig::sp_cma(), Fidelity::GateLevel);
    assert_eq!(
        sp_cma.fmac_one(a as u64, b as u64, c as u64) as u32,
        cascade.to_bits()
    );
}

#[test]
fn clif_vectors_through_the_simd_lane_batch() {
    // The scalar `fmac_one` of the SIMD tier is the word-level spec; the
    // lane kernels only run on the *batch* path. Push the whole ported
    // vector set through `fmac_batch` (28 vectors: three full lane blocks
    // plus a scalar remainder, with specials peeling in-block) on every
    // preset.
    use crate::workloads::throughput::OperandTriple;
    for cfg in FpuConfig::fpmax_units() {
        let dp = UnitDatapath::generate(&cfg, Fidelity::WordSimd);
        let vectors = clif_vectors();
        let triples: Vec<OperandTriple> = vectors
            .iter()
            .map(|t| OperandTriple {
                a: widen(&cfg, t.a),
                b: widen(&cfg, t.b),
                c: widen(&cfg, t.c),
            })
            .collect();
        let mut out = vec![0u64; triples.len()];
        dp.fmac_batch(&triples, &mut out);
        for (i, t) in vectors.iter().enumerate() {
            assert_eq!(
                out[i],
                preset_reference(&cfg, t.a, t.b, t.c),
                "vector {i} on {} via the lane batch",
                cfg.name()
            );
        }
        // NaN vectors: any NaN is acceptable, also via the batch path.
        let fmt = cfg.precision.format();
        let nan_triples: Vec<OperandTriple> = clif_nan_vectors()
            .iter()
            .map(|&(a, b, c)| OperandTriple {
                a: widen(&cfg, a),
                b: widen(&cfg, b),
                c: widen(&cfg, c),
            })
            .collect();
        let mut out = vec![0u64; nan_triples.len()];
        dp.fmac_batch(&nan_triples, &mut out);
        for (i, &bits) in out.iter().enumerate() {
            assert_eq!(
                crate::arch::decode(fmt, bits).class,
                crate::arch::Class::Nan,
                "NaN vector {i} on {} via the lane batch: got {bits:#x}",
                cfg.name()
            );
        }
    }
}

#[test]
fn clif_nan_vectors_produce_nan_on_every_preset_and_tier() {
    for (cfg, dp) in all_datapaths() {
        let fmt = cfg.precision.format();
        for (i, &(a, b, c)) in clif_nan_vectors().iter().enumerate() {
            let got = dp.fmac_one(widen(&cfg, a), widen(&cfg, b), widen(&cfg, c));
            let class = crate::arch::decode(fmt, got).class;
            assert_eq!(
                class,
                crate::arch::Class::Nan,
                "NaN vector {i} on {} at {:?}: got {got:#x}",
                cfg.name(),
                dp.fidelity()
            );
        }
    }
}
