//! Hand-built edge vectors for the transprecision tiers (FP16, BF16,
//! FP8 E4M3/E5M2): subnormal-heavy operands, NaN payloads, near-overflow
//! rounding, and FP8 saturation — the regions where narrow formats
//! diverge hardest from the SP/DP intuitions the original vector suite
//! encodes.
//!
//! Every vector runs through three independent implementations of the
//! same format semantics: the scalar softfloat spec (plus the generated
//! unit datapaths at all three fidelity tiers), the SoA lane-block batch
//! path, and the packed-SWAR word entry point. Expectations are stated
//! as explicit bit patterns built from `Format`'s structural constants —
//! never computed by the code under test.

use crate::arch::engine::{Datapath, Fidelity, UnitDatapath};
use crate::arch::generator::FpuConfig;
use crate::arch::rounding::RoundMode;
use crate::arch::softfloat::{self, lanes};
use crate::arch::{decode, Class, Format, Precision};
use crate::workloads::throughput::OperandTriple;

/// The four small-format tiers.
const SMALL: [Precision; 4] =
    [Precision::Half, Precision::Bfloat16, Precision::Fp8E4M3, Precision::Fp8E5M2];

/// Encode `v` in `fmt`, asserting exact representability so a vector
/// transcription slip cannot pass silently.
fn bits_of(fmt: Format, v: f64) -> u64 {
    let bits = softfloat::from_f64(fmt, v);
    assert_eq!(softfloat::to_f64(fmt, bits), v, "{v} is not exact in {fmt}");
    bits
}

/// What a vector demands of the result.
#[derive(Clone, Copy)]
enum Want {
    /// Exact bit pattern.
    Bits(u64),
    /// Any NaN encoding.
    Nan,
}

struct Vector {
    a: u64,
    b: u64,
    c: u64,
    want: Want,
    label: &'static str,
}

/// The format-generic edge set: each entry is exactly representable (and
/// meaningful) in all four small formats.
fn edge_vectors(fmt: Format) -> Vec<Vector> {
    let one = bits_of(fmt, 1.0);
    let two = bits_of(fmt, 2.0);
    let half = bits_of(fmt, 0.5);
    let max = fmt.max_finite(false);
    let sub1 = 1u64; // smallest positive subnormal: 2^qmin
    let min_normal = bits_of(fmt, 2f64.powi(fmt.emin()));
    let v = |a, b, c, want, label| Vector { a, b, c, want, label };
    vec![
        // Near-overflow and saturation to infinity.
        v(max, two, fmt.zero(false), Want::Bits(fmt.inf(false)), "max*2 overflows to +Inf"),
        v(max, one, max, Want::Bits(fmt.inf(false)), "max+max overflows to +Inf"),
        v(max, one, fmt.zero(false), Want::Bits(max), "max*1 stays exactly max"),
        v(
            fmt.max_finite(true),
            two,
            fmt.zero(false),
            Want::Bits(fmt.inf(true)),
            "-max*2 overflows to -Inf",
        ),
        // Subnormal-heavy arithmetic at the bottom of the range.
        v(sub1, one, sub1, Want::Bits(2), "sub1+sub1 doubles exactly (still subnormal)"),
        v(sub1, sub1, fmt.zero(false), Want::Bits(fmt.zero(false)), "sub1^2 underflows to +0"),
        v(sub1, sub1, sub1, Want::Bits(sub1), "sub1^2 is RNE-sticky against sub1"),
        v(
            min_normal,
            half,
            fmt.zero(false),
            Want::Bits(bits_of(fmt, 2f64.powi(fmt.emin() - 1))),
            "min_normal/2 lands exactly subnormal",
        ),
        // NaN payloads and invalid operations.
        v(fmt.qnan() | 1, one, fmt.zero(false), Want::Nan, "NaN payload propagates as NaN"),
        v(fmt.inf(false), fmt.zero(false), one, Want::Nan, "Inf*0 is invalid"),
        v(fmt.inf(false), one, fmt.inf(true), Want::Nan, "Inf-Inf is invalid"),
        v(fmt.inf(false), one, fmt.zero(false), Want::Bits(fmt.inf(false)), "Inf propagates"),
        // Zero sign rules under RNE.
        v(
            fmt.zero(false),
            fmt.zero(true),
            fmt.zero(false),
            Want::Bits(fmt.zero(false)),
            "(+0)*(-0)+(+0) is +0 under RNE",
        ),
    ]
}

fn check(fmt: Format, got: u64, want: Want, ctx: &str, label: &str) {
    match want {
        Want::Bits(bits) => assert_eq!(got, bits, "{ctx}: {label} (got {got:#x})"),
        Want::Nan => assert_eq!(
            decode(fmt, got).class,
            Class::Nan,
            "{ctx}: {label} (got {got:#x}, expected any NaN)"
        ),
    }
}

#[test]
fn small_format_edge_vectors_scalar_spec_and_all_tiers() {
    for precision in SMALL {
        let fmt = precision.format();
        let cfg = FpuConfig::fma_of(precision);
        let tiers: Vec<(Fidelity, UnitDatapath)> =
            [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd]
                .into_iter()
                .map(|f| (f, UnitDatapath::generate(&cfg, f)))
                .collect();
        for vec in edge_vectors(fmt) {
            // The scalar softfloat spec is the root reference.
            let spec = softfloat::fma(fmt, RoundMode::NearestEven, vec.a, vec.b, vec.c).bits;
            check(fmt, spec, vec.want, &format!("{fmt} scalar spec"), vec.label);
            // Every fidelity tier of the generated FMA unit agrees.
            for (fidelity, dp) in &tiers {
                let got = dp.fmac_one(vec.a, vec.b, vec.c);
                check(fmt, got, vec.want, &format!("{fmt} {fidelity:?}"), vec.label);
            }
        }
    }
}

#[test]
fn small_format_edge_vectors_through_lane_batch() {
    // The SoA lane blocks only run on the batch path; push the whole
    // set through `fmac_batch` per format (specials peel in-block, the
    // tail exercises the sub-block remainder).
    for precision in SMALL {
        let fmt = precision.format();
        let dp = UnitDatapath::generate(&FpuConfig::fma_of(precision), Fidelity::WordSimd);
        let vectors = edge_vectors(fmt);
        let triples: Vec<OperandTriple> =
            vectors.iter().map(|v| OperandTriple { a: v.a, b: v.b, c: v.c }).collect();
        let mut out = vec![0u64; triples.len()];
        dp.fmac_batch(&triples, &mut out);
        for (got, vec) in out.iter().zip(&vectors) {
            check(fmt, *got, vec.want, &format!("{fmt} lane batch"), vec.label);
        }
    }
}

#[test]
fn small_format_edge_vectors_through_packed_words() {
    // The packed-SWAR entry point: pack the edge set 2-or-4-per-word
    // (padding the tail with inert +0 triples), run `fma_words`, unpack,
    // and hold every real slot to the same expectations.
    for precision in SMALL {
        let fmt = precision.format();
        assert!(lanes::packed::supports(fmt), "{fmt}");
        let epw = lanes::packed::elems_per_word(fmt);
        let vectors = edge_vectors(fmt);
        let mut padded: Vec<(u64, u64, u64)> =
            vectors.iter().map(|v| (v.a, v.b, v.c)).collect();
        while padded.len() % epw != 0 {
            padded.push((0, 0, 0));
        }
        let words = padded.len() / epw;
        let (mut aw, mut bw, mut cw) = (Vec::new(), Vec::new(), Vec::new());
        let mut buf = vec![0u64; epw];
        for ch in padded.chunks(epw) {
            for (sel, dst) in [(0usize, &mut aw), (1, &mut bw), (2, &mut cw)] {
                for (i, t) in ch.iter().enumerate() {
                    buf[i] = match sel {
                        0 => t.0,
                        1 => t.1,
                        _ => t.2,
                    };
                }
                dst.push(lanes::packed::pack_word(fmt, &buf));
            }
        }
        let mut ow = vec![0u32; words];
        lanes::packed::fma_words(fmt, &aw, &bw, &cw, &mut ow);
        let mut elems = vec![0u64; epw];
        for (wi, &word) in ow.iter().enumerate() {
            lanes::packed::unpack_word(fmt, word, &mut elems);
            for (ei, &got) in elems.iter().enumerate() {
                let slot = wi * epw + ei;
                if slot >= vectors.len() {
                    assert_eq!(got, 0, "{fmt}: pad slot {slot} must stay +0");
                    continue;
                }
                let vec = &vectors[slot];
                check(fmt, got, vec.want, &format!("{fmt} packed slot {slot}"), vec.label);
            }
        }
    }
}

#[test]
fn small_format_cma_cascade_matches_two_step_scalar() {
    // The CMA presets must take the cascade (two-rounding) result on
    // every edge vector, at every tier and through the packed cascade
    // entry point — the reference is the literal mul-then-add scalar
    // composition.
    for precision in SMALL {
        let fmt = precision.format();
        let cfg = FpuConfig::cma_of(precision);
        let tiers: Vec<(Fidelity, UnitDatapath)> =
            [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd]
                .into_iter()
                .map(|f| (f, UnitDatapath::generate(&cfg, f)))
                .collect();
        for vec in edge_vectors(fmt) {
            let p = softfloat::mul(fmt, RoundMode::NearestEven, vec.a, vec.b).bits;
            let want = softfloat::add(fmt, RoundMode::NearestEven, p, vec.c).bits;
            for (fidelity, dp) in &tiers {
                assert_eq!(
                    dp.fmac_one(vec.a, vec.b, vec.c),
                    want,
                    "{fmt} {fidelity:?}: {}",
                    vec.label
                );
            }
            let epw = lanes::packed::elems_per_word(fmt);
            let mut col = vec![0u64; epw];
            let mk = |x: u64, col: &mut Vec<u64>| {
                col.fill(x);
                lanes::packed::pack_word(fmt, col)
            };
            let (aw, bw, cw) =
                ([mk(vec.a, &mut col)], [mk(vec.b, &mut col)], [mk(vec.c, &mut col)]);
            let mut ow = [0u32; 1];
            lanes::packed::cma_words(fmt, &aw, &bw, &cw, &mut ow);
            let mut elems = vec![0u64; epw];
            lanes::packed::unpack_word(fmt, ow[0], &mut elems);
            for (ei, &got) in elems.iter().enumerate() {
                assert_eq!(got, want, "{fmt} packed cascade lane {ei}: {}", vec.label);
            }
        }
    }
}

#[test]
fn fp8_e4m3_saturation_discriminates_round_from_overflow() {
    // FP8 E4M3's top binade has spacing 16: 15*15 = 225 must *round*
    // (down to 224, still finite), while 16*16 = 256 crosses the
    // max+half-spacing threshold (248) and saturates to +Inf. Both via
    // the scalar spec and the packed words — this is the saturation
    // boundary OCP E4M3 moves and our IEEE-interchange variant keeps.
    let fmt = Format::FP8E4M3;
    let fifteen = bits_of(fmt, 15.0);
    let sixteen = bits_of(fmt, 16.0);
    let z = fmt.zero(false);
    let round_want = bits_of(fmt, 224.0);
    let rne = RoundMode::NearestEven;
    assert_eq!(softfloat::fma(fmt, rne, fifteen, fifteen, z).bits, round_want);
    assert_eq!(softfloat::fma(fmt, rne, sixteen, sixteen, z).bits, fmt.inf(false));
    // 240 (max) is representable and must come back exactly.
    assert_eq!(softfloat::fma(fmt, rne, sixteen, fifteen, z).bits, fmt.max_finite(false));
    let pack1 = |x: u64| [lanes::packed::pack_word(fmt, &[x, x, x, x])];
    let mut ow = [0u32; 1];
    lanes::packed::fma_words(fmt, &pack1(fifteen), &pack1(fifteen), &pack1(z), &mut ow);
    let mut elems = [0u64; 4];
    lanes::packed::unpack_word(fmt, ow[0], &mut elems);
    assert_eq!(elems, [round_want; 4], "packed saturation rounding");
    lanes::packed::fma_words(fmt, &pack1(sixteen), &pack1(sixteen), &pack1(z), &mut ow);
    lanes::packed::unpack_word(fmt, ow[0], &mut elems);
    assert_eq!(elems, [fmt.inf(false); 4], "packed overflow to Inf");
}
