//! Differential conformance fuzzing for the FMAC datapaths.
//!
//! The trust story behind every tier swap in this repo is bit-identity:
//! gate-level structural simulation == scalar word-level softfloat ==
//! lane-batched word-simd kernels (scalar SoA *and* `std::simd` stages)
//! == the host CPU's own IEEE-754 hardware. This module checks that
//! claim the way wasmtime's differential oracles do: run the same
//! seeded operand stream through N independent engines and diff every
//! result, shrinking any disagreement to a minimal counterexample.
//!
//! Two operand generators feed the diff:
//!
//! * [`StreamKind::UniformBits`] — raw uniform bit patterns (every
//!   class appears, specials at their natural ~1/256 / ~1/2048 rate);
//! * [`StreamKind::Structured`] — bit-pattern stratified: subnormals,
//!   exponent boundaries, sparse (tie-prone) significands, NaN
//!   payloads, exact powers of two, near-overflow, and **cancellation
//!   pairs** (`c ≈ -round(a·b)`), the stratum that separates fused from
//!   cascade semantics on nearly every inexact product.
//!
//! Failures are auto-minimized by bit-flip shrinking (clear set bits /
//! zero whole operands while the disagreement persists) and rendered in
//! the `rust/src/arch/tests/edge_vectors.rs` `v(a, b, c, want)` format,
//! ready to promote into the permanent corpus (see `docs/simd.md`).
//!
//! The harness is deliberately engine-agnostic: [`Engine`] is a label
//! plus a closure, so the planted-bug self-tests (is the fuzzer able to
//! *find* a wrong rounding constant?) plug in the same way the real
//! tiers do.

use super::fp::{decode, Class, Format};
use super::generator::{FpuKind, FpuUnit};
use super::rounding::RoundMode;
use super::softfloat::{self, lanes};
use crate::util::Rng;

/// The four op kinds the chip sequencer issues and the lane kernels
/// implement. All are checked at RNE, the only mode the burst paths run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Fused `round(a·b + c)` — single rounding.
    Fma,
    /// Cascade `round(round(a·b) + c)` — the CMA units' two roundings.
    Cma,
    /// `round(a·b)`.
    Mul,
    /// `round(a + c)` (`b` is ignored).
    Add,
}

impl OpKind {
    pub const ALL: [OpKind; 4] = [OpKind::Fma, OpKind::Cma, OpKind::Mul, OpKind::Add];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Fma => "fma",
            OpKind::Cma => "cma",
            OpKind::Mul => "mul",
            OpKind::Add => "add",
        }
    }
}

/// One differential engine: a label plus a bits-in/bits-out evaluator.
///
/// `exact_nan` selects the comparison rule: the internal tiers all
/// produce the canonical quiet NaN, so they must match bit-for-bit; the
/// host's NaN payload propagation is platform-defined, so host engines
/// compare NaN results by class only.
pub struct Engine<'a> {
    pub label: &'static str,
    pub exact_nan: bool,
    eval: Box<dyn Fn(OpKind, u64, u64, u64) -> u64 + 'a>,
}

impl<'a> Engine<'a> {
    pub fn new(
        label: &'static str,
        exact_nan: bool,
        eval: impl Fn(OpKind, u64, u64, u64) -> u64 + 'a,
    ) -> Engine<'a> {
        Engine { label, exact_nan, eval: Box::new(eval) }
    }

    /// Evaluate one operation.
    pub fn eval(&self, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
        (self.eval)(kind, a, b, c)
    }
}

/// Bits of 1.0 in `fmt` (the multiplicative identity the gate engine
/// uses to express `Add` through the FMAC datapath).
fn one_bits(fmt: Format) -> u64 {
    (fmt.bias() as u64) << (fmt.sig_bits - 1)
}

/// Scalar word-level evaluation of `kind` (RNE) — the softfloat spec.
pub fn scalar_word(fmt: Format, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
    let m = RoundMode::NearestEven;
    match kind {
        OpKind::Fma => softfloat::fma(fmt, m, a, b, c).bits,
        OpKind::Cma => {
            let p = softfloat::mul(fmt, m, a, b);
            softfloat::add(fmt, m, p.bits, c).bits
        }
        OpKind::Mul => softfloat::mul(fmt, m, a, b).bits,
        OpKind::Add => softfloat::add(fmt, m, a, c).bits,
    }
}

/// Word-simd evaluation of `kind`: the triple replicated across a full
/// lane block through the dispatching lane kernels (vector stages under
/// `--features simd`, scalar SoA otherwise), lane 0 returned. Every
/// lane computes the same value, so replication exercises the full
/// 8-lane decode/multiply stages on each call.
pub fn simd_word(fmt: Format, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
    let av = [a; lanes::LANES];
    let bv = [b; lanes::LANES];
    let cv = [c; lanes::LANES];
    let mut out = [0u64; lanes::LANES];
    match kind {
        OpKind::Fma => lanes::fma_block_rne(fmt, &av, &bv, &cv, &mut out),
        OpKind::Cma => lanes::cma_block_rne(fmt, &av, &bv, &cv, &mut out),
        OpKind::Mul => lanes::mul_block_rne(fmt, &av, &bv, &mut out),
        OpKind::Add => lanes::add_block_rne(fmt, &av, &cv, &mut out),
    }
    out[0]
}

/// Scalar-reference lane evaluation (always the scalar SoA stages, even
/// under `--features simd`): the fourth internal voice of the diff.
pub fn scalar_lane(fmt: Format, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
    let av = [a; lanes::LANES];
    let bv = [b; lanes::LANES];
    let cv = [c; lanes::LANES];
    let mut out = [0u64; lanes::LANES];
    match kind {
        OpKind::Fma => lanes::scalar_ref::fma_block_rne(fmt, &av, &bv, &cv, &mut out),
        OpKind::Cma => lanes::scalar_ref::cma_block_rne(fmt, &av, &bv, &cv, &mut out),
        OpKind::Mul => lanes::scalar_ref::mul_block_rne(fmt, &av, &bv, &mut out),
        OpKind::Add => lanes::scalar_ref::add_block_rne(fmt, &av, &cv, &mut out),
    }
    out[0]
}

/// Host-hardware evaluation of `kind` through the CPU's own IEEE-754
/// units: `mul_add` is the fused reference (correctly rounded whether
/// it lowers to an FMA instruction or libm's `fma`), and the plain
/// `*`/`+` compositions are the cascade/mul/add references. Rust does
/// not enable FTZ/DAZ, so subnormal semantics match.
///
/// Sub-32-bit formats have no host arithmetic, so they evaluate in
/// `f64` and convert back per rounding step ([`softfloat::to_f64`] is
/// exact; the extra `f64` rounding is innocuous because `53 ≥
/// 2·sig_bits + 2` for every small format — Figueroa's theorem). That
/// makes this engine an *independent* correctly-rounded oracle for
/// FP16/BF16/FP8 built on the host's own `f64` units, not on the spec
/// rounder under test (`from_f64`'s final narrowing is the only shared
/// code).
pub fn host(fmt: Format, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
    if fmt.sig_bits == 24 {
        let (x, y, z) = (
            f32::from_bits(a as u32),
            f32::from_bits(b as u32),
            f32::from_bits(c as u32),
        );
        let r = match kind {
            OpKind::Fma => x.mul_add(y, z),
            OpKind::Cma => (x * y) + z,
            OpKind::Mul => x * y,
            OpKind::Add => x + z,
        };
        r.to_bits() as u64
    } else if fmt.width() == 64 {
        let (x, y, z) = (f64::from_bits(a), f64::from_bits(b), f64::from_bits(c));
        let r = match kind {
            OpKind::Fma => x.mul_add(y, z),
            OpKind::Cma => (x * y) + z,
            OpKind::Mul => x * y,
            OpKind::Add => x + z,
        };
        r.to_bits()
    } else {
        let (x, y, z) = (
            softfloat::to_f64(fmt, a),
            softfloat::to_f64(fmt, b),
            softfloat::to_f64(fmt, c),
        );
        match kind {
            // Small-format products are exact in f64 (2·sig_bits ≤ 22
            // bits), so mul_add and the narrowing are the only
            // roundings — single-rounding fused semantics hold.
            OpKind::Fma => softfloat::from_f64(fmt, x.mul_add(y, z)),
            OpKind::Cma => {
                // Cascade needs the intermediate rounded *into fmt*,
                // not into f64 — round-trip the product.
                let p = softfloat::to_f64(fmt, softfloat::from_f64(fmt, x * y));
                softfloat::from_f64(fmt, p + z)
            }
            OpKind::Mul => softfloat::from_f64(fmt, x * y),
            OpKind::Add => softfloat::from_f64(fmt, x + z),
        }
    }
}

/// Packed-SWAR evaluation of `kind`: the triple replicated across full
/// packed words through [`lanes::packed`], element 0 of word 0
/// returned. Only valid for formats with `width ≤ 16`.
pub fn packed_word(fmt: Format, kind: OpKind, a: u64, b: u64, c: u64) -> u64 {
    let epw = lanes::packed::elems_per_word(fmt);
    let wpb = lanes::LANES / epw;
    let word = |v: u64| lanes::packed::pack_word(fmt, &vec![v; epw]);
    let av = vec![word(a); wpb];
    let bv = vec![word(b); wpb];
    let cv = vec![word(c); wpb];
    let mut out = vec![0u32; wpb];
    match kind {
        OpKind::Fma => lanes::packed::fma_words(fmt, &av, &bv, &cv, &mut out),
        OpKind::Cma => lanes::packed::cma_words(fmt, &av, &bv, &cv, &mut out),
        OpKind::Mul => lanes::packed::mul_words(fmt, &av, &bv, &mut out),
        OpKind::Add => lanes::packed::add_words(fmt, &av, &cv, &mut out),
    }
    let mut elems = vec![0u64; epw];
    lanes::packed::unpack_word(fmt, out[0], &mut elems);
    elems[0]
}

/// The standard four-way engine set: gate tier (reference, first) vs
/// scalar word vs the dispatching word-simd kernels vs host hardware —
/// plus the always-scalar lane reference as a fifth voice when the
/// `simd` feature makes it a distinct code path.
///
/// `fma_unit`/`cma_unit` must be the gate-level FMA- and CMA-kind units
/// of the same format. The gate tier expresses `Mul` as `a·b + (-0)`
/// and `Add` as `a·1 + c` through the fused datapath — both identities
/// are exact under RNE (`x + (-0)` preserves every sign case because
/// the product is never an exact `-0`-cancelling partner, and `a·1` is
/// exact), so no separate gate mul/add hardware is needed.
pub fn standard_engines<'a>(fma_unit: &'a FpuUnit, cma_unit: &'a FpuUnit) -> Vec<Engine<'a>> {
    debug_assert_eq!(fma_unit.config.kind, FpuKind::Fma);
    debug_assert_eq!(cma_unit.config.kind, FpuKind::Cma);
    debug_assert_eq!(fma_unit.format, cma_unit.format);
    let fmt = fma_unit.format;
    let neg_zero = fmt.zero(true);
    let one = one_bits(fmt);
    let mut engines = vec![
        Engine::new("gate", true, move |kind, a, b, c| match kind {
            OpKind::Fma => fma_unit.fmac(a, b, c).bits,
            OpKind::Cma => cma_unit.fmac(a, b, c).bits,
            OpKind::Mul => fma_unit.fmac(a, b, neg_zero).bits,
            OpKind::Add => fma_unit.fmac(a, one, c).bits,
        }),
        Engine::new("scalar-word", true, move |kind, a, b, c| scalar_word(fmt, kind, a, b, c)),
        Engine::new("word-simd", true, move |kind, a, b, c| simd_word(fmt, kind, a, b, c)),
        Engine::new("host", false, move |kind, a, b, c| host(fmt, kind, a, b, c)),
    ];
    if cfg!(feature = "simd") {
        engines.push(Engine::new("scalar-lane", true, move |kind, a, b, c| {
            scalar_lane(fmt, kind, a, b, c)
        }));
    }
    if lanes::packed::supports(fmt) {
        engines.push(Engine::new("packed", true, move |kind, a, b, c| {
            packed_word(fmt, kind, a, b, c)
        }));
    }
    engines
}

/// Operand stream flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamKind {
    /// Raw uniform bit patterns.
    UniformBits,
    /// Bit-pattern stratified (subnormals, exponent boundaries, sparse
    /// significands, NaN payloads, cancellation pairs, ...).
    Structured,
}

/// Seeded operand-triple generator.
pub struct OperandGen {
    fmt: Format,
    stream: StreamKind,
    rng: Rng,
}

impl OperandGen {
    pub fn new(fmt: Format, stream: StreamKind, seed: u64) -> OperandGen {
        OperandGen { fmt, stream, rng: Rng::new(seed) }
    }

    /// Next `(a, b, c)` triple.
    pub fn next_triple(&mut self) -> (u64, u64, u64) {
        match self.stream {
            StreamKind::UniformBits => {
                let m = self.fmt.storage_mask();
                (self.rng.next_u64() & m, self.rng.next_u64() & m, self.rng.next_u64() & m)
            }
            StreamKind::Structured => {
                let a = self.structured_operand();
                let b = self.structured_operand();
                let c = match self.rng.below(4) {
                    // Cancellation pair: c ≈ -round(a·b). Exposes the
                    // residual a·b - round(a·b), the fused-vs-cascade
                    // discriminator, on every inexact product; the ±1-ulp
                    // jitter variant probes near-total cancellation.
                    0 | 1 => {
                        let p = softfloat::mul(self.fmt, RoundMode::NearestEven, a, b).bits;
                        let flipped = p ^ self.fmt.sign_bit();
                        if self.rng.chance(0.5) {
                            flipped
                        } else {
                            // Jitter the significand by one ulp (wrapping
                            // within storage — still a legal pattern).
                            (flipped.wrapping_add(1) & self.fmt.storage_mask())
                                | (flipped & self.fmt.sign_bit())
                        }
                    }
                    _ => self.structured_operand(),
                };
                (a, b, c)
            }
        }
    }

    /// A fraction with 0–3 random set bits: tie-prone products.
    fn sparse_frac(&mut self) -> u64 {
        let mut f = 0u64;
        for _ in 0..self.rng.below(4) {
            f |= 1u64 << self.rng.below(self.fmt.sig_bits as u64 - 1);
        }
        f & self.fmt.frac_mask()
    }

    /// One stratified operand.
    fn structured_operand(&mut self) -> u64 {
        let fmt = self.fmt;
        let sign = if self.rng.chance(0.5) { fmt.sign_bit() } else { 0 };
        let field = |biased: u64, frac: u64| sign | (biased << (fmt.sig_bits - 1)) | frac;
        match self.rng.below(8) {
            // Subnormals (dense and sparse fractions).
            0 => field(0, self.rng.next_u64() & fmt.frac_mask()),
            1 => field(0, self.sparse_frac().max(1)),
            // Exponent boundaries: qmin edge, just-normal, near/at emax
            // (the emax_biased case yields Inf/NaN operands).
            2 => {
                let edges = [
                    0,
                    1,
                    2,
                    fmt.emax_biased() - 2,
                    fmt.emax_biased() - 1,
                    fmt.emax_biased(),
                ];
                let biased = edges[self.rng.below(edges.len() as u64) as usize];
                field(biased, self.rng.next_u64() & fmt.frac_mask())
            }
            // Sparse significand at a uniform finite exponent: products
            // land exactly on round-to-even ties.
            3 => field(self.rng.below(fmt.emax_biased()), self.sparse_frac()),
            // NaN payloads (quiet and signaling-shaped) and infinities.
            4 => {
                if self.rng.chance(0.25) {
                    fmt.inf(sign != 0)
                } else {
                    let payload = (self.rng.next_u64() & fmt.frac_mask()).max(1);
                    field(fmt.emax_biased(), payload)
                }
            }
            // Exact powers of two (frac = 0) incl. ±0 at biased 0.
            5 => field(self.rng.below(fmt.emax_biased()), 0),
            // Near-overflow: all-ones fraction at the top finite binade.
            6 => field(fmt.emax_biased() - 1, fmt.frac_mask()),
            // Uniform finite (exponent-uniform, like Rng::f32_operand).
            _ => field(
                self.rng.below(fmt.emax_biased()),
                self.rng.next_u64() & fmt.frac_mask(),
            ),
        }
    }
}

/// One engine's disagreement with the reference on a triple.
#[derive(Debug, Clone)]
pub struct Mismatch {
    pub engine: &'static str,
    pub got: u64,
    pub want: u64,
}

/// A minimized failing case.
#[derive(Debug, Clone)]
pub struct Counterexample {
    pub kind: OpKind,
    pub fmt: Format,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    /// The triple as originally generated, before shrinking.
    pub original: (u64, u64, u64),
    /// Number of accepted shrink mutations.
    pub shrink_steps: usize,
    /// Engines disagreeing with the reference on the minimized triple.
    pub mismatches: Vec<Mismatch>,
}

impl Counterexample {
    /// Render in the `edge_vectors.rs` corpus format: `v(a, b, c, want)`
    /// with the gate/reference result as `want`, plus provenance. Hex
    /// width follows the storage width (8 digits for SP, 16 for DP, 4
    /// for the 16-bit formats, 2 for FP8).
    pub fn render_edge_vector(&self) -> String {
        let w = (self.fmt.width() / 4) as usize;
        let want = self.mismatches.first().map(|m| m.want).unwrap_or(0);
        let diffs: Vec<String> = self
            .mismatches
            .iter()
            .map(|m| format!("{}=0x{:0w$x}", m.engine, m.got, w = w))
            .collect();
        format!(
            "v(0x{:0w$x}, 0x{:0w$x}, 0x{:0w$x}, 0x{:0w$x}), // fuzz {} {}: {} (shrunk {} steps from 0x{:0w$x},0x{:0w$x},0x{:0w$x})",
            self.a,
            self.b,
            self.c,
            want,
            self.fmt.name(),
            self.kind.name(),
            diffs.join(" "),
            self.shrink_steps,
            self.original.0,
            self.original.1,
            self.original.2,
            w = w,
        )
    }
}

/// Fuzz-run configuration.
#[derive(Debug, Clone, Copy)]
pub struct FuzzConfig {
    /// Operand triples to generate.
    pub ops: usize,
    pub seed: u64,
    pub stream: StreamKind,
    /// Stop after this many (minimized) counterexamples.
    pub max_counterexamples: usize,
    /// Candidate-evaluation budget per minimization.
    pub shrink_budget: usize,
}

impl FuzzConfig {
    pub fn new(ops: usize, seed: u64, stream: StreamKind) -> FuzzConfig {
        FuzzConfig { ops, seed, stream, max_counterexamples: 8, shrink_budget: 4_096 }
    }
}

/// Outcome of one differential run.
#[derive(Debug)]
pub struct FuzzReport {
    pub kind: OpKind,
    pub fmt: Format,
    pub seed: u64,
    pub stream: StreamKind,
    /// Triples executed (may stop early at `max_counterexamples`).
    pub executed: usize,
    pub counterexamples: Vec<Counterexample>,
}

impl FuzzReport {
    pub fn clean(&self) -> bool {
        self.counterexamples.is_empty()
    }

    /// Multi-line human/corpus rendering of every counterexample.
    pub fn render(&self) -> String {
        let mut s = format!(
            "# {} {} stream={:?} seed=0x{:x}: {} executed, {} counterexample(s)\n",
            self.fmt.name(),
            self.kind.name(),
            self.stream,
            self.seed,
            self.executed,
            self.counterexamples.len(),
        );
        for ce in &self.counterexamples {
            s.push_str(&ce.render_edge_vector());
            s.push('\n');
        }
        s
    }
}

/// Do `got` and `want` disagree under the engine's NaN rule?
fn disagree(fmt: Format, want: u64, got: u64, exact_nan: bool) -> bool {
    if want == got {
        return false;
    }
    if !exact_nan
        && decode(fmt, want).class == Class::Nan
        && decode(fmt, got).class == Class::Nan
    {
        return false;
    }
    true
}

/// All engines beyond the first, diffed against the first (reference);
/// returns the disagreements.
fn diff_engines(
    fmt: Format,
    kind: OpKind,
    engines: &[Engine<'_>],
    a: u64,
    b: u64,
    c: u64,
) -> Vec<Mismatch> {
    let want = engines[0].eval(kind, a, b, c);
    engines[1..]
        .iter()
        .filter_map(|e| {
            let got = e.eval(kind, a, b, c);
            disagree(fmt, want, got, e.exact_nan)
                .then_some(Mismatch { engine: e.label, got, want })
        })
        .collect()
}

/// Bit-flip shrinking: repeatedly try zeroing whole operands, then
/// clearing individual set bits, keeping any mutation that preserves
/// the disagreement, until a fixpoint or the candidate budget runs out.
/// Monotone by construction (mutations only clear bits), so it
/// terminates; the result is locally minimal under single-bit clears.
fn minimize(
    fmt: Format,
    kind: OpKind,
    engines: &[Engine<'_>],
    start: (u64, u64, u64),
    budget: usize,
) -> Counterexample {
    let mut cur = start;
    let mut steps = 0usize;
    let mut evals = 0usize;
    let width = fmt.width();
    'outer: loop {
        // Whole-operand zeroing first: the biggest single shrink.
        for op in 0..3 {
            let mut cand = cur;
            let slot = match op {
                0 => &mut cand.0,
                1 => &mut cand.1,
                _ => &mut cand.2,
            };
            if *slot == 0 {
                continue;
            }
            *slot = 0;
            evals += 1;
            if !diff_engines(fmt, kind, engines, cand.0, cand.1, cand.2).is_empty() {
                cur = cand;
                steps += 1;
                if evals < budget {
                    continue 'outer;
                }
            }
            if evals >= budget {
                break 'outer;
            }
        }
        // Then single-bit clears, high to low.
        for op in 0..3 {
            for bit in (0..width).rev() {
                let mask = 1u64 << bit;
                let v = match op {
                    0 => cur.0,
                    1 => cur.1,
                    _ => cur.2,
                };
                if v & mask == 0 {
                    continue;
                }
                let mut cand = cur;
                match op {
                    0 => cand.0 &= !mask,
                    1 => cand.1 &= !mask,
                    _ => cand.2 &= !mask,
                }
                evals += 1;
                if !diff_engines(fmt, kind, engines, cand.0, cand.1, cand.2).is_empty() {
                    cur = cand;
                    steps += 1;
                    if evals < budget {
                        continue 'outer;
                    }
                }
                if evals >= budget {
                    break 'outer;
                }
            }
        }
        break;
    }
    let mismatches = diff_engines(fmt, kind, engines, cur.0, cur.1, cur.2);
    debug_assert!(!mismatches.is_empty(), "minimization lost the failure");
    Counterexample {
        kind,
        fmt,
        a: cur.0,
        b: cur.1,
        c: cur.2,
        original: start,
        shrink_steps: steps,
        mismatches,
    }
}

/// Run one differential fuzz pass: generate `cfg.ops` triples, evaluate
/// every engine on each, diff against `engines[0]` (the reference), and
/// minimize each disagreement. Fully deterministic for a given
/// `(cfg.seed, cfg.stream)`.
pub fn run_differential(
    fmt: Format,
    kind: OpKind,
    engines: &[Engine<'_>],
    cfg: &FuzzConfig,
) -> FuzzReport {
    assert!(engines.len() >= 2, "need a reference plus at least one engine to diff");
    let mut opgen = OperandGen::new(fmt, cfg.stream, cfg.seed);
    let mut report = FuzzReport {
        kind,
        fmt,
        seed: cfg.seed,
        stream: cfg.stream,
        executed: 0,
        counterexamples: Vec::new(),
    };
    for _ in 0..cfg.ops {
        let (a, b, c) = opgen.next_triple();
        report.executed += 1;
        if !diff_engines(fmt, kind, engines, a, b, c).is_empty() {
            report.counterexamples.push(minimize(
                fmt,
                kind,
                engines,
                (a, b, c),
                cfg.shrink_budget,
            ));
            if report.counterexamples.len() >= cfg.max_counterexamples {
                break;
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A planted-bug engine: the scalar spec with its rounding-mode
    /// constant mutated (`TowardZero` where the kernels round
    /// `NearestEven`). Every inexact round-up disagrees, so uniform
    /// streams find it almost immediately — the coarse detection case.
    fn planted_wrong_rounding(fmt: Format) -> Engine<'static> {
        Engine::new("planted-rz", true, move |kind, a, b, c| {
            let m = RoundMode::TowardZero;
            match kind {
                OpKind::Fma => softfloat::fma(fmt, m, a, b, c).bits,
                OpKind::Cma => {
                    let p = softfloat::mul(fmt, m, a, b);
                    softfloat::add(fmt, m, p.bits, c).bits
                }
                OpKind::Mul => softfloat::mul(fmt, m, a, b).bits,
                OpKind::Add => softfloat::add(fmt, m, a, c).bits,
            }
        })
    }

    /// A subtler planted bug: `Fma` evaluated with cascade (two-
    /// rounding) semantics. Uniform random operands almost never expose
    /// it; the structured stream's cancellation pairs expose the
    /// dropped residual on nearly every inexact product.
    fn planted_double_rounding(fmt: Format) -> Engine<'static> {
        Engine::new("planted-cascade", true, move |kind, a, b, c| match kind {
            OpKind::Fma => scalar_word(fmt, OpKind::Cma, a, b, c),
            other => scalar_word(fmt, other, a, b, c),
        })
    }

    fn reference(fmt: Format) -> Engine<'static> {
        Engine::new("spec", true, move |kind, a, b, c| scalar_word(fmt, kind, a, b, c))
    }

    #[test]
    fn planted_wrong_rounding_is_found_and_minimized() {
        for fmt in [Format::SP, Format::DP] {
            for kind in OpKind::ALL {
                let engines = [reference(fmt), planted_wrong_rounding(fmt)];
                let mut cfg = FuzzConfig::new(2_000, 0xF00D ^ fmt.sig_bits as u64, StreamKind::UniformBits);
                cfg.max_counterexamples = 1;
                let report = run_differential(fmt, kind, &engines, &cfg);
                assert!(
                    !report.clean(),
                    "{} {}: wrong-rounding bug not found in {} ops",
                    fmt.sig_bits,
                    kind.name(),
                    report.executed
                );
                // Bounded budget: a bug this coarse falls out fast.
                assert!(report.executed <= 2_000);
                let ce = &report.counterexamples[0];
                // Minimization kept the failure and never grew the triple.
                assert!(!ce.mismatches.is_empty());
                let pop = |t: (u64, u64, u64)| {
                    t.0.count_ones() + t.1.count_ones() + t.2.count_ones()
                };
                assert!(
                    pop((ce.a, ce.b, ce.c)) <= pop(ce.original),
                    "shrinking grew the counterexample"
                );
                // The minimized triple still disagrees when re-evaluated
                // from scratch.
                assert_ne!(
                    engines[0].eval(kind, ce.a, ce.b, ce.c),
                    engines[1].eval(kind, ce.a, ce.b, ce.c),
                    "minimized case no longer fails"
                );
                // And renders in corpus format.
                assert!(ce.render_edge_vector().starts_with("v(0x"));
            }
        }
    }

    #[test]
    fn planted_double_rounding_needs_the_structured_stream() {
        // The cancellation-pair stratum is what separates fused from
        // cascade: structured streams must find the planted cascade bug
        // within a small budget.
        for fmt in [Format::SP, Format::DP] {
            let engines = [reference(fmt), planted_double_rounding(fmt)];
            let mut cfg = FuzzConfig::new(5_000, 0xCAFE, StreamKind::Structured);
            cfg.max_counterexamples = 1;
            let report = run_differential(fmt, OpKind::Fma, &engines, &cfg);
            assert!(
                !report.clean(),
                "sig_bits={}: cascade bug not exposed by structured stream",
                fmt.sig_bits
            );
            let ce = &report.counterexamples[0];
            assert_ne!(
                engines[0].eval(OpKind::Fma, ce.a, ce.b, ce.c),
                engines[1].eval(OpKind::Fma, ce.a, ce.b, ce.c)
            );
        }
    }

    #[test]
    fn internal_tiers_agree_on_structured_streams() {
        // Smoke version of tests/differential.rs (which adds the gate
        // tier): spec vs word-simd vs scalar-lane vs host hardware, plus
        // the packed-SWAR voice for the formats narrow enough to pack —
        // across the full six-format matrix.
        for fmt in Format::all() {
            for kind in OpKind::ALL {
                let mut engines = vec![
                    reference(fmt),
                    Engine::new("word-simd", true, move |k, a, b, c| simd_word(fmt, k, a, b, c)),
                    Engine::new("scalar-lane", true, move |k, a, b, c| {
                        scalar_lane(fmt, k, a, b, c)
                    }),
                    Engine::new("host", false, move |k, a, b, c| host(fmt, k, a, b, c)),
                ];
                if lanes::packed::supports(fmt) {
                    engines.push(Engine::new("packed", true, move |k, a, b, c| {
                        packed_word(fmt, k, a, b, c)
                    }));
                }
                for stream in [StreamKind::UniformBits, StreamKind::Structured] {
                    let report = run_differential(
                        fmt,
                        kind,
                        &engines,
                        &FuzzConfig::new(2_000, 0x5EED, stream),
                    );
                    assert!(
                        report.clean(),
                        "{} {} {:?}:\n{}",
                        fmt.sig_bits,
                        kind.name(),
                        stream,
                        report.render()
                    );
                }
            }
        }
    }

    #[test]
    fn generators_are_deterministic_and_cover_strata() {
        let fmt = Format::SP;
        let mut g1 = OperandGen::new(fmt, StreamKind::Structured, 9);
        let mut g2 = OperandGen::new(fmt, StreamKind::Structured, 9);
        let (mut subnormal, mut special, mut zero_or_pow2) = (0, 0, 0);
        for _ in 0..4_000 {
            let t = g1.next_triple();
            assert_eq!(t, g2.next_triple(), "generator must be seed-deterministic");
            for v in [t.0, t.1, t.2] {
                let d = decode(fmt, v);
                let biased = (v >> (fmt.sig_bits - 1)) & fmt.emax_biased();
                if biased == 0 && v & fmt.frac_mask() != 0 {
                    subnormal += 1;
                }
                if d.class == Class::Nan || d.class == Class::Infinity {
                    special += 1;
                }
                if v & fmt.frac_mask() == 0 && biased < fmt.emax_biased() {
                    zero_or_pow2 += 1;
                }
            }
        }
        assert!(subnormal > 100, "subnormals undersampled: {subnormal}");
        assert!(special > 100, "NaN/Inf undersampled: {special}");
        assert!(zero_or_pow2 > 100, "powers of two undersampled: {zero_or_pow2}");
    }
}
