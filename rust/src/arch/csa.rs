//! Carry-save arithmetic: word-level models of the 3:2 and 4:2
//! compressors the reduction trees are built from.
//!
//! A 3:2 compressor (a row of full adders) maps three addends to a
//! sum/carry pair with the same total, in one full-adder delay regardless
//! of width — the reason multiplier trees defer carry propagation to a
//! single final CPA. The word-level identities
//! `sum = a⊕b⊕c`, `carry = majority(a,b,c) « 1`
//! are exact bit-level models, so these functions *are* the hardware, just
//! evaluated 128 lanes at a time.
//!
//! Each operation also accumulates [`CsaStats`]: full-adder evaluations
//! (structure; feeds area/energy) and output toggle weight (activity;
//! feeds dynamic power).

/// Activity/structure statistics accumulated across a reduction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsaStats {
    /// Full-adder positions evaluated (one per bit of each 3:2 row).
    pub fa_ops: u64,
    /// Population count of produced sum+carry words — the switching-event
    /// proxy the energy model converts to C·V² events.
    pub toggles: u64,
    /// Compressor rows (3:2 equivalents) on the critical path so far.
    pub depth: u32,
}

impl CsaStats {
    /// Merge a parallel branch: structure adds, depth takes the max.
    pub fn join_parallel(&mut self, other: CsaStats) {
        self.fa_ops += other.fa_ops;
        self.toggles += other.toggles;
        self.depth = self.depth.max(other.depth);
    }

    /// Chain a sequential stage after this one.
    pub fn chain(&mut self, other: CsaStats) {
        self.fa_ops += other.fa_ops;
        self.toggles += other.toggles;
        self.depth += other.depth;
    }
}

/// A redundant (carry-save) value: `value = (sum + carry) mod 2^width`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CarrySave {
    pub sum: u128,
    pub carry: u128,
}

impl CarrySave {
    /// A carry-save zero.
    pub const ZERO: CarrySave = CarrySave { sum: 0, carry: 0 };

    /// Resolve to a binary value with a carry-propagate add (the final
    /// CPA of the multiplier), wrapped to `width`.
    pub fn resolve(self, width: u32) -> u128 {
        self.sum.wrapping_add(self.carry) & mask(width)
    }
}

/// Bit mask of `width` low bits.
#[inline]
pub const fn mask(width: u32) -> u128 {
    if width >= 128 {
        u128::MAX
    } else {
        (1u128 << width) - 1
    }
}

/// One 3:2 compressor row over `width` bits, generic over whether
/// activity statistics are accumulated. The verification hot path
/// (`FpuUnit::fmac`) uses `TRACK = false`, compiling the three stat
/// updates (and both popcounts) out entirely; the energy-model path
/// (`fmac_mode`) uses `TRACK = true`.
#[inline(always)]
pub fn csa32_t<const TRACK: bool>(
    a: u128,
    b: u128,
    c: u128,
    width: u32,
    stats: &mut CsaStats,
) -> CarrySave {
    let m = mask(width);
    let sum = (a ^ b ^ c) & m;
    let carry = (((a & b) | (a & c) | (b & c)) << 1) & m;
    if TRACK {
        stats.fa_ops += width as u64;
        stats.toggles += (sum.count_ones() + carry.count_ones()) as u64;
        stats.depth += 1;
    }
    CarrySave { sum, carry }
}

/// One 3:2 compressor row over `width` bits (always tracking).
#[inline(always)]
pub fn csa32(a: u128, b: u128, c: u128, width: u32, stats: &mut CsaStats) -> CarrySave {
    csa32_t::<true>(a, b, c, width, stats)
}

/// One 4:2 compressor row (two chained 3:2s, but counted as ~1.5 FA delays
/// in the timing model; structurally it is two rows of cells).
#[inline]
pub fn csa42(
    a: u128,
    b: u128,
    c: u128,
    d: u128,
    width: u32,
    stats: &mut CsaStats,
) -> CarrySave {
    let first = csa32(a, b, c, width, stats);
    csa32(first.sum, first.carry, d, width, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csa32_preserves_sum() {
        let w = 64;
        let cases = [
            (0u128, 0u128, 0u128),
            (1, 1, 1),
            (0xdead_beef, 0x1234_5678, 0xffff_ffff),
            (u64::MAX as u128, u64::MAX as u128, u64::MAX as u128),
        ];
        for (a, b, c) in cases {
            let mut st = CsaStats::default();
            let cs = csa32(a, b, c, w, &mut st);
            assert_eq!(
                cs.resolve(w),
                a.wrapping_add(b).wrapping_add(c) & mask(w),
                "a={a:#x} b={b:#x} c={c:#x}"
            );
            assert_eq!(st.depth, 1);
            assert_eq!(st.fa_ops, w as u64);
        }
    }

    #[test]
    fn csa42_preserves_sum() {
        let w = 100;
        let mut st = CsaStats::default();
        let (a, b, c, d) = (0x1111_2222_3333u128, 0x9999_aaaa_bbbbu128, 0x0f0f_0f0fu128, 0xffff_ffff_ffffu128);
        let cs = csa42(a, b, c, d, w, &mut st);
        assert_eq!(cs.resolve(w), (a + b + c + d) & mask(w));
        assert_eq!(st.depth, 2); // two 3:2 rows structurally
    }

    #[test]
    fn wrapping_at_window_width() {
        // Sums that overflow the window must wrap exactly like hardware.
        let w = 8;
        let mut st = CsaStats::default();
        let cs = csa32(0xff, 0xff, 0xff, w, &mut st);
        assert_eq!(cs.resolve(w), (0xffu128 * 3) & 0xff);
    }

    #[test]
    fn stats_accumulate() {
        let mut total = CsaStats::default();
        let mut branch_a = CsaStats::default();
        csa32(1, 2, 3, 32, &mut branch_a);
        csa32(4, 5, 6, 32, &mut branch_a);
        let mut branch_b = CsaStats::default();
        csa32(7, 8, 9, 32, &mut branch_b);
        total.join_parallel(branch_a);
        total.join_parallel(branch_b);
        assert_eq!(total.depth, 2); // max of branches
        assert_eq!(total.fa_ops, 3 * 32);
        let mut seq = CsaStats::default();
        seq.chain(branch_a);
        seq.chain(branch_b);
        assert_eq!(seq.depth, 3); // chained
    }

    #[test]
    fn mask_extremes() {
        assert_eq!(mask(0), 0);
        assert_eq!(mask(1), 1);
        assert_eq!(mask(128), u128::MAX);
    }
}
