//! IEEE-754 rounding: guard/round/sticky reduction of an exact
//! intermediate result to a storage format.
//!
//! Both the golden softfloat model and the structural datapaths end their
//! computation with an exact (or sticky-summarized) value
//! `(-1)^sign · sig · 2^exp` that must be rounded once (FMA) or per
//! sub-operation (CMA). This module is that shared rounder — the same
//! dataflow the chip's final rounder stage implements with an
//! increment-and-select circuit.


use super::fp::{bitlen128, encode_finite, Format};

/// IEEE-754 rounding modes (the chip implements all four; RNE is the
/// benchmarked default).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RoundMode {
    /// roundTiesToEven.
    #[default]
    NearestEven,
    /// roundTowardZero.
    TowardZero,
    /// roundTowardPositive.
    TowardPositive,
    /// roundTowardNegative.
    TowardNegative,
}

impl RoundMode {
    /// All four modes, for exhaustive tests.
    pub const ALL: [RoundMode; 4] = [
        RoundMode::NearestEven,
        RoundMode::TowardZero,
        RoundMode::TowardPositive,
        RoundMode::TowardNegative,
    ];

    /// Should a result with the given LSB/guard/sticky round away from
    /// zero? This is exactly the increment-decision logic of the rounder
    /// stage.
    #[inline]
    pub fn increments(self, sign: bool, lsb: bool, round: bool, sticky: bool) -> bool {
        match self {
            RoundMode::NearestEven => round && (sticky || lsb),
            RoundMode::TowardZero => false,
            RoundMode::TowardPositive => !sign && (round || sticky),
            RoundMode::TowardNegative => sign && (round || sticky),
        }
    }

    /// On overflow, does this mode saturate to max-finite instead of Inf?
    #[inline]
    pub fn overflows_to_max_finite(self, sign: bool) -> bool {
        match self {
            RoundMode::NearestEven => false,
            RoundMode::TowardZero => true,
            RoundMode::TowardPositive => sign,
            RoundMode::TowardNegative => !sign,
        }
    }

    /// The sign of an exact-zero sum produced by cancellation (IEEE
    /// 754-2019 §6.3): -0 under roundTowardNegative, +0 otherwise.
    #[inline]
    pub fn cancellation_zero_sign(self) -> bool {
        matches!(self, RoundMode::TowardNegative)
    }
}

/// Exception flags raised while rounding (a subset of IEEE status flags —
/// the chip exposes these through its status register).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flags {
    pub inexact: bool,
    pub overflow: bool,
    pub underflow: bool,
    pub invalid: bool,
}

impl Flags {
    /// Merge two flag sets (used by CMA: mul flags ∪ add flags).
    pub fn merge(self, other: Flags) -> Flags {
        Flags {
            inexact: self.inexact || other.inexact,
            overflow: self.overflow || other.overflow,
            underflow: self.underflow || other.underflow,
            invalid: self.invalid || other.invalid,
        }
    }
}

/// A rounded result: the storage bits plus the flags the operation raised.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rounded {
    pub bits: u64,
    pub flags: Flags,
}

/// Round the exact value `(-1)^sign · sig · 2^exp` (with `sticky` marking
/// discarded low-order bits strictly below `sig`'s LSB) to `fmt`.
///
/// This is the single place range reduction happens: normal/subnormal
/// selection, overflow to ±Inf or ±max-finite, and underflow-to-zero all
/// live here, mirroring the chip's normalize+round+pack stages.
#[inline(always)]
pub fn round_to_format(
    fmt: Format,
    mode: RoundMode,
    sign: bool,
    exp: i32,
    sig: u128,
    sticky: bool,
) -> Rounded {
    let mut flags = Flags::default();
    if sig == 0 {
        // A zero significand with sticky set means the true value is a tiny
        // nonzero residue: round it as if it were below the smallest
        // subnormal.
        if !sticky {
            return Rounded { bits: fmt.zero(sign), flags };
        }
        flags.inexact = true;
        flags.underflow = true;
        let up = mode.increments(sign, false, false, true);
        let bits = if up { fmt.zero(sign) | 1 } else { fmt.zero(sign) };
        return Rounded { bits, flags };
    }

    // Position of the value's MSB as a power of two: value ∈ [2^(npos-1), 2^npos).
    let npos = exp + bitlen128(sig) as i32;

    // The quantum (LSB weight) of the rounded result.
    let target_q = (npos - fmt.sig_bits as i32).max(fmt.qmin());

    // Shift so the significand LSB sits at target_q. A left shift is exact;
    // sticky-in with a left shift would be ambiguous (the residue could
    // straddle the round position), but no caller produces it: sticky is
    // only set by wide right shifts, which leave ≥ sig_bits of significand.
    debug_assert!(!(target_q < exp && sticky), "sticky residue with short significand");
    let (kept, round_bit, sticky_low) = if target_q >= exp {
        shift_right_rs(sig, target_q - exp, sticky)
    } else {
        (sig << (exp - target_q), false, sticky)
    };

    let inexact = round_bit || sticky_low;
    let lsb = kept & 1 == 1;
    let mut result_sig = kept as u64; // kept < 2^sig_bits ≤ 2^53: fits u64
    let mut q = target_q;
    if mode.increments(sign, lsb, round_bit, sticky_low) {
        result_sig += 1;
        if result_sig == (1u64 << fmt.sig_bits) {
            // Carry out of the significand: renormalize.
            result_sig >>= 1;
            q += 1;
        }
    }

    flags.inexact = inexact;

    // Overflow check: MSB position of the rounded value.
    if result_sig != 0 {
        let msb = q + super::fp::bitlen64(result_sig) as i32 - 1;
        if msb > fmt.emax() {
            flags.overflow = true;
            flags.inexact = true;
            let bits = if mode.overflows_to_max_finite(sign) {
                fmt.max_finite(sign)
            } else {
                fmt.inf(sign)
            };
            return Rounded { bits, flags };
        }
        if result_sig < fmt.hidden_bit() && inexact {
            flags.underflow = true;
        }
    } else {
        // Rounded all the way to zero.
        flags.underflow = inexact;
        return Rounded { bits: fmt.zero(sign), flags };
    }

    Rounded { bits: encode_finite(fmt, sign, q, result_sig), flags }
}

/// Right-shift with round/sticky capture: returns (kept, round_bit,
/// sticky_of_lower_bits ∪ sticky_in).
#[inline]
pub fn shift_right_rs(sig: u128, shift: i32, sticky_in: bool) -> (u128, bool, bool) {
    if shift <= 0 {
        return (sig, false, sticky_in);
    }
    let shift = shift as u32;
    if shift > 128 {
        return (0, false, sticky_in || sig != 0);
    }
    if shift == 128 {
        return (0, false, sticky_in || sig != 0);
    }
    let kept = sig >> shift;
    let round_bit = (sig >> (shift - 1)) & 1 == 1;
    let below_mask = if shift >= 2 { (1u128 << (shift - 1)) - 1 } else { 0 };
    let sticky = sticky_in || (sig & below_mask) != 0;
    (kept, round_bit, sticky)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::fp::decode;

    fn round_sp(mode: RoundMode, sign: bool, exp: i32, sig: u128, sticky: bool) -> f32 {
        f32::from_bits(round_to_format(Format::SP, mode, sign, exp, sig, sticky).bits as u32)
    }

    #[test]
    fn exact_values_round_trip() {
        for x in [1.0f32, 0.5, 3.25, 1e20, -7.75] {
            let d = decode(Format::SP, x.to_bits() as u64);
            for mode in RoundMode::ALL {
                let r = round_sp(mode, d.sign, d.exp, d.sig as u128, false);
                assert_eq!(r, x, "mode {mode:?}");
            }
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.5 ulp above 1.0: sig = (1<<24) + 1, round bit set, no sticky →
        // tie → round to even (down, since lsb=... ). Construct 2^-24 below:
        // value = (2^24 + 1) · 2^-24 = 1 + 2^-24: exactly halfway between
        // 1.0 and 1.0+2^-23 → ties to 1.0.
        let r = round_sp(RoundMode::NearestEven, false, -24, (1u128 << 24) + 1, false);
        assert_eq!(r, 1.0);
        // With sticky set it is above the tie → rounds up.
        let r = round_sp(RoundMode::NearestEven, false, -24, (1u128 << 24) + 1, true);
        assert_eq!(r, 1.0 + f32::EPSILON);
        // (2^24 + 3)·2^-24: halfway between 1+ε and 1+2ε → ties to even →
        // 1+2ε.
        let r = round_sp(RoundMode::NearestEven, false, -24, (1u128 << 24) + 3, false);
        assert_eq!(r, 1.0 + 2.0 * f32::EPSILON);
    }

    #[test]
    fn directed_modes_bracket_rne() {
        // An inexact positive value: RD ≤ RNE ≤ RU and RZ == RD for
        // positives.
        let (exp, sig) = (-30, (1u128 << 30) + 12345);
        let rd = round_sp(RoundMode::TowardNegative, false, exp, sig, false);
        let rz = round_sp(RoundMode::TowardZero, false, exp, sig, false);
        let rn = round_sp(RoundMode::NearestEven, false, exp, sig, false);
        let ru = round_sp(RoundMode::TowardPositive, false, exp, sig, false);
        assert!(rd <= rn && rn <= ru);
        assert_eq!(rd, rz);
        assert_eq!(ru, rd + rd * f32::EPSILON); // adjacent ulps
    }

    #[test]
    fn overflow_behaviour_per_mode() {
        // 2^128 overflows SP.
        let sig = 1u128;
        let exp = 128;
        let r = round_to_format(Format::SP, RoundMode::NearestEven, false, exp, sig, false);
        assert_eq!(r.bits as u32, f32::INFINITY.to_bits());
        assert!(r.flags.overflow && r.flags.inexact);
        let r = round_to_format(Format::SP, RoundMode::TowardZero, false, exp, sig, false);
        assert_eq!(r.bits as u32, f32::MAX.to_bits());
        let r = round_to_format(Format::SP, RoundMode::TowardPositive, true, exp, sig, false);
        assert_eq!(r.bits as u32, (-f32::MAX).to_bits());
        let r = round_to_format(Format::SP, RoundMode::TowardNegative, true, exp, sig, false);
        assert_eq!(r.bits as u32, f32::NEG_INFINITY.to_bits());
    }

    #[test]
    fn subnormal_rounding() {
        // Half the smallest subnormal ties to even → +0 under RNE.
        let r = round_to_format(Format::SP, RoundMode::NearestEven, false, -150, 1, false);
        assert_eq!(r.bits, 0);
        assert!(r.flags.underflow && r.flags.inexact);
        // Just above half the smallest subnormal rounds to it.
        let r = round_to_format(Format::SP, RoundMode::NearestEven, false, -150, 1, true);
        assert_eq!(r.bits, 1);
        // Toward-positive forces any positive residue up to the min subnormal.
        let r = round_to_format(Format::SP, RoundMode::TowardPositive, false, -200, 7, false);
        assert_eq!(r.bits, 1);
        // Toward-zero flushes it.
        let r = round_to_format(Format::SP, RoundMode::TowardZero, false, -200, 7, false);
        assert_eq!(r.bits, 0);
    }

    #[test]
    fn sticky_only_zero_sig() {
        // sig == 0 but sticky: a vanished residue. RU must produce the min
        // subnormal; RNE produces zero.
        let r = round_to_format(Format::SP, RoundMode::TowardPositive, false, 0, 0, true);
        assert_eq!(r.bits, 1);
        let r = round_to_format(Format::SP, RoundMode::NearestEven, false, 0, 0, true);
        assert_eq!(r.bits, 0);
        assert!(r.flags.underflow);
    }

    #[test]
    fn exact_subnormals_no_underflow_flag() {
        // An exactly representable subnormal must not raise underflow.
        let r = round_to_format(Format::SP, RoundMode::NearestEven, false, -149, 5, false);
        assert_eq!(r.bits, 5);
        assert!(!r.flags.underflow && !r.flags.inexact);
    }

    #[test]
    fn shift_right_rs_cases() {
        assert_eq!(shift_right_rs(0b1011, 0, false), (0b1011, false, false));
        assert_eq!(shift_right_rs(0b1011, 1, false), (0b101, true, false));
        assert_eq!(shift_right_rs(0b1011, 2, false), (0b10, true, true));
        assert_eq!(shift_right_rs(0b1000, 3, false), (0b1, false, false));
        assert_eq!(shift_right_rs(0b1000, 4, false), (0, true, false));
        assert_eq!(shift_right_rs(1, 200, false), (0, false, true));
        assert_eq!(shift_right_rs(0, 200, false), (0, false, false));
        // Sticky-in propagates.
        assert_eq!(shift_right_rs(0b100, 1, true), (0b10, false, true));
    }

    #[test]
    fn carry_out_of_significand_renormalizes() {
        // All-ones SP significand + round up ⇒ carry into the next binade.
        let sig = ((1u128 << 24) - 1) << 1 | 1; // 25 bits: kept all-ones, round=1
        let r = round_sp(RoundMode::NearestEven, false, -25, sig, false);
        assert_eq!(r, 1.0); // (2^25-1)·2^-25 rounds to 1.0
    }
}
