//! FPU microarchitecture substrate: the FPGen-equivalent generator and
//! everything it composes.
//!
//! The module mirrors the structure of a generated FMAC:
//!
//! ```text
//!           a ──┐            ┌── c
//!           b ──┤            │
//!      ┌────────▼────────┐   │
//!      │ booth  (PP gen) │   │
//!      ├─────────────────┤   │
//!      │ tree (CSA reduce)│  │      multiplier  = booth + tree + CPA
//!      ├─────────────────┤   │
//!      │ CPA / keep CS   │   │
//!      └────────┬────────┘   │
//!        FMA: 3:2 merge ◄────┘      CMA: round, then a separate adder
//!               │
//!        LZA + normalize
//!               │
//!        round + pack            (shared: rounding.rs)
//! ```
//!
//! [`FpuUnit::generate`] plays the role of FPGen: it takes an
//! [`FpuConfig`] (precision, FMA-vs-CMA, booth radix, reduction tree,
//! pipeline depths) and returns a unit whose *numerics* are bit-exact
//! IEEE-754 and whose *structure report* feeds the timing and energy
//! models.
//!
//! High-volume execution goes through [`engine`]: one [`Datapath`] trait
//! over the generated units (gate-level) and their word-level tier, with
//! a thread-parallel [`BatchExecutor`] and a unified
//! [`ActivityAccumulator`] feeding the energy model.

pub mod booth;
pub mod cma;
pub mod csa;
pub mod engine;
pub mod fma;
pub mod fp;
pub mod fuzz;
pub mod generator;
pub mod multiplier;
pub mod rounding;
pub mod softfloat;
pub mod tree;

pub use engine::{
    calibration_key, lane_kernel_fingerprint, window_ring, ActivityAccumulator, ActivityTrace,
    ActivityWindow, BatchExecutor, BatchLenError, CrossCheck, Datapath, ExecutorRegistry,
    Fidelity, GoldenFma, RingWindow, UnitDatapath, WindowConsumer, WindowProducer, WordSimdUnit,
    WordUnit,
};
pub use fp::{decode, encode_finite, Class, Decoded, Format, Precision};
pub use generator::{FpuConfig, FpuKind, FpuUnit, StructureReport};
pub use rounding::{Flags, RoundMode, Rounded};

#[cfg(test)]
mod tests;
