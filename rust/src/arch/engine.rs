//! The batched execution engine: **one execution interface** for every
//! consumer of an FMAC datapath (coordinator, DSE sweeps, chip
//! sequencer, workload drivers, benches), with selectable fidelity.
//!
//! The FPMax paper separates what a unit *computes* (bit-exact IEEE
//! semantics per Table I) from how fast the silicon *delivers* it; FPnew
//! and Snitch make the same split in hardware — a parameterized FPU
//! behind a streaming front-end that keeps it fed. This module is that
//! split in software:
//!
//! * [`Datapath`] — the execution trait. `fmac_one` is the scalar op;
//!   `fmac_batch` has a streaming default so no implementation hand-rolls
//!   batching (the executor chunks batches across workers and drives it
//!   per chunk); `*_tracked` variants accumulate per-op activity into an
//!   [`ActivityAccumulator`].
//! * [`Fidelity`] — **GateLevel** evaluates the structural multiplier
//!   (every Booth mux and 3:2 row, yielding toggle counts for the energy
//!   model); **WordLevel** skips the gate simulation of the multiplier
//!   tree and computes through the exact softfloat path. Both tiers are
//!   **bit-identical** — the gate-level datapath is checked against the
//!   word-level spec in debug builds, and [`BatchExecutor::run_checked`]
//!   cross-checks sampled results at run time.
//! * [`BatchExecutor`] — thread-parallel fork-join over operand slices
//!   (`std::thread::scope`; the offline environment has no tokio, and the
//!   workload is pure CPU compute).
//!
//! Implementations provided: [`FpuUnit`] (the generated gate-level
//! datapath), [`WordUnit`] (the word-level tier of a unit),
//! [`UnitDatapath`] (a unit bound to a fidelity at run time), and
//! [`GoldenFma`] (the fused softfloat spec, regardless of unit kind).

use super::fma::FmaActivity;
use super::fp::{decode, Class, Format};
use super::generator::{FpuConfig, FpuKind, FpuUnit, StructureReport};
use super::multiplier::MultiplierConfig;
use super::rounding::{Flags, RoundMode, Rounded};
use super::softfloat;
use crate::workloads::throughput::OperandTriple;

/// Execution fidelity tier of a datapath implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Fidelity {
    /// Structural simulation: Booth recoding, every 3:2 compressor row,
    /// toggle counting. Slow; feeds the energy model real activity.
    #[default]
    GateLevel,
    /// Exact integer-significand arithmetic, no per-row gate evaluation.
    /// Bit-identical results, ~an order of magnitude faster.
    WordLevel,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::GateLevel => "gate",
            Fidelity::WordLevel => "word",
        }
    }
}

/// The per-unit Table-I semantics at word level: fused units round once,
/// cascade units round after the multiply and again after the add. This
/// is the single spec function the coordinator, the chip tester, and the
/// word-level tier all share.
#[inline]
pub fn reference_fmac(
    kind: FpuKind,
    fmt: Format,
    mode: RoundMode,
    a: u64,
    b: u64,
    c: u64,
) -> Rounded {
    match kind {
        FpuKind::Fma => softfloat::fma(fmt, mode, a, b, c),
        FpuKind::Cma => {
            let p = softfloat::mul(fmt, mode, a, b);
            let s = softfloat::add(fmt, mode, p.bits, c);
            Rounded { bits: s.bits, flags: Flags::merge(p.flags, s.flags) }
        }
    }
}

/// Unified activity accumulator: the sum of per-op [`FmaActivity`]
/// records over a batch, mergeable across worker threads. This replaces
/// the ad-hoc per-module toggle counters that used to feed the energy
/// model — [`crate::energy::power::evaluate_measured`] consumes one
/// directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityAccumulator {
    /// Ops recorded.
    pub ops: u64,
    /// Ops that took the special/early-out path (clock-gated datapath).
    pub special_ops: u64,
    /// Total Booth digits across ops.
    pub digits: u64,
    /// Nonzero Booth digits (mux/negate activity).
    pub nonzero_digits: u64,
    /// Tree full-adder evaluations (gate-level only).
    pub tree_fa_ops: u64,
    /// Tree output toggle weight (gate-level only).
    pub tree_toggles: u64,
    /// Summed alignment-shifter distances.
    pub align_shift: u64,
    /// Summed normalization distances.
    pub norm_shift: u64,
}

impl ActivityAccumulator {
    /// Fold one op's activity record in.
    #[inline]
    pub fn record(&mut self, act: &FmaActivity) {
        self.ops += 1;
        if act.special {
            self.special_ops += 1;
        }
        self.digits += act.digits as u64;
        self.nonzero_digits += act.nonzero_digits as u64;
        self.tree_fa_ops += act.tree_fa_ops;
        self.tree_toggles += act.tree_toggles;
        self.align_shift += act.align_shift as u64;
        self.norm_shift += act.norm_shift as u64;
    }

    /// Merge another accumulator (fork-join reduction).
    pub fn merge(&mut self, other: &ActivityAccumulator) {
        self.ops += other.ops;
        self.special_ops += other.special_ops;
        self.digits += other.digits;
        self.nonzero_digits += other.nonzero_digits;
        self.tree_fa_ops += other.tree_fa_ops;
        self.tree_toggles += other.tree_toggles;
        self.align_shift += other.align_shift;
        self.norm_shift += other.norm_shift;
    }

    /// Fraction of ops that exercised the full datapath (specials gate
    /// the multiplier clock).
    pub fn active_fraction(&self) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        1.0 - self.special_ops as f64 / self.ops as f64
    }

    /// Data-activity scale factor for [`crate::energy::UnitCost::dyn_energy_pj`]
    /// (1.0 = the calibrated average-operand activity).
    ///
    /// Gate-level runs scale by measured tree toggles per op against the
    /// half-the-tree-cells random baseline. Word-level runs carry no
    /// toggle counts but do record Booth digit statistics (the recoder is
    /// word-level computable), so they scale by the nonzero-digit ratio
    /// against the random-operand expectation of the radix — 3/4 for
    /// Booth-2, 7/8 for Booth-3 — times the active-op fraction. Only an
    /// empty accumulator is neutral.
    pub fn activity_scale(&self, s: &StructureReport) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        if self.tree_fa_ops > 0 {
            let per_op = self.tree_toggles as f64 / self.ops as f64;
            let baseline = (s.tree_cells as f64 / 2.0).max(1.0);
            (per_op / baseline).clamp(0.05, 2.0)
        } else if self.digits > 0 {
            let ratio = self.nonzero_digits as f64 / self.digits as f64;
            let baseline = if s.has_triple_adder { 7.0 / 8.0 } else { 3.0 / 4.0 };
            (self.active_fraction() * ratio / baseline).clamp(0.05, 2.0)
        } else {
            self.active_fraction().clamp(0.05, 1.0)
        }
    }
}

/// One execution interface over every FMAC datapath implementation.
///
/// Results are raw bit patterns in the datapath's [`Format`] (SP in the
/// low 32 bits). All implementations of the same unit configuration are
/// bit-identical across fidelity tiers; rounding is round-to-nearest-even
/// (the benchmarked default — mode-explicit execution stays on
/// [`FpuUnit::fmac_mode`]).
pub trait Datapath: Sync {
    /// Operand/result format.
    fn format(&self) -> Format;

    /// FMAC organization this datapath implements (fused or cascade).
    fn kind(&self) -> FpuKind;

    /// Fidelity tier of this implementation.
    fn fidelity(&self) -> Fidelity;

    /// Structural report, when this datapath models a generated unit.
    fn structure(&self) -> Option<&StructureReport> {
        None
    }

    /// Display label for benches and reports.
    fn label(&self) -> String {
        format!("{}/{}", self.kind().name(), self.fidelity().name())
    }

    /// One FMAC (`a·b + c` in Table-I semantics); returns result bits.
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64;

    /// One FMAC with activity accumulation.
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        acc.ops += 1;
        self.fmac_one(a, b, c)
    }

    /// Execute a batch into `out`. The default streams the scalar op over
    /// the slice pair; the *parallel* chunking lives in
    /// [`BatchExecutor`], which splits the batch across workers and calls
    /// this per chunk.
    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one(t.a, t.b, t.c);
        }
    }

    /// Execute a batch with activity accumulation.
    fn fmac_batch_tracked(
        &self,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: &mut ActivityAccumulator,
    ) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one_tracked(t.a, t.b, t.c, acc);
        }
    }
}

/// The generated unit itself is the gate-level tier.
impl Datapath for FpuUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.config.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::GateLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(FpuUnit::structure(self))
    }

    fn label(&self) -> String {
        format!("{}/{}", self.config.name(), Fidelity::GateLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        self.fmac(a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        let (r, act) = self.fmac_mode(RoundMode::NearestEven, a, b, c);
        acc.record(&act);
        r.bits
    }
}

/// The word-level tier of a generated unit: same Table-I semantics and
/// structure report, no per-row gate simulation. Bit-identical to the
/// gate-level tier by construction (the gate-level datapath asserts
/// equality against this very spec in debug builds); `run_checked`
/// re-verifies that on sampled operands in release.
#[derive(Debug, Clone)]
pub struct WordUnit {
    format: Format,
    kind: FpuKind,
    mul: MultiplierConfig,
    structure: StructureReport,
    name: String,
}

impl WordUnit {
    /// The word-level view of an elaborated unit.
    pub fn of(unit: &FpuUnit) -> WordUnit {
        WordUnit {
            format: unit.format,
            kind: unit.config.kind,
            mul: *unit.multiplier_config(),
            structure: *unit.structure(),
            name: unit.config.name(),
        }
    }

    /// Elaborate a configuration straight into the word-level tier.
    pub fn generate(cfg: &FpuConfig) -> WordUnit {
        WordUnit::of(&FpuUnit::generate(cfg))
    }
}

/// Booth digit statistics of a multiplier operand, computed directly
/// from the recoding windows — no partial products materialized, no
/// tree. Mirrors `booth::partial_products_into`'s recode exactly, so a
/// word-level tracked run reports the same digit counts the gate-level
/// tier does.
fn booth_digit_stats(y: u64, mul: &MultiplierConfig) -> (u32, u32) {
    let b = mul.booth.bits_per_digit();
    let n = mul.booth.digit_count(mul.sig_bits);
    let y2 = (y as u128) << 1;
    let mut nonzero = 0;
    for i in 0..n {
        let window = ((y2 >> (i * b)) & ((1u128 << (b + 1)) - 1)) as u64;
        let msb = (window >> b) & 1;
        let value = ((window >> 1) + (window & 1)) as i64 - ((1i64 << b) * msb as i64);
        if value != 0 {
            nonzero += 1;
        }
    }
    (n, nonzero)
}

impl Datapath for WordUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(&self.structure)
    }

    fn label(&self) -> String {
        format!("{}/{}", self.name, Fidelity::WordLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        reference_fmac(self.kind, self.format, RoundMode::NearestEven, a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        // Word level carries no toggle counts, but the special/early-out
        // accounting (clock gating) and the Booth digit statistics are
        // both word-level observable — those are what the energy model's
        // word-level activity scale is built from.
        let da = decode(self.format, a);
        let db = decode(self.format, b);
        let special = match self.kind {
            FpuKind::Fma => {
                let dc = decode(self.format, c);
                da.non_finite()
                    || db.non_finite()
                    || dc.non_finite()
                    || da.is_zero()
                    || db.is_zero()
            }
            FpuKind::Cma => {
                !(matches!(da.class, Class::Normal | Class::Subnormal)
                    && matches!(db.class, Class::Normal | Class::Subnormal))
            }
        };
        acc.ops += 1;
        if special {
            acc.special_ops += 1;
        } else {
            // Same operand the gate-level multiplier recodes (y = b.sig).
            let (digits, nonzero) = booth_digit_stats(db.sig, &self.mul);
            acc.digits += digits as u64;
            acc.nonzero_digits += nonzero as u64;
        }
        self.fmac_one(a, b, c)
    }
}

/// A generated unit bound to a fidelity tier chosen at run time — the
/// handle consumers pass to the executor when the tier is a parameter
/// (DSE sweeps run word-level, verification runs gate-level).
#[derive(Debug, Clone)]
pub enum UnitDatapath {
    Gate(FpuUnit),
    Word(WordUnit),
}

impl UnitDatapath {
    /// Bind an elaborated unit to a tier.
    pub fn new(unit: &FpuUnit, fidelity: Fidelity) -> UnitDatapath {
        match fidelity {
            Fidelity::GateLevel => UnitDatapath::Gate(unit.clone()),
            Fidelity::WordLevel => UnitDatapath::Word(WordUnit::of(unit)),
        }
    }

    /// Elaborate a configuration at a tier.
    pub fn generate(cfg: &FpuConfig, fidelity: Fidelity) -> UnitDatapath {
        UnitDatapath::new(&FpuUnit::generate(cfg), fidelity)
    }
}

impl Datapath for UnitDatapath {
    fn format(&self) -> Format {
        match self {
            UnitDatapath::Gate(u) => u.format,
            UnitDatapath::Word(w) => Datapath::format(w),
        }
    }

    fn kind(&self) -> FpuKind {
        match self {
            UnitDatapath::Gate(u) => u.config.kind,
            UnitDatapath::Word(w) => Datapath::kind(w),
        }
    }

    fn fidelity(&self) -> Fidelity {
        match self {
            UnitDatapath::Gate(_) => Fidelity::GateLevel,
            UnitDatapath::Word(_) => Fidelity::WordLevel,
        }
    }

    fn structure(&self) -> Option<&StructureReport> {
        match self {
            UnitDatapath::Gate(u) => Some(FpuUnit::structure(u)),
            UnitDatapath::Word(w) => Datapath::structure(w),
        }
    }

    fn label(&self) -> String {
        match self {
            UnitDatapath::Gate(u) => Datapath::label(u),
            UnitDatapath::Word(w) => Datapath::label(w),
        }
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac(a, b, c).bits,
            UnitDatapath::Word(w) => w.fmac_one(a, b, c),
        }
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac_one_tracked(a, b, c, acc),
            UnitDatapath::Word(w) => w.fmac_one_tracked(a, b, c, acc),
        }
    }
}

/// The golden softfloat spec as an engine datapath: always **fused**
/// semantics, whatever unit it is compared against. This is what the
/// coordinator checks the PJRT artifact with.
#[derive(Debug, Clone, Copy)]
pub struct GoldenFma {
    pub format: Format,
}

impl Datapath for GoldenFma {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        FpuKind::Fma
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn label(&self) -> String {
        "golden/fused".to_string()
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        softfloat::fma(self.format, RoundMode::NearestEven, a, b, c).bits
    }
}

/// Report of a sampled gate-level cross-check of a word-level run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CrossCheck {
    /// How many operands were re-executed at gate level.
    pub sampled: usize,
    /// Indices (into the batch) that disagreed, capped at 16.
    pub mismatches: Vec<usize>,
}

impl CrossCheck {
    pub fn clean(&self) -> bool {
        self.mismatches.is_empty()
    }
}

const CROSSCHECK_CAP: usize = 16;

/// Thread-parallel batch executor: splits an operand slice into per-worker
/// chunks and drives any [`Datapath`] through a scoped fork-join.
#[derive(Debug, Clone, Copy)]
pub struct BatchExecutor {
    workers: usize,
}

impl Default for BatchExecutor {
    fn default() -> Self {
        BatchExecutor::auto()
    }
}

impl BatchExecutor {
    /// Fixed worker count (clamped to ≥ 1).
    pub fn new(workers: usize) -> BatchExecutor {
        BatchExecutor { workers: workers.max(1) }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> BatchExecutor {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        BatchExecutor::new(n)
    }

    /// Single-threaded executor (scalar-equivalent ordering, no spawns).
    pub fn serial() -> BatchExecutor {
        BatchExecutor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute a batch, returning result bits in operand order.
    pub fn run<D: Datapath + ?Sized>(&self, dp: &D, triples: &[OperandTriple]) -> Vec<u64> {
        let mut out = vec![0u64; triples.len()];
        self.run_into(dp, triples, &mut out);
        out
    }

    /// Execute a batch into a caller-provided buffer.
    pub fn run_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        let n = triples.len();
        if n == 0 {
            return;
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            dp.fmac_batch(triples, out);
            return;
        }
        let chunk = n.div_ceil(workers);
        std::thread::scope(|s| {
            for (ts, os) in triples.chunks(chunk).zip(out.chunks_mut(chunk)) {
                s.spawn(move || dp.fmac_batch(ts, os));
            }
        });
    }

    /// Execute a batch while accumulating activity (merged across
    /// workers; the merge is order-independent because the accumulator is
    /// a plain sum).
    pub fn run_tracked<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
    ) -> (Vec<u64>, ActivityAccumulator) {
        let n = triples.len();
        let mut out = vec![0u64; n];
        let mut total = ActivityAccumulator::default();
        if n == 0 {
            return (out, total);
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            dp.fmac_batch_tracked(triples, &mut out, &mut total);
            return (out, total);
        }
        let chunk = n.div_ceil(workers);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (ts, os) in triples.chunks(chunk).zip(out.chunks_mut(chunk)) {
                handles.push(s.spawn(move || {
                    let mut acc = ActivityAccumulator::default();
                    dp.fmac_batch_tracked(ts, os, &mut acc);
                    acc
                }));
            }
            for h in handles {
                total.merge(&h.join().expect("engine worker panicked"));
            }
        });
        (out, total)
    }

    /// Word-level execution of a unit with a sampled gate-level
    /// cross-check: every `sample_every`-th operand is re-executed through
    /// the structural datapath and compared bit-for-bit. This is the
    /// release-build guard on the word-level tier's bit-identity claim.
    /// The gate-level sample runs through the executor too, so the check
    /// does not serialize the call at small strides.
    pub fn run_checked(
        &self,
        unit: &FpuUnit,
        triples: &[OperandTriple],
        sample_every: usize,
    ) -> (Vec<u64>, CrossCheck) {
        let word = WordUnit::of(unit);
        let out = self.run(&word, triples);
        let step = sample_every.max(1);
        let indices: Vec<usize> = (0..triples.len()).step_by(step).collect();
        let sampled: Vec<OperandTriple> = indices.iter().map(|&i| triples[i]).collect();
        let gate = self.run(unit, &sampled);
        let mut check = CrossCheck { sampled: indices.len(), mismatches: Vec::new() };
        for (k, &i) in indices.iter().enumerate() {
            if gate[k] != out[i] && check.mismatches.len() < CROSSCHECK_CAP {
                check.mismatches.push(i);
            }
        }
        (out, check)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    fn sample(cfg: &FpuConfig, mix: OperandMix, n: usize, seed: u64) -> Vec<OperandTriple> {
        OperandStream::new(cfg.precision, mix, seed).batch(n)
    }

    #[test]
    fn tiers_bit_identical_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let word = WordUnit::of(&unit);
            for t in sample(&cfg, OperandMix::Anything, 3_000, 0xE16).iter() {
                assert_eq!(
                    unit.fmac_one(t.a, t.b, t.c),
                    word.fmac_one(t.a, t.b, t.c),
                    "{}: a={:#x} b={:#x} c={:#x}",
                    cfg.name(),
                    t.a,
                    t.b,
                    t.c
                );
            }
        }
    }

    #[test]
    fn executor_matches_scalar_loop_any_worker_count() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 2_531, 7); // not a worker multiple
        let scalar: Vec<u64> =
            triples.iter().map(|t| unit.fmac_one(t.a, t.b, t.c)).collect();
        for workers in [1, 2, 3, 5, 16, 64] {
            let got = BatchExecutor::new(workers).run(&unit, &triples);
            assert_eq!(got, scalar, "workers={workers}");
        }
    }

    #[test]
    fn tracked_run_merges_activity_like_serial() {
        let cfg = FpuConfig::dp_cma();
        let unit = FpuUnit::generate(&cfg);
        let mut triples = sample(&cfg, OperandMix::Anything, 2_000, 11);
        // One guaranteed special so the clock-gating counter is exercised
        // regardless of what the random stream drew.
        triples.push(OperandTriple { a: f64::NAN.to_bits(), b: 0, c: 0 });
        let (bits1, acc1) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let (bits8, acc8) = BatchExecutor::new(8).run_tracked(&unit, &triples);
        assert_eq!(bits1, bits8);
        assert_eq!(acc1, acc8, "activity sums must be worker-count invariant");
        assert_eq!(acc1.ops, 2_001);
        assert!(acc1.tree_toggles > 0);
        assert!(acc1.special_ops > 0, "the NaN op must take the special path");
    }

    #[test]
    fn word_level_tracks_special_fraction() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Anything, 4_000, 23);
        let (_, gate) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let word = WordUnit::of(&unit);
        let (_, wacc) = BatchExecutor::serial().run_tracked(&word, &triples);
        // Word level sees exactly the same clock-gating decisions and the
        // same Booth recoding — digit statistics must agree exactly.
        assert_eq!(gate.special_ops, wacc.special_ops);
        assert_eq!(gate.ops, wacc.ops);
        assert_eq!(gate.digits, wacc.digits);
        assert_eq!(gate.nonzero_digits, wacc.nonzero_digits);
        // ... but word level carries no gate toggles.
        assert_eq!(wacc.tree_toggles, 0);
        assert_eq!(wacc.tree_fa_ops, 0);
    }

    #[test]
    fn run_checked_clean_on_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Anything, 5_000, 0xC0FFEE);
            let (out, check) = BatchExecutor::new(4).run_checked(&unit, &triples, 37);
            assert!(check.clean(), "{}: {:?}", cfg.name(), check.mismatches);
            assert_eq!(check.sampled, triples.len().div_ceil(37));
            assert_eq!(out.len(), triples.len());
        }
    }

    #[test]
    fn golden_fma_is_fused_spec() {
        let g = GoldenFma { format: Format::SP };
        let a = 1.0f32 + 2f32.powi(-12);
        let c = -(1.0f32 + 2f32.powi(-11));
        let r = g.fmac_one(a.to_bits() as u64, a.to_bits() as u64, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r as u32), 2f32.powi(-24)); // cascade would give 0
    }

    #[test]
    fn activity_scale_tracks_operand_density() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let s = *unit.structure();
        let dense = OperandTriple {
            a: 0x3fff_ffff,
            b: 0x3faa_aaaa,
            c: 0x3f80_0000,
        };
        let quiet = OperandTriple { a: 0x3f80_0000, b: 0x0040_0000, c: 0 };
        let mut acc_dense = ActivityAccumulator::default();
        let mut acc_quiet = ActivityAccumulator::default();
        for _ in 0..64 {
            unit.fmac_one_tracked(dense.a, dense.b, dense.c, &mut acc_dense);
            unit.fmac_one_tracked(quiet.a, quiet.b, quiet.c, &mut acc_quiet);
        }
        assert!(acc_dense.activity_scale(&s) > acc_quiet.activity_scale(&s));
        // Empty accumulator is neutral.
        assert_eq!(ActivityAccumulator::default().activity_scale(&s), 1.0);
    }

    #[test]
    fn unit_datapath_binds_fidelity() {
        let cfg = FpuConfig::dp_fma();
        let unit = FpuUnit::generate(&cfg);
        let gate = UnitDatapath::new(&unit, Fidelity::GateLevel);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        assert_eq!(gate.fidelity(), Fidelity::GateLevel);
        assert_eq!(word.fidelity(), Fidelity::WordLevel);
        assert!(gate.label().contains("gate") && word.label().contains("word"));
        assert_eq!(
            Datapath::structure(&gate).unwrap(),
            Datapath::structure(&word).unwrap()
        );
        let t = OperandTriple {
            a: 1.5f64.to_bits(),
            b: 2.0f64.to_bits(),
            c: 0.25f64.to_bits(),
        };
        assert_eq!(gate.fmac_one(t.a, t.b, t.c), word.fmac_one(t.a, t.b, t.c));
    }

    #[test]
    fn default_batch_covers_every_slot() {
        let cfg = FpuConfig::sp_cma();
        let word = WordUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 1_357, 3);
        let mut out = vec![u64::MAX; triples.len()];
        word.fmac_batch(&triples, &mut out);
        for (i, (t, &o)) in triples.iter().zip(out.iter()).enumerate() {
            assert_eq!(o, word.fmac_one(t.a, t.b, t.c), "slot {i}");
        }
    }
}
