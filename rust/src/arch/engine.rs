//! The batched execution engine: **one execution interface** for every
//! consumer of an FMAC datapath (coordinator, DSE sweeps, chip
//! sequencer, workload drivers, benches), with selectable fidelity.
//!
//! The FPMax paper separates what a unit *computes* (bit-exact IEEE
//! semantics per Table I) from how fast the silicon *delivers* it; FPnew
//! and Snitch make the same split in hardware — a parameterized FPU
//! behind a streaming front-end that keeps it fed. This module is that
//! split in software:
//!
//! * [`Datapath`] — the execution trait. `fmac_one` is the scalar op;
//!   `fmac_batch` has a streaming default so no implementation hand-rolls
//!   batching (the executor chunks batches across workers and drives it
//!   per chunk); `*_tracked` variants accumulate per-op activity into an
//!   [`ActivityAccumulator`].
//! * [`Fidelity`] — the three execution tiers. All are **bit-identical**
//!   on every operand; they differ only in what they *simulate* and
//!   therefore how fast they run:
//!
//!   | tier | computes | skips | guarantee | use it for |
//!   |------|----------|-------|-----------|------------|
//!   | `GateLevel` | every Booth mux and 3:2 row, toggle counts | nothing | is the DUT | verification, measured-activity energy |
//!   | `WordLevel` | exact integer-significand softfloat, scalar | per-row gate simulation | bit-identical; debug-asserted vs gate, sampled gate cross-checks at run time | DSE sweeps, fast verify |
//!   | `WordSimd` | the same spec restructured into branch-light SoA lane kernels ([`softfloat::lanes`]) | gate simulation **and** the scalar decode/class branches | bit-identical; same sampled gate-level cross-check machinery as `WordLevel` | throughput-bound batch serving |
//!
//! * [`BatchExecutor`] — thread-parallel execution over operand slices
//!   through a **persistent worker pool** (threads spawn once on the
//!   first parallel run and park between runs; the offline environment
//!   has no tokio, and the workload is pure CPU compute). The hot path is
//!   **allocation-free**: `*_into` variants write caller-provided
//!   buffers, workers pull load-aware chunks off an atomic cursor (chunk
//!   size autotuned by a one-shot calibration pass persisted in the
//!   executor), and the sampled cross-check walks indices directly
//!   instead of materializing index/operand vectors. Mismatched caller
//!   buffers return a typed [`BatchLenError`] instead of panicking.
//! * [`ActivityTrace`] — the **time-resolved** activity layer: fixed-width
//!   windows (configurable ops-per-window) of toggle counts and
//!   occupancy. [`BatchExecutor::run_windowed_into`] produces one from a
//!   live batch (windows are keyed by absolute operand index, so the
//!   per-window sums are deterministic whatever the worker interleaving),
//!   the chip sequencer emits one per traced program, and
//!   [`ActivityTrace::from_profile`] converts a synthetic
//!   [`UtilizationProfile`] into the same shape. The invariant pinned by
//!   tests: the sum of a trace's windows **equals** the aggregate
//!   [`ActivityAccumulator`] of the same run, bit for bit. The body-bias
//!   controller ([`crate::bb`]) consumes traces to react to workload
//!   phases instead of run-level averages.
//! * [`window_ring`] — a bounded, lock-free, allocation-free SPSC ring
//!   carrying completed [`ActivityWindow`]s from the engine side to a
//!   live consumer (the streaming body-bias controller of the serve
//!   layer, [`crate::runtime::serve`]). Overflow coalesces windows —
//!   granularity degrades, slot/toggle accounting never drops. Custom
//!   schedulers drive the persistent pool through
//!   [`BatchExecutor::run_region`].
//!
//! Implementations provided: [`FpuUnit`] (the generated gate-level
//! datapath), [`WordUnit`] (the scalar word-level tier of a unit),
//! [`WordSimdUnit`] (the lane-batched word-level tier), [`UnitDatapath`]
//! (a unit bound to a fidelity at run time), and [`GoldenFma`] (the fused
//! softfloat spec, regardless of unit kind).

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::fma::FmaActivity;
use super::fp::{decode, Class, Format};
use super::generator::{FpuConfig, FpuKind, FpuUnit, StructureReport};
use super::multiplier::MultiplierConfig;
use super::rounding::{Flags, RoundMode, Rounded};
use super::softfloat;
use crate::workloads::throughput::{OperandStream, OperandTriple};
use crate::workloads::utilization::UtilizationProfile;

/// Execution fidelity tier of a datapath implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Fidelity {
    /// Structural simulation: Booth recoding, every 3:2 compressor row,
    /// toggle counting. Slow; feeds the energy model real activity.
    #[default]
    GateLevel,
    /// Exact integer-significand arithmetic, no per-row gate evaluation.
    /// Bit-identical results, ~an order of magnitude faster.
    WordLevel,
    /// Lane-batched word level: the same exact arithmetic restructured
    /// into branch-light SoA lane kernels
    /// ([`softfloat::lanes`]), special-case lanes peeled to the scalar
    /// slow path. Bit-identical to both other tiers.
    WordSimd,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::GateLevel => "gate",
            Fidelity::WordLevel => "word",
            Fidelity::WordSimd => "word-simd",
        }
    }
}

/// The per-unit Table-I semantics at word level: fused units round once,
/// cascade units round after the multiply and again after the add. This
/// is the single spec function the coordinator, the chip tester, and the
/// word-level tier all share.
#[inline]
pub fn reference_fmac(
    kind: FpuKind,
    fmt: Format,
    mode: RoundMode,
    a: u64,
    b: u64,
    c: u64,
) -> Rounded {
    match kind {
        FpuKind::Fma => softfloat::fma(fmt, mode, a, b, c),
        FpuKind::Cma => {
            let p = softfloat::mul(fmt, mode, a, b);
            let s = softfloat::add(fmt, mode, p.bits, c);
            Rounded { bits: s.bits, flags: Flags::merge(p.flags, s.flags) }
        }
    }
}

/// Unified activity accumulator: the sum of per-op [`FmaActivity`]
/// records over a batch, mergeable across worker threads. This replaces
/// the ad-hoc per-module toggle counters that used to feed the energy
/// model — [`crate::energy::power::evaluate_measured`] consumes one
/// directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityAccumulator {
    /// Ops recorded.
    pub ops: u64,
    /// Ops that took the special/early-out path (clock-gated datapath).
    pub special_ops: u64,
    /// Total Booth digits across ops.
    pub digits: u64,
    /// Nonzero Booth digits (mux/negate activity).
    pub nonzero_digits: u64,
    /// Tree full-adder evaluations (gate-level only).
    pub tree_fa_ops: u64,
    /// Tree output toggle weight (gate-level only).
    pub tree_toggles: u64,
    /// Summed alignment-shifter distances.
    pub align_shift: u64,
    /// Summed normalization distances.
    pub norm_shift: u64,
}

impl ActivityAccumulator {
    /// Fold one op's activity record in.
    #[inline]
    pub fn record(&mut self, act: &FmaActivity) {
        self.ops += 1;
        if act.special {
            self.special_ops += 1;
        }
        self.digits += act.digits as u64;
        self.nonzero_digits += act.nonzero_digits as u64;
        self.tree_fa_ops += act.tree_fa_ops;
        self.tree_toggles += act.tree_toggles;
        self.align_shift += act.align_shift as u64;
        self.norm_shift += act.norm_shift as u64;
    }

    /// Merge another accumulator (fork-join reduction).
    pub fn merge(&mut self, other: &ActivityAccumulator) {
        self.ops += other.ops;
        self.special_ops += other.special_ops;
        self.digits += other.digits;
        self.nonzero_digits += other.nonzero_digits;
        self.tree_fa_ops += other.tree_fa_ops;
        self.tree_toggles += other.tree_toggles;
        self.align_shift += other.align_shift;
        self.norm_shift += other.norm_shift;
    }

    /// Fraction of ops that exercised the full datapath (specials gate
    /// the multiplier clock).
    pub fn active_fraction(&self) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        1.0 - self.special_ops as f64 / self.ops as f64
    }

    /// Data-activity scale factor for [`crate::energy::UnitCost::dyn_energy_pj`]
    /// (1.0 = the calibrated average-operand activity).
    ///
    /// Gate-level runs scale by measured tree toggles per op against the
    /// half-the-tree-cells random baseline. Word-level runs carry no
    /// toggle counts but do record Booth digit statistics (the recoder is
    /// word-level computable), so they scale by the nonzero-digit ratio
    /// against the random-operand expectation of the radix — 3/4 for
    /// Booth-2, 7/8 for Booth-3 — times the active-op fraction. Only an
    /// empty accumulator is neutral.
    pub fn activity_scale(&self, s: &StructureReport) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        if self.tree_fa_ops > 0 {
            let per_op = self.tree_toggles as f64 / self.ops as f64;
            let baseline = (s.tree_cells as f64 / 2.0).max(1.0);
            (per_op / baseline).clamp(0.05, 2.0)
        } else if self.digits > 0 {
            let ratio = self.nonzero_digits as f64 / self.digits as f64;
            let baseline = if s.has_triple_adder { 7.0 / 8.0 } else { 3.0 / 4.0 };
            (self.active_fraction() * ratio / baseline).clamp(0.05, 2.0)
        } else {
            self.active_fraction().clamp(0.05, 1.0)
        }
    }
}

/// Typed error of the `run_*_into` family: the caller-provided output
/// buffer does not match the operand count. The executor returns this
/// instead of panicking so a serving layer can resize and retry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchLenError {
    /// Operand triples submitted.
    pub ops: usize,
    /// Output-buffer length provided.
    pub out: usize,
}

impl std::fmt::Display for BatchLenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "batch length mismatch: {} operand triples but the output buffer holds {}",
            self.ops, self.out
        )
    }
}

impl std::error::Error for BatchLenError {}

/// A parallel-region closure panicked on one or more pool workers (see
/// [`BatchExecutor::run_region_checked`]). The pool itself survives —
/// each worker catches its epoch's unwind — so this is a per-call fault,
/// not a poisoned executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPanicked {
    /// How many workers' closure invocations panicked in this region.
    pub workers: usize,
}

impl std::fmt::Display for WorkerPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parallel region panicked on {} engine worker(s)", self.workers)
    }
}

impl std::error::Error for WorkerPanicked {}

#[inline]
fn check_len(triples: &[OperandTriple], out: &[u64]) -> Result<(), BatchLenError> {
    if triples.len() == out.len() {
        Ok(())
    } else {
        Err(BatchLenError { ops: triples.len(), out: out.len() })
    }
}

/// One fixed-width window of a time-resolved [`ActivityTrace`]: how many
/// issue slots the window covers and the summed activity of the ops that
/// actually issued in it. `slots > acc.ops` means the window contains
/// idle slots — the signal the phase-aware body-bias controller keys on.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityWindow {
    /// Issue slots covered by this window (ops + idle slots).
    pub slots: u64,
    /// Summed activity of the ops that issued in this window.
    pub acc: ActivityAccumulator,
}

impl ActivityWindow {
    /// Fraction of this window's issue slots that carried an op.
    pub fn occupancy(&self) -> f64 {
        if self.slots == 0 {
            0.0
        } else {
            self.acc.ops as f64 / self.slots as f64
        }
    }
}

/// A time-resolved activity trace: the run's issue-slot timeline cut into
/// fixed-width windows of toggle counts and occupancy.
///
/// Windows are laid out on an absolute slot axis: window `w` covers slots
/// `[w·window_slots, (w+1)·window_slots)` (the final window may cover
/// fewer). Producers either stream slots in order (`push_*`, used by the
/// chip sequencer and the profile weaves) or merge worker partials by
/// window index ([`BatchExecutor::run_windowed_into`]); both constructions
/// are deterministic because per-window sums are plain integer additions.
///
/// **Invariant** (pinned by tests across all fidelity tiers): the sum of
/// all windows, [`ActivityTrace::aggregate`], equals bit-for-bit the
/// [`ActivityAccumulator`] an unwindowed tracked run of the same ops
/// would return.
#[derive(Debug, Clone, PartialEq)]
pub struct ActivityTrace {
    window_slots: u64,
    windows: Vec<ActivityWindow>,
}

impl ActivityTrace {
    /// Empty trace with the given window width in issue slots (≥ 1).
    pub fn new(window_slots: u64) -> ActivityTrace {
        assert!(window_slots >= 1, "window width must be at least one slot");
        ActivityTrace { window_slots, windows: Vec::new() }
    }

    /// Assemble a trace from per-window accumulators produced by the
    /// parallel executor: window `i` covers ops `[i·w, (i+1)·w)` of a
    /// fully-occupied `total_ops`-op batch.
    fn from_windows(
        window_slots: u64,
        total_ops: u64,
        accs: Vec<ActivityAccumulator>,
    ) -> ActivityTrace {
        let windows = accs
            .into_iter()
            .enumerate()
            .map(|(i, acc)| {
                let lo = i as u64 * window_slots;
                ActivityWindow { slots: window_slots.min(total_ops - lo), acc }
            })
            .collect();
        ActivityTrace { window_slots, windows }
    }

    /// Window width in issue slots.
    pub fn window_slots(&self) -> u64 {
        self.window_slots
    }

    /// The windows, in slot order.
    pub fn windows(&self) -> &[ActivityWindow] {
        &self.windows
    }

    /// Number of windows.
    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Total issue slots covered (ops + idle).
    pub fn total_slots(&self) -> u64 {
        self.windows.iter().map(|w| w.slots).sum()
    }

    /// Total ops recorded.
    pub fn total_ops(&self) -> u64 {
        self.windows.iter().map(|w| w.acc.ops).sum()
    }

    /// Overall occupancy: ops / slots.
    pub fn occupancy(&self) -> f64 {
        let slots = self.total_slots();
        if slots == 0 {
            0.0
        } else {
            self.total_ops() as f64 / slots as f64
        }
    }

    /// The exact aggregate of the trace: summing every window recovers
    /// the run-level [`ActivityAccumulator`] bit for bit.
    pub fn aggregate(&self) -> ActivityAccumulator {
        let mut total = ActivityAccumulator::default();
        for w in &self.windows {
            total.merge(&w.acc);
        }
        total
    }

    /// Free slots left in the currently-open window (0 when the next push
    /// must open a fresh window).
    fn room(&self) -> u64 {
        match self.windows.last() {
            Some(w) if w.slots < self.window_slots => self.window_slots - w.slots,
            _ => 0,
        }
    }

    /// Append `slots` issue slots carrying `acc` into the open window.
    /// The caller guarantees they fit (streaming producers split at
    /// window boundaries before calling this).
    fn push_into_current(&mut self, slots: u64, acc: &ActivityAccumulator) {
        if self.room() == 0 {
            self.windows.push(ActivityWindow::default());
        }
        let w = self.windows.last_mut().expect("window just ensured");
        debug_assert!(w.slots + slots <= self.window_slots, "window overfill");
        w.slots += slots;
        w.acc.merge(acc);
    }

    /// Slots the next streaming push may emit without crossing a window
    /// boundary.
    fn open_slots(&self) -> u64 {
        match self.room() {
            0 => self.window_slots,
            r => r,
        }
    }

    /// Append idle issue slots (clock-gated; no op issued), splitting
    /// across window boundaries as needed.
    pub fn push_idle(&mut self, mut slots: u64) {
        while slots > 0 {
            let take = slots.min(self.open_slots());
            self.push_into_current(take, &ActivityAccumulator::default());
            slots -= take;
        }
    }

    /// Append `ops` issue slots that each carried an op with no detailed
    /// activity record (occupancy-only accounting — e.g. the chip
    /// sequencer's Mul/Add bursts, or synthetic profile conversion).
    pub fn push_untracked_ops(&mut self, mut ops: u64) {
        while ops > 0 {
            let take = ops.min(self.open_slots());
            let acc = ActivityAccumulator { ops: take, ..ActivityAccumulator::default() };
            self.push_into_current(take, &acc);
            ops -= take;
        }
    }

    /// Append one already-recorded op (one issue slot). Used by scalar
    /// sequencer paths that captured activity out of band.
    pub fn push_op(&mut self, acc: &ActivityAccumulator) {
        debug_assert_eq!(acc.ops, 1, "push_op takes exactly one op's record");
        self.push_into_current(1, acc);
    }

    /// Execute one op through `dp` with tracking and append it as one
    /// issue slot; returns the result bits.
    pub fn push_op_tracked<D: Datapath + ?Sized>(&mut self, dp: &D, a: u64, b: u64, c: u64) -> u64 {
        let mut acc = ActivityAccumulator::default();
        let bits = dp.fmac_one_tracked(a, b, c, &mut acc);
        self.push_into_current(1, &acc);
        bits
    }

    /// Execute a batch through `dp` with tracking, one issue slot per op,
    /// splitting the tracked sub-runs at window boundaries so every
    /// window's sum is exact.
    pub fn push_batch_tracked<D: Datapath + ?Sized>(
        &mut self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) -> Result<(), BatchLenError> {
        check_len(triples, out)?;
        let mut i = 0;
        while i < triples.len() {
            let take = (self.open_slots() as usize).min(triples.len() - i);
            let mut acc = ActivityAccumulator::default();
            dp.fmac_batch_tracked(&triples[i..i + take], &mut out[i..i + take], &mut acc);
            self.push_into_current(take as u64, &acc);
            i += take;
        }
        Ok(())
    }

    /// The profile → trace shim: convert a synthetic
    /// [`UtilizationProfile`] into a trace with the same active/idle
    /// timeline (active slots carry occupancy-only activity records, so
    /// the energy model's activity scale stays at the calibrated 1.0 —
    /// exactly what the profile-based Fig. 4 path assumes).
    pub fn from_profile(profile: &UtilizationProfile, window_slots: u64) -> ActivityTrace {
        let mut t = ActivityTrace::new(window_slots);
        for seg in &profile.segments {
            if seg.active {
                t.push_untracked_ops(seg.cycles);
            } else {
                t.push_idle(seg.cycles);
            }
        }
        t
    }

    /// Measured phase-aware trace: execute one FMAC per **active** cycle
    /// of `profile` through `dp` (operands drawn from `stream`), pushing
    /// the idle gaps through unchanged. This is how the Fig. 4 workloads
    /// produce traces with *measured* per-window activity instead of the
    /// profile shim's synthetic occupancy.
    pub fn record_profile<D: Datapath + ?Sized>(
        dp: &D,
        profile: &UtilizationProfile,
        window_slots: u64,
        stream: &mut OperandStream,
    ) -> ActivityTrace {
        const CHUNK: usize = 4096;
        let mut trace = ActivityTrace::new(window_slots);
        let mut ops_buf = vec![OperandTriple { a: 0, b: 0, c: 0 }; CHUNK];
        let mut out_buf = vec![0u64; CHUNK];
        for seg in &profile.segments {
            if !seg.active {
                trace.push_idle(seg.cycles);
                continue;
            }
            let mut left = seg.cycles;
            while left > 0 {
                let take = left.min(CHUNK as u64) as usize;
                stream.fill(&mut ops_buf[..take]);
                trace
                    .push_batch_tracked(dp, &ops_buf[..take], &mut out_buf[..take])
                    .expect("scratch buffers are sized together");
                left -= take as u64;
            }
        }
        trace
    }

    /// Assemble a trace directly from explicit windows, kept verbatim.
    /// Unlike the streaming `push_*` builders this never merges or
    /// splits at window boundaries, so interior windows may be partial —
    /// the shape a serving layer produces when successive batches are
    /// not multiples of the window width. `window_slots` records the
    /// nominal width the producer was cutting at.
    pub fn from_raw_windows(window_slots: u64, windows: Vec<ActivityWindow>) -> ActivityTrace {
        assert!(window_slots >= 1, "window width must be at least one slot");
        ActivityTrace { window_slots, windows }
    }

    /// Append one already-formed window verbatim (no boundary
    /// splitting). The serve layer's master trace mirrors exactly the
    /// window sequence it published to the [`window_ring`], so the
    /// post-hoc schedule computed on this trace is comparable
    /// bit-for-bit with the streamed one.
    pub fn push_window(&mut self, w: ActivityWindow) {
        self.windows.push(w);
    }
}

/// One published entry of a [`window_ring`]: an activity window plus the
/// number of engine windows it carries. `coalesced == 1` is a pristine
/// window; `> 1` means the ring was full and the producer merged
/// neighbouring windows — slot counts and toggle statistics are all
/// retained (energy accounting never drops), only the window-granular
/// idle structure degrades to the merged window's occupancy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RingWindow {
    pub window: ActivityWindow,
    pub coalesced: u32,
}

/// The shared state of a bounded SPSC window ring. Slots are a fixed
/// array written only by the producer and read only by the consumer;
/// `head`/`tail` are monotonic counters (index = counter mod capacity).
struct WindowRing {
    slots: Box<[UnsafeCell<RingWindow>]>,
    /// Next slot the consumer reads.
    head: AtomicUsize,
    /// Next slot the producer writes.
    tail: AtomicUsize,
    /// Producer has closed the stream (set after its last push).
    closed: AtomicBool,
    /// Consumer is (about to be) parked in [`WindowConsumer::recv`].
    /// Producer publishes check it with a store/fence/load handshake so
    /// the consumer never burns a core waiting out a long batch, and
    /// the producer pays nothing while the consumer is running.
    parked: AtomicBool,
    /// Parking lot for the blocking consumer; the producer notifies
    /// while holding the (otherwise empty) mutex, which closes the
    /// check-then-wait window.
    park: Mutex<()>,
    wake: Condvar,
}

// SAFETY: slot `i` is written only by the single producer while
// `tail - head < capacity` keeps the consumer away from it, and read
// only by the single consumer after the Release store of `tail` has
// published the write. The counters are monotonic, so no slot is ever
// aliased by a read and a write at once.
unsafe impl Send for WindowRing {}
unsafe impl Sync for WindowRing {}

/// Create a bounded SPSC ring carrying completed [`ActivityWindow`]s
/// from the engine side (single producer: the serve dispatcher
/// publishing each batch's windows in order) to a live consumer (the
/// streaming body-bias controller, [`crate::bb::StreamingController`]).
///
/// Push and pop are lock-free and allocation-free after construction
/// (pinned by `rust/tests/alloc.rs`). Overflow never blocks the engine
/// and never drops activity: a window published into a full ring is
/// merged into a producer-side pending window and delivered as soon as
/// a slot frees, marked by its [`RingWindow::coalesced`] count.
pub fn window_ring(capacity: usize) -> (WindowProducer, WindowConsumer) {
    assert!(capacity >= 1, "window ring needs at least one slot");
    let slots: Box<[UnsafeCell<RingWindow>]> =
        (0..capacity).map(|_| UnsafeCell::new(RingWindow::default())).collect();
    let shared = Arc::new(WindowRing {
        slots,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
        parked: AtomicBool::new(false),
        park: Mutex::new(()),
        wake: Condvar::new(),
    });
    (
        WindowProducer { shared: Arc::clone(&shared), pending: None, coalesced: 0 },
        WindowConsumer { shared },
    )
}

/// Producer half of a [`window_ring`]. **Single-producer**: exactly one
/// thread may hold and use this handle.
pub struct WindowProducer {
    shared: Arc<WindowRing>,
    /// Window merged while the ring was full, waiting for a free slot.
    pending: Option<RingWindow>,
    coalesced: u64,
}

impl WindowProducer {
    fn try_push(&self, e: RingWindow) -> bool {
        let tail = self.shared.tail.load(Ordering::Relaxed);
        if tail.wrapping_sub(self.shared.head.load(Ordering::Acquire)) == self.shared.slots.len()
        {
            return false;
        }
        let idx = tail % self.shared.slots.len();
        // SAFETY: tail - head < capacity, so the consumer cannot be
        // reading this slot, and this thread is the only producer.
        unsafe { *self.shared.slots[idx].get() = e };
        self.shared.tail.store(tail.wrapping_add(1), Ordering::Release);
        // Wake a parked consumer. Store-fence-load pairs with recv()'s
        // store-fence-load: at least one side sees the other's store,
        // so either we notify here or the consumer's recheck sees the
        // new tail — never a lost wakeup. When the consumer is live,
        // this is a single relaxed load.
        fence(Ordering::SeqCst);
        if self.shared.parked.load(Ordering::Relaxed) {
            let _g = self.shared.park.lock().expect("window ring poisoned");
            self.shared.wake.notify_one();
        }
        true
    }

    /// Publish one completed window. Never blocks and never drops
    /// activity: when the ring is full the window is folded into a
    /// pending coalesced window (occupancy and toggle sums retained,
    /// window granularity lost) that is pushed as soon as a slot frees.
    pub fn publish(&mut self, w: ActivityWindow) {
        if let Some(p) = self.pending.take() {
            if !self.try_push(p) {
                let mut p = p;
                p.window.slots += w.slots;
                p.window.acc.merge(&w.acc);
                p.coalesced += 1;
                self.coalesced += 1;
                self.pending = Some(p);
                return;
            }
        }
        let e = RingWindow { window: w, coalesced: 1 };
        if !self.try_push(e) {
            self.pending = Some(e);
        }
    }

    /// Windows that were merged into a neighbour because the ring was
    /// full (0 = the consumer saw the pristine window sequence).
    pub fn coalesced(&self) -> u64 {
        self.coalesced
    }

    /// Flush the pending window (waiting for the consumer to free a
    /// slot) and close the stream; returns the total coalesced-window
    /// count. If the consumer handle is already gone, the pending window
    /// is dropped — nothing is left to account it to.
    pub fn close(mut self) -> u64 {
        while let Some(p) = self.pending.take() {
            if self.try_push(p) {
                break;
            }
            if Arc::strong_count(&self.shared) == 1 {
                break;
            }
            self.pending = Some(p);
            std::thread::yield_now();
        }
        self.shared.closed.store(true, Ordering::Release);
        // Unconditional wake: a parked consumer must observe the close.
        let _g = self.shared.park.lock().expect("window ring poisoned");
        self.shared.wake.notify_all();
        self.coalesced
    }
}

impl Drop for WindowProducer {
    /// A producer that goes away without [`WindowProducer::close`] —
    /// e.g. a serve dispatcher unwinding mid-run — still closes the
    /// stream, so a consumer parked in [`WindowConsumer::recv`] wakes
    /// and drains instead of hanging forever. (The orderly `close` path
    /// has already stored the flag by the time this runs; storing it
    /// twice is harmless.)
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::Release);
        match self.shared.park.lock() {
            Ok(_g) => self.shared.wake.notify_all(),
            Err(p) => {
                let _g = p.into_inner();
                self.shared.wake.notify_all();
            }
        }
    }
}

/// Consumer half of a [`window_ring`]. **Single-consumer**: exactly one
/// thread may hold and use this handle.
pub struct WindowConsumer {
    shared: Arc<WindowRing>,
}

impl WindowConsumer {
    /// Non-blocking pop of the oldest published window.
    pub fn pop(&mut self) -> Option<RingWindow> {
        let head = self.shared.head.load(Ordering::Relaxed);
        if self.shared.tail.load(Ordering::Acquire) == head {
            return None;
        }
        let idx = head % self.shared.slots.len();
        // SAFETY: head < tail, so the producer's Release store has
        // published this slot, and it cannot be overwriting it (that
        // would need tail - head == capacity).
        let e = unsafe { *self.shared.slots[idx].get() };
        self.shared.head.store(head.wrapping_add(1), Ordering::Release);
        Some(e)
    }

    /// Blocking receive: parks on the ring's condvar until a window
    /// arrives, or returns `None` once the producer has closed and the
    /// ring is drained. Parking (instead of spinning) matters in the
    /// serve layer: the controller thread would otherwise burn a core
    /// against the engine workers for the whole duration of every
    /// batch.
    pub fn recv(&mut self) -> Option<RingWindow> {
        loop {
            if let Some(e) = self.pop() {
                return Some(e);
            }
            if self.shared.closed.load(Ordering::Acquire) {
                // One final pop closes the push-then-close race: the
                // Acquire load of `closed` orders us after every push
                // the producer made before closing.
                return self.pop();
            }
            // Park. Store-fence-load pairs with the producer's
            // publish-side store-fence-load (see `try_push`): if the
            // producer missed our flag, the recheck below sees its
            // tail; if the recheck misses the tail, the producer saw
            // the flag and will notify — under the same mutex we wait
            // on, so the notify cannot slip between recheck and wait.
            self.shared.parked.store(true, Ordering::Relaxed);
            fence(Ordering::SeqCst);
            let nonempty = self.shared.tail.load(Ordering::Acquire)
                != self.shared.head.load(Ordering::Relaxed);
            if !nonempty && !self.shared.closed.load(Ordering::Acquire) {
                let g = self.shared.park.lock().expect("window ring poisoned");
                let nonempty_now = self.shared.tail.load(Ordering::Acquire)
                    != self.shared.head.load(Ordering::Relaxed);
                if !nonempty_now && !self.shared.closed.load(Ordering::Acquire) {
                    // Spurious wakeups are fine: the outer loop
                    // re-examines everything.
                    let _g = self.shared.wake.wait(g).expect("window ring poisoned");
                }
            }
            self.shared.parked.store(false, Ordering::Relaxed);
        }
    }
}

/// One execution interface over every FMAC datapath implementation.
///
/// Results are raw bit patterns in the datapath's [`Format`] (SP in the
/// low 32 bits). All implementations of the same unit configuration are
/// bit-identical across fidelity tiers; rounding is round-to-nearest-even
/// (the benchmarked default — mode-explicit execution stays on
/// [`FpuUnit::fmac_mode`]).
pub trait Datapath: Sync {
    /// Operand/result format.
    fn format(&self) -> Format;

    /// FMAC organization this datapath implements (fused or cascade).
    fn kind(&self) -> FpuKind;

    /// Fidelity tier of this implementation.
    fn fidelity(&self) -> Fidelity;

    /// Structural report, when this datapath models a generated unit.
    fn structure(&self) -> Option<&StructureReport> {
        None
    }

    /// Display label for benches and reports.
    fn label(&self) -> String {
        format!("{}/{}", self.kind().name(), self.fidelity().name())
    }

    /// One FMAC (`a·b + c` in Table-I semantics); returns result bits.
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64;

    /// One FMAC with activity accumulation.
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        acc.ops += 1;
        self.fmac_one(a, b, c)
    }

    /// Execute a batch into `out`. The default streams the scalar op over
    /// the slice pair; the *parallel* chunking lives in
    /// [`BatchExecutor`], which splits the batch across workers and calls
    /// this per chunk.
    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one(t.a, t.b, t.c);
        }
    }

    /// Execute a batch with activity accumulation.
    fn fmac_batch_tracked(
        &self,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: &mut ActivityAccumulator,
    ) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one_tracked(t.a, t.b, t.c, acc);
        }
    }
}

/// The generated unit itself is the gate-level tier.
impl Datapath for FpuUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.config.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::GateLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(FpuUnit::structure(self))
    }

    fn label(&self) -> String {
        format!("{}/{}", self.config.name(), Fidelity::GateLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        self.fmac(a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        let (r, act) = self.fmac_mode(RoundMode::NearestEven, a, b, c);
        acc.record(&act);
        r.bits
    }
}

/// The word-level tier of a generated unit: same Table-I semantics and
/// structure report, no per-row gate simulation. Bit-identical to the
/// gate-level tier by construction (the gate-level datapath asserts
/// equality against this very spec in debug builds); `run_checked`
/// re-verifies that on sampled operands in release.
#[derive(Debug, Clone)]
pub struct WordUnit {
    format: Format,
    kind: FpuKind,
    mul: MultiplierConfig,
    structure: StructureReport,
    name: String,
}

impl WordUnit {
    /// The word-level view of an elaborated unit.
    pub fn of(unit: &FpuUnit) -> WordUnit {
        WordUnit {
            format: unit.format,
            kind: unit.config.kind,
            mul: *unit.multiplier_config(),
            structure: *unit.structure(),
            name: unit.config.name(),
        }
    }

    /// Elaborate a configuration straight into the word-level tier.
    pub fn generate(cfg: &FpuConfig) -> WordUnit {
        WordUnit::of(&FpuUnit::generate(cfg))
    }

    /// The word-level activity observables of one op — the clock-gating
    /// decision and the Booth digit statistics — without computing the
    /// result. Shared by the scalar tracked path and the lane-batched
    /// tier's activity post-pass, so both word tiers report identical
    /// accumulators.
    #[inline]
    fn record_activity(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) {
        let da = decode(self.format, a);
        let db = decode(self.format, b);
        let special = match self.kind {
            FpuKind::Fma => {
                let dc = decode(self.format, c);
                da.non_finite()
                    || db.non_finite()
                    || dc.non_finite()
                    || da.is_zero()
                    || db.is_zero()
            }
            FpuKind::Cma => {
                !(matches!(da.class, Class::Normal | Class::Subnormal)
                    && matches!(db.class, Class::Normal | Class::Subnormal))
            }
        };
        acc.ops += 1;
        if special {
            acc.special_ops += 1;
        } else {
            // Same operand the gate-level multiplier recodes (y = b.sig).
            let (digits, nonzero) = booth_digit_stats(db.sig, &self.mul);
            acc.digits += digits as u64;
            acc.nonzero_digits += nonzero as u64;
        }
    }
}

/// Booth digit statistics of a multiplier operand, computed directly
/// from the recoding windows — no partial products materialized, no
/// tree. Mirrors `booth::partial_products_into`'s recode exactly, so a
/// word-level tracked run reports the same digit counts the gate-level
/// tier does.
fn booth_digit_stats(y: u64, mul: &MultiplierConfig) -> (u32, u32) {
    let b = mul.booth.bits_per_digit();
    let n = mul.booth.digit_count(mul.sig_bits);
    let y2 = (y as u128) << 1;
    let mut nonzero = 0;
    for i in 0..n {
        let window = ((y2 >> (i * b)) & ((1u128 << (b + 1)) - 1)) as u64;
        let msb = (window >> b) & 1;
        let value = ((window >> 1) + (window & 1)) as i64 - ((1i64 << b) * msb as i64);
        if value != 0 {
            nonzero += 1;
        }
    }
    (n, nonzero)
}

impl Datapath for WordUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(&self.structure)
    }

    fn label(&self) -> String {
        format!("{}/{}", self.name, Fidelity::WordLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        reference_fmac(self.kind, self.format, RoundMode::NearestEven, a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        // Word level carries no toggle counts, but the special/early-out
        // accounting (clock gating) and the Booth digit statistics are
        // both word-level observable — those are what the energy model's
        // word-level activity scale is built from.
        self.record_activity(a, b, c, acc);
        self.fmac_one(a, b, c)
    }
}

/// The lane-batched word-level tier of a generated unit: scalar calls
/// compute through the same word-level spec as [`WordUnit`]; batch calls
/// stream full lane blocks through the branch-light SoA kernels in
/// [`softfloat::lanes`], peeling special-case lanes to the scalar slow
/// path, with the sub-lane-width remainder handled scalar. Bit-identical
/// to both other tiers (debug-asserted per lane inside the kernels,
/// sampled gate-level cross-checks at run time).
#[derive(Debug, Clone)]
pub struct WordSimdUnit {
    inner: WordUnit,
}

impl WordSimdUnit {
    /// The lane-batched word-level view of an elaborated unit.
    pub fn of(unit: &FpuUnit) -> WordSimdUnit {
        WordSimdUnit { inner: WordUnit::of(unit) }
    }

    /// Elaborate a configuration straight into the lane-batched tier.
    pub fn generate(cfg: &FpuConfig) -> WordSimdUnit {
        WordSimdUnit::of(&FpuUnit::generate(cfg))
    }
}

impl Datapath for WordSimdUnit {
    fn format(&self) -> Format {
        self.inner.format
    }

    fn kind(&self) -> FpuKind {
        self.inner.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordSimd
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(&self.inner.structure)
    }

    fn label(&self) -> String {
        format!("{}/{}", self.inner.name, Fidelity::WordSimd.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        self.inner.fmac_one(a, b, c)
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        // Activity is a word-level observable; the lane restructuring
        // changes execution speed, not what the silicon would toggle.
        self.inner.fmac_one_tracked(a, b, c, acc)
    }

    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        use crate::arch::softfloat::lanes::{cma_block_rne, fma_block_rne, LANES};
        let fmt = self.inner.format;
        let mut a = [0u64; LANES];
        let mut b = [0u64; LANES];
        let mut c = [0u64; LANES];
        let mut o = [0u64; LANES];
        let n = triples.len();
        let mut i = 0;
        while i + LANES <= n {
            for j in 0..LANES {
                let t = &triples[i + j];
                a[j] = t.a;
                b[j] = t.b;
                c[j] = t.c;
            }
            match self.inner.kind {
                FpuKind::Fma => fma_block_rne(fmt, &a, &b, &c, &mut o),
                FpuKind::Cma => cma_block_rne(fmt, &a, &b, &c, &mut o),
            }
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
        // Sub-lane remainder: scalar spec.
        for j in i..n {
            let t = &triples[j];
            out[j] = self.inner.fmac_one(t.a, t.b, t.c);
        }
    }

    fn fmac_batch_tracked(
        &self,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: &mut ActivityAccumulator,
    ) {
        // Keep the lane kernels for the results and record activity in a
        // decode-only post-pass (activity is a word-level observable the
        // lane restructuring does not change). This is what keeps traced
        // word-simd runs close to untracked throughput instead of
        // falling back to the scalar tracked op.
        self.fmac_batch(triples, out);
        for t in triples {
            self.inner.record_activity(t.a, t.b, t.c, acc);
        }
    }
}

/// Batched word-level multiply (`round(a·b)` per triple) for the chip
/// sequencer's `Mul` bursts: RNE streams through the SoA lane kernel,
/// explicit-rounding modes through the scalar spec.
pub fn mul_batch(fmt: Format, mode: RoundMode, triples: &[OperandTriple], out: &mut [u64]) {
    assert_eq!(triples.len(), out.len(), "batch length mismatch");
    use crate::arch::softfloat::lanes::{mul_block_rne, LANES};
    let n = triples.len();
    let mut i = 0;
    if mode == RoundMode::NearestEven {
        let (mut a, mut b, mut o) = ([0u64; LANES], [0u64; LANES], [0u64; LANES]);
        while i + LANES <= n {
            for j in 0..LANES {
                a[j] = triples[i + j].a;
                b[j] = triples[i + j].b;
            }
            mul_block_rne(fmt, &a, &b, &mut o);
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
    }
    for j in i..n {
        out[j] = softfloat::mul(fmt, mode, triples[j].a, triples[j].b).bits;
    }
}

/// Batched word-level add (`round(a + c)` per triple) for the chip
/// sequencer's `Add` bursts: RNE through the lane kernel, explicit
/// modes scalar.
pub fn add_batch(fmt: Format, mode: RoundMode, triples: &[OperandTriple], out: &mut [u64]) {
    assert_eq!(triples.len(), out.len(), "batch length mismatch");
    use crate::arch::softfloat::lanes::{add_block_rne, LANES};
    let n = triples.len();
    let mut i = 0;
    if mode == RoundMode::NearestEven {
        let (mut a, mut c, mut o) = ([0u64; LANES], [0u64; LANES], [0u64; LANES]);
        while i + LANES <= n {
            for j in 0..LANES {
                a[j] = triples[i + j].a;
                c[j] = triples[i + j].c;
            }
            add_block_rne(fmt, &a, &c, &mut o);
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
    }
    for j in i..n {
        out[j] = softfloat::add(fmt, mode, triples[j].a, triples[j].c).bits;
    }
}

/// A generated unit bound to a fidelity tier chosen at run time — the
/// handle consumers pass to the executor when the tier is a parameter
/// (DSE sweeps run word-level, verification runs gate-level).
#[derive(Debug, Clone)]
pub enum UnitDatapath {
    Gate(FpuUnit),
    Word(WordUnit),
    Simd(WordSimdUnit),
}

impl UnitDatapath {
    /// Bind an elaborated unit to a tier.
    pub fn new(unit: &FpuUnit, fidelity: Fidelity) -> UnitDatapath {
        match fidelity {
            Fidelity::GateLevel => UnitDatapath::Gate(unit.clone()),
            Fidelity::WordLevel => UnitDatapath::Word(WordUnit::of(unit)),
            Fidelity::WordSimd => UnitDatapath::Simd(WordSimdUnit::of(unit)),
        }
    }

    /// Elaborate a configuration at a tier.
    pub fn generate(cfg: &FpuConfig, fidelity: Fidelity) -> UnitDatapath {
        UnitDatapath::new(&FpuUnit::generate(cfg), fidelity)
    }
}

impl Datapath for UnitDatapath {
    fn format(&self) -> Format {
        match self {
            UnitDatapath::Gate(u) => u.format,
            UnitDatapath::Word(w) => Datapath::format(w),
            UnitDatapath::Simd(s) => Datapath::format(s),
        }
    }

    fn kind(&self) -> FpuKind {
        match self {
            UnitDatapath::Gate(u) => u.config.kind,
            UnitDatapath::Word(w) => Datapath::kind(w),
            UnitDatapath::Simd(s) => Datapath::kind(s),
        }
    }

    fn fidelity(&self) -> Fidelity {
        match self {
            UnitDatapath::Gate(_) => Fidelity::GateLevel,
            UnitDatapath::Word(_) => Fidelity::WordLevel,
            UnitDatapath::Simd(_) => Fidelity::WordSimd,
        }
    }

    fn structure(&self) -> Option<&StructureReport> {
        match self {
            UnitDatapath::Gate(u) => Some(FpuUnit::structure(u)),
            UnitDatapath::Word(w) => Datapath::structure(w),
            UnitDatapath::Simd(s) => Datapath::structure(s),
        }
    }

    fn label(&self) -> String {
        match self {
            UnitDatapath::Gate(u) => Datapath::label(u),
            UnitDatapath::Word(w) => Datapath::label(w),
            UnitDatapath::Simd(s) => Datapath::label(s),
        }
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac(a, b, c).bits,
            UnitDatapath::Word(w) => w.fmac_one(a, b, c),
            UnitDatapath::Simd(s) => s.fmac_one(a, b, c),
        }
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac_one_tracked(a, b, c, acc),
            UnitDatapath::Word(w) => w.fmac_one_tracked(a, b, c, acc),
            UnitDatapath::Simd(s) => s.fmac_one_tracked(a, b, c, acc),
        }
    }

    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        // Delegate so the Simd variant's lane driver is reached (the
        // trait default would stream the scalar op).
        match self {
            UnitDatapath::Gate(u) => u.fmac_batch(triples, out),
            UnitDatapath::Word(w) => w.fmac_batch(triples, out),
            UnitDatapath::Simd(s) => s.fmac_batch(triples, out),
        }
    }

    fn fmac_batch_tracked(
        &self,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: &mut ActivityAccumulator,
    ) {
        // Delegate for the same reason: the Simd variant's tracked batch
        // keeps the lane kernels and records activity in a post-pass.
        match self {
            UnitDatapath::Gate(u) => u.fmac_batch_tracked(triples, out, acc),
            UnitDatapath::Word(w) => w.fmac_batch_tracked(triples, out, acc),
            UnitDatapath::Simd(s) => s.fmac_batch_tracked(triples, out, acc),
        }
    }
}

/// The golden softfloat spec as an engine datapath: always **fused**
/// semantics, whatever unit it is compared against. This is what the
/// coordinator checks the PJRT artifact with.
#[derive(Debug, Clone, Copy)]
pub struct GoldenFma {
    pub format: Format,
}

impl Datapath for GoldenFma {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        FpuKind::Fma
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn label(&self) -> String {
        "golden/fused".to_string()
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        softfloat::fma(self.format, RoundMode::NearestEven, a, b, c).bits
    }
}

/// Report of a sampled gate-level cross-check of a word-level run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CrossCheck {
    /// How many operands were re-executed at gate level.
    pub sampled: usize,
    /// Indices (into the batch) that disagreed, capped at 16.
    pub mismatches: Vec<usize>,
}

impl CrossCheck {
    pub fn clean(&self) -> bool {
        self.mismatches.is_empty()
    }
}

const CROSSCHECK_CAP: usize = 16;

/// Below this batch size the scoped-spawn overhead dominates any
/// parallel win: run on the calling thread. (Shared with the serve
/// layer's stealing scheduler, which applies the same cutoff.)
pub(crate) const SERIAL_CUTOFF: usize = 512;
/// Ops executed serially by the one-shot chunk calibration pass.
pub(crate) const CALIBRATION_OPS: usize = 2_048;
/// Target wall-clock per pulled chunk: long enough to amortize the
/// atomic cursor, short enough that a straggler chunk cannot idle the
/// other workers for long (specials-heavy regions run slower than
/// finite-dense ones, so static `n / workers` splits load-imbalance).
pub(crate) const TARGET_CHUNK_SECS: f64 = 2e-3;
pub(crate) const MIN_CHUNK: usize = 256;
pub(crate) const MAX_CHUNK: usize = 1 << 16;
/// A persisted chunk hint is stale for batches more than this factor
/// smaller than the batch that calibrated it: a hint timed on a 1M-op
/// pass can exceed a whole serve-sized submission, collapsing it onto
/// one worker. Such runs drop the hint and re-time at their own scale
/// (the rule is one-sided — growing batches keep the hint, because the
/// per-op cost estimate it encodes is batch-size independent).
pub(crate) const RECAL_RATIO: usize = 8;

/// A raw pointer that may cross thread boundaries. Workers derive
/// disjoint sub-slices from it (ranges handed out by an atomic cursor),
/// so no two threads ever alias a byte. (`pub(crate)`: the serve
/// layer's stealing scheduler uses the same wrapper.)
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// The one chunk-sizing formula: ops per pulled chunk so one chunk runs
/// ≈ the target wall-clock at the measured per-op cost. Shared by the
/// executor's calibration pass and the serve layer's window-aligned
/// calibration, so the two paths can never drift apart.
pub(crate) fn chunk_from_per_op(per_op_secs: f64) -> usize {
    ((TARGET_CHUNK_SECS / per_op_secs.max(1e-9)) as usize).clamp(MIN_CHUNK, MAX_CHUNK)
}

/// Compile-time fingerprint of the lane-kernel implementation this
/// binary carries: the `WordSimd` tier's per-op cost depends on whether
/// `softfloat::lanes` was built with the scalar SoA stages or the
/// `std::simd` vector stages (`--features simd`), so a chunk hint
/// calibrated by one build must not be reused by the other. The values
/// are arbitrary distinct tags, stable across compilations of the same
/// feature set.
pub const fn lane_kernel_fingerprint() -> u64 {
    if cfg!(feature = "simd") {
        0x513D_0002
    } else {
        0x5CA1_0001
    }
}

/// Calibration key for a fidelity tier: what a persisted chunk hint is
/// validated against before reuse (see [`BatchExecutor::seed_calibration`]).
/// Gate- and word-level tiers key on the tier alone (their kernels are
/// identical in every build); the `WordSimd` tier additionally mixes in
/// [`lane_kernel_fingerprint`], so a hint persisted by a scalar build is
/// stale — and re-timed, not trusted — under `--features simd` and vice
/// versa. Never returns 0 (0 = uncalibrated).
pub const fn calibration_key(tier: Fidelity) -> u64 {
    match tier {
        Fidelity::GateLevel => 1,
        Fidelity::WordLevel => 2,
        Fidelity::WordSimd => 3 | (lane_kernel_fingerprint() << 8),
    }
}

/// A type-erased parallel region: `run` is a monomorphized worker entry
/// point, `ctx` points at a stack-held context struct that outlives the
/// broadcast (the submitter blocks until every worker has finished).
#[derive(Clone, Copy)]
struct Job {
    run: unsafe fn(*const ()),
    ctx: *const (),
}
// SAFETY: the context behind `ctx` is only dereferenced between job
// publication and completion, during which the submitting thread keeps
// it alive and blocked; the pointed-to data is Sync (shared slices,
// atomics, mutexes).
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per published job; workers run each epoch exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not yet finished the current epoch's job.
    remaining: usize,
    /// Workers that panicked inside the current epoch's job.
    panics: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitter parks here until `remaining` drains to zero.
    done: Condvar,
}

fn pool_worker_loop(shared: &PoolShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().expect("engine pool poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(job) = st.job {
                        seen = st.epoch;
                        break job;
                    }
                }
                st = shared.work.wait(st).expect("engine pool poisoned");
            }
        };
        // SAFETY: the submitter keeps the job context alive until every
        // worker has decremented `remaining` below.
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.run)(job.ctx)
        }))
        .is_ok();
        let mut st = shared.state.lock().expect("engine pool poisoned");
        if !ok {
            st.panics += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

/// The persistent worker pool behind a [`BatchExecutor`]: threads are
/// spawned once (on the first parallel run) and **park between runs**,
/// so steady-state parallel execution pays neither the O(workers)
/// per-run thread-spawn latency nor its allocations.
struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes broadcasts so concurrent `&self` runs on one executor
    /// cannot interleave epochs.
    submit: Mutex<()>,
}

impl WorkerPool {
    fn start(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                panics: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fpmax-engine-{i}"))
                    .spawn(move || pool_worker_loop(&shared))
                    .expect("spawn engine worker")
            })
            .collect();
        WorkerPool { shared, handles, submit: Mutex::new(()) }
    }

    /// Publish `job` to every pool thread and block until all have run
    /// it to completion. Each worker runs the job body exactly once; the
    /// bodies coordinate actual work division through an atomic cursor
    /// inside the context, so threads that find no work return
    /// immediately.
    ///
    /// Returns the number of workers whose job body panicked this epoch.
    /// Worker threads themselves survive a panicking body (each epoch is
    /// wrapped in `catch_unwind` inside [`pool_worker_loop`]), so the
    /// pool stays usable afterwards; the *caller* decides whether a
    /// non-zero count is an invariant violation (the chunked/windowed
    /// batch paths, whose partial output would be silently wrong) or a
    /// containable fault (the serve layer's checked regions).
    fn broadcast(&self, job: Job) -> usize {
        let _turn = self.submit.lock().expect("engine pool poisoned");
        let workers = self.handles.len();
        {
            let mut st = self.shared.state.lock().expect("engine pool poisoned");
            st.epoch += 1;
            st.job = Some(job);
            st.remaining = workers;
            st.panics = 0;
        }
        self.shared.work.notify_all();
        let mut st = self.shared.state.lock().expect("engine pool poisoned");
        while st.remaining > 0 {
            st = self.shared.done.wait(st).expect("engine pool poisoned");
        }
        st.job = None;
        let panics = st.panics;
        drop(st);
        panics
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("engine pool poisoned");
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Context of one chunked parallel run (plain or tracked).
struct ChunkCtx<'a, D: ?Sized> {
    dp: &'a D,
    triples: &'a [OperandTriple],
    out: SendPtr<u64>,
    n: usize,
    chunk: usize,
    cursor: &'a AtomicUsize,
    track: bool,
    merged: &'a Mutex<ActivityAccumulator>,
}

/// Worker body of a chunked run: pull `chunk`-sized ranges off the
/// shared cursor until the slice is drained. Each range is claimed by
/// exactly one `fetch_add` winner, so the raw-pointer sub-slices are
/// disjoint.
unsafe fn chunk_worker<D: Datapath + ?Sized>(ctx: *const ()) {
    let c = &*(ctx as *const ChunkCtx<'_, D>);
    let mut local = ActivityAccumulator::default();
    loop {
        let lo = c.cursor.fetch_add(c.chunk, Ordering::Relaxed);
        if lo >= c.n {
            break;
        }
        let hi = (lo + c.chunk).min(c.n);
        // SAFETY: [lo, hi) came from a unique fetch_add claim, so this
        // sub-slice aliases no other worker's; the submitter keeps `out`
        // alive until the broadcast returns.
        let os = std::slice::from_raw_parts_mut(c.out.0.add(lo), hi - lo);
        if c.track {
            c.dp.fmac_batch_tracked(&c.triples[lo..hi], os, &mut local);
        } else {
            c.dp.fmac_batch(&c.triples[lo..hi], os);
        }
    }
    if c.track && local != ActivityAccumulator::default() {
        c.merged.lock().expect("engine worker poisoned").merge(&local);
    }
}

/// Context of one windowed parallel run: the cursor counts *windows*,
/// and each window's accumulator is produced whole by the single worker
/// that claimed it — per-window sums are therefore identical to a serial
/// run, whatever the interleaving.
struct WindowCtx<'a, D: ?Sized> {
    dp: &'a D,
    triples: &'a [OperandTriple],
    out: SendPtr<u64>,
    accs: SendPtr<ActivityAccumulator>,
    n: usize,
    window: usize,
    n_windows: usize,
    chunk_windows: usize,
    cursor: &'a AtomicUsize,
}

/// Context of a custom parallel region (see [`BatchExecutor::run_region`]).
struct RegionCtx<'a, F> {
    f: &'a F,
    ticket: &'a AtomicUsize,
}

unsafe fn region_worker<F: Fn(usize) + Sync>(ctx: *const ()) {
    let c = &*(ctx as *const RegionCtx<'_, F>);
    let id = c.ticket.fetch_add(1, Ordering::Relaxed);
    (c.f)(id);
}

unsafe fn window_worker<D: Datapath + ?Sized>(ctx: *const ()) {
    let c = &*(ctx as *const WindowCtx<'_, D>);
    loop {
        let w0 = c.cursor.fetch_add(c.chunk_windows, Ordering::Relaxed);
        if w0 >= c.n_windows {
            break;
        }
        let w1 = (w0 + c.chunk_windows).min(c.n_windows);
        for w in w0..w1 {
            let lo = w * c.window;
            let hi = ((w + 1) * c.window).min(c.n);
            // SAFETY: window w was claimed by exactly one fetch_add
            // winner, so both the output sub-slice and the accumulator
            // slot are unaliased; the submitter keeps them alive.
            let os = std::slice::from_raw_parts_mut(c.out.0.add(lo), hi - lo);
            let acc = &mut *c.accs.0.add(w);
            c.dp.fmac_batch_tracked(&c.triples[lo..hi], os, acc);
        }
    }
}

/// Thread-parallel batch executor: drives any [`Datapath`] over an
/// operand slice with workers pulling load-aware chunks off a shared
/// atomic cursor. The workers come from a **persistent pool** spawned on
/// the first parallel run and parked between runs.
///
/// The hot path allocates nothing: callers can hand in reusable output
/// buffers via the `*_into` variants (the `Vec`-returning wrappers exist
/// for convenience), chunk descriptors are never materialized, and the
/// sampled gate-level cross-check walks indices directly. Chunk size is
/// autotuned by a one-shot calibration pass — the first ~2k ops of the
/// first batch run serially under a timer, and the derived
/// ops-per-chunk value persists in the executor (see
/// [`BatchExecutor::recalibrate`]).
pub struct BatchExecutor {
    workers: usize,
    /// Calibrated ops per pulled chunk; 0 = not yet calibrated. Interior
    /// mutability so calibration can persist through `&self` (executors
    /// are shared immutably across call sites and worker threads).
    chunk_hint: AtomicUsize,
    /// Batch length of the run that produced `chunk_hint` (0 = none).
    /// Runs more than [`RECAL_RATIO`]× smaller treat the hint as stale
    /// and re-calibrate, so tiny serve submissions never inherit a
    /// chunk size tuned on a million-op pass.
    calibrated_ops: AtomicUsize,
    /// [`calibration_key`] of the run that produced `chunk_hint`
    /// (0 = none): fidelity tier + lane-kernel fingerprint. A run whose
    /// key differs drops the hint and re-times, so a hint calibrated by
    /// a different tier — or persisted from a build with the other lane
    /// kernels (scalar vs `--features simd`) — is never reused.
    cal_key: AtomicU64,
    /// Persistent worker pool, spawned lazily by the first parallel run.
    pool: OnceLock<WorkerPool>,
}

impl std::fmt::Debug for BatchExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchExecutor")
            .field("workers", &self.workers)
            .field("chunk_hint", &self.chunk_hint.load(Ordering::Relaxed))
            .field("calibrated_ops", &self.calibrated_ops.load(Ordering::Relaxed))
            .field("cal_key", &self.cal_key.load(Ordering::Relaxed))
            .field("pool_started", &self.pool.get().is_some())
            .finish()
    }
}

impl Default for BatchExecutor {
    fn default() -> Self {
        BatchExecutor::auto()
    }
}

impl Clone for BatchExecutor {
    fn clone(&self) -> Self {
        // The clone keeps the calibration but gets its own (lazily
        // spawned) worker pool.
        BatchExecutor {
            workers: self.workers,
            chunk_hint: AtomicUsize::new(self.chunk_hint.load(Ordering::Relaxed)),
            calibrated_ops: AtomicUsize::new(self.calibrated_ops.load(Ordering::Relaxed)),
            cal_key: AtomicU64::new(self.cal_key.load(Ordering::Relaxed)),
            pool: OnceLock::new(),
        }
    }
}

impl BatchExecutor {
    /// Fixed worker count (clamped to ≥ 1).
    pub fn new(workers: usize) -> BatchExecutor {
        BatchExecutor {
            workers: workers.max(1),
            chunk_hint: AtomicUsize::new(0),
            calibrated_ops: AtomicUsize::new(0),
            cal_key: AtomicU64::new(0),
            pool: OnceLock::new(),
        }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> BatchExecutor {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        BatchExecutor::new(n)
    }

    /// Single-threaded executor (scalar-equivalent ordering, no spawns).
    pub fn serial() -> BatchExecutor {
        BatchExecutor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The calibrated ops-per-chunk value (0 until the first parallel
    /// run calibrates it).
    pub fn chunk_hint(&self) -> usize {
        self.chunk_hint.load(Ordering::Relaxed)
    }

    /// Batch length of the run that produced the current chunk hint
    /// (0 = uncalibrated).
    pub fn calibrated_ops(&self) -> usize {
        self.calibrated_ops.load(Ordering::Relaxed)
    }

    /// The [`calibration_key`] of the run that produced the current
    /// chunk hint (0 = uncalibrated).
    pub fn calibration_key(&self) -> u64 {
        self.cal_key.load(Ordering::Relaxed)
    }

    /// Drop the persisted chunk calibration — the next run re-times. Use
    /// when switching this executor to a datapath with a very different
    /// per-op cost (gate-level is ~an order of magnitude slower than
    /// word-level; a stale hint only costs load-balance granularity,
    /// never correctness).
    pub fn recalibrate(&self) {
        self.chunk_hint.store(0, Ordering::Relaxed);
        self.calibrated_ops.store(0, Ordering::Relaxed);
        self.cal_key.store(0, Ordering::Relaxed);
    }

    /// Install a previously-observed calibration (all values 0 clears
    /// it). The serve layer keeps one executor — one persistent pool —
    /// across fidelity tiers whose per-op costs differ by ~an order of
    /// magnitude, and swaps each tier's saved calibration back in
    /// instead of re-timing on every tier switch.
    ///
    /// `key` is the [`calibration_key`] the calibration was observed
    /// under. Runs validate it before reusing the hint, so seeding a
    /// calibration persisted by a build with different lane kernels
    /// (scalar vs `--features simd`), or observed on a different tier,
    /// costs one re-timing pass instead of a mis-sized chunk.
    pub fn seed_calibration(&self, chunk: usize, calibrated_ops: usize, key: u64) {
        self.chunk_hint.store(chunk, Ordering::Relaxed);
        self.calibrated_ops.store(calibrated_ops, Ordering::Relaxed);
        self.cal_key.store(key, Ordering::Relaxed);
    }

    /// Apply the staleness rules for an `n`-op run under `key`: a hint
    /// calibrated on a batch more than [`RECAL_RATIO`]× larger, or under
    /// a different [`calibration_key`] (other tier, or other lane-kernel
    /// build), is dropped so this run re-times (or, on paths that never
    /// time, falls back to an even per-worker split).
    pub(crate) fn refresh_calibration(&self, n: usize, key: u64) {
        let cal = self.calibrated_ops.load(Ordering::Relaxed);
        if cal != 0
            && (n.saturating_mul(RECAL_RATIO) < cal
                || self.cal_key.load(Ordering::Relaxed) != key)
        {
            self.recalibrate();
        }
    }

    /// Chunk size for an `n`-op parallel run: the calibrated hint,
    /// bounded so there is at least one chunk per worker.
    fn chunk_for(&self, n: usize) -> usize {
        let hint = self.chunk_hint.load(Ordering::Relaxed);
        let fallback = n.div_ceil(self.workers);
        if hint == 0 {
            fallback
        } else {
            hint.min(fallback.max(MIN_CHUNK)).clamp(1, n.max(1))
        }
    }

    /// One-shot calibration: time a short serial prefix of the batch
    /// (its results land in `out[..prefix]`, so no work is wasted) and
    /// persist the chunk size that makes one chunk ≈ the target
    /// wall-clock. Returns the prefix length already executed.
    fn calibrate<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: Option<&mut ActivityAccumulator>,
    ) -> usize {
        if self.chunk_hint.load(Ordering::Relaxed) != 0 {
            return 0;
        }
        let prefix = CALIBRATION_OPS.min(triples.len());
        let t0 = std::time::Instant::now();
        match acc {
            Some(acc) => dp.fmac_batch_tracked(&triples[..prefix], &mut out[..prefix], acc),
            None => dp.fmac_batch(&triples[..prefix], &mut out[..prefix]),
        }
        let per_op = t0.elapsed().as_secs_f64() / prefix as f64;
        self.chunk_hint.store(chunk_from_per_op(per_op), Ordering::Relaxed);
        self.calibrated_ops.store(triples.len(), Ordering::Relaxed);
        self.cal_key.store(calibration_key(dp.fidelity()), Ordering::Relaxed);
        prefix
    }

    /// The persistent pool, spawning it on first use.
    fn pool(&self) -> &WorkerPool {
        self.pool.get_or_init(|| WorkerPool::start(self.workers))
    }

    /// Run `f` once on every pool worker concurrently (spawning the
    /// persistent pool on first use); each invocation receives a dense
    /// per-region worker index in `0..workers()`. With one worker the
    /// closure runs on the calling thread; either way the call blocks
    /// until every invocation has returned, so `f` may freely borrow
    /// from the caller's stack.
    ///
    /// This is the extension point custom schedulers use to drive the
    /// same parked threads the chunked runs use — the serve layer's
    /// per-worker stealing queues dispatch through it.
    pub fn run_region<F: Fn(usize) + Sync>(&self, f: F) {
        if self.workers <= 1 {
            f(0);
            return;
        }
        let ticket = AtomicUsize::new(0);
        let ctx = RegionCtx { f: &f, ticket: &ticket };
        let panics = self.pool().broadcast(Job {
            run: region_worker::<F>,
            ctx: &ctx as *const RegionCtx<'_, F> as *const (),
        });
        assert_eq!(panics, 0, "invariant: run_region closure panicked on {panics} worker(s)");
    }

    /// [`BatchExecutor::run_region`] with panic containment: a region
    /// closure that panics on any worker (or, with one worker, on the
    /// calling thread) yields `Err(WorkerPanicked)` instead of unwinding
    /// the caller or aborting the process. The persistent pool survives
    /// — parked threads catch each epoch's unwind — so the executor
    /// remains fully usable for subsequent runs. This is the serve
    /// dispatcher's entry point: a lane-kernel panic must error one
    /// batch's tickets, not take down the shard's process.
    pub fn run_region_checked<F: Fn(usize) + Sync>(&self, f: F) -> Result<(), WorkerPanicked> {
        if self.workers <= 1 {
            return std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)))
                .map_err(|_| WorkerPanicked { workers: 1 });
        }
        let ticket = AtomicUsize::new(0);
        let ctx = RegionCtx { f: &f, ticket: &ticket };
        let panics = self.pool().broadcast(Job {
            run: region_worker::<F>,
            ctx: &ctx as *const RegionCtx<'_, F> as *const (),
        });
        if panics == 0 {
            Ok(())
        } else {
            Err(WorkerPanicked { workers: panics })
        }
    }

    /// Parallel region: workers pull `chunk`-sized ranges off an atomic
    /// cursor until the slice is drained (see [`chunk_worker`]). Runs on
    /// the persistent pool; the calling thread blocks until the batch is
    /// complete.
    fn run_chunked<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: Option<&mut ActivityAccumulator>,
    ) {
        let n = triples.len();
        if n == 0 {
            return;
        }
        let chunk = self.chunk_for(n).max(1);
        let workers = self.workers.min(n.div_ceil(chunk));
        if workers <= 1 {
            match acc {
                Some(acc) => dp.fmac_batch_tracked(triples, out, acc),
                None => dp.fmac_batch(triples, out),
            }
            return;
        }
        let track = acc.is_some();
        let cursor = AtomicUsize::new(0);
        let merged = Mutex::new(ActivityAccumulator::default());
        let ctx = ChunkCtx {
            dp,
            triples,
            out: SendPtr(out.as_mut_ptr()),
            n,
            chunk,
            cursor: &cursor,
            track,
            merged: &merged,
        };
        let panics = self.pool().broadcast(Job {
            run: chunk_worker::<D>,
            ctx: &ctx as *const ChunkCtx<'_, D> as *const (),
        });
        // A panic mid-chunk leaves `out` partially written with no record
        // of which ranges completed — that is unrecoverable corruption,
        // not a containable fault.
        assert_eq!(panics, 0, "invariant: datapath kernel panicked mid-chunked-batch on {panics} worker(s)");
        if let Some(acc) = acc {
            acc.merge(&merged.into_inner().expect("engine worker poisoned"));
        }
    }

    /// Execute a batch, returning result bits in operand order.
    pub fn run<D: Datapath + ?Sized>(&self, dp: &D, triples: &[OperandTriple]) -> Vec<u64> {
        let mut out = vec![0u64; triples.len()];
        self.run_into(dp, triples, &mut out).expect("buffer sized above");
        out
    }

    /// Execute a batch into a caller-provided buffer — the
    /// allocation-free hot path (serial runs allocate nothing; parallel
    /// runs allocate nothing after the pool's first-run warmup). A
    /// wrongly-sized buffer returns [`BatchLenError`] instead of
    /// panicking.
    pub fn run_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) -> Result<(), BatchLenError> {
        check_len(triples, out)?;
        let n = triples.len();
        if n == 0 {
            return Ok(());
        }
        if self.workers <= 1 || n <= SERIAL_CUTOFF {
            dp.fmac_batch(triples, out);
            return Ok(());
        }
        self.refresh_calibration(n, calibration_key(dp.fidelity()));
        let done = self.calibrate(dp, triples, out, None);
        self.run_chunked(dp, &triples[done..], &mut out[done..], None);
        Ok(())
    }

    /// Execute a batch while accumulating activity (merged across
    /// workers; the merge is order-independent because the accumulator is
    /// a plain sum).
    pub fn run_tracked<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
    ) -> (Vec<u64>, ActivityAccumulator) {
        let mut out = vec![0u64; triples.len()];
        let acc = self.run_tracked_into(dp, triples, &mut out).expect("buffer sized above");
        (out, acc)
    }

    /// Tracked execution into a caller-provided buffer; returns the
    /// merged activity.
    pub fn run_tracked_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) -> Result<ActivityAccumulator, BatchLenError> {
        check_len(triples, out)?;
        let mut total = ActivityAccumulator::default();
        let n = triples.len();
        if n == 0 {
            return Ok(total);
        }
        if self.workers <= 1 || n <= SERIAL_CUTOFF {
            dp.fmac_batch_tracked(triples, out, &mut total);
            return Ok(total);
        }
        self.refresh_calibration(n, calibration_key(dp.fidelity()));
        let done = self.calibrate(dp, triples, out, Some(&mut total));
        self.run_chunked(dp, &triples[done..], &mut out[done..], Some(&mut total));
        Ok(total)
    }

    /// Windowed tracked execution: run the batch and return its
    /// time-resolved [`ActivityTrace`] with `window_ops` ops per window.
    pub fn run_windowed<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        window_ops: usize,
    ) -> (Vec<u64>, ActivityTrace) {
        let mut out = vec![0u64; triples.len()];
        let trace = self
            .run_windowed_into(dp, triples, &mut out, window_ops)
            .expect("buffer sized above");
        (out, trace)
    }

    /// Windowed tracked execution into a caller-provided buffer: the
    /// batch's slot timeline is cut into `window_ops`-op windows, each
    /// with its own activity sum. Windows are keyed by absolute operand
    /// index and each window is computed whole by exactly one worker, so
    /// the trace is **deterministic** — identical to a serial run —
    /// whatever the worker count or chunk interleaving, and
    /// [`ActivityTrace::aggregate`] equals what
    /// [`BatchExecutor::run_tracked_into`] would have returned, bit for
    /// bit.
    pub fn run_windowed_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
        window_ops: usize,
    ) -> Result<ActivityTrace, BatchLenError> {
        check_len(triples, out)?;
        let n = triples.len();
        let window = window_ops.max(1);
        let n_windows = n.div_ceil(window);
        let mut accs = vec![ActivityAccumulator::default(); n_windows];
        let parallel = self.workers > 1 && n > SERIAL_CUTOFF && n_windows > 1;
        if !parallel {
            for (w, acc) in accs.iter_mut().enumerate() {
                let lo = w * window;
                let hi = ((w + 1) * window).min(n);
                dp.fmac_batch_tracked(&triples[lo..hi], &mut out[lo..hi], acc);
            }
        } else {
            // No timed calibration pass here (it would straddle window
            // boundaries); reuse the persisted hint when present — after
            // the staleness rule — else fall back to an even static
            // split.
            self.refresh_calibration(n, calibration_key(dp.fidelity()));
            let chunk_windows = (self.chunk_for(n) / window).max(1);
            let cursor = AtomicUsize::new(0);
            let ctx = WindowCtx {
                dp,
                triples,
                out: SendPtr(out.as_mut_ptr()),
                accs: SendPtr(accs.as_mut_ptr()),
                n,
                window,
                n_windows,
                chunk_windows,
                cursor: &cursor,
            };
            let panics = self.pool().broadcast(Job {
                run: window_worker::<D>,
                ctx: &ctx as *const WindowCtx<'_, D> as *const (),
            });
            // Same invariant as the chunked path: a partial windowed run
            // would publish wrong per-window activity sums.
            assert_eq!(
                panics, 0,
                "invariant: datapath kernel panicked mid-windowed-batch on {panics} worker(s)"
            );
        }
        Ok(ActivityTrace::from_windows(window as u64, n as u64, accs))
    }

    /// Word-level execution of a unit with a sampled gate-level
    /// cross-check (see [`BatchExecutor::run_checked_into`]).
    pub fn run_checked(
        &self,
        unit: &FpuUnit,
        triples: &[OperandTriple],
        sample_every: usize,
    ) -> (Vec<u64>, CrossCheck) {
        self.run_checked_tier(unit, Fidelity::WordLevel, triples, sample_every)
    }

    /// Tier-selectable checked execution returning a fresh buffer.
    pub fn run_checked_tier(
        &self,
        unit: &FpuUnit,
        tier: Fidelity,
        triples: &[OperandTriple],
        sample_every: usize,
    ) -> (Vec<u64>, CrossCheck) {
        let mut out = vec![0u64; triples.len()];
        let check = self
            .run_checked_into(unit, tier, triples, sample_every, &mut out)
            .expect("buffer sized above");
        (out, check)
    }

    /// Execute a unit's word tier (`WordLevel` or `WordSimd`) into a
    /// caller-provided buffer with a sampled gate-level cross-check:
    /// every `sample_every`-th operand is re-executed through the
    /// structural datapath and compared bit-for-bit. This is the
    /// release-build guard on the word tiers' bit-identity claim.
    ///
    /// The sampling pass materializes nothing — sample indices are
    /// walked directly, partitioned round-robin across workers (the
    /// gate-level re-execution is the expensive part, so it parallelizes
    /// through the same scoped threads). `GateLevel` runs plain (the
    /// gate tier is the reference; `sampled` reports 0).
    pub fn run_checked_into(
        &self,
        unit: &FpuUnit,
        tier: Fidelity,
        triples: &[OperandTriple],
        sample_every: usize,
        out: &mut [u64],
    ) -> Result<CrossCheck, BatchLenError> {
        match tier {
            Fidelity::GateLevel => {
                self.run_into(unit, triples, out)?;
                return Ok(CrossCheck::default());
            }
            Fidelity::WordLevel => {
                let word = WordUnit::of(unit);
                self.run_into(&word, triples, out)?;
            }
            Fidelity::WordSimd => {
                let simd = WordSimdUnit::of(unit);
                self.run_into(&simd, triples, out)?;
            }
        }
        let n = triples.len();
        if n == 0 {
            return Ok(CrossCheck::default());
        }
        let step = sample_every.max(1);
        let sampled = n.div_ceil(step);
        let workers = self.workers.min(sampled);
        let mut mismatches = if workers <= 1 || sampled <= 64 {
            let mut mm = Vec::new();
            let mut i = 0;
            while i < n {
                let t = &triples[i];
                if unit.fmac_one(t.a, t.b, t.c) != out[i] && mm.len() < CROSSCHECK_CAP {
                    mm.push(i);
                }
                i += step;
            }
            mm
        } else {
            let shared = Mutex::new(Vec::new());
            let out_ro: &[u64] = out;
            std::thread::scope(|s| {
                for w in 0..workers {
                    let shared = &shared;
                    s.spawn(move || {
                        let mut local = Vec::new();
                        let mut k = w;
                        while k < sampled {
                            let i = k * step;
                            let t = &triples[i];
                            if unit.fmac_one(t.a, t.b, t.c) != out_ro[i]
                                && local.len() < CROSSCHECK_CAP
                            {
                                local.push(i);
                            }
                            k += workers;
                        }
                        if !local.is_empty() {
                            shared
                                .lock()
                                .expect("cross-check worker panicked")
                                .extend_from_slice(&local);
                        }
                    });
                }
            });
            shared.into_inner().expect("cross-check worker panicked")
        };
        mismatches.sort_unstable();
        mismatches.truncate(CROSSCHECK_CAP);
        Ok(CrossCheck { sampled, mismatches })
    }
}

/// Registry portioning one fleet-wide worker budget across shard
/// executors.
///
/// The serve router runs one [`BatchExecutor`] — one persistent pool —
/// per (unit preset × precision × fidelity tier) shard. Sizing each of
/// those pools independently at `available_parallelism` would
/// oversubscribe the host by the shard count; the registry hands out
/// executors whose worker counts sum to at most the budget (each grant
/// clamped to what remains, but never below one worker, so a late shard
/// still makes progress).
///
/// Every granted executor is fully independent: its own pool, its own
/// chunk-size calibration. That is the per-shard calibration-isolation
/// guarantee — a gate-level shard's ~10×-slower per-op cost can never
/// poison a word-simd sibling's chunk hint, because they do not share a
/// `chunk_hint` cell to begin with.
pub struct ExecutorRegistry {
    budget: usize,
    claimed: AtomicUsize,
}

impl ExecutorRegistry {
    /// A registry over a fixed worker budget (clamped to ≥ 1).
    pub fn new(budget: usize) -> ExecutorRegistry {
        ExecutorRegistry { budget: budget.max(1), claimed: AtomicUsize::new(0) }
    }

    /// The total worker budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Workers granted so far (may exceed the budget only by the
    /// one-worker floor of grants made after exhaustion).
    pub fn claimed(&self) -> usize {
        self.claimed.load(Ordering::Relaxed)
    }

    /// Claim a shard executor of up to `requested` workers, clamped to
    /// the remaining budget (always at least one). The executor is
    /// independent of every other grant — no shared pool, no shared
    /// calibration.
    pub fn shard(&self, requested: usize) -> BatchExecutor {
        let want = requested.max(1);
        let mut cur = self.claimed.load(Ordering::Relaxed);
        loop {
            let grant = want.min(self.budget.saturating_sub(cur)).max(1);
            match self.claimed.compare_exchange_weak(
                cur,
                cur + grant,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return BatchExecutor::new(grant),
                Err(seen) => cur = seen,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    fn sample(cfg: &FpuConfig, mix: OperandMix, n: usize, seed: u64) -> Vec<OperandTriple> {
        OperandStream::new(cfg.precision, mix, seed).batch(n)
    }

    #[test]
    fn tiers_bit_identical_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let word = WordUnit::of(&unit);
            for t in sample(&cfg, OperandMix::Anything, 3_000, 0xE16).iter() {
                assert_eq!(
                    unit.fmac_one(t.a, t.b, t.c),
                    word.fmac_one(t.a, t.b, t.c),
                    "{}: a={:#x} b={:#x} c={:#x}",
                    cfg.name(),
                    t.a,
                    t.b,
                    t.c
                );
            }
        }
    }

    #[test]
    fn executor_matches_scalar_loop_any_worker_count() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 2_531, 7); // not a worker multiple
        let scalar: Vec<u64> =
            triples.iter().map(|t| unit.fmac_one(t.a, t.b, t.c)).collect();
        for workers in [1, 2, 3, 5, 16, 64] {
            let got = BatchExecutor::new(workers).run(&unit, &triples);
            assert_eq!(got, scalar, "workers={workers}");
        }
    }

    #[test]
    fn tracked_run_merges_activity_like_serial() {
        let cfg = FpuConfig::dp_cma();
        let unit = FpuUnit::generate(&cfg);
        let mut triples = sample(&cfg, OperandMix::Anything, 2_000, 11);
        // One guaranteed special so the clock-gating counter is exercised
        // regardless of what the random stream drew.
        triples.push(OperandTriple { a: f64::NAN.to_bits(), b: 0, c: 0 });
        let (bits1, acc1) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let (bits8, acc8) = BatchExecutor::new(8).run_tracked(&unit, &triples);
        assert_eq!(bits1, bits8);
        assert_eq!(acc1, acc8, "activity sums must be worker-count invariant");
        assert_eq!(acc1.ops, 2_001);
        assert!(acc1.tree_toggles > 0);
        assert!(acc1.special_ops > 0, "the NaN op must take the special path");
    }

    #[test]
    fn word_level_tracks_special_fraction() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Anything, 4_000, 23);
        let (_, gate) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let word = WordUnit::of(&unit);
        let (_, wacc) = BatchExecutor::serial().run_tracked(&word, &triples);
        // Word level sees exactly the same clock-gating decisions and the
        // same Booth recoding — digit statistics must agree exactly.
        assert_eq!(gate.special_ops, wacc.special_ops);
        assert_eq!(gate.ops, wacc.ops);
        assert_eq!(gate.digits, wacc.digits);
        assert_eq!(gate.nonzero_digits, wacc.nonzero_digits);
        // ... but word level carries no gate toggles.
        assert_eq!(wacc.tree_toggles, 0);
        assert_eq!(wacc.tree_fa_ops, 0);
    }

    #[test]
    fn run_checked_clean_on_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Anything, 5_000, 0xC0FFEE);
            let (out, check) = BatchExecutor::new(4).run_checked(&unit, &triples, 37);
            assert!(check.clean(), "{}: {:?}", cfg.name(), check.mismatches);
            assert_eq!(check.sampled, triples.len().div_ceil(37));
            assert_eq!(out.len(), triples.len());
        }
    }

    #[test]
    fn golden_fma_is_fused_spec() {
        let g = GoldenFma { format: Format::SP };
        let a = 1.0f32 + 2f32.powi(-12);
        let c = -(1.0f32 + 2f32.powi(-11));
        let r = g.fmac_one(a.to_bits() as u64, a.to_bits() as u64, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r as u32), 2f32.powi(-24)); // cascade would give 0
    }

    #[test]
    fn activity_scale_tracks_operand_density() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let s = *unit.structure();
        let dense = OperandTriple {
            a: 0x3fff_ffff,
            b: 0x3faa_aaaa,
            c: 0x3f80_0000,
        };
        let quiet = OperandTriple { a: 0x3f80_0000, b: 0x0040_0000, c: 0 };
        let mut acc_dense = ActivityAccumulator::default();
        let mut acc_quiet = ActivityAccumulator::default();
        for _ in 0..64 {
            unit.fmac_one_tracked(dense.a, dense.b, dense.c, &mut acc_dense);
            unit.fmac_one_tracked(quiet.a, quiet.b, quiet.c, &mut acc_quiet);
        }
        assert!(acc_dense.activity_scale(&s) > acc_quiet.activity_scale(&s));
        // Empty accumulator is neutral.
        assert_eq!(ActivityAccumulator::default().activity_scale(&s), 1.0);
    }

    #[test]
    fn unit_datapath_binds_fidelity() {
        let cfg = FpuConfig::dp_fma();
        let unit = FpuUnit::generate(&cfg);
        let gate = UnitDatapath::new(&unit, Fidelity::GateLevel);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        assert_eq!(gate.fidelity(), Fidelity::GateLevel);
        assert_eq!(word.fidelity(), Fidelity::WordLevel);
        assert!(gate.label().contains("gate") && word.label().contains("word"));
        assert_eq!(
            Datapath::structure(&gate).unwrap(),
            Datapath::structure(&word).unwrap()
        );
        let t = OperandTriple {
            a: 1.5f64.to_bits(),
            b: 2.0f64.to_bits(),
            c: 0.25f64.to_bits(),
        };
        assert_eq!(gate.fmac_one(t.a, t.b, t.c), word.fmac_one(t.a, t.b, t.c));
    }

    #[test]
    fn word_simd_batch_bit_identical_all_presets() {
        // Lane kernels + remainder path vs the gate-level scalar op, on
        // operand mixes that hit every special class. 1_003 is not a
        // lane-width multiple, so the scalar tail runs too.
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let simd = WordSimdUnit::of(&unit);
            for (mix, seed) in [(OperandMix::Anything, 0x51D0u64), (OperandMix::SpecialHeavy, 7)] {
                let triples = OperandStream::new(cfg.precision, mix, seed).batch(1_003);
                let mut out = vec![0u64; triples.len()];
                simd.fmac_batch(&triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        unit.fmac_one(t.a, t.b, t.c),
                        "{} {mix:?} slot {i}: a={:#x} b={:#x} c={:#x}",
                        cfg.name(),
                        t.a,
                        t.b,
                        t.c
                    );
                }
            }
        }
    }

    #[test]
    fn run_checked_simd_tier_clean_on_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Anything, 5_000, 0xD00D);
            let exec = BatchExecutor::new(4);
            let (out, check) = exec.run_checked_tier(&unit, Fidelity::WordSimd, &triples, 41);
            assert!(check.clean(), "{}: {:?}", cfg.name(), check.mismatches);
            assert_eq!(check.sampled, triples.len().div_ceil(41));
            let want = BatchExecutor::serial().run(&unit, &triples);
            assert_eq!(out, want, "{}", cfg.name());
        }
    }

    #[test]
    fn run_checked_stride_one_and_gate_tier() {
        // Stride 1 checks every operand (sampled == n); the gate tier
        // reports no sampling because it *is* the reference.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 300, 5);
        let exec = BatchExecutor::serial();
        let mut out = vec![0u64; triples.len()];
        let check =
            exec.run_checked_into(&unit, Fidelity::WordLevel, &triples, 1, &mut out).unwrap();
        assert!(check.clean());
        assert_eq!(check.sampled, 300);
        // GateLevel tier: no sampling (the gate tier is the reference).
        let check =
            exec.run_checked_into(&unit, Fidelity::GateLevel, &triples, 7, &mut out).unwrap();
        assert_eq!(check.sampled, 0);
        assert!(check.clean());
    }

    #[test]
    fn executor_buffer_reuse_and_calibration_persist() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = WordUnit::of(&unit);
        let triples = sample(&cfg, OperandMix::Finite, 9_001, 13);
        let exec = BatchExecutor::new(8);
        assert_eq!(exec.chunk_hint(), 0);
        let mut out1 = vec![u64::MAX; triples.len()];
        exec.run_into(&word, &triples, &mut out1).unwrap();
        let hint = exec.chunk_hint();
        assert!(hint >= 1, "first parallel run must calibrate");
        // Re-running into the same buffer gives identical bits and keeps
        // the calibration.
        let mut out2 = vec![0u64; triples.len()];
        exec.run_into(&word, &triples, &mut out2).unwrap();
        assert_eq!(out1, out2);
        assert_eq!(exec.chunk_hint(), hint);
        // A cloned executor carries the calibration; recalibrate drops it.
        let cloned = exec.clone();
        assert_eq!(cloned.chunk_hint(), hint);
        exec.recalibrate();
        assert_eq!(exec.chunk_hint(), 0);
        // Tracked runs agree with untracked whatever the chunking.
        let acc = exec.run_tracked_into(&word, &triples, &mut out2).unwrap();
        assert_eq!(out1, out2);
        assert_eq!(acc.ops, triples.len() as u64);
    }

    #[test]
    fn mismatched_buffers_return_typed_error() {
        // Regression for the `run_into`-family panics: a wrongly-sized
        // caller buffer must surface as a BatchLenError, not a panic, and
        // must leave the executor usable.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = WordUnit::of(&unit);
        let triples = sample(&cfg, OperandMix::Finite, 100, 1);
        let exec = BatchExecutor::new(4);
        let mut short = vec![0u64; 99];
        assert_eq!(
            exec.run_into(&word, &triples, &mut short),
            Err(BatchLenError { ops: 100, out: 99 })
        );
        assert_eq!(
            exec.run_tracked_into(&word, &triples, &mut short).unwrap_err(),
            BatchLenError { ops: 100, out: 99 }
        );
        assert_eq!(
            exec.run_windowed_into(&word, &triples, &mut short, 16).unwrap_err(),
            BatchLenError { ops: 100, out: 99 }
        );
        let mut long = vec![0u64; 101];
        let err = exec
            .run_checked_into(&unit, Fidelity::WordSimd, &triples, 7, &mut long)
            .unwrap_err();
        assert_eq!((err.ops, err.out), (100, 101));
        // The error formats usefully and converts into anyhow.
        assert!(err.to_string().contains("100"));
        let _: anyhow::Error = err.into();
        // A correctly-sized retry succeeds.
        let mut ok = vec![0u64; 100];
        exec.run_into(&word, &triples, &mut ok).unwrap();
        assert_eq!(ok[0], word.fmac_one(triples[0].a, triples[0].b, triples[0].c));
    }

    #[test]
    fn windowed_trace_sums_to_aggregate_every_tier() {
        // The trace invariant: for every fidelity tier, worker count and
        // window width, the sum of the windows equals the aggregate
        // accumulator of an unwindowed tracked run, bit for bit — and the
        // per-window accumulators match a serial windowed run exactly.
        let cfg = FpuConfig::sp_cma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Anything, 3_271, 0x77AC3);
        for fidelity in [Fidelity::GateLevel, Fidelity::WordLevel, Fidelity::WordSimd] {
            let dp = UnitDatapath::new(&unit, fidelity);
            let (_, want_acc) = BatchExecutor::serial().run_tracked(&dp, &triples);
            let (serial_bits, serial_trace) =
                BatchExecutor::serial().run_windowed(&dp, &triples, 256);
            for workers in [1, 3, 8] {
                for window in [1usize, 7, 256, 4_000] {
                    let exec = BatchExecutor::new(workers);
                    let (bits, trace) = exec.run_windowed(&dp, &triples, window);
                    assert_eq!(bits, serial_bits, "{fidelity:?} w={workers} win={window}");
                    assert_eq!(
                        trace.aggregate(),
                        want_acc,
                        "{fidelity:?} w={workers} win={window}: window sums != aggregate"
                    );
                    assert_eq!(trace.len(), triples.len().div_ceil(window));
                    assert_eq!(trace.total_slots(), triples.len() as u64);
                    assert_eq!(trace.total_ops(), triples.len() as u64);
                    // Live batches are fully occupied.
                    for w in trace.windows() {
                        assert_eq!(w.acc.ops, w.slots);
                        assert!((w.occupancy() - 1.0).abs() < 1e-12);
                    }
                    if window == 256 {
                        assert_eq!(
                            trace, serial_trace,
                            "{fidelity:?} w={workers}: parallel trace must be deterministic"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn word_simd_tracked_batch_matches_word_tier() {
        // The lane-kernel tracked path (results via SoA blocks, activity
        // via the decode-only post-pass) must report bit-identical
        // results *and* bit-identical activity to the scalar word tier.
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let word = WordUnit::of(&unit);
            let simd = WordSimdUnit::of(&unit);
            for mix in [OperandMix::Anything, OperandMix::SpecialHeavy] {
                let triples = OperandStream::new(cfg.precision, mix, 0xB00).batch(1_003);
                let mut out_w = vec![0u64; triples.len()];
                let mut out_s = vec![0u64; triples.len()];
                let mut acc_w = ActivityAccumulator::default();
                let mut acc_s = ActivityAccumulator::default();
                word.fmac_batch_tracked(&triples, &mut out_w, &mut acc_w);
                simd.fmac_batch_tracked(&triples, &mut out_s, &mut acc_s);
                assert_eq!(out_w, out_s, "{} {mix:?}", cfg.name());
                assert_eq!(acc_w, acc_s, "{} {mix:?}", cfg.name());
            }
        }
    }

    #[test]
    fn pool_persists_across_runs_and_datapaths() {
        // One executor, many runs over different datapaths: the pool
        // spawns once and every run stays bit-identical to serial.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        let simd = UnitDatapath::new(&unit, Fidelity::WordSimd);
        let triples = sample(&cfg, OperandMix::Anything, 6_007, 0xF00);
        let want = BatchExecutor::serial().run(&word, &triples);
        let exec = BatchExecutor::new(4);
        let mut out = vec![0u64; triples.len()];
        for _ in 0..3 {
            exec.run_into(&word, &triples, &mut out).unwrap();
            assert_eq!(out, want);
            exec.run_into(&simd, &triples, &mut out).unwrap();
            assert_eq!(out, want);
            let acc = exec.run_tracked_into(&word, &triples, &mut out).unwrap();
            assert_eq!(acc.ops, triples.len() as u64);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn trace_streaming_pushes_split_at_window_boundaries() {
        let mut t = ActivityTrace::new(10);
        t.push_untracked_ops(7); // window 0: 7 ops
        t.push_idle(5); // window 0 fills to 10, window 1 gets 2 idle
        t.push_untracked_ops(14); // windows 1..3
        assert_eq!(t.len(), 3);
        assert_eq!(t.windows()[0].slots, 10);
        assert_eq!(t.windows()[0].acc.ops, 7);
        assert_eq!(t.windows()[1].slots, 10);
        assert_eq!(t.windows()[1].acc.ops, 8); // 2 idle + 8 ops
        assert_eq!(t.windows()[2].slots, 6);
        assert_eq!(t.windows()[2].acc.ops, 6);
        assert_eq!(t.total_slots(), 26);
        assert_eq!(t.total_ops(), 21);
        assert!((t.occupancy() - 21.0 / 26.0).abs() < 1e-12);
        assert_eq!(t.aggregate().ops, 21);
    }

    #[test]
    fn from_profile_preserves_timeline_and_occupancy() {
        use crate::workloads::utilization::UtilizationProfile;
        let profile = UtilizationProfile::duty(0.1, 100, 10_000);
        let t = ActivityTrace::from_profile(&profile, 100);
        assert_eq!(t.total_slots(), profile.total_cycles());
        assert_eq!(t.total_ops(), profile.active_cycles());
        assert!((t.occupancy() - profile.utilization()).abs() < 1e-12);
        // Aligned windows never mix active and idle for this profile.
        for w in t.windows() {
            assert!(w.acc.ops == 0 || w.acc.ops == w.slots);
        }
        // Synthetic occupancy records are activity-neutral for the
        // energy model.
        let unit = FpuUnit::generate(&FpuConfig::sp_cma());
        for w in t.windows() {
            if w.acc.ops > 0 {
                assert_eq!(w.acc.activity_scale(unit.structure()), 1.0);
            }
        }
    }

    #[test]
    fn record_profile_weaves_measured_activity_with_idle_gaps() {
        use crate::workloads::utilization::UtilizationProfile;
        let cfg = FpuConfig::sp_cma();
        let unit = FpuUnit::generate(&cfg);
        let word = WordUnit::of(&unit);
        let profile = UtilizationProfile::duty(0.25, 500, 20_000);
        let mut stream = OperandStream::new(cfg.precision, OperandMix::Finite, 7);
        let t = ActivityTrace::record_profile(&word, &profile, 250, &mut stream);
        assert_eq!(t.total_slots(), profile.total_cycles());
        assert_eq!(t.total_ops(), profile.active_cycles());
        let agg = t.aggregate();
        assert_eq!(agg.ops, profile.active_cycles());
        // Measured traces carry real Booth statistics, unlike the shim.
        assert!(agg.digits > 0);
    }

    #[test]
    fn mul_add_batches_match_scalar_all_modes() {
        use crate::arch::softfloat;
        for cfg in [FpuConfig::sp_fma(), FpuConfig::dp_fma()] {
            let fmt = cfg.precision.format();
            // 107 ops: exercises lane blocks + remainder.
            let triples = sample(&cfg, OperandMix::Anything, 107, 0xAB);
            let mut out = vec![0u64; triples.len()];
            for mode in RoundMode::ALL {
                mul_batch(fmt, mode, &triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(out[i], softfloat::mul(fmt, mode, t.a, t.b).bits, "mul {mode:?} {i}");
                }
                add_batch(fmt, mode, &triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(out[i], softfloat::add(fmt, mode, t.a, t.c).bits, "add {mode:?} {i}");
                }
            }
        }
    }

    #[test]
    fn window_ring_delivers_in_order_and_coalesces_on_overflow() {
        let (mut p, mut c) = window_ring(4);
        let win = |slots: u64, ops: u64| ActivityWindow {
            slots,
            acc: ActivityAccumulator { ops, digits: 3 * ops, ..ActivityAccumulator::default() },
        };
        // In-order delivery with room to spare.
        p.publish(win(10, 10));
        p.publish(win(10, 7));
        assert_eq!(c.pop().unwrap(), RingWindow { window: win(10, 10), coalesced: 1 });
        assert_eq!(c.pop().unwrap(), RingWindow { window: win(10, 7), coalesced: 1 });
        assert_eq!(c.pop(), None);
        // Overflow: 10 publishes into 4 slots with no pops in between.
        // Nothing is dropped — the surplus merges into one pending
        // window delivered at close, slots and activity intact.
        for i in 0..10u64 {
            p.publish(win(10, i));
        }
        assert!(p.coalesced() > 0);
        let mut received = Vec::new();
        while let Some(e) = c.pop() {
            received.push(e); // drain the ring so close() can flush
        }
        let total_coalesced = p.close();
        while let Some(e) = c.recv() {
            received.push(e);
        }
        let slots: u64 = received.iter().map(|e| e.window.slots).sum();
        let mut agg = ActivityAccumulator::default();
        for e in &received {
            agg.merge(&e.window.acc);
        }
        let carried: u64 = received.iter().map(|e| e.coalesced as u64).sum();
        assert_eq!(slots, 100, "every published slot must arrive");
        assert_eq!(agg.ops, (0..10).sum::<u64>());
        assert_eq!(agg.digits, 3 * agg.ops, "activity sums survive coalescing");
        assert_eq!(carried, 10, "each original window is carried exactly once");
        assert_eq!(received.len() as u64 + total_coalesced, 10);
        assert!(received.len() < 10, "overflow must have merged some windows");
        // After close + drain, recv reports end of stream.
        assert_eq!(c.recv(), None);
    }

    #[test]
    fn window_ring_close_flushes_pending() {
        // A pending overflow window must be delivered by close() even if
        // the consumer only starts draining afterwards.
        let (mut p, mut c) = window_ring(1);
        let w = ActivityWindow {
            slots: 5,
            acc: ActivityAccumulator { ops: 5, ..ActivityAccumulator::default() },
        };
        p.publish(w);
        p.publish(w); // ring full -> pending
        // Drain one so close() can flush without spinning forever.
        assert_eq!(c.pop().unwrap().window.slots, 5);
        p.close();
        let e = c.recv().unwrap();
        assert_eq!(e.window.slots, 5);
        assert_eq!(e.coalesced, 1);
        assert_eq!(c.recv(), None);
    }

    #[test]
    fn run_region_visits_every_worker_once() {
        for workers in [1usize, 4] {
            let exec = BatchExecutor::new(workers);
            let visits: Vec<AtomicUsize> = (0..workers).map(|_| AtomicUsize::new(0)).collect();
            exec.run_region(|w| {
                visits[w].fetch_add(1, Ordering::Relaxed);
            });
            for (w, v) in visits.iter().enumerate() {
                assert_eq!(v.load(Ordering::Relaxed), 1, "worker {w} of {workers}");
            }
            // The pool is reusable for ordinary runs afterwards.
            let cfg = FpuConfig::sp_fma();
            let word = WordUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Finite, 700, 2);
            let got = exec.run(&word, &triples);
            assert_eq!(got[0], word.fmac_one(triples[0].a, triples[0].b, triples[0].c));
        }
    }

    #[test]
    fn run_region_checked_contains_panics_and_pool_survives() {
        for workers in [1usize, 4] {
            let exec = BatchExecutor::new(workers);
            // A clean region reports Ok.
            assert_eq!(exec.run_region_checked(|_| {}), Ok(()));
            // A panicking region is contained: the call errors instead
            // of unwinding, and reports how many workers blew up.
            let err = exec
                .run_region_checked(|w| {
                    if w == 0 {
                        panic!("injected lane-kernel fault");
                    }
                })
                .unwrap_err();
            assert_eq!(err.workers, 1);
            // The same parked pool keeps serving both checked regions
            // and ordinary batch runs afterwards.
            assert_eq!(exec.run_region_checked(|_| {}), Ok(()));
            let cfg = FpuConfig::sp_fma();
            let word = WordUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Finite, 700, 9);
            let got = exec.run(&word, &triples);
            assert_eq!(got[0], word.fmac_one(triples[0].a, triples[0].b, triples[0].c));
        }
    }

    #[test]
    fn small_batches_recalibrate_stale_chunk_hint() {
        // Satellite fix: a chunk hint calibrated on a huge batch must
        // not be reused verbatim by a much smaller submission (tiny
        // serve batches were inheriting chunk sizes tuned on million-op
        // passes). Mixed big/small submissions each calibrate at their
        // own scale, stay bit-identical to serial, and the rule is
        // one-sided so alternating sizes cannot thrash.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = WordUnit::of(&unit);
        let big = sample(&cfg, OperandMix::Finite, 1_000_000, 3);
        let small = sample(&cfg, OperandMix::Finite, 4_096, 4);
        let tiny = sample(&cfg, OperandMix::Finite, 64, 5);
        let exec = BatchExecutor::new(8);

        let mut out_big = vec![0u64; big.len()];
        exec.run_into(&word, &big, &mut out_big).unwrap();
        assert_eq!(exec.calibrated_ops(), big.len());
        assert!(exec.chunk_hint() > 0);
        for i in [0usize, 999_999] {
            assert_eq!(out_big[i], word.fmac_one(big[i].a, big[i].b, big[i].c));
        }

        // Tiny submissions run serially (below the cutoff) and leave
        // the calibration alone.
        let mut out_tiny = vec![0u64; tiny.len()];
        exec.run_into(&word, &tiny, &mut out_tiny).unwrap();
        assert_eq!(exec.calibrated_ops(), big.len());
        for (i, t) in tiny.iter().enumerate() {
            assert_eq!(out_tiny[i], word.fmac_one(t.a, t.b, t.c), "tiny slot {i}");
        }

        // A parallel-sized but 8×-smaller batch re-times at its own
        // scale instead of inheriting the 1M-op hint.
        let mut out_small = vec![0u64; small.len()];
        exec.run_into(&word, &small, &mut out_small).unwrap();
        assert_eq!(exec.calibrated_ops(), small.len());
        assert!(exec.chunk_hint() > 0);
        for (i, t) in small.iter().enumerate() {
            assert_eq!(out_small[i], word.fmac_one(t.a, t.b, t.c), "small slot {i}");
        }

        // One-sided: the next big batch keeps the small calibration
        // (the per-op estimate is scale-independent) — no flapping.
        exec.run_into(&word, &big, &mut out_big).unwrap();
        assert_eq!(exec.calibrated_ops(), small.len());
        assert_eq!(out_big[77], word.fmac_one(big[77].a, big[77].b, big[77].c));

        // seed_calibration round-trips (the serve layer's per-tier swap).
        let saved = (exec.chunk_hint(), exec.calibrated_ops(), exec.calibration_key());
        assert_eq!(saved.2, calibration_key(Fidelity::WordLevel));
        exec.seed_calibration(0, 0, 0);
        assert_eq!((exec.chunk_hint(), exec.calibrated_ops(), exec.calibration_key()), (0, 0, 0));
        exec.seed_calibration(saved.0, saved.1, saved.2);
        assert_eq!((exec.chunk_hint(), exec.calibrated_ops(), exec.calibration_key()), saved);
    }

    #[test]
    fn foreign_lane_kernel_calibration_is_dropped() {
        // Satellite fix: a chunk hint persisted by the *other* lane-
        // kernel build (scalar vs `--features simd`) — or by another
        // tier — must not be reused verbatim: the per-op cost it encodes
        // was measured on different kernels. Seeding under a mismatched
        // key costs exactly one re-timing pass.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let simd = WordSimdUnit::of(&unit);
        let triples = sample(&cfg, OperandMix::Finite, 9_001, 11);
        let exec = BatchExecutor::new(4);
        let my_key = calibration_key(Fidelity::WordSimd);

        // Simulate a persisted calibration from the other build: same
        // tier tag, flipped lane-kernel fingerprint bits.
        let foreign_key = my_key ^ (0xDEAD << 8);
        assert_ne!(foreign_key, my_key);
        exec.seed_calibration(MAX_CHUNK, 10_000_000, foreign_key);
        assert_eq!(exec.chunk_hint(), MAX_CHUNK);

        let mut out = vec![0u64; triples.len()];
        exec.run_into(&simd, &triples, &mut out).unwrap();
        // The foreign hint was dropped and the run re-calibrated at its
        // own scale under its own key (results stay bit-exact either way).
        assert_eq!(exec.calibrated_ops(), triples.len(), "foreign-key hint was reused");
        assert_eq!(exec.calibration_key(), my_key);
        for (i, t) in triples.iter().enumerate().step_by(997) {
            assert_eq!(out[i], simd.fmac_one(t.a, t.b, t.c), "slot {i}");
        }

        // A matching-key seed IS reused: no re-timing, hint intact.
        exec.seed_calibration(1_024, triples.len(), my_key);
        exec.run_into(&simd, &triples, &mut out).unwrap();
        assert_eq!(exec.chunk_hint(), 1_024, "matching-key hint was dropped");
        assert_eq!(exec.calibrated_ops(), triples.len());

        // Cross-tier reuse is keyed off too: the scalar word tier drops
        // a WordSimd-keyed hint instead of inheriting it.
        let word = WordUnit::of(&unit);
        exec.run_into(&word, &triples, &mut out).unwrap();
        assert_eq!(exec.calibration_key(), calibration_key(Fidelity::WordLevel));
    }

    #[test]
    fn raw_window_trace_keeps_partial_interior_windows() {
        let w = |slots: u64, ops: u64| ActivityWindow {
            slots,
            acc: ActivityAccumulator { ops, ..ActivityAccumulator::default() },
        };
        let mut t = ActivityTrace::from_raw_windows(10, vec![w(10, 10), w(3, 3)]);
        t.push_window(w(10, 0));
        t.push_window(w(7, 7));
        // Verbatim: the partial interior window is NOT merged into its
        // successor (unlike the streaming push_* builders).
        assert_eq!(t.len(), 4);
        assert_eq!(t.windows()[1].slots, 3);
        assert_eq!(t.total_slots(), 30);
        assert_eq!(t.total_ops(), 20);
        assert_eq!(t.aggregate().ops, 20);
    }

    #[test]
    fn default_batch_covers_every_slot() {
        let cfg = FpuConfig::sp_cma();
        let word = WordUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 1_357, 3);
        let mut out = vec![u64::MAX; triples.len()];
        word.fmac_batch(&triples, &mut out);
        for (i, (t, &o)) in triples.iter().zip(out.iter()).enumerate() {
            assert_eq!(o, word.fmac_one(t.a, t.b, t.c), "slot {i}");
        }
    }

    #[test]
    fn registry_portions_the_worker_budget() {
        let reg = ExecutorRegistry::new(4);
        assert_eq!(reg.budget(), 4);
        let a = reg.shard(3);
        assert_eq!(a.workers(), 3);
        let b = reg.shard(3);
        assert_eq!(b.workers(), 1, "clamped to the remaining budget");
        // Budget exhausted: the floor still grants one worker so a late
        // shard can make progress.
        let c = reg.shard(5);
        assert_eq!(c.workers(), 1);
        assert!(reg.claimed() >= reg.budget());
    }

    #[test]
    fn registry_shards_do_not_share_calibration() {
        // The per-shard isolation guarantee behind the serve router: a
        // calibration observed on one shard's executor (say a slow
        // gate-level tier) must be invisible to every sibling.
        let reg = ExecutorRegistry::new(8);
        let gate_shard = reg.shard(2);
        let simd_shard = reg.shard(2);
        gate_shard.seed_calibration(512, 1_000_000, calibration_key(Fidelity::GateLevel));
        assert_eq!(simd_shard.chunk_hint(), 0, "sibling saw a foreign chunk hint");
        assert_eq!(simd_shard.calibrated_ops(), 0);
        simd_shard.seed_calibration(65_536, 4_096, calibration_key(Fidelity::WordSimd));
        assert_eq!(gate_shard.chunk_hint(), 512);
        assert_eq!(gate_shard.calibrated_ops(), 1_000_000);
        gate_shard.recalibrate();
        assert_eq!(simd_shard.chunk_hint(), 65_536);
    }

    #[test]
    fn window_ring_producer_drop_closes_the_stream() {
        // A producer dropped without close() (dispatcher death) must
        // still wake and terminate a blocking consumer.
        let (producer, mut consumer) = window_ring(4);
        let t = std::thread::spawn(move || {
            let mut seen = 0u64;
            while consumer.recv().is_some() {
                seen += 1;
            }
            seen
        });
        let mut producer = producer;
        producer.publish(ActivityWindow {
            slots: 5,
            acc: ActivityAccumulator { ops: 5, ..ActivityAccumulator::default() },
        });
        drop(producer);
        assert_eq!(t.join().expect("consumer thread"), 1);
    }
}
