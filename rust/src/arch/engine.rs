//! The batched execution engine: **one execution interface** for every
//! consumer of an FMAC datapath (coordinator, DSE sweeps, chip
//! sequencer, workload drivers, benches), with selectable fidelity.
//!
//! The FPMax paper separates what a unit *computes* (bit-exact IEEE
//! semantics per Table I) from how fast the silicon *delivers* it; FPnew
//! and Snitch make the same split in hardware — a parameterized FPU
//! behind a streaming front-end that keeps it fed. This module is that
//! split in software:
//!
//! * [`Datapath`] — the execution trait. `fmac_one` is the scalar op;
//!   `fmac_batch` has a streaming default so no implementation hand-rolls
//!   batching (the executor chunks batches across workers and drives it
//!   per chunk); `*_tracked` variants accumulate per-op activity into an
//!   [`ActivityAccumulator`].
//! * [`Fidelity`] — the three execution tiers. All are **bit-identical**
//!   on every operand; they differ only in what they *simulate* and
//!   therefore how fast they run:
//!
//!   | tier | computes | skips | guarantee | use it for |
//!   |------|----------|-------|-----------|------------|
//!   | `GateLevel` | every Booth mux and 3:2 row, toggle counts | nothing | is the DUT | verification, measured-activity energy |
//!   | `WordLevel` | exact integer-significand softfloat, scalar | per-row gate simulation | bit-identical; debug-asserted vs gate, sampled gate cross-checks at run time | DSE sweeps, fast verify |
//!   | `WordSimd` | the same spec restructured into branch-light SoA lane kernels ([`softfloat::lanes`]) | gate simulation **and** the scalar decode/class branches | bit-identical; same sampled gate-level cross-check machinery as `WordLevel` | throughput-bound batch serving |
//!
//! * [`BatchExecutor`] — thread-parallel execution over operand slices
//!   (`std::thread::scope`; the offline environment has no tokio, and the
//!   workload is pure CPU compute). The hot path is **allocation-free**:
//!   `*_into` variants write caller-provided buffers, workers pull
//!   load-aware chunks off an atomic cursor (chunk size autotuned by a
//!   one-shot calibration pass persisted in the executor), and the
//!   sampled cross-check walks indices directly instead of materializing
//!   index/operand vectors.
//!
//! Implementations provided: [`FpuUnit`] (the generated gate-level
//! datapath), [`WordUnit`] (the scalar word-level tier of a unit),
//! [`WordSimdUnit`] (the lane-batched word-level tier), [`UnitDatapath`]
//! (a unit bound to a fidelity at run time), and [`GoldenFma`] (the fused
//! softfloat spec, regardless of unit kind).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::fma::FmaActivity;
use super::fp::{decode, Class, Format};
use super::generator::{FpuConfig, FpuKind, FpuUnit, StructureReport};
use super::multiplier::MultiplierConfig;
use super::rounding::{Flags, RoundMode, Rounded};
use super::softfloat;
use crate::workloads::throughput::OperandTriple;

/// Execution fidelity tier of a datapath implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Fidelity {
    /// Structural simulation: Booth recoding, every 3:2 compressor row,
    /// toggle counting. Slow; feeds the energy model real activity.
    #[default]
    GateLevel,
    /// Exact integer-significand arithmetic, no per-row gate evaluation.
    /// Bit-identical results, ~an order of magnitude faster.
    WordLevel,
    /// Lane-batched word level: the same exact arithmetic restructured
    /// into branch-light SoA lane kernels
    /// ([`softfloat::lanes`]), special-case lanes peeled to the scalar
    /// slow path. Bit-identical to both other tiers.
    WordSimd,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::GateLevel => "gate",
            Fidelity::WordLevel => "word",
            Fidelity::WordSimd => "word-simd",
        }
    }
}

/// The per-unit Table-I semantics at word level: fused units round once,
/// cascade units round after the multiply and again after the add. This
/// is the single spec function the coordinator, the chip tester, and the
/// word-level tier all share.
#[inline]
pub fn reference_fmac(
    kind: FpuKind,
    fmt: Format,
    mode: RoundMode,
    a: u64,
    b: u64,
    c: u64,
) -> Rounded {
    match kind {
        FpuKind::Fma => softfloat::fma(fmt, mode, a, b, c),
        FpuKind::Cma => {
            let p = softfloat::mul(fmt, mode, a, b);
            let s = softfloat::add(fmt, mode, p.bits, c);
            Rounded { bits: s.bits, flags: Flags::merge(p.flags, s.flags) }
        }
    }
}

/// Unified activity accumulator: the sum of per-op [`FmaActivity`]
/// records over a batch, mergeable across worker threads. This replaces
/// the ad-hoc per-module toggle counters that used to feed the energy
/// model — [`crate::energy::power::evaluate_measured`] consumes one
/// directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityAccumulator {
    /// Ops recorded.
    pub ops: u64,
    /// Ops that took the special/early-out path (clock-gated datapath).
    pub special_ops: u64,
    /// Total Booth digits across ops.
    pub digits: u64,
    /// Nonzero Booth digits (mux/negate activity).
    pub nonzero_digits: u64,
    /// Tree full-adder evaluations (gate-level only).
    pub tree_fa_ops: u64,
    /// Tree output toggle weight (gate-level only).
    pub tree_toggles: u64,
    /// Summed alignment-shifter distances.
    pub align_shift: u64,
    /// Summed normalization distances.
    pub norm_shift: u64,
}

impl ActivityAccumulator {
    /// Fold one op's activity record in.
    #[inline]
    pub fn record(&mut self, act: &FmaActivity) {
        self.ops += 1;
        if act.special {
            self.special_ops += 1;
        }
        self.digits += act.digits as u64;
        self.nonzero_digits += act.nonzero_digits as u64;
        self.tree_fa_ops += act.tree_fa_ops;
        self.tree_toggles += act.tree_toggles;
        self.align_shift += act.align_shift as u64;
        self.norm_shift += act.norm_shift as u64;
    }

    /// Merge another accumulator (fork-join reduction).
    pub fn merge(&mut self, other: &ActivityAccumulator) {
        self.ops += other.ops;
        self.special_ops += other.special_ops;
        self.digits += other.digits;
        self.nonzero_digits += other.nonzero_digits;
        self.tree_fa_ops += other.tree_fa_ops;
        self.tree_toggles += other.tree_toggles;
        self.align_shift += other.align_shift;
        self.norm_shift += other.norm_shift;
    }

    /// Fraction of ops that exercised the full datapath (specials gate
    /// the multiplier clock).
    pub fn active_fraction(&self) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        1.0 - self.special_ops as f64 / self.ops as f64
    }

    /// Data-activity scale factor for [`crate::energy::UnitCost::dyn_energy_pj`]
    /// (1.0 = the calibrated average-operand activity).
    ///
    /// Gate-level runs scale by measured tree toggles per op against the
    /// half-the-tree-cells random baseline. Word-level runs carry no
    /// toggle counts but do record Booth digit statistics (the recoder is
    /// word-level computable), so they scale by the nonzero-digit ratio
    /// against the random-operand expectation of the radix — 3/4 for
    /// Booth-2, 7/8 for Booth-3 — times the active-op fraction. Only an
    /// empty accumulator is neutral.
    pub fn activity_scale(&self, s: &StructureReport) -> f64 {
        if self.ops == 0 {
            return 1.0;
        }
        if self.tree_fa_ops > 0 {
            let per_op = self.tree_toggles as f64 / self.ops as f64;
            let baseline = (s.tree_cells as f64 / 2.0).max(1.0);
            (per_op / baseline).clamp(0.05, 2.0)
        } else if self.digits > 0 {
            let ratio = self.nonzero_digits as f64 / self.digits as f64;
            let baseline = if s.has_triple_adder { 7.0 / 8.0 } else { 3.0 / 4.0 };
            (self.active_fraction() * ratio / baseline).clamp(0.05, 2.0)
        } else {
            self.active_fraction().clamp(0.05, 1.0)
        }
    }
}

/// One execution interface over every FMAC datapath implementation.
///
/// Results are raw bit patterns in the datapath's [`Format`] (SP in the
/// low 32 bits). All implementations of the same unit configuration are
/// bit-identical across fidelity tiers; rounding is round-to-nearest-even
/// (the benchmarked default — mode-explicit execution stays on
/// [`FpuUnit::fmac_mode`]).
pub trait Datapath: Sync {
    /// Operand/result format.
    fn format(&self) -> Format;

    /// FMAC organization this datapath implements (fused or cascade).
    fn kind(&self) -> FpuKind;

    /// Fidelity tier of this implementation.
    fn fidelity(&self) -> Fidelity;

    /// Structural report, when this datapath models a generated unit.
    fn structure(&self) -> Option<&StructureReport> {
        None
    }

    /// Display label for benches and reports.
    fn label(&self) -> String {
        format!("{}/{}", self.kind().name(), self.fidelity().name())
    }

    /// One FMAC (`a·b + c` in Table-I semantics); returns result bits.
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64;

    /// One FMAC with activity accumulation.
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        acc.ops += 1;
        self.fmac_one(a, b, c)
    }

    /// Execute a batch into `out`. The default streams the scalar op over
    /// the slice pair; the *parallel* chunking lives in
    /// [`BatchExecutor`], which splits the batch across workers and calls
    /// this per chunk.
    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one(t.a, t.b, t.c);
        }
    }

    /// Execute a batch with activity accumulation.
    fn fmac_batch_tracked(
        &self,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: &mut ActivityAccumulator,
    ) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        for (t, o) in triples.iter().zip(out.iter_mut()) {
            *o = self.fmac_one_tracked(t.a, t.b, t.c, acc);
        }
    }
}

/// The generated unit itself is the gate-level tier.
impl Datapath for FpuUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.config.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::GateLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(FpuUnit::structure(self))
    }

    fn label(&self) -> String {
        format!("{}/{}", self.config.name(), Fidelity::GateLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        self.fmac(a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        let (r, act) = self.fmac_mode(RoundMode::NearestEven, a, b, c);
        acc.record(&act);
        r.bits
    }
}

/// The word-level tier of a generated unit: same Table-I semantics and
/// structure report, no per-row gate simulation. Bit-identical to the
/// gate-level tier by construction (the gate-level datapath asserts
/// equality against this very spec in debug builds); `run_checked`
/// re-verifies that on sampled operands in release.
#[derive(Debug, Clone)]
pub struct WordUnit {
    format: Format,
    kind: FpuKind,
    mul: MultiplierConfig,
    structure: StructureReport,
    name: String,
}

impl WordUnit {
    /// The word-level view of an elaborated unit.
    pub fn of(unit: &FpuUnit) -> WordUnit {
        WordUnit {
            format: unit.format,
            kind: unit.config.kind,
            mul: *unit.multiplier_config(),
            structure: *unit.structure(),
            name: unit.config.name(),
        }
    }

    /// Elaborate a configuration straight into the word-level tier.
    pub fn generate(cfg: &FpuConfig) -> WordUnit {
        WordUnit::of(&FpuUnit::generate(cfg))
    }
}

/// Booth digit statistics of a multiplier operand, computed directly
/// from the recoding windows — no partial products materialized, no
/// tree. Mirrors `booth::partial_products_into`'s recode exactly, so a
/// word-level tracked run reports the same digit counts the gate-level
/// tier does.
fn booth_digit_stats(y: u64, mul: &MultiplierConfig) -> (u32, u32) {
    let b = mul.booth.bits_per_digit();
    let n = mul.booth.digit_count(mul.sig_bits);
    let y2 = (y as u128) << 1;
    let mut nonzero = 0;
    for i in 0..n {
        let window = ((y2 >> (i * b)) & ((1u128 << (b + 1)) - 1)) as u64;
        let msb = (window >> b) & 1;
        let value = ((window >> 1) + (window & 1)) as i64 - ((1i64 << b) * msb as i64);
        if value != 0 {
            nonzero += 1;
        }
    }
    (n, nonzero)
}

impl Datapath for WordUnit {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        self.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(&self.structure)
    }

    fn label(&self) -> String {
        format!("{}/{}", self.name, Fidelity::WordLevel.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        reference_fmac(self.kind, self.format, RoundMode::NearestEven, a, b, c).bits
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        // Word level carries no toggle counts, but the special/early-out
        // accounting (clock gating) and the Booth digit statistics are
        // both word-level observable — those are what the energy model's
        // word-level activity scale is built from.
        let da = decode(self.format, a);
        let db = decode(self.format, b);
        let special = match self.kind {
            FpuKind::Fma => {
                let dc = decode(self.format, c);
                da.non_finite()
                    || db.non_finite()
                    || dc.non_finite()
                    || da.is_zero()
                    || db.is_zero()
            }
            FpuKind::Cma => {
                !(matches!(da.class, Class::Normal | Class::Subnormal)
                    && matches!(db.class, Class::Normal | Class::Subnormal))
            }
        };
        acc.ops += 1;
        if special {
            acc.special_ops += 1;
        } else {
            // Same operand the gate-level multiplier recodes (y = b.sig).
            let (digits, nonzero) = booth_digit_stats(db.sig, &self.mul);
            acc.digits += digits as u64;
            acc.nonzero_digits += nonzero as u64;
        }
        self.fmac_one(a, b, c)
    }
}

/// The lane-batched word-level tier of a generated unit: scalar calls
/// compute through the same word-level spec as [`WordUnit`]; batch calls
/// stream full lane blocks through the branch-light SoA kernels in
/// [`softfloat::lanes`], peeling special-case lanes to the scalar slow
/// path, with the sub-lane-width remainder handled scalar. Bit-identical
/// to both other tiers (debug-asserted per lane inside the kernels,
/// sampled gate-level cross-checks at run time).
#[derive(Debug, Clone)]
pub struct WordSimdUnit {
    inner: WordUnit,
}

impl WordSimdUnit {
    /// The lane-batched word-level view of an elaborated unit.
    pub fn of(unit: &FpuUnit) -> WordSimdUnit {
        WordSimdUnit { inner: WordUnit::of(unit) }
    }

    /// Elaborate a configuration straight into the lane-batched tier.
    pub fn generate(cfg: &FpuConfig) -> WordSimdUnit {
        WordSimdUnit::of(&FpuUnit::generate(cfg))
    }
}

impl Datapath for WordSimdUnit {
    fn format(&self) -> Format {
        self.inner.format
    }

    fn kind(&self) -> FpuKind {
        self.inner.kind
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordSimd
    }

    fn structure(&self) -> Option<&StructureReport> {
        Some(&self.inner.structure)
    }

    fn label(&self) -> String {
        format!("{}/{}", self.inner.name, Fidelity::WordSimd.name())
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        self.inner.fmac_one(a, b, c)
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        // Activity is a word-level observable; the lane restructuring
        // changes execution speed, not what the silicon would toggle.
        self.inner.fmac_one_tracked(a, b, c, acc)
    }

    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        use crate::arch::softfloat::lanes::{cma_block_rne, fma_block_rne, LANES};
        let fmt = self.inner.format;
        let mut a = [0u64; LANES];
        let mut b = [0u64; LANES];
        let mut c = [0u64; LANES];
        let mut o = [0u64; LANES];
        let n = triples.len();
        let mut i = 0;
        while i + LANES <= n {
            for j in 0..LANES {
                let t = &triples[i + j];
                a[j] = t.a;
                b[j] = t.b;
                c[j] = t.c;
            }
            match self.inner.kind {
                FpuKind::Fma => fma_block_rne(fmt, &a, &b, &c, &mut o),
                FpuKind::Cma => cma_block_rne(fmt, &a, &b, &c, &mut o),
            }
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
        // Sub-lane remainder: scalar spec.
        for j in i..n {
            let t = &triples[j];
            out[j] = self.inner.fmac_one(t.a, t.b, t.c);
        }
    }
}

/// Batched word-level multiply (`round(a·b)` per triple) for the chip
/// sequencer's `Mul` bursts: RNE streams through the SoA lane kernel,
/// explicit-rounding modes through the scalar spec.
pub fn mul_batch(fmt: Format, mode: RoundMode, triples: &[OperandTriple], out: &mut [u64]) {
    assert_eq!(triples.len(), out.len(), "batch length mismatch");
    use crate::arch::softfloat::lanes::{mul_block_rne, LANES};
    let n = triples.len();
    let mut i = 0;
    if mode == RoundMode::NearestEven {
        let (mut a, mut b, mut o) = ([0u64; LANES], [0u64; LANES], [0u64; LANES]);
        while i + LANES <= n {
            for j in 0..LANES {
                a[j] = triples[i + j].a;
                b[j] = triples[i + j].b;
            }
            mul_block_rne(fmt, &a, &b, &mut o);
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
    }
    for j in i..n {
        out[j] = softfloat::mul(fmt, mode, triples[j].a, triples[j].b).bits;
    }
}

/// Batched word-level add (`round(a + c)` per triple) for the chip
/// sequencer's `Add` bursts: RNE through the lane kernel, explicit
/// modes scalar.
pub fn add_batch(fmt: Format, mode: RoundMode, triples: &[OperandTriple], out: &mut [u64]) {
    assert_eq!(triples.len(), out.len(), "batch length mismatch");
    use crate::arch::softfloat::lanes::{add_block_rne, LANES};
    let n = triples.len();
    let mut i = 0;
    if mode == RoundMode::NearestEven {
        let (mut a, mut c, mut o) = ([0u64; LANES], [0u64; LANES], [0u64; LANES]);
        while i + LANES <= n {
            for j in 0..LANES {
                a[j] = triples[i + j].a;
                c[j] = triples[i + j].c;
            }
            add_block_rne(fmt, &a, &c, &mut o);
            out[i..i + LANES].copy_from_slice(&o);
            i += LANES;
        }
    }
    for j in i..n {
        out[j] = softfloat::add(fmt, mode, triples[j].a, triples[j].c).bits;
    }
}

/// A generated unit bound to a fidelity tier chosen at run time — the
/// handle consumers pass to the executor when the tier is a parameter
/// (DSE sweeps run word-level, verification runs gate-level).
#[derive(Debug, Clone)]
pub enum UnitDatapath {
    Gate(FpuUnit),
    Word(WordUnit),
    Simd(WordSimdUnit),
}

impl UnitDatapath {
    /// Bind an elaborated unit to a tier.
    pub fn new(unit: &FpuUnit, fidelity: Fidelity) -> UnitDatapath {
        match fidelity {
            Fidelity::GateLevel => UnitDatapath::Gate(unit.clone()),
            Fidelity::WordLevel => UnitDatapath::Word(WordUnit::of(unit)),
            Fidelity::WordSimd => UnitDatapath::Simd(WordSimdUnit::of(unit)),
        }
    }

    /// Elaborate a configuration at a tier.
    pub fn generate(cfg: &FpuConfig, fidelity: Fidelity) -> UnitDatapath {
        UnitDatapath::new(&FpuUnit::generate(cfg), fidelity)
    }
}

impl Datapath for UnitDatapath {
    fn format(&self) -> Format {
        match self {
            UnitDatapath::Gate(u) => u.format,
            UnitDatapath::Word(w) => Datapath::format(w),
            UnitDatapath::Simd(s) => Datapath::format(s),
        }
    }

    fn kind(&self) -> FpuKind {
        match self {
            UnitDatapath::Gate(u) => u.config.kind,
            UnitDatapath::Word(w) => Datapath::kind(w),
            UnitDatapath::Simd(s) => Datapath::kind(s),
        }
    }

    fn fidelity(&self) -> Fidelity {
        match self {
            UnitDatapath::Gate(_) => Fidelity::GateLevel,
            UnitDatapath::Word(_) => Fidelity::WordLevel,
            UnitDatapath::Simd(_) => Fidelity::WordSimd,
        }
    }

    fn structure(&self) -> Option<&StructureReport> {
        match self {
            UnitDatapath::Gate(u) => Some(FpuUnit::structure(u)),
            UnitDatapath::Word(w) => Datapath::structure(w),
            UnitDatapath::Simd(s) => Datapath::structure(s),
        }
    }

    fn label(&self) -> String {
        match self {
            UnitDatapath::Gate(u) => Datapath::label(u),
            UnitDatapath::Word(w) => Datapath::label(w),
            UnitDatapath::Simd(s) => Datapath::label(s),
        }
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac(a, b, c).bits,
            UnitDatapath::Word(w) => w.fmac_one(a, b, c),
            UnitDatapath::Simd(s) => s.fmac_one(a, b, c),
        }
    }

    #[inline]
    fn fmac_one_tracked(&self, a: u64, b: u64, c: u64, acc: &mut ActivityAccumulator) -> u64 {
        match self {
            UnitDatapath::Gate(u) => u.fmac_one_tracked(a, b, c, acc),
            UnitDatapath::Word(w) => w.fmac_one_tracked(a, b, c, acc),
            UnitDatapath::Simd(s) => s.fmac_one_tracked(a, b, c, acc),
        }
    }

    fn fmac_batch(&self, triples: &[OperandTriple], out: &mut [u64]) {
        // Delegate so the Simd variant's lane driver is reached (the
        // trait default would stream the scalar op).
        match self {
            UnitDatapath::Gate(u) => u.fmac_batch(triples, out),
            UnitDatapath::Word(w) => w.fmac_batch(triples, out),
            UnitDatapath::Simd(s) => s.fmac_batch(triples, out),
        }
    }
}

/// The golden softfloat spec as an engine datapath: always **fused**
/// semantics, whatever unit it is compared against. This is what the
/// coordinator checks the PJRT artifact with.
#[derive(Debug, Clone, Copy)]
pub struct GoldenFma {
    pub format: Format,
}

impl Datapath for GoldenFma {
    fn format(&self) -> Format {
        self.format
    }

    fn kind(&self) -> FpuKind {
        FpuKind::Fma
    }

    fn fidelity(&self) -> Fidelity {
        Fidelity::WordLevel
    }

    fn label(&self) -> String {
        "golden/fused".to_string()
    }

    #[inline]
    fn fmac_one(&self, a: u64, b: u64, c: u64) -> u64 {
        softfloat::fma(self.format, RoundMode::NearestEven, a, b, c).bits
    }
}

/// Report of a sampled gate-level cross-check of a word-level run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CrossCheck {
    /// How many operands were re-executed at gate level.
    pub sampled: usize,
    /// Indices (into the batch) that disagreed, capped at 16.
    pub mismatches: Vec<usize>,
}

impl CrossCheck {
    pub fn clean(&self) -> bool {
        self.mismatches.is_empty()
    }
}

const CROSSCHECK_CAP: usize = 16;

/// Below this batch size the scoped-spawn overhead dominates any
/// parallel win: run on the calling thread.
const SERIAL_CUTOFF: usize = 512;
/// Ops executed serially by the one-shot chunk calibration pass.
const CALIBRATION_OPS: usize = 2_048;
/// Target wall-clock per pulled chunk: long enough to amortize the
/// atomic cursor, short enough that a straggler chunk cannot idle the
/// other workers for long (specials-heavy regions run slower than
/// finite-dense ones, so static `n / workers` splits load-imbalance).
const TARGET_CHUNK_SECS: f64 = 2e-3;
const MIN_CHUNK: usize = 256;
const MAX_CHUNK: usize = 1 << 16;

/// A raw pointer that may cross thread boundaries. Workers derive
/// disjoint sub-slices from it (ranges handed out by an atomic cursor),
/// so no two threads ever alias a byte.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Thread-parallel batch executor: drives any [`Datapath`] over an
/// operand slice with workers pulling load-aware chunks off a shared
/// atomic cursor.
///
/// The hot path allocates nothing: callers can hand in reusable output
/// buffers via the `*_into` variants (the `Vec`-returning wrappers exist
/// for convenience), chunk descriptors are never materialized, and the
/// sampled gate-level cross-check walks indices directly. Chunk size is
/// autotuned by a one-shot calibration pass — the first ~2k ops of the
/// first batch run serially under a timer, and the derived
/// ops-per-chunk value persists in the executor (see
/// [`BatchExecutor::recalibrate`]).
#[derive(Debug)]
pub struct BatchExecutor {
    workers: usize,
    /// Calibrated ops per pulled chunk; 0 = not yet calibrated. Interior
    /// mutability so calibration can persist through `&self` (executors
    /// are shared immutably across call sites and worker threads).
    chunk_hint: AtomicUsize,
}

impl Default for BatchExecutor {
    fn default() -> Self {
        BatchExecutor::auto()
    }
}

impl Clone for BatchExecutor {
    fn clone(&self) -> Self {
        BatchExecutor {
            workers: self.workers,
            chunk_hint: AtomicUsize::new(self.chunk_hint.load(Ordering::Relaxed)),
        }
    }
}

impl BatchExecutor {
    /// Fixed worker count (clamped to ≥ 1).
    pub fn new(workers: usize) -> BatchExecutor {
        BatchExecutor { workers: workers.max(1), chunk_hint: AtomicUsize::new(0) }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> BatchExecutor {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        BatchExecutor::new(n)
    }

    /// Single-threaded executor (scalar-equivalent ordering, no spawns).
    pub fn serial() -> BatchExecutor {
        BatchExecutor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The calibrated ops-per-chunk value (0 until the first parallel
    /// run calibrates it).
    pub fn chunk_hint(&self) -> usize {
        self.chunk_hint.load(Ordering::Relaxed)
    }

    /// Drop the persisted chunk calibration — the next run re-times. Use
    /// when switching this executor to a datapath with a very different
    /// per-op cost (gate-level is ~an order of magnitude slower than
    /// word-level; a stale hint only costs load-balance granularity,
    /// never correctness).
    pub fn recalibrate(&self) {
        self.chunk_hint.store(0, Ordering::Relaxed);
    }

    /// Chunk size for an `n`-op parallel run: the calibrated hint,
    /// bounded so there is at least one chunk per worker.
    fn chunk_for(&self, n: usize) -> usize {
        let hint = self.chunk_hint.load(Ordering::Relaxed);
        let fallback = n.div_ceil(self.workers);
        if hint == 0 {
            fallback
        } else {
            hint.min(fallback.max(MIN_CHUNK)).clamp(1, n.max(1))
        }
    }

    /// One-shot calibration: time a short serial prefix of the batch
    /// (its results land in `out[..prefix]`, so no work is wasted) and
    /// persist the chunk size that makes one chunk ≈ the target
    /// wall-clock. Returns the prefix length already executed.
    fn calibrate<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: Option<&mut ActivityAccumulator>,
    ) -> usize {
        if self.chunk_hint.load(Ordering::Relaxed) != 0 {
            return 0;
        }
        let prefix = CALIBRATION_OPS.min(triples.len());
        let t0 = std::time::Instant::now();
        match acc {
            Some(acc) => dp.fmac_batch_tracked(&triples[..prefix], &mut out[..prefix], acc),
            None => dp.fmac_batch(&triples[..prefix], &mut out[..prefix]),
        }
        let per_op = (t0.elapsed().as_secs_f64() / prefix as f64).max(1e-9);
        let chunk = ((TARGET_CHUNK_SECS / per_op) as usize).clamp(MIN_CHUNK, MAX_CHUNK);
        self.chunk_hint.store(chunk, Ordering::Relaxed);
        prefix
    }

    /// Parallel region: workers pull `chunk`-sized ranges off an atomic
    /// cursor until the slice is drained. Each range is claimed by
    /// exactly one `fetch_add` winner, so the raw-pointer sub-slices are
    /// disjoint.
    fn run_chunked<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
        acc: Option<&mut ActivityAccumulator>,
    ) {
        let n = triples.len();
        if n == 0 {
            return;
        }
        let chunk = self.chunk_for(n).max(1);
        let workers = self.workers.min(n.div_ceil(chunk));
        if workers <= 1 {
            match acc {
                Some(acc) => dp.fmac_batch_tracked(triples, out, acc),
                None => dp.fmac_batch(triples, out),
            }
            return;
        }
        let track = acc.is_some();
        let cursor = AtomicUsize::new(0);
        let merged = Mutex::new(ActivityAccumulator::default());
        let out_ptr = SendPtr(out.as_mut_ptr());
        std::thread::scope(|s| {
            for _ in 0..workers {
                let cursor = &cursor;
                let merged = &merged;
                s.spawn(move || {
                    let mut local = ActivityAccumulator::default();
                    loop {
                        let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if lo >= n {
                            break;
                        }
                        let hi = (lo + chunk).min(n);
                        // SAFETY: [lo, hi) came from a unique fetch_add
                        // claim, so this sub-slice aliases no other
                        // worker's; `out` outlives the scope.
                        let os = unsafe {
                            std::slice::from_raw_parts_mut(out_ptr.0.add(lo), hi - lo)
                        };
                        if track {
                            dp.fmac_batch_tracked(&triples[lo..hi], os, &mut local);
                        } else {
                            dp.fmac_batch(&triples[lo..hi], os);
                        }
                    }
                    if track && local != ActivityAccumulator::default() {
                        merged.lock().expect("engine worker panicked").merge(&local);
                    }
                });
            }
        });
        if let Some(acc) = acc {
            acc.merge(&merged.into_inner().expect("engine worker panicked"));
        }
    }

    /// Execute a batch, returning result bits in operand order.
    pub fn run<D: Datapath + ?Sized>(&self, dp: &D, triples: &[OperandTriple]) -> Vec<u64> {
        let mut out = vec![0u64; triples.len()];
        self.run_into(dp, triples, &mut out);
        out
    }

    /// Execute a batch into a caller-provided buffer — the
    /// allocation-free hot path (serial runs allocate nothing; parallel
    /// runs allocate only the O(workers) scoped-thread bookkeeping,
    /// independent of batch size).
    pub fn run_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        let n = triples.len();
        if n == 0 {
            return;
        }
        if self.workers <= 1 || n <= SERIAL_CUTOFF {
            dp.fmac_batch(triples, out);
            return;
        }
        let done = self.calibrate(dp, triples, out, None);
        self.run_chunked(dp, &triples[done..], &mut out[done..], None);
    }

    /// Execute a batch while accumulating activity (merged across
    /// workers; the merge is order-independent because the accumulator is
    /// a plain sum).
    pub fn run_tracked<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
    ) -> (Vec<u64>, ActivityAccumulator) {
        let mut out = vec![0u64; triples.len()];
        let acc = self.run_tracked_into(dp, triples, &mut out);
        (out, acc)
    }

    /// Tracked execution into a caller-provided buffer; returns the
    /// merged activity.
    pub fn run_tracked_into<D: Datapath + ?Sized>(
        &self,
        dp: &D,
        triples: &[OperandTriple],
        out: &mut [u64],
    ) -> ActivityAccumulator {
        assert_eq!(triples.len(), out.len(), "batch length mismatch");
        let mut total = ActivityAccumulator::default();
        let n = triples.len();
        if n == 0 {
            return total;
        }
        if self.workers <= 1 || n <= SERIAL_CUTOFF {
            dp.fmac_batch_tracked(triples, out, &mut total);
            return total;
        }
        let done = self.calibrate(dp, triples, out, Some(&mut total));
        self.run_chunked(dp, &triples[done..], &mut out[done..], Some(&mut total));
        total
    }

    /// Word-level execution of a unit with a sampled gate-level
    /// cross-check (see [`BatchExecutor::run_checked_into`]).
    pub fn run_checked(
        &self,
        unit: &FpuUnit,
        triples: &[OperandTriple],
        sample_every: usize,
    ) -> (Vec<u64>, CrossCheck) {
        self.run_checked_tier(unit, Fidelity::WordLevel, triples, sample_every)
    }

    /// Tier-selectable checked execution returning a fresh buffer.
    pub fn run_checked_tier(
        &self,
        unit: &FpuUnit,
        tier: Fidelity,
        triples: &[OperandTriple],
        sample_every: usize,
    ) -> (Vec<u64>, CrossCheck) {
        let mut out = vec![0u64; triples.len()];
        let check = self.run_checked_into(unit, tier, triples, sample_every, &mut out);
        (out, check)
    }

    /// Execute a unit's word tier (`WordLevel` or `WordSimd`) into a
    /// caller-provided buffer with a sampled gate-level cross-check:
    /// every `sample_every`-th operand is re-executed through the
    /// structural datapath and compared bit-for-bit. This is the
    /// release-build guard on the word tiers' bit-identity claim.
    ///
    /// The sampling pass materializes nothing — sample indices are
    /// walked directly, partitioned round-robin across workers (the
    /// gate-level re-execution is the expensive part, so it parallelizes
    /// through the same scoped threads). `GateLevel` runs plain (the
    /// gate tier is the reference; `sampled` reports 0).
    pub fn run_checked_into(
        &self,
        unit: &FpuUnit,
        tier: Fidelity,
        triples: &[OperandTriple],
        sample_every: usize,
        out: &mut [u64],
    ) -> CrossCheck {
        match tier {
            Fidelity::GateLevel => {
                self.run_into(unit, triples, out);
                return CrossCheck::default();
            }
            Fidelity::WordLevel => {
                let word = WordUnit::of(unit);
                self.run_into(&word, triples, out);
            }
            Fidelity::WordSimd => {
                let simd = WordSimdUnit::of(unit);
                self.run_into(&simd, triples, out);
            }
        }
        let n = triples.len();
        if n == 0 {
            return CrossCheck::default();
        }
        let step = sample_every.max(1);
        let sampled = n.div_ceil(step);
        let workers = self.workers.min(sampled);
        let mut mismatches = if workers <= 1 || sampled <= 64 {
            let mut mm = Vec::new();
            let mut i = 0;
            while i < n {
                let t = &triples[i];
                if unit.fmac_one(t.a, t.b, t.c) != out[i] && mm.len() < CROSSCHECK_CAP {
                    mm.push(i);
                }
                i += step;
            }
            mm
        } else {
            let shared = Mutex::new(Vec::new());
            let out_ro: &[u64] = out;
            std::thread::scope(|s| {
                for w in 0..workers {
                    let shared = &shared;
                    s.spawn(move || {
                        let mut local = Vec::new();
                        let mut k = w;
                        while k < sampled {
                            let i = k * step;
                            let t = &triples[i];
                            if unit.fmac_one(t.a, t.b, t.c) != out_ro[i]
                                && local.len() < CROSSCHECK_CAP
                            {
                                local.push(i);
                            }
                            k += workers;
                        }
                        if !local.is_empty() {
                            shared
                                .lock()
                                .expect("cross-check worker panicked")
                                .extend_from_slice(&local);
                        }
                    });
                }
            });
            shared.into_inner().expect("cross-check worker panicked")
        };
        mismatches.sort_unstable();
        mismatches.truncate(CROSSCHECK_CAP);
        CrossCheck { sampled, mismatches }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::throughput::{OperandMix, OperandStream};

    fn sample(cfg: &FpuConfig, mix: OperandMix, n: usize, seed: u64) -> Vec<OperandTriple> {
        OperandStream::new(cfg.precision, mix, seed).batch(n)
    }

    #[test]
    fn tiers_bit_identical_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let word = WordUnit::of(&unit);
            for t in sample(&cfg, OperandMix::Anything, 3_000, 0xE16).iter() {
                assert_eq!(
                    unit.fmac_one(t.a, t.b, t.c),
                    word.fmac_one(t.a, t.b, t.c),
                    "{}: a={:#x} b={:#x} c={:#x}",
                    cfg.name(),
                    t.a,
                    t.b,
                    t.c
                );
            }
        }
    }

    #[test]
    fn executor_matches_scalar_loop_any_worker_count() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 2_531, 7); // not a worker multiple
        let scalar: Vec<u64> =
            triples.iter().map(|t| unit.fmac_one(t.a, t.b, t.c)).collect();
        for workers in [1, 2, 3, 5, 16, 64] {
            let got = BatchExecutor::new(workers).run(&unit, &triples);
            assert_eq!(got, scalar, "workers={workers}");
        }
    }

    #[test]
    fn tracked_run_merges_activity_like_serial() {
        let cfg = FpuConfig::dp_cma();
        let unit = FpuUnit::generate(&cfg);
        let mut triples = sample(&cfg, OperandMix::Anything, 2_000, 11);
        // One guaranteed special so the clock-gating counter is exercised
        // regardless of what the random stream drew.
        triples.push(OperandTriple { a: f64::NAN.to_bits(), b: 0, c: 0 });
        let (bits1, acc1) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let (bits8, acc8) = BatchExecutor::new(8).run_tracked(&unit, &triples);
        assert_eq!(bits1, bits8);
        assert_eq!(acc1, acc8, "activity sums must be worker-count invariant");
        assert_eq!(acc1.ops, 2_001);
        assert!(acc1.tree_toggles > 0);
        assert!(acc1.special_ops > 0, "the NaN op must take the special path");
    }

    #[test]
    fn word_level_tracks_special_fraction() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Anything, 4_000, 23);
        let (_, gate) = BatchExecutor::serial().run_tracked(&unit, &triples);
        let word = WordUnit::of(&unit);
        let (_, wacc) = BatchExecutor::serial().run_tracked(&word, &triples);
        // Word level sees exactly the same clock-gating decisions and the
        // same Booth recoding — digit statistics must agree exactly.
        assert_eq!(gate.special_ops, wacc.special_ops);
        assert_eq!(gate.ops, wacc.ops);
        assert_eq!(gate.digits, wacc.digits);
        assert_eq!(gate.nonzero_digits, wacc.nonzero_digits);
        // ... but word level carries no gate toggles.
        assert_eq!(wacc.tree_toggles, 0);
        assert_eq!(wacc.tree_fa_ops, 0);
    }

    #[test]
    fn run_checked_clean_on_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Anything, 5_000, 0xC0FFEE);
            let (out, check) = BatchExecutor::new(4).run_checked(&unit, &triples, 37);
            assert!(check.clean(), "{}: {:?}", cfg.name(), check.mismatches);
            assert_eq!(check.sampled, triples.len().div_ceil(37));
            assert_eq!(out.len(), triples.len());
        }
    }

    #[test]
    fn golden_fma_is_fused_spec() {
        let g = GoldenFma { format: Format::SP };
        let a = 1.0f32 + 2f32.powi(-12);
        let c = -(1.0f32 + 2f32.powi(-11));
        let r = g.fmac_one(a.to_bits() as u64, a.to_bits() as u64, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r as u32), 2f32.powi(-24)); // cascade would give 0
    }

    #[test]
    fn activity_scale_tracks_operand_density() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let s = *unit.structure();
        let dense = OperandTriple {
            a: 0x3fff_ffff,
            b: 0x3faa_aaaa,
            c: 0x3f80_0000,
        };
        let quiet = OperandTriple { a: 0x3f80_0000, b: 0x0040_0000, c: 0 };
        let mut acc_dense = ActivityAccumulator::default();
        let mut acc_quiet = ActivityAccumulator::default();
        for _ in 0..64 {
            unit.fmac_one_tracked(dense.a, dense.b, dense.c, &mut acc_dense);
            unit.fmac_one_tracked(quiet.a, quiet.b, quiet.c, &mut acc_quiet);
        }
        assert!(acc_dense.activity_scale(&s) > acc_quiet.activity_scale(&s));
        // Empty accumulator is neutral.
        assert_eq!(ActivityAccumulator::default().activity_scale(&s), 1.0);
    }

    #[test]
    fn unit_datapath_binds_fidelity() {
        let cfg = FpuConfig::dp_fma();
        let unit = FpuUnit::generate(&cfg);
        let gate = UnitDatapath::new(&unit, Fidelity::GateLevel);
        let word = UnitDatapath::new(&unit, Fidelity::WordLevel);
        assert_eq!(gate.fidelity(), Fidelity::GateLevel);
        assert_eq!(word.fidelity(), Fidelity::WordLevel);
        assert!(gate.label().contains("gate") && word.label().contains("word"));
        assert_eq!(
            Datapath::structure(&gate).unwrap(),
            Datapath::structure(&word).unwrap()
        );
        let t = OperandTriple {
            a: 1.5f64.to_bits(),
            b: 2.0f64.to_bits(),
            c: 0.25f64.to_bits(),
        };
        assert_eq!(gate.fmac_one(t.a, t.b, t.c), word.fmac_one(t.a, t.b, t.c));
    }

    #[test]
    fn word_simd_batch_bit_identical_all_presets() {
        // Lane kernels + remainder path vs the gate-level scalar op, on
        // operand mixes that hit every special class. 1_003 is not a
        // lane-width multiple, so the scalar tail runs too.
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let simd = WordSimdUnit::of(&unit);
            for (mix, seed) in [(OperandMix::Anything, 0x51D0u64), (OperandMix::SpecialHeavy, 7)] {
                let triples = OperandStream::new(cfg.precision, mix, seed).batch(1_003);
                let mut out = vec![0u64; triples.len()];
                simd.fmac_batch(&triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        unit.fmac_one(t.a, t.b, t.c),
                        "{} {mix:?} slot {i}: a={:#x} b={:#x} c={:#x}",
                        cfg.name(),
                        t.a,
                        t.b,
                        t.c
                    );
                }
            }
        }
    }

    #[test]
    fn run_checked_simd_tier_clean_on_all_presets() {
        for cfg in FpuConfig::fpmax_units() {
            let unit = FpuUnit::generate(&cfg);
            let triples = sample(&cfg, OperandMix::Anything, 5_000, 0xD00D);
            let exec = BatchExecutor::new(4);
            let (out, check) = exec.run_checked_tier(&unit, Fidelity::WordSimd, &triples, 41);
            assert!(check.clean(), "{}: {:?}", cfg.name(), check.mismatches);
            assert_eq!(check.sampled, triples.len().div_ceil(41));
            let want = BatchExecutor::serial().run(&unit, &triples);
            assert_eq!(out, want, "{}", cfg.name());
        }
    }

    #[test]
    fn run_checked_stride_one_and_gate_tier() {
        // Stride 1 checks every operand (sampled == n); the gate tier
        // reports no sampling because it *is* the reference.
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 300, 5);
        let exec = BatchExecutor::serial();
        let mut out = vec![0u64; triples.len()];
        let check = exec.run_checked_into(&unit, Fidelity::WordLevel, &triples, 1, &mut out);
        assert!(check.clean());
        assert_eq!(check.sampled, 300);
        // GateLevel tier: no sampling (the gate tier is the reference).
        let check = exec.run_checked_into(&unit, Fidelity::GateLevel, &triples, 7, &mut out);
        assert_eq!(check.sampled, 0);
        assert!(check.clean());
    }

    #[test]
    fn executor_buffer_reuse_and_calibration_persist() {
        let cfg = FpuConfig::sp_fma();
        let unit = FpuUnit::generate(&cfg);
        let word = WordUnit::of(&unit);
        let triples = sample(&cfg, OperandMix::Finite, 9_001, 13);
        let exec = BatchExecutor::new(8);
        assert_eq!(exec.chunk_hint(), 0);
        let mut out1 = vec![u64::MAX; triples.len()];
        exec.run_into(&word, &triples, &mut out1);
        let hint = exec.chunk_hint();
        assert!(hint >= 1, "first parallel run must calibrate");
        // Re-running into the same buffer gives identical bits and keeps
        // the calibration.
        let mut out2 = vec![0u64; triples.len()];
        exec.run_into(&word, &triples, &mut out2);
        assert_eq!(out1, out2);
        assert_eq!(exec.chunk_hint(), hint);
        // A cloned executor carries the calibration; recalibrate drops it.
        let cloned = exec.clone();
        assert_eq!(cloned.chunk_hint(), hint);
        exec.recalibrate();
        assert_eq!(exec.chunk_hint(), 0);
        // Tracked runs agree with untracked whatever the chunking.
        let acc = exec.run_tracked_into(&word, &triples, &mut out2);
        assert_eq!(out1, out2);
        assert_eq!(acc.ops, triples.len() as u64);
    }

    #[test]
    fn mul_add_batches_match_scalar_all_modes() {
        use crate::arch::softfloat;
        for cfg in [FpuConfig::sp_fma(), FpuConfig::dp_fma()] {
            let fmt = cfg.precision.format();
            // 107 ops: exercises lane blocks + remainder.
            let triples = sample(&cfg, OperandMix::Anything, 107, 0xAB);
            let mut out = vec![0u64; triples.len()];
            for mode in RoundMode::ALL {
                mul_batch(fmt, mode, &triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(out[i], softfloat::mul(fmt, mode, t.a, t.b).bits, "mul {mode:?} {i}");
                }
                add_batch(fmt, mode, &triples, &mut out);
                for (i, t) in triples.iter().enumerate() {
                    assert_eq!(out[i], softfloat::add(fmt, mode, t.a, t.c).bits, "add {mode:?} {i}");
                }
            }
        }
    }

    #[test]
    fn default_batch_covers_every_slot() {
        let cfg = FpuConfig::sp_cma();
        let word = WordUnit::generate(&cfg);
        let triples = sample(&cfg, OperandMix::Finite, 1_357, 3);
        let mut out = vec![u64::MAX; triples.len()];
        word.fmac_batch(&triples, &mut out);
        for (i, (t, &o)) in triples.iter().zip(out.iter()).enumerate() {
            assert_eq!(o, word.fmac_one(t.a, t.b, t.c), "slot {i}");
        }
    }
}
