//! Booth recoding and partial-product generation.
//!
//! The paper's FPUs differ in Booth radix (Table I): the DP units and the
//! SP FMA use **Booth 3** (radix-8, digits −4…4, needs the hard ×3
//! multiple but emits ~m/3 partial products), while the SP CMA's shorter
//! cycle forces **Booth 2** (radix-4, digits −2…2, ~m/2 partial products,
//! no hard multiple). Fewer partial products shrink the reduction tree —
//! area and energy — at the cost of the ×3 pre-adder's delay; this is the
//! exact trade FPGen sweeps.
//!
//! Partial products are materialized as two's-complement words masked to
//! the multiplier's window width, so summing them with carry-save
//! arithmetic reproduces the product *mod 2^W* exactly as the silicon
//! array does with sign-extension encoding.


/// Booth recoding radix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoothRadix {
    /// Radix-4 (overlapping triplets, digits −2…+2).
    Booth2,
    /// Radix-8 (overlapping quadruplets, digits −4…+4; requires a 3M
    /// pre-adder).
    Booth3,
}

impl BoothRadix {
    /// Bits consumed per digit.
    pub const fn bits_per_digit(self) -> u32 {
        match self {
            BoothRadix::Booth2 => 2,
            BoothRadix::Booth3 => 3,
        }
    }

    /// Number of Booth digits needed to cover an `m`-bit unsigned
    /// multiplier (one extra high bit guarantees the final digit is
    /// non-negative for an unsigned operand).
    pub const fn digit_count(self, m: u32) -> u32 {
        let b = self.bits_per_digit();
        (m + b) / b // ceil((m+1)/b)
    }

    /// Does this radix require the hard ×3 multiple (a carry-propagate
    /// pre-add of the multiplicand)?
    pub const fn needs_triple(self) -> bool {
        matches!(self, BoothRadix::Booth3)
    }

    /// Short name for reports ("2" / "3", as in the paper's Table I).
    pub fn name(self) -> &'static str {
        match self {
            BoothRadix::Booth2 => "2",
            BoothRadix::Booth3 => "3",
        }
    }
}

/// One recoded Booth digit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoothDigit {
    /// Digit value in −4…+4 (−2…+2 for Booth-2).
    pub value: i8,
    /// Weight: the digit contributes `value · 2^shift · multiplicand`.
    pub shift: u32,
}

/// Recode an `m`-bit unsigned multiplier into Booth digits.
///
/// Standard overlapping-window recoding: window `i` inspects bits
/// `[i·b − 1, i·b + b − 1]` (bit −1 reads as 0) and produces digit
/// `window_value − 2b·(top bit)`, guaranteeing Σ digit_i · 2^(i·b) = y.
pub fn recode(y: u64, m: u32, radix: BoothRadix) -> Vec<BoothDigit> {
    assert!(m <= 62, "multiplier width exceeds recoder");
    debug_assert!(m == 64 || y < (1u64 << m), "multiplier has bits above m");
    let b = radix.bits_per_digit();
    let n = radix.digit_count(m);
    // y extended with a 0 at bit -1: examine (b+1)-bit windows of 2y.
    let y2 = (y as u128) << 1;
    let mut digits = Vec::with_capacity(n as usize);
    for i in 0..n {
        let lo = i * b;
        let window = ((y2 >> lo) & ((1u128 << (b + 1)) - 1)) as u64;
        // Window LSB carries half weight (it is the overlap bit y[lo−1]):
        // digit = ⌊w/2⌋ + (w&1) − 2^b·msb(w), e.g. radix-4's
        // y_{2i−1} + y_{2i} − 2·y_{2i+1}.
        let msb = (window >> b) & 1;
        let value = ((window >> 1) + (window & 1)) as i64 - ((1i64 << b) * msb as i64);
        digits.push(BoothDigit { value: value as i8, shift: i * b });
    }
    digits
}

/// Statistics from partial-product generation, consumed by the energy
/// model (switching events) and timing model (PP count → tree size).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PpStats {
    /// Total digits (= number of partial products).
    pub digits: u32,
    /// Digits with a nonzero value (actual mux/negate activity).
    pub nonzero_digits: u32,
    /// Whether the ×3 hard multiple was computed (Booth-3 only).
    pub used_triple: bool,
}

/// Maximum partial products any supported configuration emits (DP
/// Booth-2: 27) — sizes the allocation-free hot-path buffers.
pub const MAX_PPS: usize = 28;

/// Allocation-free partial-product generation into a caller-provided
/// buffer (the FMAC hot path). Returns the PP count and stats.
///
/// Recoding is fused in (no intermediate digit vector): window `i` of
/// `2y` yields digit `⌊w/2⌋ + (w&1) − 2^b·msb(w)`; each digit's
/// multiple of `x` is wrapped two's-complement to the window width,
/// exactly like the silicon's sign-extension encoding.
#[inline(always)]
pub fn partial_products_into(
    x: u64,
    y: u64,
    m: u32,
    radix: BoothRadix,
    width: u32,
    out: &mut [u128],
) -> (usize, PpStats) {
    debug_assert!(width <= 128 && width >= 2 * m, "window too narrow for the product");
    debug_assert!(m == 64 || y < (1u64 << m), "multiplier has bits above m");
    let mask: u128 = if width == 128 { u128::MAX } else { (1u128 << width) - 1 };
    let b = radix.bits_per_digit();
    let n = radix.digit_count(m) as usize;
    debug_assert!(out.len() >= n);
    let mut stats = PpStats { digits: n as u32, ..Default::default() };
    let y2 = (y as u128) << 1;
    let window_mask = (1u64 << (b + 1)) - 1;
    for (i, slot) in out.iter_mut().enumerate().take(n) {
        let lo = i as u32 * b;
        let window = ((y2 >> lo) as u64) & window_mask;
        let msb = (window >> b) & 1;
        let value = ((window >> 1) + (window & 1)) as i64 - ((1i64 << b) * msb as i64);
        if value != 0 {
            stats.nonzero_digits += 1;
        }
        if value.unsigned_abs() == 3 {
            stats.used_triple = true;
        }
        let mult = (value as i128) * (x as i128);
        *slot = ((mult as u128) << lo) & mask;
    }
    (n, stats)
}

/// Partial products of `x · y` (both `m`-bit unsigned), as two's-complement
/// words masked to `width` bits. Their sum mod 2^width equals `x·y`.
/// (Vec wrapper over [`partial_products_into`] for non-hot-path callers.)
pub fn partial_products(
    x: u64,
    y: u64,
    m: u32,
    radix: BoothRadix,
    width: u32,
) -> (Vec<u128>, PpStats) {
    assert!(width <= 128 && width >= 2 * m, "window too narrow for the product");
    let mut buf = [0u128; MAX_PPS];
    let (n, stats) = partial_products_into(x, y, m, radix, width, &mut buf);
    (buf[..n].to_vec(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digits_value(digits: &[BoothDigit]) -> i128 {
        digits.iter().map(|d| (d.value as i128) << d.shift).sum()
    }

    #[test]
    fn recode_reconstructs_value_booth2() {
        for y in [0u64, 1, 2, 3, 0xff, 0xdead_beef & 0xffffff, (1 << 24) - 1, 0x00ab_cdef] {
            let d = recode(y, 24, BoothRadix::Booth2);
            assert_eq!(digits_value(&d), y as i128, "y={y:#x}");
            assert_eq!(d.len(), 13); // ceil(25/2)
        }
    }

    #[test]
    fn recode_reconstructs_value_booth3() {
        for y in [0u64, 1, 5, (1 << 53) - 1, 0x000f_ffff_ffff_ffff, 0x0012_3456_789a_bcde & ((1 << 53) - 1)] {
            let d = recode(y, 53, BoothRadix::Booth3);
            assert_eq!(digits_value(&d), y as i128, "y={y:#x}");
            assert_eq!(d.len(), 18); // ceil(54/3)
        }
    }

    #[test]
    fn digit_ranges() {
        for y in 0..(1u64 << 12) {
            for (radix, lim) in [(BoothRadix::Booth2, 2i8), (BoothRadix::Booth3, 4i8)] {
                for d in recode(y, 12, radix) {
                    assert!(d.value >= -lim && d.value <= lim, "digit {} out of range", d.value);
                }
            }
        }
    }

    #[test]
    fn digit_counts_match_table() {
        // SP (m=24): Booth-2 → 13 PPs, Booth-3 → 9 PPs (the paper's SP FMA
        // tree is roughly 30% smaller than the SP CMA's).
        assert_eq!(BoothRadix::Booth2.digit_count(24), 13);
        assert_eq!(BoothRadix::Booth3.digit_count(24), 9);
        // DP (m=53): Booth-2 → 27, Booth-3 → 18.
        assert_eq!(BoothRadix::Booth2.digit_count(53), 27);
        assert_eq!(BoothRadix::Booth3.digit_count(53), 18);
    }

    #[test]
    fn partial_products_sum_to_product() {
        let m = 24;
        let width = 2 * m + 2;
        let mask = (1u128 << width) - 1;
        for (x, y) in [(0u64, 0u64), (1, 1), (0xffffff, 0xffffff), (0x923456, 0x654321), (1 << 23, 3)] {
            for radix in [BoothRadix::Booth2, BoothRadix::Booth3] {
                let (pps, stats) = partial_products(x, y, m, radix, width);
                let sum = pps.iter().fold(0u128, |a, &p| (a.wrapping_add(p)) & mask);
                assert_eq!(sum, (x as u128 * y as u128) & mask, "x={x:#x} y={y:#x} {radix:?}");
                assert_eq!(stats.digits, radix.digit_count(m));
            }
        }
    }

    #[test]
    fn partial_products_dp_booth3() {
        let m = 53;
        let width = 2 * m + 2;
        let mask = (1u128 << width) - 1;
        let x = (1u64 << 53) - 1;
        let y = 0x001a_5a5a_5a5a_5a5a & ((1 << 53) - 1);
        let (pps, stats) = partial_products(x, y, m, BoothRadix::Booth3, width);
        let sum = pps.iter().fold(0u128, |a, &p| (a.wrapping_add(p)) & mask);
        assert_eq!(sum, (x as u128 * y as u128) & mask);
        assert!(stats.used_triple || !pps.is_empty());
    }

    #[test]
    fn zero_multiplier_all_zero_digits() {
        let (pps, stats) = partial_products(0xabcdef, 0, 24, BoothRadix::Booth2, 50);
        assert!(pps.iter().all(|&p| p == 0));
        assert_eq!(stats.nonzero_digits, 0);
    }

    #[test]
    fn triple_usage_detection() {
        // y = 3 recodes (radix-8) to the single digit 3 → triple used.
        let (_, stats) = partial_products(5, 3, 24, BoothRadix::Booth3, 50);
        assert!(stats.used_triple);
        // y = 4 recodes to digit 4 (shiftable) → no triple.
        let (_, stats) = partial_products(5, 4, 24, BoothRadix::Booth3, 50);
        assert!(!stats.used_triple);
        // Booth-2 never uses a triple.
        let (_, stats) = partial_products(5, 3, 24, BoothRadix::Booth2, 50);
        assert!(!stats.used_triple);
    }
}
