//! The FPGen-equivalent generator: an [`FpuConfig`] — the same parameter
//! vector the paper's Table I reports per unit — is elaborated into an
//! [`FpuUnit`] whose numerics are bit-exact and whose
//! [`StructureReport`] feeds the timing and energy models.
//!
//! The four presets ([`FpuConfig::sp_fma`] etc.) are the fabricated FPMax
//! units; the DSE sweep in [`crate::dse`] explores the surrounding
//! parameter space exactly the way Fig. 3's triangle-marked curve was
//! produced.


use super::booth::BoothRadix;
use super::cma::{self, CmaStructure};
use super::fma::{self, FmaActivity, FmaStructure};
use super::fp::{Format, Precision};
use super::multiplier::MultiplierConfig;
use super::rounding::{RoundMode, Rounded};
use super::tree::TreeKind;

/// FMAC organization: fused (one rounding) or cascade (two roundings,
/// short accumulation path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpuKind {
    Fma,
    Cma,
}

impl FpuKind {
    pub fn name(self) -> &'static str {
        match self {
            FpuKind::Fma => "FMA",
            FpuKind::Cma => "CMA",
        }
    }
}

/// The generator's full parameter vector (one row of Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FpuConfig {
    pub precision: Precision,
    pub kind: FpuKind,
    pub booth: BoothRadix,
    pub tree: TreeKind,
    /// Total pipeline stages (issue → writeback).
    pub stages: u32,
    /// Multiplier pipeline depth (stages before the add/merge).
    pub mul_pipe: u32,
    /// Adder pipeline depth (CMA only; the FMA merge is folded into the
    /// post-multiplier stages).
    pub add_pipe: u32,
    /// Internal before-rounding forwarding (Fig. 2's bypasses).
    pub forwarding: bool,
}

impl FpuConfig {
    /// Table I, column "DP CMA": 5 stages, mul 2 + add 2 (+1 round),
    /// Booth-3, Wallace.
    pub fn dp_cma() -> FpuConfig {
        FpuConfig {
            precision: Precision::Double,
            kind: FpuKind::Cma,
            booth: BoothRadix::Booth3,
            tree: TreeKind::Wallace,
            stages: 5,
            mul_pipe: 2,
            add_pipe: 2,
            forwarding: true,
        }
    }

    /// Table I, column "DP FMA": 6 stages, mul 2, Booth-3, array.
    pub fn dp_fma() -> FpuConfig {
        FpuConfig {
            precision: Precision::Double,
            kind: FpuKind::Fma,
            booth: BoothRadix::Booth3,
            tree: TreeKind::Array,
            stages: 6,
            mul_pipe: 2,
            add_pipe: 0,
            forwarding: true,
        }
    }

    /// Table I, column "SP CMA": 6 stages (deeper, faster clock), mul 3 +
    /// add 2 (+1 round), Booth-2 (short cycle forbids the ×3 pre-add),
    /// Wallace.
    pub fn sp_cma() -> FpuConfig {
        FpuConfig {
            precision: Precision::Single,
            kind: FpuKind::Cma,
            booth: BoothRadix::Booth2,
            tree: TreeKind::Wallace,
            stages: 6,
            mul_pipe: 3,
            add_pipe: 2,
            forwarding: true,
        }
    }

    /// Table I, column "SP FMA": 4 stages, mul 2, Booth-3, ZM tree.
    pub fn sp_fma() -> FpuConfig {
        FpuConfig {
            precision: Precision::Single,
            kind: FpuKind::Fma,
            booth: BoothRadix::Booth3,
            tree: TreeKind::Zm,
            stages: 4,
            mul_pipe: 2,
            add_pipe: 0,
            forwarding: true,
        }
    }

    /// The four fabricated units in Table I order.
    pub fn fpmax_units() -> [FpuConfig; 4] {
        [Self::dp_cma(), Self::dp_fma(), Self::sp_cma(), Self::sp_fma()]
    }

    /// Transprecision FMA preset for the small formats (FP16 / BF16 /
    /// FP8): a shallow 3-stage fused pipe (mul 1 + merge + round),
    /// Booth-2 + Wallace — the short significands (≤ 11 bits) neither
    /// need deeper multiplier cuts nor amortize the ×3 pre-adder,
    /// mirroring FPnew's small-format slices.
    pub fn small_fma(precision: Precision) -> FpuConfig {
        FpuConfig {
            precision,
            kind: FpuKind::Fma,
            booth: BoothRadix::Booth2,
            tree: TreeKind::Wallace,
            stages: 3,
            mul_pipe: 1,
            add_pipe: 0,
            forwarding: true,
        }
    }

    /// Transprecision CMA preset (mul 1 + add 1 + round).
    pub fn small_cma(precision: Precision) -> FpuConfig {
        FpuConfig {
            precision,
            kind: FpuKind::Cma,
            booth: BoothRadix::Booth2,
            tree: TreeKind::Wallace,
            stages: 3,
            mul_pipe: 1,
            add_pipe: 1,
            forwarding: true,
        }
    }

    /// The FMA-kind preset for any precision: the Table I unit for
    /// SP/DP, the transprecision preset otherwise.
    pub fn fma_of(precision: Precision) -> FpuConfig {
        match precision {
            Precision::Single => Self::sp_fma(),
            Precision::Double => Self::dp_fma(),
            _ => Self::small_fma(precision),
        }
    }

    /// The CMA-kind preset for any precision (see [`FpuConfig::fma_of`]).
    pub fn cma_of(precision: Precision) -> FpuConfig {
        match precision {
            Precision::Single => Self::sp_cma(),
            Precision::Double => Self::dp_cma(),
            _ => Self::small_cma(precision),
        }
    }

    /// Unit name as in Table I ("SP FMA" etc.).
    pub fn name(&self) -> String {
        format!("{} {}", self.precision.name().to_uppercase(), self.kind.name())
    }

    /// The multiplier slice of this configuration.
    pub fn multiplier(&self) -> MultiplierConfig {
        MultiplierConfig {
            sig_bits: self.precision.format().sig_bits,
            booth: self.booth,
            tree: self.tree,
        }
    }

    /// Basic well-formedness: pipe depths must fit in the stage budget.
    pub fn validate(&self) -> crate::Result<()> {
        let min = match self.kind {
            // mul + merge/add + round, at least one stage each.
            FpuKind::Fma => self.mul_pipe + 2,
            FpuKind::Cma => self.mul_pipe + self.add_pipe + 1,
        };
        if self.stages < min {
            anyhow::bail!("{}: {} stages < minimum {min} for its organization", self.name(), self.stages);
        }
        if self.mul_pipe == 0 || (self.kind == FpuKind::Cma && self.add_pipe == 0) {
            anyhow::bail!("{}: zero-depth functional block", self.name());
        }
        Ok(())
    }
}

/// Structural summary the timing/energy models consume — every number is
/// derived from the config, never free-floating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StructureReport {
    /// Significand width m.
    pub sig_bits: u32,
    /// Booth partial products.
    pub pp_count: u32,
    /// Whether a ×3 pre-adder exists.
    pub has_triple_adder: bool,
    /// Reduction-tree depth in 3:2 levels.
    pub tree_levels: u32,
    /// Total 3:2 cells in the tree: (n−2)·window (topology-independent).
    pub tree_cells: u64,
    /// Multiplier window width (2m+2).
    pub mul_window: u32,
    /// Significand-add datapath width (3m+5 for FMA merge, m+4 for CMA).
    pub adder_width: u32,
    /// LZA/normalizer scan width.
    pub lza_width: u32,
    /// Rounder count (FMA 1, CMA 2).
    pub rounders: u32,
    /// Total pipeline registers (bits), estimated per cut datapath width.
    pub register_bits: u64,
    /// Pipeline stages.
    pub stages: u32,
    /// Wiring irregularity factor of the tree.
    pub wiring_factor: f64,
}

/// A generated FPU instance.
#[derive(Debug, Clone)]
pub struct FpuUnit {
    pub config: FpuConfig,
    pub format: Format,
    mul_cfg: MultiplierConfig,
    structure: StructureReport,
}

impl FpuUnit {
    /// Elaborate a configuration — FPGen's "generate" step.
    pub fn generate(config: &FpuConfig) -> FpuUnit {
        let format = config.precision.format();
        let mul_cfg = config.multiplier();
        let m = format.sig_bits;
        let n = mul_cfg.pp_count();
        let window = mul_cfg.window();
        let (adder_width, lza_width, rounders) = match config.kind {
            FpuKind::Fma => {
                let s = FmaStructure::derive(&mul_cfg);
                (s.adder_width, s.lza_width, 1)
            }
            FpuKind::Cma => {
                let s = CmaStructure::derive(&mul_cfg);
                (s.adder_width, m + 4, s.rounders)
            }
        };
        // Pipeline registers: each stage cut latches roughly the live
        // datapath width at that point. Multiplier cuts hold the
        // carry-save pair (2·window); add/normalize cuts hold the adder
        // width; the final cut holds the packed result.
        let mul_cut_bits = 2 * window as u64;
        let add_cut_bits = adder_width as u64;
        let cuts_mul = config.mul_pipe as u64;
        let cuts_rest = (config.stages - config.mul_pipe) as u64;
        let register_bits =
            cuts_mul * mul_cut_bits + cuts_rest * add_cut_bits + format.width() as u64;
        let structure = StructureReport {
            sig_bits: m,
            pp_count: n,
            has_triple_adder: mul_cfg.booth.needs_triple(),
            tree_levels: mul_cfg.tree_depth(),
            tree_cells: (n.saturating_sub(2) as u64) * window as u64,
            mul_window: window,
            adder_width,
            lza_width,
            rounders,
            register_bits,
            stages: config.stages,
            wiring_factor: config.tree.wiring_factor(),
        };
        FpuUnit { config: *config, format, mul_cfg, structure }
    }

    /// The structural report (static; independent of operands).
    pub fn structure(&self) -> &StructureReport {
        &self.structure
    }

    /// The multiplier configuration in use.
    pub fn multiplier_config(&self) -> &MultiplierConfig {
        &self.mul_cfg
    }

    /// Execute one FMAC (`a·b + c`) in round-to-nearest-even — the
    /// verification hot path: activity tracking is compiled out.
    #[inline]
    pub fn fmac(&self, a: u64, b: u64, c: u64) -> Rounded {
        match self.config.kind {
            FpuKind::Fma => {
                fma::fmac_t::<false>(self.format, &self.mul_cfg, RoundMode::NearestEven, a, b, c).0
            }
            FpuKind::Cma => {
                cma::fmac_t::<false>(self.format, &self.mul_cfg, RoundMode::NearestEven, a, b, c)
                    .0
                    .result
            }
        }
    }

    /// Execute one FMAC in an explicit rounding mode, with activity.
    pub fn fmac_mode(&self, mode: RoundMode, a: u64, b: u64, c: u64) -> (Rounded, FmaActivity) {
        match self.config.kind {
            FpuKind::Fma => fma::fmac(self.format, &self.mul_cfg, mode, a, b, c),
            FpuKind::Cma => {
                let (r, act) = cma::fmac(self.format, &self.mul_cfg, mode, a, b, c);
                (r.result, act)
            }
        }
    }

    // ---- Latency taps for the pipeline simulator (in cycles) ----------
    //
    // Fig. 2(a,b): a producer issued at cycle 0 writes back at `stages`;
    // consumers can enter earlier through the bypass network.

    /// Full (rounded, written-back) result latency.
    pub fn latency_full(&self) -> u32 {
        self.config.stages
    }

    /// Earliest issue-to-issue distance when the consumer uses the result
    /// as its **addend/accumulator** input.
    pub fn latency_to_add_input(&self) -> u32 {
        match (self.config.kind, self.config.forwarding) {
            // CMA bypass: unrounded sum at stage mul+add feeds the adder
            // input (stage mul+1) of the dependent op → distance add_pipe.
            (FpuKind::Cma, true) => self.config.add_pipe,
            // FMA bypass: unrounded result one stage early, consumed at
            // issue (the merge happens after the multiply, but the operand
            // enters the alignment at issue).
            (FpuKind::Fma, true) => self.config.stages - 1,
            _ => self.config.stages,
        }
    }

    /// Earliest issue-to-issue distance when the consumer uses the result
    /// as a **multiplier** input.
    pub fn latency_to_mul_input(&self) -> u32 {
        match (self.config.kind, self.config.forwarding) {
            // CMA bypass to the multiplier input: unrounded sum at stage
            // mul+add feeds stage 1 → distance mul+add.
            (FpuKind::Cma, true) => self.config.mul_pipe + self.config.add_pipe,
            (FpuKind::Fma, true) => self.config.stages - 1,
            _ => self.config.stages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        let dp_cma = FpuConfig::dp_cma();
        assert_eq!(dp_cma.stages, 5);
        assert_eq!(dp_cma.mul_pipe, 2);
        assert_eq!(dp_cma.add_pipe, 2);
        assert_eq!(dp_cma.booth, BoothRadix::Booth3);
        assert_eq!(dp_cma.tree, TreeKind::Wallace);
        assert_eq!(dp_cma.name(), "DP CMA");

        let sp_cma = FpuConfig::sp_cma();
        assert_eq!(sp_cma.stages, 6);
        assert_eq!(sp_cma.mul_pipe, 3);
        assert_eq!(sp_cma.booth, BoothRadix::Booth2);

        let sp_fma = FpuConfig::sp_fma();
        assert_eq!(sp_fma.stages, 4);
        assert_eq!(sp_fma.tree, TreeKind::Zm);
        assert_eq!(sp_fma.name(), "SP FMA");

        let dp_fma = FpuConfig::dp_fma();
        assert_eq!(dp_fma.stages, 6);
        assert_eq!(dp_fma.tree, TreeKind::Array);

        for cfg in FpuConfig::fpmax_units() {
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn small_format_presets_validate_and_compute() {
        use super::super::rounding::RoundMode;
        use super::super::softfloat;
        use crate::util::Rng;
        for p in [
            Precision::Half,
            Precision::Bfloat16,
            Precision::Fp8E4M3,
            Precision::Fp8E5M2,
        ] {
            let fma_cfg = FpuConfig::fma_of(p);
            let cma_cfg = FpuConfig::cma_of(p);
            fma_cfg.validate().unwrap();
            cma_cfg.validate().unwrap();
            assert_eq!(fma_cfg.precision, p);
            assert_eq!(
                fma_cfg.name(),
                format!("{} FMA", p.name().to_uppercase())
            );
            // Gate units of both kinds match the softfloat spec on raw
            // uniform patterns (specials included at natural rates).
            let fma_unit = FpuUnit::generate(&fma_cfg);
            let cma_unit = FpuUnit::generate(&cma_cfg);
            let fmt = p.format();
            assert_eq!(fma_unit.format, fmt);
            let mut rng = Rng::new(0x5ca1e ^ fmt.sig_bits as u64);
            for _ in 0..500 {
                let a = rng.next_u64() & fmt.storage_mask();
                let b = rng.next_u64() & fmt.storage_mask();
                let c = rng.next_u64() & fmt.storage_mask();
                assert_eq!(
                    fma_unit.fmac(a, b, c).bits,
                    softfloat::fma(fmt, RoundMode::NearestEven, a, b, c).bits,
                    "{} fmac({a:#x},{b:#x},{c:#x})",
                    fma_cfg.name()
                );
                let pr = softfloat::mul(fmt, RoundMode::NearestEven, a, b);
                assert_eq!(
                    cma_unit.fmac(a, b, c).bits,
                    softfloat::add(fmt, RoundMode::NearestEven, pr.bits, c).bits,
                    "{} fmac({a:#x},{b:#x},{c:#x})",
                    cma_cfg.name()
                );
            }
        }
        // SP/DP routing through the *_of helpers stays on Table I.
        assert_eq!(FpuConfig::fma_of(Precision::Single), FpuConfig::sp_fma());
        assert_eq!(FpuConfig::cma_of(Precision::Double), FpuConfig::dp_cma());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut bad = FpuConfig::sp_fma();
        bad.stages = 2; // less than mul_pipe + 2
        assert!(bad.validate().is_err());
        let mut bad = FpuConfig::dp_cma();
        bad.add_pipe = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn all_units_compute_their_ieee_semantics() {
        // FMA units: fused semantics; CMA units: cascade semantics.
        let triples = [
            (1.5f32, 2.0f32, 0.25f32),
            (0.1, 10.0, -1.0),
            (1.0 + 2f32.powi(-12), 1.0 + 2f32.powi(-12), -(1.0 + 2f32.powi(-11))),
        ];
        let sp_fma = FpuUnit::generate(&FpuConfig::sp_fma());
        let sp_cma = FpuUnit::generate(&FpuConfig::sp_cma());
        for &(a, b, c) in &triples {
            let fused = f32::from_bits(
                sp_fma.fmac(a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64).bits as u32,
            );
            assert_eq!(fused, a.mul_add(b, c));
            let casc = f32::from_bits(
                sp_cma.fmac(a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64).bits as u32,
            );
            assert_eq!(casc, a * b + c);
        }
    }

    #[test]
    fn latency_taps_match_fig2() {
        // DP CMA (Fig. 2(a)): accumulate distance 2, multiply distance 4,
        // full 5.
        let u = FpuUnit::generate(&FpuConfig::dp_cma());
        assert_eq!(u.latency_full(), 5);
        assert_eq!(u.latency_to_add_input(), 2);
        assert_eq!(u.latency_to_mul_input(), 4);
        // The comparison FMAs of Fig. 2(c): 5-cycle FMA w/ fwd → 4; w/o → 5.
        let mut fma5 = FpuConfig::dp_fma();
        fma5.stages = 5;
        let u = FpuUnit::generate(&fma5);
        assert_eq!(u.latency_to_add_input(), 4);
        assert_eq!(u.latency_to_mul_input(), 4);
        let mut fma5_nofwd = fma5;
        fma5_nofwd.forwarding = false;
        let u = FpuUnit::generate(&fma5_nofwd);
        assert_eq!(u.latency_to_add_input(), 5);
    }

    #[test]
    fn structure_report_consistency() {
        for cfg in FpuConfig::fpmax_units() {
            let u = FpuUnit::generate(&cfg);
            let s = u.structure();
            assert_eq!(s.stages, cfg.stages);
            assert_eq!(s.pp_count, cfg.booth.digit_count(s.sig_bits));
            assert_eq!(s.has_triple_adder, cfg.booth.needs_triple());
            assert!(s.register_bits > 0);
            match cfg.kind {
                FpuKind::Fma => {
                    assert_eq!(s.rounders, 1);
                    assert_eq!(s.adder_width, 3 * s.sig_bits + 5);
                }
                FpuKind::Cma => {
                    assert_eq!(s.rounders, 2);
                    assert_eq!(s.adder_width, s.sig_bits + 4);
                }
            }
        }
    }

    #[test]
    fn fma_structure_smaller_registers_sp() {
        // The SP FMA is the smallest unit in Table I (0.0081 mm² vs 0.018
        // for SP CMA): fewer stages and fewer PPs ⇒ fewer register bits
        // and tree cells.
        let fma = FpuUnit::generate(&FpuConfig::sp_fma());
        let cma = FpuUnit::generate(&FpuConfig::sp_cma());
        assert!(fma.structure().tree_cells < cma.structure().tree_cells);
        assert!(fma.structure().register_bits < cma.structure().register_bits);
    }
}
