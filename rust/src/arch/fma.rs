//! The fused multiply-add (FMA) datapath: `round(a·b + c)` with one
//! rounding, the architecture of the paper's two throughput units
//! (Fig. 1(a)).
//!
//! Structure (per Fig. 1(a), Lang/Bruguera-style):
//!
//! 1. multiplier (Booth + tree) leaves `a·b` in carry-save form;
//! 2. the addend `c` is aligned against the product into a `3m+5`-bit
//!    window (far-out addends collapse into a sticky bit);
//! 3. a 3:2 row merges `c` with the product's sum/carry pair;
//! 4. the wide CPA + LZA + normalizer produce the exact magnitude;
//! 5. one shared rounder packs the result.
//!
//! The multiplier is simulated gate-level (every 3:2 row evaluated); the
//! align/add/normalize path is simulated word-level with exact sticky
//! semantics — numerically indistinguishable from the silicon, while the
//! per-structure costs (alignment shifter span, adder width, LZA width)
//! are reported to the timing/energy models through [`FmaStructure`].

use super::fp::{decode, Class, Decoded, Format};
use super::multiplier::{multiply_t, MultiplierConfig};
use super::rounding::{RoundMode, Rounded};
use super::softfloat::{self, add_exact, Exact};

/// Static structural parameters of an FMA datapath, derived from the
/// format and multiplier config. All widths in bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FmaStructure {
    /// Significand bits m.
    pub sig_bits: u32,
    /// Multiplier window (2m+2).
    pub mul_window: u32,
    /// Alignment window for the addend (3m+5): c can sit up to m+2 bits
    /// above the product and collapses to sticky beyond 2m+3 below.
    pub align_window: u32,
    /// Width of the final carry-propagate adder.
    pub adder_width: u32,
    /// Width the leading-zero anticipator scans.
    pub lza_width: u32,
    /// Partial products entering the tree.
    pub pp_count: u32,
    /// Tree depth in 3:2 levels.
    pub tree_levels: u32,
}

impl FmaStructure {
    /// Derive the structure from a multiplier configuration.
    pub fn derive(mul: &MultiplierConfig) -> FmaStructure {
        let m = mul.sig_bits;
        FmaStructure {
            sig_bits: m,
            mul_window: mul.window(),
            align_window: 3 * m + 5,
            adder_width: 3 * m + 5,
            lza_width: 3 * m + 5,
            pp_count: mul.pp_count(),
            tree_levels: mul.tree_depth(),
        }
    }
}

/// Per-operation activity record: what actually toggled for this operand
/// triple. The energy model integrates these into joules.
#[derive(Debug, Clone, Copy, Default)]
pub struct FmaActivity {
    /// Booth digits that were nonzero.
    pub nonzero_digits: u32,
    /// Total Booth digits.
    pub digits: u32,
    /// Tree full-adder evaluations.
    pub tree_fa_ops: u64,
    /// Tree output toggle weight (popcount proxy).
    pub tree_toggles: u64,
    /// Alignment shift distance actually exercised.
    pub align_shift: u32,
    /// Normalization (cancellation) shift distance.
    pub norm_shift: u32,
    /// Whether the op took the special/early-out path (no datapath
    /// activity beyond decode).
    pub special: bool,
}

/// One fused multiply-add through the structural datapath.
///
/// Returns the IEEE result (bit-identical to [`softfloat::fma`], which is
/// asserted in debug builds) plus the activity record.
pub fn fmac(
    fmt: Format,
    mul: &MultiplierConfig,
    mode: RoundMode,
    a_bits: u64,
    b_bits: u64,
    c_bits: u64,
) -> (Rounded, FmaActivity) {
    fmac_t::<true>(fmt, mul, mode, a_bits, b_bits, c_bits)
}

/// Fused datapath generic over activity tracking (`TRACK = false` is the
/// verification hot path: no toggle counts, no shift-distance records).
#[inline(always)]
pub fn fmac_t<const TRACK: bool>(
    fmt: Format,
    mul: &MultiplierConfig,
    mode: RoundMode,
    a_bits: u64,
    b_bits: u64,
    c_bits: u64,
) -> (Rounded, FmaActivity) {
    debug_assert_eq!(fmt.sig_bits, mul.sig_bits, "format/multiplier width mismatch");
    let a = decode(fmt, a_bits);
    let b = decode(fmt, b_bits);
    let c = decode(fmt, c_bits);

    // Specials and zero products bypass the datapath (the chip gates the
    // multiplier clock in these cases — `special` tells the energy model).
    if a.non_finite() || b.non_finite() || c.non_finite() || a.is_zero() || b.is_zero() {
        let r = softfloat::fma(fmt, mode, a_bits, b_bits, c_bits);
        return (r, FmaActivity { special: true, ..Default::default() });
    }

    let mut act = FmaActivity::default();

    // 1-2. Structural multiplier: a·b in carry-save form.
    let mr = multiply_t::<TRACK>(mul, a.sig, b.sig);
    if TRACK {
        act.digits = mr.pp_stats.digits;
        act.nonzero_digits = mr.pp_stats.nonzero_digits;
        act.tree_fa_ops = mr.tree_stats.fa_ops;
        act.tree_toggles = mr.tree_stats.toggles;
    }

    // 3-4. Resolve and merge the addend with exact sticky semantics.
    let product = Exact {
        sign: a.sign ^ b.sign,
        exp: a.exp + b.exp,
        sig: mr.product(mul),
        sticky: false,
    };
    let addend = exact_of(&c);

    // Record the alignment distance the shifter would traverse (clamped to
    // the window, as the barrel shifter is).
    if TRACK && c.sig != 0 && product.sig != 0 {
        let structure = FmaStructure::derive(mul);
        let d = addend.npos() - product.npos();
        act.align_shift = d.unsigned_abs().min(structure.align_window);
    }

    let sum = if c.is_zero() {
        // c = ±0: the product alone (sign rules live in add_exact when the
        // product is also zero, but a zero product already early-outed).
        product
    } else {
        add_exact(product, addend, mode)
    };

    // Normalization distance: how far the leading bit fell vs. the wider
    // of the two inputs (cancellation depth) — drives LZA/normalizer
    // energy.
    if TRACK && sum.sig != 0 {
        let in_npos = product.npos().max(addend.npos());
        act.norm_shift = (in_npos - sum.npos()).max(0) as u32;
    }

    // 5. Single rounding.
    let r = softfloat::round(fmt, mode, sum);
    debug_assert_eq!(
        r.bits,
        softfloat::fma(fmt, mode, a_bits, b_bits, c_bits).bits,
        "FMA datapath diverged from softfloat: a={a_bits:#x} b={b_bits:#x} c={c_bits:#x}"
    );
    (r, act)
}

fn exact_of(d: &Decoded) -> Exact {
    debug_assert!(matches!(d.class, Class::Zero | Class::Subnormal | Class::Normal));
    Exact::from_decoded(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::booth::BoothRadix;
    use crate::arch::tree::TreeKind;

    fn sp_cfg() -> MultiplierConfig {
        MultiplierConfig { sig_bits: 24, booth: BoothRadix::Booth3, tree: TreeKind::Zm }
    }

    fn dp_cfg() -> MultiplierConfig {
        MultiplierConfig { sig_bits: 53, booth: BoothRadix::Booth3, tree: TreeKind::Array }
    }

    #[test]
    fn matches_hardware_fma_sp() {
        let cfg = sp_cfg();
        let vals = [0.0f32, -0.0, 1.0, -1.5, 3.14159, f32::MIN_POSITIVE, 2f32.powi(-140),
                    f32::MAX, f32::INFINITY, f32::NAN, 1e-20, -2.5e10];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let (r, _) = fmac(
                        Format::SP, &cfg, RoundMode::NearestEven,
                        a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64,
                    );
                    let got = f32::from_bits(r.bits as u32);
                    let want = a.mul_add(b, c);
                    assert!(
                        (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                        "fma({a:e},{b:e},{c:e}) = {got:e} want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_hardware_fma_dp() {
        let cfg = dp_cfg();
        let vals = [0.0f64, 1.0, -1.0 - f64::EPSILON, 1e300, 1e-300, 2f64.powi(-1074),
                    f64::MAX, -f64::MAX, 0.1, 7.0];
        for &a in &vals {
            for &b in &vals {
                for &c in &vals {
                    let (r, _) = fmac(
                        Format::DP, &cfg, RoundMode::NearestEven,
                        a.to_bits(), b.to_bits(), c.to_bits(),
                    );
                    let got = f64::from_bits(r.bits);
                    let want = a.mul_add(b, c);
                    assert!(
                        (got.is_nan() && want.is_nan()) || got.to_bits() == want.to_bits(),
                        "fma({a:e},{b:e},{c:e}) = {got:e} want {want:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_rounding_modes_agree_with_softfloat() {
        let cfg = sp_cfg();
        let triples = [(1.1f32, 2.3f32, -2.52f32), (1e-30, 1e-30, 1e10), (3.0, 1.0 / 3.0, -1.0)];
        for mode in RoundMode::ALL {
            for &(a, b, c) in &triples {
                let (r, _) = fmac(Format::SP, &cfg, mode,
                                  a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64);
                let want = softfloat::fma(Format::SP, mode,
                                          a.to_bits() as u64, b.to_bits() as u64, c.to_bits() as u64);
                assert_eq!(r.bits, want.bits, "mode {mode:?} ({a},{b},{c})");
                assert_eq!(r.flags, want.flags);
            }
        }
    }

    #[test]
    fn activity_reflects_dataflow() {
        let cfg = sp_cfg();
        // A special op does no datapath work.
        let (_, act) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                            f32::NAN.to_bits() as u64, 1, 1);
        assert!(act.special);
        assert_eq!(act.tree_fa_ops, 0);
        // A zero multiplicand early-outs too (clock gating).
        let (_, act) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                            0, 0x3f80_0000, 0x3f80_0000);
        assert!(act.special);
        // Dense operands exercise the tree.
        let (_, act) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                            0x3fff_ffff, 0x3faa_aaaa, 0x3f80_0000);
        assert!(!act.special);
        assert!(act.tree_fa_ops > 0 && act.tree_toggles > 0);
        assert_eq!(act.digits, 9);
    }

    #[test]
    fn cancellation_records_norm_shift() {
        let cfg = sp_cfg();
        // 1·1 + (-(1+ε)) cancels ~23 bits.
        let a = 1.0f32;
        let c = -(1.0f32 + f32::EPSILON);
        let (r, act) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                            a.to_bits() as u64, a.to_bits() as u64, c.to_bits() as u64);
        assert_eq!(f32::from_bits(r.bits as u32), -f32::EPSILON);
        assert!(act.norm_shift >= 20, "norm_shift = {}", act.norm_shift);
    }

    #[test]
    fn far_addend_records_large_align() {
        let cfg = sp_cfg();
        let (_, act) = fmac(Format::SP, &cfg, RoundMode::NearestEven,
                            1.0f32.to_bits() as u64, 1.0f32.to_bits() as u64,
                            2f32.powi(40).to_bits() as u64);
        assert!(act.align_shift >= 30, "align_shift = {}", act.align_shift);
    }

    #[test]
    fn structure_derivation() {
        let s = FmaStructure::derive(&sp_cfg());
        assert_eq!(s.align_window, 77);
        assert_eq!(s.adder_width, 77);
        assert_eq!(s.pp_count, 9);
        let s = FmaStructure::derive(&dp_cfg());
        assert_eq!(s.align_window, 164);
        assert_eq!(s.pp_count, 18);
        assert_eq!(s.tree_levels, 16);
    }
}
