//! Partial-product reduction structures: Wallace tree, linear array, and
//! the ZM (Zuras–McAllister) higher-order array.
//!
//! Table I of the paper assigns a different combiner to each FPU:
//!
//! * **Wallace** (both CMAs) — minimum logic depth, O(log n) 3:2 levels;
//!   fastest, but irregular wiring costs area. Latency designs take it.
//! * **Array** (DP FMA) — a linear chain of 3:2 rows; O(n) depth but
//!   perfectly regular, dense, and low-energy per op when the clock
//!   period is set by throughput pipelining anyway.
//! * **ZM** (SP FMA) — Zuras & McAllister's "higher-order array"
//!   (JSSC 1986): partial products are grouped into chains whose partial
//!   sums feed a second-level chain, giving O(√n) depth with array-like
//!   regularity. The paper calls it a "modified array"; it is the sweet
//!   spot the SP FMA's 4-stage pipe needs.
//!
//! All three reduce a PP vector to one [`CarrySave`] pair and report the
//! same [`CsaStats`], so the generator can swap them freely and the
//! timing/energy models see honest structural numbers.


use super::csa::{csa32_t, CarrySave, CsaStats};

/// Reduction-tree topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TreeKind {
    /// Logarithmic-depth Wallace tree of 3:2 compressors.
    Wallace,
    /// Linear array: one 3:2 row per partial product.
    Array,
    /// Zuras–McAllister higher-order (order-2) array: √n chains of √n.
    Zm,
}

impl TreeKind {
    /// Name as printed in the paper's Table I.
    pub fn name(self) -> &'static str {
        match self {
            TreeKind::Wallace => "Wallace",
            TreeKind::Array => "Array",
            TreeKind::Zm => "ZM",
        }
    }

    /// Reduce `pps` (two's-complement words in a `width`-bit window) to a
    /// carry-save pair whose resolved value is Σpps mod 2^width.
    pub fn reduce(self, pps: &[u128], width: u32, stats: &mut CsaStats) -> CarrySave {
        self.reduce_t::<true>(pps, width, stats)
    }

    /// Reduction generic over stat tracking (see [`csa32_t`]): the
    /// verification hot path uses `TRACK = false`.
    #[inline(always)]
    pub fn reduce_t<const TRACK: bool>(
        self,
        pps: &[u128],
        width: u32,
        stats: &mut CsaStats,
    ) -> CarrySave {
        match self {
            TreeKind::Wallace => reduce_wallace::<TRACK>(pps, width, stats),
            TreeKind::Array => reduce_array::<TRACK>(pps, width, stats),
            TreeKind::Zm => reduce_zm::<TRACK>(pps, width, stats),
        }
    }

    /// Critical-path depth in 3:2-compressor levels for `n` partial
    /// products — the number the timing model converts to FO4.
    pub fn depth_levels(self, n: u32) -> u32 {
        match self {
            TreeKind::Wallace => wallace_levels(n),
            TreeKind::Array => n.saturating_sub(2),
            TreeKind::Zm => {
                if n <= 2 {
                    0
                } else {
                    let b = zm_block_size(n);
                    let nblocks = n.div_ceil(b);
                    // Per-block chain depth + second-level chain over 2
                    // outputs per block.
                    (b.saturating_sub(2)) + (2 * nblocks).saturating_sub(2)
                }
            }
        }
    }

    /// Relative wiring-irregularity factor (dimensionless; 1.0 = perfectly
    /// regular array). The energy/area models scale interconnect
    /// capacitance by this — Wallace pays for its speed in wires, which is
    /// precisely why the throughput designs avoid it (paper §FPU
    /// Architectures).
    pub fn wiring_factor(self) -> f64 {
        match self {
            TreeKind::Wallace => 1.35,
            TreeKind::Array => 1.0,
            TreeKind::Zm => 1.08,
        }
    }
}

/// Scratch capacity for allocation-free reduction: a Wallace level never
/// grows its operand count, and no supported config exceeds
/// [`crate::arch::booth::MAX_PPS`] partial products.
const SCRATCH: usize = crate::arch::booth::MAX_PPS + 4;

/// Wallace reduction: at each level, group the live operands into triples
/// through 3:2 compressors (leftovers pass through) until two remain.
/// Allocation-free: ping-pongs between two stack buffers (hot path).
fn reduce_wallace<const TRACK: bool>(pps: &[u128], width: u32, stats: &mut CsaStats) -> CarrySave {
    if pps.is_empty() {
        return CarrySave::ZERO;
    }
    debug_assert!(pps.len() <= SCRATCH);
    let mut buf_a = [0u128; SCRATCH];
    let mut buf_b = [0u128; SCRATCH];
    buf_a[..pps.len()].copy_from_slice(pps);
    let mut n = pps.len();
    let (mut cur, mut next) = (&mut buf_a, &mut buf_b);
    while n > 2 {
        let mut level = CsaStats::default();
        let mut out = 0;
        let mut i = 0;
        while i + 3 <= n {
            let mut one = CsaStats::default();
            let cs = csa32_t::<TRACK>(cur[i], cur[i + 1], cur[i + 2], width, &mut one);
            level.join_parallel(one);
            next[out] = cs.sum;
            next[out + 1] = cs.carry;
            out += 2;
            i += 3;
        }
        while i < n {
            next[out] = cur[i];
            out += 1;
            i += 1;
        }
        stats.chain(level);
        n = out;
        std::mem::swap(&mut cur, &mut next);
    }
    match n {
        2 => CarrySave { sum: cur[0], carry: cur[1] },
        1 => CarrySave { sum: cur[0], carry: 0 },
        _ => CarrySave::ZERO,
    }
}

/// Array reduction: a linear chain — each row folds one more PP into the
/// running carry-save pair.
fn reduce_array<const TRACK: bool>(pps: &[u128], width: u32, stats: &mut CsaStats) -> CarrySave {
    match pps.len() {
        0 => CarrySave::ZERO,
        1 => CarrySave { sum: pps[0], carry: 0 },
        _ => {
            let mut cs = CarrySave { sum: pps[0], carry: pps[1] };
            for &pp in &pps[2..] {
                cs = csa32_t::<TRACK>(cs.sum, cs.carry, pp, width, stats);
            }
            cs
        }
    }
}

/// Block size for the ZM order-2 array: ⌈√n⌉.
fn zm_block_size(n: u32) -> u32 {
    (n as f64).sqrt().ceil() as u32
}

/// ZM reduction: split PPs into ⌈√n⌉-sized blocks, reduce each block with
/// a linear chain (in parallel), then chain the block outputs linearly.
/// Allocation-free (hot path).
fn reduce_zm<const TRACK: bool>(pps: &[u128], width: u32, stats: &mut CsaStats) -> CarrySave {
    let n = pps.len() as u32;
    if n <= 3 {
        return reduce_array::<TRACK>(pps, width, stats);
    }
    let b = zm_block_size(n) as usize;
    let mut block_outs = [0u128; SCRATCH];
    let mut outs = 0;
    let mut blocks_stats = CsaStats::default();
    for block in pps.chunks(b) {
        let mut one = CsaStats::default();
        let cs = reduce_array::<TRACK>(block, width, &mut one);
        blocks_stats.join_parallel(one);
        block_outs[outs] = cs.sum;
        outs += 1;
        if cs.carry != 0 || block.len() > 1 {
            block_outs[outs] = cs.carry;
            outs += 1;
        }
    }
    stats.chain(blocks_stats);
    // Second-level linear combine of the block outputs.
    reduce_array::<TRACK>(&block_outs[..outs], width, stats)
}

/// Wallace-tree level count for `n` operands (Dadda sequence).
pub fn wallace_levels(n: u32) -> u32 {
    let mut levels = 0;
    let mut k = n;
    while k > 2 {
        // Each level maps groups of 3 to 2: k → 2⌊k/3⌋ + k mod 3.
        k = 2 * (k / 3) + k % 3;
        levels += 1;
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::csa::mask;

    fn check_reduce(kind: TreeKind, pps: &[u128], width: u32) {
        let want = pps.iter().fold(0u128, |a, &p| a.wrapping_add(p)) & mask(width);
        let mut stats = CsaStats::default();
        let cs = kind.reduce(pps, width, &mut stats);
        assert_eq!(cs.resolve(width), want, "{kind:?} over {} pps", pps.len());
        if pps.len() > 2 {
            assert!(stats.fa_ops > 0);
        }
    }

    #[test]
    fn all_kinds_preserve_sums() {
        let pps: Vec<u128> = (0..13u128).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) & mask(50)).collect();
        for kind in [TreeKind::Wallace, TreeKind::Array, TreeKind::Zm] {
            for n in 0..pps.len() {
                check_reduce(kind, &pps[..n], 50);
            }
        }
    }

    #[test]
    fn wallace_levels_sequence() {
        // Known Wallace/Dadda level counts.
        assert_eq!(wallace_levels(2), 0);
        assert_eq!(wallace_levels(3), 1);
        assert_eq!(wallace_levels(4), 2);
        assert_eq!(wallace_levels(6), 3);
        assert_eq!(wallace_levels(9), 4);
        assert_eq!(wallace_levels(13), 5);
        assert_eq!(wallace_levels(19), 6);
        assert_eq!(wallace_levels(27), 7); // DP Booth-2 count
        assert_eq!(wallace_levels(18), 6); // DP Booth-3 count
    }

    #[test]
    fn depth_ordering_wallace_fastest_array_slowest() {
        // For the paper's PP counts, Wallace < ZM < Array in depth.
        for n in [9u32, 13, 18, 27] {
            let w = TreeKind::Wallace.depth_levels(n);
            let z = TreeKind::Zm.depth_levels(n);
            let a = TreeKind::Array.depth_levels(n);
            assert!(w <= z && z <= a, "n={n}: wallace={w} zm={z} array={a}");
            assert!(w < a, "n={n}");
        }
    }

    #[test]
    fn measured_depth_matches_model_wallace() {
        // The depth the reducer actually accumulates must equal the
        // model's prediction (structure honesty).
        for n in [3usize, 6, 9, 13, 18, 27] {
            let pps: Vec<u128> = (1..=n as u128).collect();
            let mut stats = CsaStats::default();
            TreeKind::Wallace.reduce(&pps, 60, &mut stats);
            assert_eq!(stats.depth, wallace_levels(n as u32), "n={n}");
        }
    }

    #[test]
    fn measured_depth_matches_model_array() {
        for n in [3usize, 9, 13, 27] {
            let pps: Vec<u128> = (1..=n as u128).collect();
            let mut stats = CsaStats::default();
            TreeKind::Array.reduce(&pps, 60, &mut stats);
            assert_eq!(stats.depth, n as u32 - 2, "n={n}");
        }
    }

    #[test]
    fn zm_depth_between_array_and_wallace_measured() {
        for n in [9usize, 13, 18, 27] {
            let pps: Vec<u128> = (1..=n as u128).map(|i| i * 0x1234_5678).collect();
            let mut zm = CsaStats::default();
            TreeKind::Zm.reduce(&pps, 80, &mut zm);
            let mut ar = CsaStats::default();
            TreeKind::Array.reduce(&pps, 80, &mut ar);
            let mut wa = CsaStats::default();
            TreeKind::Wallace.reduce(&pps, 80, &mut wa);
            assert!(zm.depth <= ar.depth, "n={n}: zm {} vs array {}", zm.depth, ar.depth);
            assert!(zm.depth >= wa.depth, "n={n}: zm {} vs wallace {}", zm.depth, wa.depth);
        }
    }

    #[test]
    fn wiring_factors_ordering() {
        assert!(TreeKind::Array.wiring_factor() < TreeKind::Zm.wiring_factor());
        assert!(TreeKind::Zm.wiring_factor() < TreeKind::Wallace.wiring_factor());
    }

    #[test]
    fn empty_and_small_inputs() {
        for kind in [TreeKind::Wallace, TreeKind::Array, TreeKind::Zm] {
            let mut stats = CsaStats::default();
            assert_eq!(kind.reduce(&[], 32, &mut stats).resolve(32), 0);
            assert_eq!(kind.reduce(&[7], 32, &mut stats).resolve(32), 7);
            assert_eq!(kind.reduce(&[7, 8], 32, &mut stats).resolve(32), 15);
        }
    }
}
